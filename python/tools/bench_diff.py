#!/usr/bin/env python3
"""Structural diff for the committed BENCH_*.json perf-trajectory files.

The committed baselines at the repo root record the *shape* of the perf
trajectory: which benches exist, which cases they measure, and which
metrics each case reports.  The metric values themselves are wall-clock
(machine-dependent) or evolve across PRs, so CI compares the committed
file against a fresh smoke run **structurally**:

  * objects must have the same key sets (recursively),
  * arrays must have the same length and element structure,
  * string leaves (bench/case names) must match exactly,
  * numeric leaves must agree on kind (number) but not on value.

A silent bench rename, a dropped case, or a removed metric — the
"perf-format rot" that previously let the trajectory decay unnoticed —
fails the build; a faster or slower machine does not.

A small set of *bounded-contract* keys is the exception to value
freedom: `batch_efficiency` is a fraction and `h2c_share_error` carries
the DESIGN.md §15 ±5% plan-fidelity contract, so the fresh run's value
must stay inside the contracted range even though the baseline's exact
number is free to move.

`--validate` checks schema-versioned telemetry exports (the
`--metrics-out` / `--trace-out` snapshots and the `BENCH_*_metrics.json`
companions, DESIGN.md §14) instead of diffing against a baseline: the
file must parse, carry an integer top-level `schema_version`, and hold
no boolean leaves outside the known flag keys — a metric silently
exported as true/false is format rot, not a value change.

Usage: bench_diff.py COMMITTED_JSON FRESH_JSON
       bench_diff.py --validate FILE [FILE ...]
"""

import json
import sys


# Bounded-contract keys: metrics that carry a correctness contract, not
# just a trajectory value.  The fresh run must stay inside the range
# (DESIGN.md §15); the committed baseline's exact number is still free.
RANGE_KEYS = {
    "batch_efficiency": (0.0, 1.0),
    "h2c_share_error": (0.0, 0.05),
    "config_cache_hit_rate": (0.0, 1.0),
    # DESIGN.md §17: the kernel-zoo bench mix routes a bounded share of
    # traffic to config-declared kernels — a fraction by construction.
    "zoo_stage_fraction": (0.0, 1.0),
}


def diff(path, committed, fresh, problems, key=""):
    # bool subclasses int in Python: without this check a numeric metric
    # replaced by true/false would slip through the numeric escape below.
    both_numbers = (
        isinstance(committed, (int, float))
        and isinstance(fresh, (int, float))
        and not isinstance(committed, bool)
        and not isinstance(fresh, bool)
    )
    if type(committed) is not type(fresh) and not both_numbers:
        problems.append(
            f"{path}: type changed "
            f"({type(committed).__name__} -> {type(fresh).__name__})"
        )
        return
    if isinstance(committed, dict):
        missing = sorted(set(committed) - set(fresh))
        added = sorted(set(fresh) - set(committed))
        if missing:
            problems.append(f"{path}: keys vanished from fresh run: {missing}")
        if added:
            problems.append(f"{path}: keys not in committed baseline: {added}")
        for k in sorted(set(committed) & set(fresh)):
            diff(f"{path}.{k}", committed[k], fresh[k], problems, k)
    elif isinstance(committed, list):
        if len(committed) != len(fresh):
            problems.append(
                f"{path}: length changed ({len(committed)} -> {len(fresh)})"
            )
        for i, (c, f) in enumerate(zip(committed, fresh)):
            diff(f"{path}[{i}]", c, f, problems, key)
    elif isinstance(committed, str):
        if committed != fresh:
            problems.append(f"{path}: '{committed}' != '{fresh}'")
    elif both_numbers and key in RANGE_KEYS:
        lo, hi = RANGE_KEYS[key]
        if not lo <= fresh <= hi:
            problems.append(
                f"{path}: fresh value {fresh} breaks the "
                f"[{lo}, {hi}] contract"
            )
    # Other numeric and boolean leaves: kind already matched above;
    # values are allowed to move — that is the trajectory.


# Keys whose boolean values are intentional (claim results and per-event
# flags), not a numeric metric that decayed into true/false.
BOOL_KEYS = {"ok", "migrated"}


def validate_leaves(path, node, key, problems):
    if isinstance(node, dict):
        for k in sorted(node):
            validate_leaves(f"{path}.{k}", node[k], k, problems)
    elif isinstance(node, list):
        for i, item in enumerate(node):
            validate_leaves(f"{path}[{i}]", item, key, problems)
    elif isinstance(node, bool) and key not in BOOL_KEYS:
        problems.append(f"{path}: boolean leaf under key '{key}'")
    elif isinstance(node, (int, float)) and key in RANGE_KEYS:
        lo, hi = RANGE_KEYS[key]
        if not lo <= node <= hi:
            problems.append(
                f"{path}: {node} breaks the [{lo}, {hi}] contract"
            )


def validate(paths):
    failed = False
    for p in paths:
        problems = []
        try:
            with open(p) as fh:
                doc = json.load(fh)
        except (OSError, ValueError) as e:
            problems.append(f"$: {e}")
            doc = None
        if isinstance(doc, dict):
            version = doc.get("schema_version")
            if version is None:
                problems.append("$: missing top-level schema_version")
            elif isinstance(version, bool) or not isinstance(version, int):
                problems.append(
                    f"$.schema_version: expected an integer, got {version!r}"
                )
            validate_leaves("$", doc, "", problems)
        elif doc is not None:
            problems.append(f"$: expected a JSON object, got {type(doc).__name__}")
        if problems:
            failed = True
            print(f"invalid telemetry snapshot: {p}")
            for problem in problems:
                print(f"  {problem}")
        else:
            print(f"ok: {p} is a well-formed schema_version {doc['schema_version']} snapshot")
    if failed:
        sys.exit(1)


def main():
    if len(sys.argv) >= 3 and sys.argv[1] == "--validate":
        validate(sys.argv[2:])
        return
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    committed_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(committed_path) as fh:
        committed = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    problems = []
    diff("$", committed, fresh, problems)
    if problems:
        print(f"perf-trajectory format rot: {committed_path} vs {fresh_path}")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"ok: {fresh_path} matches the committed baseline structurally")


if __name__ == "__main__":
    main()
