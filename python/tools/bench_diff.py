#!/usr/bin/env python3
"""Structural diff for the committed BENCH_*.json perf-trajectory files.

The committed baselines at the repo root record the *shape* of the perf
trajectory: which benches exist, which cases they measure, and which
metrics each case reports.  The metric values themselves are wall-clock
(machine-dependent) or evolve across PRs, so CI compares the committed
file against a fresh smoke run **structurally**:

  * objects must have the same key sets (recursively),
  * arrays must have the same length and element structure,
  * string leaves (bench/case names) must match exactly,
  * numeric leaves must agree on kind (number) but not on value.

A silent bench rename, a dropped case, or a removed metric — the
"perf-format rot" that previously let the trajectory decay unnoticed —
fails the build; a faster or slower machine does not.

Usage: bench_diff.py COMMITTED_JSON FRESH_JSON
"""

import json
import sys


def diff(path, committed, fresh, problems):
    # bool subclasses int in Python: without this check a numeric metric
    # replaced by true/false would slip through the numeric escape below.
    both_numbers = (
        isinstance(committed, (int, float))
        and isinstance(fresh, (int, float))
        and not isinstance(committed, bool)
        and not isinstance(fresh, bool)
    )
    if type(committed) is not type(fresh) and not both_numbers:
        problems.append(
            f"{path}: type changed "
            f"({type(committed).__name__} -> {type(fresh).__name__})"
        )
        return
    if isinstance(committed, dict):
        missing = sorted(set(committed) - set(fresh))
        added = sorted(set(fresh) - set(committed))
        if missing:
            problems.append(f"{path}: keys vanished from fresh run: {missing}")
        if added:
            problems.append(f"{path}: keys not in committed baseline: {added}")
        for key in sorted(set(committed) & set(fresh)):
            diff(f"{path}.{key}", committed[key], fresh[key], problems)
    elif isinstance(committed, list):
        if len(committed) != len(fresh):
            problems.append(
                f"{path}: length changed ({len(committed)} -> {len(fresh)})"
            )
        for i, (c, f) in enumerate(zip(committed, fresh)):
            diff(f"{path}[{i}]", c, f, problems)
    elif isinstance(committed, str):
        if committed != fresh:
            problems.append(f"{path}: '{committed}' != '{fresh}'")
    # Numeric and boolean leaves: kind already matched above; values are
    # allowed to move — that is the trajectory.


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    committed_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(committed_path) as fh:
        committed = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    problems = []
    diff("$", committed, fresh, problems)
    if problems:
        print(f"perf-trajectory format rot: {committed_path} vs {fresh_path}")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"ok: {fresh_path} matches the committed baseline structurally")


if __name__ == "__main__":
    main()
