"""L1 Pallas kernel: the paper's *constant multiplier* computation module.

On the KCU1500 the module is combinational DSP logic behind a WB slave
interface consuming one 32-bit word per cycle.  The TPU-idiomatic mapping
(DESIGN.md §Hardware-Adaptation) is a word-parallel VPU kernel: one VMEM
block of uint32 words per grid step, elementwise wrapping multiply.

``interpret=True`` is mandatory — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Words per VMEM block.  1024 u32 = 4 KiB/block, a multiple of the VPU lane
# count (128); the 16 KB use-case buffer (4096 words) runs as a 4-step grid.
BLOCK = 1024


def _multiplier_kernel(x_ref, o_ref, *, k: int):
    o_ref[...] = x_ref[...] * jnp.uint32(k)


def multiplier(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Elementwise wrapping ``x * k`` over uint32, as a Pallas call."""
    assert x.dtype == jnp.uint32 and x.ndim == 1
    n = x.shape[0]
    block = min(BLOCK, n)
    assert n % block == 0, f"buffer length {n} not a multiple of {block}"
    return pl.pallas_call(
        functools.partial(_multiplier_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x)
