"""L1 Pallas kernels: Hamming(31,26) encoder and decoder modules.

FPGA incarnation: per-bit parity trees (XOR reductions over tapped
codeword bits) in LUT logic, one word per WB cycle.  TPU mapping
(DESIGN.md §Hardware-Adaptation): the parity tree over bits of one word
becomes ``popcount(word & mask) & 1`` vectorized across the whole VMEM
block — 5 masked popcounts per word replace the 5 XOR trees, and the
26-tap bit gather/scatter unrolls into static shift/or chains (the Mosaic
compiler fuses these into a handful of VPU ops per word).

Both kernels run ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned to `ref.py` by pytest/hypothesis.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hamming_spec import (
    CODE_MASK,
    DATA_MASK,
    DATA_POSITIONS,
    NUM_PARITY,
    PARITY_MASKS,
)

BLOCK = 1024


def _u32(x: int) -> jnp.ndarray:
    return jnp.uint32(x)


def _encode_block(d):
    """Encode one block of payload words (low 26 bits each)."""
    d = d & _u32(DATA_MASK)
    cw = jnp.zeros_like(d)
    # Static unroll: data bit k -> codeword bit DATA_POSITIONS[k]-1.
    for kbit, p in enumerate(DATA_POSITIONS):
        cw = cw | (((d >> _u32(kbit)) & _u32(1)) << _u32(p - 1))
    # Parity bit i covers PARITY_MASKS[i]; even parity.
    for i in range(NUM_PARITY):
        par = jax.lax.population_count(cw & _u32(PARITY_MASKS[i])) & _u32(1)
        cw = cw | (par << _u32((1 << i) - 1))
    return cw


def _decode_block(cw):
    """Decode one block of codewords -> (payload, syndrome)."""
    cw = cw & _u32(CODE_MASK)
    syn = jnp.zeros_like(cw)
    for i in range(NUM_PARITY):
        par = jax.lax.population_count(cw & _u32(PARITY_MASKS[i])) & _u32(1)
        syn = syn | (par << _u32(i))
    flip = jnp.where(syn > _u32(0), _u32(1) << (syn - _u32(1)), _u32(0))
    cw = cw ^ flip
    d = jnp.zeros_like(cw)
    for kbit, p in enumerate(DATA_POSITIONS):
        d = d | (((cw >> _u32(p - 1)) & _u32(1)) << _u32(kbit))
    return d, syn


def _encode_kernel(x_ref, o_ref):
    o_ref[...] = _encode_block(x_ref[...])


def _decode_kernel(x_ref, d_ref, s_ref):
    d, s = _decode_block(x_ref[...])
    d_ref[...] = d
    s_ref[...] = s


def _grid_spec(n: int):
    block = min(BLOCK, n)
    assert n % block == 0, f"buffer length {n} not a multiple of {block}"
    return block, n // block


def hamming_encode(x: jnp.ndarray) -> jnp.ndarray:
    """Hamming(31,26)-encode each word's low 26 bits, as a Pallas call."""
    assert x.dtype == jnp.uint32 and x.ndim == 1
    n = x.shape[0]
    block, grid = _grid_spec(n)
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x)


def hamming_decode(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode + single-error-correct each codeword -> (payload, syndrome)."""
    assert x.dtype == jnp.uint32 and x.ndim == 1
    n = x.shape[0]
    block, grid = _grid_spec(n)
    return pl.pallas_call(
        _decode_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        interpret=True,
    )(x)
