"""Pure-jnp oracles for the three computation-module kernels.

These are the correctness references the Pallas kernels are tested against
(exact integer equality — no tolerance).  They are written with the most
obvious jnp formulation, no pallas, no custom control flow, so they are
easy to audit against `hamming_spec`.
"""

import jax
import jax.numpy as jnp

from .hamming_spec import (
    CODE_MASK,
    DATA_MASK,
    DATA_POSITIONS,
    NUM_PARITY,
    PARITY_MASKS,
)


def _u32(x: int) -> jnp.ndarray:
    return jnp.uint32(x)


def multiplier_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Constant multiplier module: elementwise wrapping u32 multiply."""
    assert x.dtype == jnp.uint32
    return x * _u32(k)


def hamming_encode_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Hamming(31,26) encoder over the low 26 bits of each word."""
    assert x.dtype == jnp.uint32
    d = x & _u32(DATA_MASK)
    cw = jnp.zeros_like(d)
    for kbit, p in enumerate(DATA_POSITIONS):
        cw = cw | (((d >> _u32(kbit)) & _u32(1)) << _u32(p - 1))
    for i in range(NUM_PARITY):
        par = jax.lax.population_count(cw & _u32(PARITY_MASKS[i])) & _u32(1)
        cw = cw | (par << _u32((1 << i) - 1))
    return cw


def hamming_decode_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Hamming(31,26) decoder: corrects single-bit errors.

    Returns ``(data, syndrome)``; syndrome 0 means no error detected.
    """
    assert x.dtype == jnp.uint32
    cw = x & _u32(CODE_MASK)
    syn = jnp.zeros_like(cw)
    for i in range(NUM_PARITY):
        par = jax.lax.population_count(cw & _u32(PARITY_MASKS[i])) & _u32(1)
        syn = syn | (par << _u32(i))
    # Flip the bit named by the (1-indexed) syndrome; syndrome 0 -> no flip.
    flip = jnp.where(syn > 0, _u32(1) << (syn - _u32(1)), _u32(0))
    cw = cw ^ flip
    d = jnp.zeros_like(cw)
    for kbit, p in enumerate(DATA_POSITIONS):
        d = d | (((cw >> _u32(p - 1)) & _u32(1)) << _u32(kbit))
    return d, syn


def pipeline_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """The Fig-5 use case: multiply -> encode -> decode."""
    y = multiplier_ref(x, k)
    cw = hamming_encode_ref(y)
    d, _syn = hamming_decode_ref(cw)
    return d
