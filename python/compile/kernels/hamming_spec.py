"""Bit-level specification of the Hamming(31,26) code used by the paper's
encoder/decoder computation modules (Section V.B: "the hamming encoder, and
the hamming decoder").

This module is the single source of truth for bit positions and parity
masks.  The same constants are mirrored in the Rust golden model
(`rust/src/hamming/mod.rs`); `python/tests/test_hamming_spec.py` asserts the
derivation, and the Rust unit tests assert the mirrored values, so the two
implementations cannot drift silently.

Convention
----------
Codeword positions are 1-indexed 1..31 (classic Hamming numbering).
Position ``p`` is stored in bit ``p - 1`` of a ``uint32`` word, so a
codeword occupies bits [0, 30] and bit 31 is always zero.

* Parity positions: powers of two {1, 2, 4, 8, 16} -> bits {0, 1, 3, 7, 15}.
* Data positions: the remaining 26 positions, in increasing order; data bit
  ``k`` (LSB-first) of the 26-bit payload lives at codeword position
  ``DATA_POSITIONS[k]``.
* ``PARITY_MASKS[i]`` covers every position ``p`` with ``p & (1 << i)``;
  the syndrome is the 5-bit vector of parities of ``codeword & mask``.
"""

NUM_PARITY = 5
CODE_BITS = 31  # codeword length (bits 0..30 of a u32)
DATA_BITS = 26  # payload width
DATA_MASK = (1 << DATA_BITS) - 1  # 0x03FF_FFFF
CODE_MASK = (1 << CODE_BITS) - 1  # 0x7FFF_FFFF

PARITY_POSITIONS = tuple(1 << i for i in range(NUM_PARITY))  # (1, 2, 4, 8, 16)

DATA_POSITIONS = tuple(
    p for p in range(1, CODE_BITS + 1) if p not in PARITY_POSITIONS
)
assert len(DATA_POSITIONS) == DATA_BITS

# PARITY_MASKS[i]: u32 mask of codeword *bits* checked by parity i.
PARITY_MASKS = tuple(
    sum(1 << (p - 1) for p in range(1, CODE_BITS + 1) if p & (1 << i))
    for i in range(NUM_PARITY)
)

# Spot-check against the textbook values for Hamming(31,26).
assert PARITY_MASKS[0] == 0x55555555 & CODE_MASK
assert PARITY_MASKS[1] == 0x66666666 & CODE_MASK
assert PARITY_MASKS[2] == 0x78787878 & CODE_MASK
assert PARITY_MASKS[3] == 0x7F807F80 & CODE_MASK
assert PARITY_MASKS[4] == 0x7FFF8000 & CODE_MASK


def encode_int(d: int) -> int:
    """Reference encoder over plain Python ints (used only in tests)."""
    d &= DATA_MASK
    cw = 0
    for k, p in enumerate(DATA_POSITIONS):
        cw |= ((d >> k) & 1) << (p - 1)
    for i in range(NUM_PARITY):
        par = bin(cw & PARITY_MASKS[i]).count("1") & 1
        cw |= par << ((1 << i) - 1)
    return cw


def decode_int(cw: int) -> tuple[int, int]:
    """Reference decoder over plain Python ints -> (data, syndrome)."""
    cw &= CODE_MASK
    syn = 0
    for i in range(NUM_PARITY):
        syn |= (bin(cw & PARITY_MASKS[i]).count("1") & 1) << i
    if syn:
        cw ^= 1 << (syn - 1)
    d = 0
    for k, p in enumerate(DATA_POSITIONS):
        d |= ((cw >> (p - 1)) & 1) << k
    return d, syn
