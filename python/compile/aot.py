"""AOT lowering: JAX model -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Run once per build: ``cd python && python -m compile.aot --out-dir
../artifacts``.  Python is never on the request path.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import EXPORTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_export(name: str) -> tuple[str, int]:
    fn, n_words = EXPORTS[name]
    spec = jax.ShapeDtypeStruct((n_words,), jnp.uint32)
    lowered = jax.jit(fn, donate_argnums=(0,)).lower(spec)
    return to_hlo_text(lowered), n_words


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of export names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    names = args.only or list(EXPORTS)
    for name in names:
        text, n_words = lower_export(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "input_words": n_words,
            "dtype": "u32",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars, {n_words} words)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
