"""L2: the application compute graph, composed from the L1 Pallas kernels.

The paper's Fig-5 use case processes a 16 KB buffer through three
computation modules in sequence: constant multiplier -> Hamming(31,26)
encoder -> Hamming(31,26) decoder.  Each stage is exported standalone
(the elastic manager schedules stages onto PR regions independently, and
on-server stages run exactly one stage's artifact), plus the fused
whole-pipeline graph used when all stages are co-resident.

Everything here is build-time only: `aot.py` lowers these functions to
HLO text once; the Rust coordinator executes the artifacts via PJRT and
never imports Python.
"""

import jax.numpy as jnp

from .kernels.hamming import hamming_decode, hamming_encode
from .kernels.multiplier import multiplier

# The paper's constant multiplier is not given a constant; we fix one and
# mirror it in the Rust golden model (rust/src/hamming/mod.rs).
MULT_CONSTANT = 0x9E3779B1  # 2654435761, Knuth's multiplicative-hash odd const

# 16 KB of 32-bit words — the exact Fig-5 buffer size.
PIPELINE_WORDS = 4096
# Small variant for fast tests / quickstart.
PIPELINE_WORDS_SMALL = 256


def multiplier_stage(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Stage 1: elementwise wrapping multiply by MULT_CONSTANT."""
    return (multiplier(x, MULT_CONSTANT),)


def encoder_stage(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Stage 2: Hamming(31,26) encode of each word's low 26 bits."""
    return (hamming_encode(x),)


def decoder_stage(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Stage 3: Hamming(31,26) decode + single-error correction.

    Only the corrected payload is exported; the syndrome feeds the module's
    error-status register in the hardware, which the Rust golden model
    recomputes (the artifact interface stays single-output like [16]'s
    32-bit data interface).
    """
    data, _syndrome = hamming_decode(x)
    return (data,)


def pipeline(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """All three stages fused: dec(enc(mult(x)))."""
    (y,) = multiplier_stage(x)
    (cw,) = encoder_stage(y)
    return decoder_stage(cw)


# AOT export table: artifact name -> (function, input length in words).
EXPORTS = {
    "multiplier": (multiplier_stage, PIPELINE_WORDS),
    "hamming_enc": (encoder_stage, PIPELINE_WORDS),
    "hamming_dec": (decoder_stage, PIPELINE_WORDS),
    "pipeline": (pipeline, PIPELINE_WORDS),
    "pipeline_small": (pipeline, PIPELINE_WORDS_SMALL),
}
