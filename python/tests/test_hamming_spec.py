"""Derivation checks on the Hamming(31,26) bit-level spec.

These constants are mirrored byte-for-byte in rust/src/hamming/mod.rs;
keep both sides in sync.
"""

from compile.kernels.hamming_spec import (
    CODE_BITS,
    CODE_MASK,
    DATA_BITS,
    DATA_MASK,
    DATA_POSITIONS,
    NUM_PARITY,
    PARITY_MASKS,
    PARITY_POSITIONS,
    decode_int,
    encode_int,
)


def test_position_partition():
    assert set(PARITY_POSITIONS) | set(DATA_POSITIONS) == set(
        range(1, CODE_BITS + 1)
    )
    assert not set(PARITY_POSITIONS) & set(DATA_POSITIONS)
    assert len(DATA_POSITIONS) == DATA_BITS == 26
    assert NUM_PARITY == 5 and CODE_BITS == 31


def test_masks_cover_each_position_by_its_binary_index():
    for p in range(1, CODE_BITS + 1):
        covered = [i for i in range(NUM_PARITY) if PARITY_MASKS[i] >> (p - 1) & 1]
        want = [i for i in range(NUM_PARITY) if p >> i & 1]
        assert covered == want, f"position {p}"


def test_parity_position_isolated_in_own_mask():
    """Parity position 2^i appears in mask i only — required for the
    set-parity-last encoding order to be valid."""
    for i, p in enumerate(PARITY_POSITIONS):
        for j in range(NUM_PARITY):
            in_mask = PARITY_MASKS[j] >> (p - 1) & 1
            assert in_mask == (1 if i == j else 0)


def test_known_vectors():
    # All-zeros and all-ones payloads.
    assert encode_int(0) == 0
    cw = encode_int(DATA_MASK)
    assert cw & ~CODE_MASK == 0
    d, syn = decode_int(cw)
    assert d == DATA_MASK and syn == 0


def test_distinct_codewords_for_distinct_payloads():
    seen = {encode_int(d) for d in range(2048)}
    assert len(seen) == 2048


def test_mirrored_rust_constants():
    """The exact literals embedded in rust/src/hamming/mod.rs."""
    assert PARITY_MASKS == (
        0x55555555,
        0x66666666,
        0x78787878,
        0x7F807F80,
        0x7FFF8000,
    )
    assert DATA_MASK == 0x03FFFFFF
    assert CODE_MASK == 0x7FFFFFFF
