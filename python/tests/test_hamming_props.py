"""Hypothesis property sweeps over the Pallas kernels (L1).

Shapes and values are swept; every property is checked exactly against
the pure-jnp oracle or the algebraic spec.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not installable in the offline container; skip the sweep
# module cleanly rather than failing collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.hamming import hamming_decode, hamming_encode
from compile.kernels.hamming_spec import (
    CODE_MASK,
    DATA_MASK,
    decode_int,
    encode_int,
)
from compile.kernels.multiplier import multiplier

# Buffer lengths must divide the kernel block size or be a multiple of it;
# the kernels assert n % block == 0 with block = min(1024, n).
LENGTHS = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048])

u32s = st.integers(min_value=0, max_value=2**32 - 1)


def buf(draw_len, values, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 2**32, size=draw_len, dtype=np.uint32)
    )


@settings(max_examples=25, deadline=None)
@given(n=LENGTHS, k=u32s, seed=st.integers(0, 2**31))
def test_multiplier_any_shape_any_constant(n, k, seed):
    x = buf(n, None, seed)
    got = np.asarray(multiplier(x, k))
    want = np.asarray(ref.multiplier_ref(x, k))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(n=LENGTHS, seed=st.integers(0, 2**31))
def test_encode_then_decode_recovers_payload(n, seed):
    x = buf(n, None, seed)
    d, syn = hamming_decode(hamming_encode(x))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(x) & DATA_MASK)
    assert not np.asarray(syn).any()


@settings(max_examples=50, deadline=None)
@given(d=st.integers(0, DATA_MASK), bit=st.integers(0, 30))
def test_scalar_single_error_correction(d, bit):
    """Int-spec cross-check: every 1-bit corruption of every codeword is
    corrected, and the syndrome names the corrupted position (1-indexed)."""
    cw = encode_int(d)
    corrupted = cw ^ (1 << bit)
    got_d, got_syn = decode_int(corrupted)
    assert got_d == d
    assert got_syn == bit + 1


@settings(max_examples=50, deadline=None)
@given(d=st.integers(0, DATA_MASK))
def test_scalar_codeword_properties(d):
    cw = encode_int(d)
    assert cw & ~CODE_MASK == 0  # fits in 31 bits
    got_d, syn = decode_int(cw)
    assert got_d == d and syn == 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_kernel_decoder_agrees_with_int_spec(seed):
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 2**32, size=128, dtype=np.uint32)
    d, syn = hamming_decode(jnp.asarray(raw))
    for i, w in enumerate(raw.tolist()):
        wd, wsyn = decode_int(w)
        assert int(np.asarray(d)[i]) == wd
        assert int(np.asarray(syn)[i]) == wsyn
