"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

All comparisons are exact (integer kernels, no tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.hamming import hamming_decode, hamming_encode
from compile.kernels.hamming_spec import CODE_MASK, DATA_MASK, encode_int
from compile.kernels.multiplier import multiplier
from compile.model import MULT_CONSTANT


def rand_u32(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))


@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("k", [0, 1, 3, MULT_CONSTANT, 0xFFFFFFFF])
def test_multiplier_matches_ref(n, k):
    x = rand_u32(n, seed=n)
    got = multiplier(x, k)
    want = ref.multiplier_ref(x, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [256, 1024, 4096])
def test_encoder_matches_ref(n):
    x = rand_u32(n, seed=n + 1)
    got = hamming_encode(x)
    want = ref.hamming_encode_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [256, 1024, 4096])
def test_decoder_matches_ref(n):
    x = rand_u32(n, seed=n + 2)
    got_d, got_s = hamming_decode(x)
    want_d, want_s = ref.hamming_decode_ref(x)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_encoder_matches_int_spec():
    """Kernel agrees with the plain-Python-int spec on scalar payloads."""
    vals = [0, 1, DATA_MASK, 0x155_5555, 0x2AA_AAAA, 12345678]
    x = jnp.asarray(vals, dtype=jnp.uint32)
    # pad to a full block multiple
    pad = jnp.zeros(256 - len(vals), dtype=jnp.uint32)
    got = np.asarray(hamming_encode(jnp.concatenate([x, pad])))[: len(vals)]
    want = [encode_int(v) for v in vals]
    assert got.tolist() == want


def test_encode_decode_roundtrip():
    x = rand_u32(1024, seed=7)
    cw = hamming_encode(x)
    d, syn = hamming_decode(cw)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(x) & DATA_MASK)
    assert not np.asarray(syn).any()


def test_single_bit_error_corrected():
    """Flip one random bit (position 1..31) in every codeword; decode must
    recover the payload and report a non-zero syndrome."""
    x = rand_u32(1024, seed=8)
    cw = np.asarray(hamming_encode(x))
    rng = np.random.default_rng(9)
    bits = rng.integers(0, 31, size=cw.shape, dtype=np.uint32)
    corrupted = jnp.asarray(cw ^ (np.uint32(1) << bits))
    d, syn = hamming_decode(corrupted)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(x) & DATA_MASK)
    assert (np.asarray(syn) != 0).all()


def test_decoder_masks_bit31():
    """Bit 31 is outside the 31-bit codeword and must be ignored."""
    x = rand_u32(256, seed=10)
    cw = hamming_encode(x)
    with_junk = cw | jnp.uint32(0x8000_0000)
    d0, s0 = hamming_decode(cw)
    d1, s1 = hamming_decode(with_junk)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
