"""L2 model tests: pipeline composition, shapes, and the AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import lower_export, to_hlo_text
from compile.kernels import ref
from compile.kernels.hamming_spec import DATA_MASK


def rand_u32(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))


def test_pipeline_equals_stage_composition():
    x = rand_u32(model.PIPELINE_WORDS_SMALL, seed=1)
    (y,) = model.multiplier_stage(x)
    (cw,) = model.encoder_stage(y)
    (d,) = model.decoder_stage(cw)
    (fused,) = model.pipeline(x)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(d))


def test_pipeline_algebraic_identity():
    """dec(enc(mult(x))) == (x * K) & DATA_MASK — the end-to-end contract
    the Rust golden model also enforces."""
    x = rand_u32(model.PIPELINE_WORDS_SMALL, seed=2)
    (fused,) = model.pipeline(x)
    want = (np.asarray(x) * np.uint32(model.MULT_CONSTANT)) & np.uint32(
        DATA_MASK
    )
    np.testing.assert_array_equal(np.asarray(fused), want)


def test_pipeline_matches_ref_pipeline():
    x = rand_u32(model.PIPELINE_WORDS_SMALL, seed=3)
    (fused,) = model.pipeline(x)
    want = ref.pipeline_ref(x, model.MULT_CONSTANT)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


@pytest.mark.parametrize("name", list(model.EXPORTS))
def test_exports_shape_stable(name):
    fn, n = model.EXPORTS[name]
    out = fn(rand_u32(n, seed=4))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (n,) and out[0].dtype == jnp.uint32


@pytest.mark.parametrize("name", list(model.EXPORTS))
def test_aot_lowering_emits_hlo_text(name):
    text, n_words = lower_export(name)
    # HLO text module header and a u32 entry parameter of the right length.
    assert text.startswith("HloModule")
    assert f"u32[{n_words}]" in text
    # interpret=True must have erased all pallas/mosaic custom-calls;
    # a custom-call in the artifact would be unloadable by CPU PJRT.
    assert "custom-call" not in text.lower()


def test_lowered_pipeline_executes_in_jax():
    """Sanity: the exact lowered computation (via jit) matches the oracle."""
    fn, n = model.EXPORTS["pipeline_small"]
    x = rand_u32(n, seed=5)
    (got,) = jax.jit(fn)(x)
    want = ref.pipeline_ref(x, model.MULT_CONSTANT)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
