//! Fleet-scale elastic serving: 100 000 requests across 8 simulated
//! fabrics, end to end, in seconds.
//!
//! ```bash
//! cargo run --release --example fleet_serving
//! ```
//!
//! * a 100k-request multi-tenant trace (small payloads, mixed stage
//!   chains) is generated deterministically;
//! * the fleet routes it least-loaded while two boards run degraded
//!   (fenced PR regions), so chains that would overflow onto the server
//!   CPU migrate to boards that can host them fully on fabric;
//! * service costs come from the cycle-accurate fabric simulator via the
//!   event-driven fast-path (one oracle run per request shape, memoized);
//! * a 200-request prefix is replayed on the pure cycle-by-cycle oracle
//!   and must schedule identically — the fast-path's exactness check.
//!
//! The timing profile models an edge deployment (NIC-attached board,
//! small descriptors) rather than Fig 5's 16 KB testbed: the paper's
//! 5.36 ms XDMA round would dwarf the sub-millisecond payloads here.

use elastic_fpga::config::SystemConfig;
use elastic_fpga::fleet::{AdmissionPolicy, Fleet};
use elastic_fpga::workload::{generate_count, WorkloadSpec};

const REQUESTS: usize = 100_000;
const FABRICS: usize = 8;
const ORACLE_PREFIX: usize = 200;

fn edge_profile() -> SystemConfig {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.timing.xdma_round_ms = 0.02;
    cfg.timing.cpu_stage_ms = 0.05;
    cfg
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = edge_profile();
    let spec = WorkloadSpec::fleet_mix();
    println!("generating {REQUESTS} requests...");
    let trace = generate_count(&spec, 1, REQUESTS);

    let mut fleet =
        Fleet::launch(FABRICS, &cfg, None, AdmissionPolicy::LeastLoaded, true);
    // Degrade two boards: board 0 to one region, board 1 to two.
    fleet.fence_node(0, 2);
    fleet.fence_node(1, 1);

    println!("serving across {FABRICS} fabrics (fast-path)...");
    let t0 = std::time::Instant::now();
    let mut report = fleet.run_trace(&trace)?;
    let wall = t0.elapsed();

    assert_eq!(report.completed as usize, REQUESTS, "lost requests");
    println!(
        "completed {}/{REQUESTS} in {wall:.2?} ({:.0} req/s simulated)",
        report.completed,
        REQUESTS as f64 / wall.as_secs_f64()
    );
    println!(
        "virtual makespan {:.1} ms | {:.0} req/s of virtual time",
        cfg.cycles_to_ms(report.makespan_cycles),
        report.throughput_per_s(&cfg)
    );
    println!(
        "queue wait p50 {} p99 {} cycles | latency p50 {} p99 {} cycles",
        report.queue_wait.percentile(0.50),
        report.queue_wait.percentile(0.99),
        report.latency.percentile(0.50),
        report.latency.percentile(0.99),
    );
    println!(
        "per-node served {:?}\nmigrated {} | oracle runs {} | fast-path hits {}",
        report.per_node_served,
        report.migrated,
        report.oracle_runs,
        report.fast_path_hits
    );
    assert!(report.migrated > 0, "degraded boards should force migrations");

    // Exactness: replay a prefix on the pure oracle and require the
    // identical schedule.
    println!("\ncross-checking a {ORACLE_PREFIX}-request prefix on the oracle...");
    let prefix = &trace[..ORACLE_PREFIX];
    let mut fast =
        Fleet::launch(FABRICS, &cfg, None, AdmissionPolicy::LeastLoaded, true);
    fast.fence_node(0, 2);
    fast.fence_node(1, 1);
    let mut oracle =
        Fleet::launch(FABRICS, &cfg, None, AdmissionPolicy::LeastLoaded, false);
    oracle.fence_node(0, 2);
    oracle.fence_node(1, 1);
    let fast_report = fast.run_trace(prefix)?;
    let oracle_report = oracle.run_trace(prefix)?;
    assert_eq!(
        fast_report.outcomes, oracle_report.outcomes,
        "fast-path diverged from the cycle-by-cycle oracle"
    );
    println!(
        "oracle agreement on {} outcomes (fast-path used {} oracle runs, \
         oracle mode used {})",
        fast_report.outcomes.len(),
        fast_report.oracle_runs,
        oracle_report.oracle_runs
    );
    println!("fleet_serving: OK");
    Ok(())
}
