//! Quickstart: one 16 KB acceleration request end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Boots the PJRT runtime on the AOT artifacts, starts the serving loop,
//! submits the paper's Fig-5 use case (16 KB through constant multiplier
//! -> Hamming(31,26) encoder -> decoder), and prints the verified result
//! plus the modelled execution time.  Falls back to the golden-model CPU
//! path if `artifacts/` is missing (run `make artifacts`).

use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::AppRequest;
use elastic_fpga::runtime::RuntimeThread;
use elastic_fpga::server::{call, Server};
use elastic_fpga::util::SplitMix64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = SystemConfig::paper_defaults();

    // PJRT runtime over the AOT-lowered JAX/Pallas artifacts.
    let runtime = match RuntimeThread::spawn(elastic_fpga::DEFAULT_ARTIFACT_DIR) {
        Ok(rt) => {
            rt.handle().preload_all()?;
            println!("pjrt runtime up (artifacts preloaded)");
            Some(rt)
        }
        Err(e) => {
            eprintln!("warning: no PJRT runtime ({e}); using the golden model");
            None
        }
    };

    let server = Server::start(cfg, runtime.as_ref().map(|t| t.handle()));

    // The paper's workload: 16 KB of 32-bit words.
    let mut rng = SplitMix64::new(42);
    let mut data = vec![0u32; 4096];
    rng.fill_u32(&mut data);

    let report = call(&server, AppRequest::pipeline(0, data))?;

    println!(
        "processed {} words through {} FPGA stage(s); verified = {}",
        report.output.len(),
        report.fpga_stages,
        report.verified
    );
    println!(
        "modelled execution time: {:.2} ms  (pcie {:.2} + fabric {:.3} + cpu {:.2})",
        report.cost.total_ms(),
        report.cost.pcie_ms,
        report.cost.fabric_ms,
        report.cost.cpu_ms
    );
    println!("first 4 output words: {:08x?}", &report.output[..4]);

    server.shutdown();
    assert!(report.verified);
    println!("quickstart OK");
    Ok(())
}
