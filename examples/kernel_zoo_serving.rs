//! Kernel-zoo serving: config-declared kernels next to the seed three.
//!
//! ```bash
//! cargo run --release --example kernel_zoo_serving           # 20k requests
//! cargo run --release --example kernel_zoo_serving -- 4000   # CI smoke
//! ```
//!
//! Until the kernel registry (DESIGN.md §17), every layer of the stack
//! was hard-wired to the three-variant module enum: a new tenant kernel
//! meant editing `rust/src/modules/` and every `match` above it.  This
//! example provisions a three-kernel zoo purely from a `[kernels]`
//! config table — no source edits — and drives it through the two
//! serving planes on 16-port boards:
//!
//! 1. **Fleet serving** — a mixed seed/zoo trace over two boards with
//!    same-app batching and the resident-module configuration cache;
//!    zoo shapes memoize, batch, and rebind exactly like seed shapes;
//! 2. **Closed-loop autoscaling** — six diurnal tenants, half chaining
//!    zoo kernels and half the seed pipeline, scaled by the predictive
//!    policy against the static even split.

use elastic_fpga::autoscale::{
    run_tenant_scenario, serving_profile_on, AutoscaleReport, PolicyKind,
};
use elastic_fpga::config::SystemConfig;
use elastic_fpga::fleet::{AdmissionPolicy, Fleet};
use elastic_fpga::kernels;
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::workload::{self, generate_count, WorkloadSpec};

const NODES: usize = 2;
const TENANTS: u32 = 6;
const PERIOD_S: f64 = 10.0;
const SEED: u64 = 1;

/// The zoo, exactly as an operator would declare it: three synthetic
/// table kernels with different latency models and masks, parsed from
/// the same `[kernels.<name>]` schema `--kernels FILE` accepts.
const ZOO_TOML: &str = "\
[kernels.zoo-mul3]
op = \"mul\"
operand = 3
latency_base = 2
latency_per_word = 1

[kernels.zoo-xor-mix]
op = \"xor\"
operand = 0x9E3779B1
latency_base = 1

[kernels.zoo-rot13]
op = \"rotl\"
operand = 13
mask = 0x00FFFFFF
latency_base = 4
latency_per_word = 2
";

fn scale16_cfg() -> SystemConfig {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/scale16.toml");
    let cfg = SystemConfig::load(std::path::Path::new(path))
        .expect("configs/scale16.toml must parse");
    serving_profile_on(cfg)
}

fn fleet_leg(cfg: &SystemConfig, zoo: &[ModuleKind], requests: usize) {
    let mut cfg = cfg.clone();
    cfg.manager.config_cache_regions = 6;
    let trace = generate_count(&WorkloadSpec::zoo_mix(zoo), SEED, requests);
    let mut fleet =
        Fleet::launch(NODES, &cfg, None, AdmissionPolicy::LeastLoaded, true);
    fleet.batch_window = 4;
    let t0 = std::time::Instant::now();
    let report = fleet.run_trace(&trace).expect("zoo trace must serve");
    let wall = t0.elapsed();
    assert_eq!(report.completed, requests as u64, "requests lost");
    let zoo_served = report
        .outcomes
        .iter()
        .zip(trace.iter())
        .filter(|(_, e)| e.request.stages.iter().any(|k| zoo.contains(k)))
        .count();
    assert!(zoo_served > 0, "the mix never emitted a zoo request");
    println!(
        "fleet: {}/{} served ({zoo_served} zoo-kernel requests) | \
         makespan {:.1} ms | {} batches | cache {} hits / {} misses | \
         wall {wall:.2?}",
        report.completed,
        requests,
        cfg.cycles_to_ms(report.makespan_cycles),
        report.batches_formed,
        report.config_cache_hits,
        report.config_cache_misses,
    );
}

fn describe(cfg: &SystemConfig, name: &str, r: &AutoscaleReport) {
    let mut wait = r.queue_wait.clone();
    println!(
        "{name} ({}): util {:.1}% | queue wait p50 {:.2} ms p99 {:.2} ms | \
         SLO {:.1}% | fabric/cpu {}/{} | grows {} shrinks {} | icap {}",
        r.policy,
        r.utilization * 100.0,
        cfg.cycles_to_ms(wait.percentile(0.50)),
        cfg.cycles_to_ms(wait.percentile(0.99)),
        r.slo_attainment * 100.0,
        r.fabric_requests,
        r.cpu_requests,
        r.grows,
        r.shrinks,
        r.icap_events.len(),
    );
}

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|a| a.parse().expect("usage: kernel_zoo_serving [requests]"))
        .unwrap_or(20_000);
    let decls = SystemConfig::parse(ZOO_TOML)
        .expect("zoo [kernels] tables must parse")
        .kernels;
    let zoo = kernels::install_declared(&decls, None)
        .expect("zoo declarations must validate");
    println!(
        "kernel zoo: installed {:?} next to seeds {:?}\n\
         {} boards x {} PR regions, {TENANTS} tenants, {requests} requests\n",
        zoo,
        ModuleKind::pipeline(),
        NODES,
        scale16_cfg().fabric.num_pr_regions,
    );
    let cfg = scale16_cfg();

    fleet_leg(&cfg, &zoo, requests);

    // Six tenants, chains cycling through the zoo and the seed
    // pipeline: tenant i runs chains[i % 4].
    let chains = vec![
        vec![zoo[0]],
        ModuleKind::pipeline().to_vec(),
        vec![zoo[1], zoo[2]],
        vec![ModuleKind::Multiplier, zoo[0]],
    ];
    let tenants =
        workload::zoo_tenants(TENANTS, &chains, 30.0, 450.0, PERIOD_S, 64);
    let t0 = std::time::Instant::now();
    let rep = run_tenant_scenario(
        &cfg,
        NODES,
        &tenants,
        requests,
        SEED,
        true,
        PolicyKind::Predictive,
    )
    .expect("scenario must complete");
    println!("(simulated in {:.2?})", t0.elapsed());
    describe(&cfg, "autoscaled", &rep.autoscaled);
    describe(&cfg, "static    ", &rep.static_baseline);

    let auto = &rep.autoscaled;
    assert_eq!(auto.completed, requests as u64, "requests lost");
    assert_eq!(
        rep.static_baseline.completed,
        requests as u64,
        "requests lost by the baseline"
    );
    assert!(auto.fabric_requests > 0, "zoo chains never reached fabric");
    // The point of the registry: zoo kernels in live ICAP programmings,
    // placed by a control loop that never heard of them at compile time.
    let zoo_programmed = auto
        .icap_events
        .iter()
        .filter(|e| match e.kind {
            elastic_fpga::autoscale::IcapEventKind::Program(k) => {
                zoo.contains(&k)
            }
            _ => false,
        })
        .count();
    assert!(
        zoo_programmed > 0,
        "no ICAP programming ever streamed a zoo kernel"
    );
    println!(
        "\nOK: {zoo_programmed} zoo-kernel ICAP programmings, \
         utilization {:.1}% vs static {:.1}%",
        auto.utilization * 100.0,
        rep.static_baseline.utilization * 100.0
    );
}
