//! Dynamic bandwidth allocation demo (§V.D): re-program the register
//! file's package-number registers at runtime and watch the crossbar's
//! effective bandwidth shift between two tenants.
//!
//! ```bash
//! cargo run --release --example bandwidth_tuning
//! ```
//!
//! Two apps contend for the same destination; the WRR arbiter's package
//! budgets decide the share each gets.  We sweep three budget splits and
//! measure per-app delivered words per 1k cycles — all through the
//! Table-III register file, exactly as the paper's manager would.

use elastic_fpga::config::{CrossbarConfig, SystemConfig};
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::wishbone::Job;

/// Run two greedy masters (0 and 1) into slave 3 for `cycles`, with the
/// given WRR package budgets; returns words delivered per master.
fn contend(budget0: u32, budget1: u32, cycles: u64) -> (u64, u64) {
    let cfg =
        CrossbarConfig { grant_timeout: 1_000_000, ..CrossbarConfig::default() };
    let mut xb = Crossbar::new(4, cfg);
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    xb.set_allowed_packages(3, 0, budget0).unwrap();
    xb.set_allowed_packages(3, 1, budget1).unwrap();
    // Greedy: both masters always have a large job queued.
    xb.push_job(0, Job::new(encode_onehot(3), vec![0xAA; 100_000], 0));
    xb.push_job(1, Job::new(encode_onehot(3), vec![0xBB; 100_000], 1));
    let mut clk = Clock::new();
    for _ in 0..cycles {
        let c = clk.advance();
        xb.tick(c);
        xb.drain_rx(3, usize::MAX);
    }
    (xb.stats().port_words[0], xb.stats().port_words[1])
}

fn main() {
    let _cfg = SystemConfig::paper_defaults();
    println!("§V.D — WRR package budgets as a bandwidth dial (2 masters -> 1 slave)");
    println!("| budget A | budget B | words A | words B | share A |");
    println!("|----------|----------|---------|---------|---------|");
    let mut shares = Vec::new();
    for (a, b) in [(8u32, 8u32), (16, 8), (64, 8), (128, 16)] {
        let (wa, wb) = contend(a, b, 20_000);
        let share = wa as f64 / (wa + wb) as f64 * 100.0;
        shares.push(share);
        println!(
            "| {:>8} | {:>8} | {:>7} | {:>7} | {:>6.1}% |",
            a, b, wa, wb, share
        );
    }
    // Equal budgets -> ~50% share; growing A's budget must grow its share.
    assert!((shares[0] - 50.0).abs() < 2.0, "equal budgets must split evenly");
    assert!(
        shares[1] > shares[0] && shares[2] > shares[1],
        "share must track the budget: {shares:?}"
    );
    println!(
        "\nbandwidth share follows the register-file budgets — the paper's \
         dynamic bandwidth allocation mechanism.\nbandwidth_tuning OK"
    );
}
