//! Closed-loop elasticity at scale: a 100 000-request diurnal trace
//! with board churn, served by the demand-driven PR-region autoscaler
//! and by a static even split of the same fleet.
//!
//! ```bash
//! cargo run --release --example autoscale_serving
//! ```
//!
//! * four anti-phase diurnal tenants (30..450 req/s each, 20 s period)
//!   over five 3-region boards — peaks rotate around the tenant set, so
//!   a fixed partitioning always has one starved app next to idle
//!   regions;
//! * seeded churn: board outages (graceful drain + cross-fabric
//!   re-placement) and region fencing mid-trace;
//! * every grow/shrink is actuated through the timed, serialized ICAP
//!   model and reprograms the register file's destinations + WRR
//!   weights;
//! * the run asserts the paper's promise: strictly higher PR-region
//!   utilization than the static baseline at equal-or-better p99 queue
//!   wait.

use elastic_fpga::autoscale::{
    autoscale_profile, run_diurnal_scenario, AutoscaleReport, PolicyKind,
};
use elastic_fpga::config::SystemConfig;

const REQUESTS: usize = 100_000;
const NODES: usize = 5;
const TENANTS: u32 = 4;
const PERIOD_S: f64 = 20.0;
const SEED: u64 = 1;

fn describe(cfg: &SystemConfig, name: &str, r: &AutoscaleReport) {
    let mut wait = r.queue_wait.clone();
    let mut lat = r.latency.clone();
    println!(
        "{name} ({}):\n  \
         utilization {:.1}% ({} busy / {} capacity region-cycles)\n  \
         queue wait p50 {:.2} ms | p99 {:.2} ms | SLO attainment {:.1}%\n  \
         latency p99 {:.2} ms | fabric/cpu requests {}/{}\n  \
         grows {} | shrinks {} | transitions {} | ICAP events {}",
        r.policy,
        r.utilization * 100.0,
        r.busy_region_cycles,
        r.capacity_region_cycles,
        cfg.cycles_to_ms(wait.percentile(0.50)),
        cfg.cycles_to_ms(wait.percentile(0.99)),
        r.slo_attainment * 100.0,
        cfg.cycles_to_ms(lat.percentile(0.99)),
        r.fabric_requests,
        r.cpu_requests,
        r.grows,
        r.shrinks,
        r.transitions.len(),
        r.icap_events.len(),
    );
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = autoscale_profile();
    println!(
        "autoscale_serving: {REQUESTS} requests, {TENANTS} diurnal tenants \
         over {NODES} boards, churn on"
    );
    let t0 = std::time::Instant::now();
    let rep = run_diurnal_scenario(
        &cfg,
        NODES,
        TENANTS,
        REQUESTS,
        PERIOD_S,
        SEED,
        true,
        PolicyKind::TargetQueueDepth,
    )?;
    println!("simulated both runs in {:.2?}\n", t0.elapsed());
    describe(&cfg, "autoscaled     ", &rep.autoscaled);
    describe(&cfg, "static baseline", &rep.static_baseline);

    let auto = &rep.autoscaled;
    let stat = &rep.static_baseline;
    assert_eq!(auto.completed as usize, REQUESTS, "lost requests");
    assert_eq!(stat.completed as usize, REQUESTS, "lost requests");
    assert!(
        auto.utilization > stat.utilization,
        "autoscaler must beat the static split on PR-region utilization"
    );
    let mut aw = auto.queue_wait.clone();
    let mut sw = stat.queue_wait.clone();
    assert!(
        aw.percentile(0.99) <= sw.percentile(0.99),
        "autoscaler must not regress p99 queue wait"
    );
    assert!(auto.grows > 0 && auto.shrinks > 0, "loop never closed");
    println!(
        "\nOK: +{:.1} utilization points, p99 queue wait {:.2} ms vs {:.2} ms",
        (auto.utilization - stat.utilization) * 100.0,
        cfg.cycles_to_ms(aw.percentile(0.99)),
        cfg.cycles_to_ms(sw.percentile(0.99)),
    );
    Ok(())
}
