//! End-to-end validation driver (DESIGN.md §5): a multi-tenant serving
//! run with fluctuating PR-region availability.
//!
//! ```bash
//! make artifacts && cargo run --release --example elastic_serving
//! ```
//!
//! * loads the real AOT artifacts and executes every on-server stage via
//!   PJRT (the actual request path, not a mock);
//! * replays 200 application requests (16 KB each) while a churn
//!   schedule fences and releases PR regions, so requests land on 0..=3
//!   FPGA stages — the full elasticity range of Fig 5;
//! * verifies every single result against the Rust golden model;
//! * reports wall-clock latency percentiles, throughput, and the mean
//!   modelled execution time per elasticity case.
//!
//! The run is recorded in EXPERIMENTS.md.

use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::{AppRequest, ElasticManager};
use elastic_fpga::metrics::{LatencyRecorder, Throughput};
use elastic_fpga::runtime::RuntimeThread;
use elastic_fpga::util::SplitMix64;

const REQUESTS: usize = 200;
const WORDS: usize = 4096; // 16 KB, the paper's buffer

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = SystemConfig::paper_defaults();
    let runtime = match RuntimeThread::spawn(elastic_fpga::DEFAULT_ARTIFACT_DIR) {
        Ok(rt) => {
            rt.handle().preload_all()?;
            println!("pjrt runtime up: executing on-server stages for real");
            Some(rt)
        }
        Err(e) => {
            eprintln!("warning: no PJRT runtime ({e}); golden-model CPU path");
            None
        }
    };

    let mut manager =
        ElasticManager::new(cfg.clone(), runtime.as_ref().map(|t| t.handle()));
    let mut rng = SplitMix64::new(2024);
    let mut churn = SplitMix64::new(7);

    let mut wall = LatencyRecorder::new();
    let mut thr = Throughput::start();
    // Per elasticity case: (count, total modelled ms).
    let mut case_acc = [(0usize, 0.0f64); 4];
    let mut verified = 0usize;

    for i in 0..REQUESTS {
        // Churn: every few requests, re-roll how many regions are fenced
        // (simulates other tenants grabbing/releasing PR regions).
        if i % 5 == 0 {
            manager.unfence_all();
            let fenced = churn.below(4) as usize; // 0..=3
            manager.fence_regions(fenced);
        }

        let mut data = vec![0u32; WORDS];
        rng.fill_u32(&mut data);
        let req = AppRequest::pipeline((i % 4) as u32, data);

        let t0 = std::time::Instant::now();
        let report = manager.execute(&req)?;
        wall.record(t0.elapsed());
        thr.record((WORDS * 4) as u64);

        assert!(report.verified, "request {i} failed verification");
        verified += 1;
        let c = &mut case_acc[report.fpga_stages];
        c.0 += 1;
        c.1 += report.cost.total_ms();
    }

    println!("\n=== elastic_serving results ===");
    println!("requests: {REQUESTS}  verified: {verified} (100% required)");
    println!(
        "wall latency: mean {:.1} us  p50 {} us  p99 {} us  max {} us",
        wall.mean_us(),
        wall.percentile_us(0.50),
        wall.percentile_us(0.99),
        wall.max_us()
    );
    println!(
        "throughput: {:.1} req/s  ({:.1} MB/s of payload)",
        thr.items_per_sec(),
        thr.mbytes_per_sec()
    );
    println!("\nmodelled execution time by elasticity case (Fig-5 axis):");
    println!("| FPGA stages | requests | mean modelled ms |");
    for (stages, (count, total)) in case_acc.iter().enumerate() {
        if *count > 0 {
            println!(
                "|      {}      | {:>8} | {:>16.2} |",
                stages,
                count,
                total / *count as f64
            );
        }
    }
    println!("(paper Fig 5: 1 stage = 16.9 ms ... 3 stages = 10.87 ms)");

    assert_eq!(verified, REQUESTS);
    // The Fig-5 ordering must hold across the churned run for the cases
    // the paper plots (1..=3 FPGA stages; case 0 never crosses PCIe in
    // the model, so it is outside Fig 5's axis).
    let means: Vec<(usize, f64)> = case_acc
        .iter()
        .enumerate()
        .skip(1)
        .filter(|(_, (c, _))| *c > 0)
        .map(|(s, (c, t))| (s, t / *c as f64))
        .collect();
    for w in means.windows(2) {
        assert!(
            w[0].1 > w[1].1,
            "more FPGA stages must be faster: {means:?}"
        );
    }
    println!("\nelastic_serving OK");
    Ok(())
}
