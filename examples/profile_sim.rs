//! Profiling driver for the L3 hot path (used by the §Perf pass and
//! handy for flamegraphs): runs the saturated-crossbar and full-fabric
//! loops for a fixed cycle budget and prints Mcycles/s.
//!
//! ```bash
//! cargo run --release --example profile_sim [xbar|fabric] [mcycles]
//! ```

use elastic_fpga::config::{CrossbarConfig, SystemConfig};
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::fabric::Fabric;
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::util::SplitMix64;
use elastic_fpga::wishbone::Job;
use elastic_fpga::xdma::H2cBurst;

fn xbar_loop(cycles: u64) -> f64 {
    let cfg = CrossbarConfig {
        grant_timeout: u64::MAX / 2,
        ..CrossbarConfig::default()
    };
    let mut xb = Crossbar::new(4, cfg);
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    for m in 0..4usize {
        xb.push_job(
            m,
            Job::new(encode_onehot(((m + 1) % 4) as u32), vec![0xA5; 1 << 22], 0),
        );
    }
    let mut clk = Clock::new();
    let mut sink = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..cycles {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..4 {
            xb.drain_rx_into(s, usize::MAX, &mut sink);
            sink.clear();
        }
    }
    cycles as f64 / t0.elapsed().as_secs_f64() / 1e6
}

fn fabric_loop(rounds: u64) -> f64 {
    let cfg = SystemConfig::paper_defaults();
    let mut f = Fabric::new(cfg);
    let ports = [1usize, 2, 3];
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.regfile.set_allowed_slaves(0, 0b0010).unwrap();
    for (i, &p) in ports.iter().enumerate() {
        let next = ports.get(i + 1).copied().unwrap_or(0);
        f.regfile.set_pr_destination(p, 1 << next).unwrap();
        f.regfile.set_allowed_slaves(p, 1 << next).unwrap();
    }
    for (&p, &k) in ports.iter().zip(ModuleKind::pipeline().iter()) {
        f.install_static_module(p, k, 0);
    }
    let mut rng = SplitMix64::new(1);
    let mut data = vec![0u32; 4096];
    rng.fill_u32(&mut data);
    let mut total_cycles = 0u64;
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        for chunk in data.chunks(8) {
            f.h2c_push(0, H2cBurst { app_id: 0, words: chunk.to_vec() });
        }
        total_cycles += f.run_until_idle(10_000_000).unwrap();
        let _ = f.take_app_output(0);
    }
    total_cycles as f64 / t0.elapsed().as_secs_f64() / 1e6
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mode = args.first().map(String::as_str).unwrap_or("xbar");
    match mode {
        "xbar" => {
            let mc: u64 = args
                .get(1)
                .and_then(|s| s.parse().ok())
                .unwrap_or(5_000_000);
            println!("xbar: {:.1} Mcycles/s", xbar_loop(mc));
        }
        "fabric" => {
            let rounds: u64 =
                args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
            println!("fabric: {:.1} Mcycles/s", fabric_loop(rounds));
        }
        "pjrt" => {
            // §Perf L2: wall time per artifact execution on the CPU PJRT
            // client (pipeline = the fused 3-stage graph).
            let rt = elastic_fpga::runtime::Runtime::open(
                elastic_fpga::DEFAULT_ARTIFACT_DIR,
            )
            .expect("run `make artifacts`");
            for name in ["multiplier", "hamming_enc", "hamming_dec", "pipeline"] {
                let exe = rt.load(name).unwrap();
                let mut rng = SplitMix64::new(9);
                let mut x = vec![0u32; exe.input_words()];
                rng.fill_u32(&mut x);
                // warmup
                for _ in 0..3 {
                    exe.run_u32(&x).unwrap();
                }
                let reps = 100;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    std::hint::black_box(exe.run_u32(&x).unwrap());
                }
                let us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
                println!("{name}: {us:.1} us/exec (4096 words)");
            }
        }
        other => {
            eprintln!("unknown mode '{other}' (use xbar|fabric|pjrt)");
            std::process::exit(1);
        }
    }
}
