//! Scale-out serving on 16-port boards: the placements PR 2 refused.
//!
//! ```bash
//! cargo run --release --example scale_out_serving            # 40k requests
//! cargo run --release --example scale_out_serving -- 10000   # CI smoke
//! ```
//!
//! Until the banked register-file layout, `configs/scale16.toml` could
//! only be *simulated*: the manager refused any placement past crossbar
//! port 3 (and any app ID past 3) with `ElasticError::RegfileWindow`,
//! capping every board at 3 programmable PR regions.  This example
//! drives the two things that used to fail:
//!
//! 1. **Direct programming** — an `ElasticManager` on the shipped
//!    16-port config programs destinations, isolation masks and WRR
//!    package budgets for a chain spanning regions 4..=12, then
//!    executes a 9-stage request entirely on fabric;
//! 2. **Closed-loop serving** — the autoscaler (feed-forward
//!    predictive policy) serves six diurnal tenants — app IDs 4 and 5
//!    included — over two 15-region boards with churn, against the
//!    static even split; the transition history shows regions beyond
//!    port 3 in live use from the first allocation on.

use elastic_fpga::autoscale::{
    run_diurnal_scenario, serving_profile_on, AutoscaleReport, PolicyKind,
};
use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::{AppRequest, ElasticManager};
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::util::SplitMix64;

const NODES: usize = 2;
const TENANTS: u32 = 6; // app IDs 0..=5 — two beyond the old window
const PERIOD_S: f64 = 10.0;
const SEED: u64 = 1;

fn scale16_cfg() -> SystemConfig {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/scale16.toml");
    let cfg = SystemConfig::load(std::path::Path::new(path))
        .expect("configs/scale16.toml must parse");
    // Serving-profile timing (lighter descriptor rounds, region-sized
    // partial bitstreams) — the same overlay the `autoscale --config`
    // CLI path applies.
    serving_profile_on(cfg)
}

/// Part 1: program a chain across regions Table III never had registers
/// for, and run a 9-stage request on it.
fn direct_programming(cfg: &SystemConfig) {
    let mut mgr = ElasticManager::new(cfg.clone(), None);
    let chain: Vec<usize> = (4..=12).collect();
    mgr.program_app_chain(2, &chain)
        .expect("regions 4..=12 are inside the 16-port layout");
    // The shipped [qos] table contracts app 2 at 600/1000: the plan
    // compiler — not this call site — lowered that share into the nine
    // masters' budget fields (38 packages, largest-remainder split).
    let shares = mgr.bandwidth_shares();
    println!(
        "programmed app 2 across regions 4..=12 (bandwidth {:?} ppu):",
        shares
    );
    let rf = &mgr.fabric().regfile;
    for &r in &chain {
        println!(
            "  region {r:>2}: dest {:#07x}  mask {:#07x}  wrr {}",
            rf.pr_destination(r).unwrap(),
            rf.allowed_slaves(r).unwrap(),
            rf.allowed_packages(0, r).unwrap(),
        );
    }

    let mut data = vec![0u32; 512];
    SplitMix64::new(7).fill_u32(&mut data);
    let req = AppRequest {
        app_id: 2,
        data,
        stages: vec![ModuleKind::Multiplier; 9],
    };
    let rep = mgr.execute(&req).expect("9-stage chain on a 16-port board");
    assert_eq!(rep.fpga_stages, 9, "whole chain must land on fabric");
    assert!(rep.verified);
    println!(
        "9-stage request: {} words, {} FPGA stages, verified={}, \
         {:.2} ms modelled\n",
        rep.output.len(),
        rep.fpga_stages,
        rep.verified,
        rep.cost.total_ms()
    );
}

fn describe(cfg: &SystemConfig, name: &str, r: &AutoscaleReport) {
    let mut wait = r.queue_wait.clone();
    println!(
        "{name} ({}): util {:.1}% | queue wait p50 {:.2} ms p99 {:.2} ms | \
         SLO {:.1}% | fabric/cpu {}/{} | grows {} shrinks {} | icap {}",
        r.policy,
        r.utilization * 100.0,
        cfg.cycles_to_ms(wait.percentile(0.50)),
        cfg.cycles_to_ms(wait.percentile(0.99)),
        r.slo_attainment * 100.0,
        r.fabric_requests,
        r.cpu_requests,
        r.grows,
        r.shrinks,
        r.icap_events.len(),
    );
}

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|a| a.parse().expect("usage: scale_out_serving [requests]"))
        .unwrap_or(40_000);
    let cfg = scale16_cfg();
    println!(
        "scale-out serving: {} boards x {} PR regions, {} tenants, \
         {requests} requests\n",
        NODES, cfg.fabric.num_pr_regions, TENANTS
    );

    direct_programming(&cfg);

    let t0 = std::time::Instant::now();
    let rep = run_diurnal_scenario(
        &cfg,
        NODES,
        TENANTS,
        requests,
        PERIOD_S,
        SEED,
        true,
        PolicyKind::Predictive,
    )
    .expect("scenario must complete");
    println!("(simulated in {:.2?})", t0.elapsed());
    describe(&cfg, "autoscaled", &rep.autoscaled);
    describe(&cfg, "static    ", &rep.static_baseline);

    let auto = &rep.autoscaled;
    assert_eq!(auto.completed, requests as u64, "requests lost");
    assert_eq!(
        rep.static_baseline.completed,
        requests as u64,
        "requests lost by the baseline"
    );
    // The point of the refactor: allocations beyond the old 4-port
    // register-file window, live in the transition history.
    let high_regions: usize = auto
        .transitions
        .iter()
        .flat_map(|t| t.regions.iter())
        .filter(|&&r| r > 3)
        .count();
    assert!(
        high_regions > 0,
        "no placement ever used a region beyond crossbar port 3"
    );
    let high_apps = auto.transitions.iter().any(|t| t.app_id > 3);
    assert!(high_apps, "no allocation for an app ID beyond Table III");
    if requests >= 10_000 {
        // Long enough for the diurnal peaks to bite: the predictive
        // loop must actually exercise both directions.
        assert!(auto.grows > 0, "no grow over a diurnal trace");
        assert!(auto.shrinks > 0, "no shrink over a diurnal trace");
    }
    println!(
        "\nOK: {high_regions} region placements beyond the Table III \
         window (apps 0..={} serving), utilization {:.1}% vs static {:.1}%",
        TENANTS - 1,
        auto.utilization * 100.0,
        rep.static_baseline.utilization * 100.0
    );
}
