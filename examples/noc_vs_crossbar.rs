//! Crossbar vs NoC vs shared bus, head to head (Table II / §V.G).
//!
//! ```bash
//! cargo run --release --example noc_vs_crossbar
//! ```
//!
//! Runs the same communication pattern — every module sends an 8-word
//! package to a destination — on all three interconnects and prints the
//! completion latencies next to the area/power numbers, reproducing the
//! paper's comparison: the crossbar completes a request in 69% fewer
//! cycles than the NoC of [16] while using 61% fewer LUTs, and trades
//! area for parallelism against the shared bus of [21].

use elastic_fpga::area;
use elastic_fpga::baselines::noc::{Coord, MeshNoc};
use elastic_fpga::baselines::sharedbus::SharedBus;
use elastic_fpga::config::CrossbarConfig;
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::wishbone::Job;

fn crossbar_latency(parallel: bool) -> Vec<u64> {
    let mut xb = Crossbar::new(4, CrossbarConfig::default());
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    if parallel {
        // Disjoint pairs: 0->1 and 2->3.
        xb.push_job(0, Job::new(encode_onehot(1), vec![0; 8], 0));
        xb.push_job(2, Job::new(encode_onehot(3), vec![0; 8], 0));
    } else {
        xb.push_job(0, Job::new(encode_onehot(3), vec![0; 8], 0));
    }
    let mut clk = Clock::new();
    let mut lats = Vec::new();
    for _ in 0..1000 {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..4 {
            xb.drain_rx(s, usize::MAX);
        }
        for e in xb.take_events() {
            lats.push(e.completion_latency());
        }
        if xb.quiescent() {
            break;
        }
    }
    lats
}

fn noc_latency(parallel: bool) -> Vec<u64> {
    let mut noc = MeshNoc::new(2, 2);
    if parallel {
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 0 }, vec![0; 8]);
        noc.inject(Coord { x: 0, y: 1 }, Coord { x: 1, y: 1 }, vec![0; 8]);
    } else {
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 0 }, vec![0; 8]);
    }
    let mut clk = Clock::new();
    clk.run_until(&mut noc, 10_000, |n| !n.busy()).unwrap();
    noc.take_delivered()
        .iter()
        .map(|d| d.completion_latency())
        .collect()
}

fn bus_latency(parallel: bool) -> Vec<u64> {
    let mut bus = SharedBus::new();
    if parallel {
        bus.request(0, 1, 8);
        bus.request(2, 3, 8);
    } else {
        bus.request(0, 3, 8);
    }
    let mut clk = Clock::new();
    clk.run_until(&mut bus, 10_000, |b| !b.busy()).unwrap();
    bus.take_delivered()
        .iter()
        .map(|d| d.completion_latency())
        .collect()
}

fn main() {
    println!("Interconnect head-to-head: one 8-word request\n");
    let xb = crossbar_latency(false)[0];
    let noc = noc_latency(false)[0];
    let bus = bus_latency(false)[0];
    println!("| interconnect    | completion (cc) | LUTs | FFs  | power |");
    println!("|-----------------|-----------------|------|------|-------|");
    println!(
        "| 4x4 WB crossbar | {:>15} | {:>4} | {:>4} |  1 mW |",
        xb,
        area::table2::WB_CROSSBAR_4X4.luts,
        area::table2::WB_CROSSBAR_4X4.ffs
    );
    println!(
        "| 2x2 NoC [16]    | {:>15} | {:>4} | {:>4} | 80 mW |",
        noc,
        area::table2::NOC_2X2_3PORT.luts,
        area::table2::NOC_2X2_3PORT.ffs
    );
    println!(
        "| shared bus [21] | {:>15} | {:>4} | {:>4} |   -   |",
        bus,
        area::table2::EWB_X4.luts,
        area::table2::EWB_X4.ffs
    );

    println!("\nTwo disjoint 8-word transfers (parallelism test):");
    let xb_par = crossbar_latency(true);
    let noc_par = noc_latency(true);
    let bus_par = bus_latency(true);
    println!("  crossbar: {:?} cc (parallel, both at best case)", xb_par);
    println!("  NoC:      {:?} cc (parallel paths)", noc_par);
    println!("  bus:      {:?} cc (serialized!)", bus_par);

    // The paper's claims.
    assert_eq!(xb, 13);
    assert_eq!(noc, 22);
    let advantage = (noc as f64 - xb as f64) / xb as f64 * 100.0;
    assert!((advantage - 69.0).abs() < 1.0);
    assert!(xb_par.iter().all(|&l| l == 13), "crossbar must parallelize");
    assert!(bus_par.iter().any(|&l| l > 13), "bus must serialize");
    println!(
        "\ncrossbar completes in {advantage:.0}% fewer cycles than the NoC \
         (paper: 69%), with {:.0}% fewer LUTs (paper: 61%).\nnoc_vs_crossbar OK",
        100.0 * (1.0 - 475.0 / 1220.0)
    );
}
