//! Trace replay on a multi-board cluster — the §VI "Kubernetes engine"
//! vision: several FPGA nodes, a placement policy, and a heterogeneous
//! multi-tenant workload trace.
//!
//! ```bash
//! cargo run --release --example trace_replay
//! ```

use elastic_fpga::cluster::{Cluster, PlacementPolicy};
use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::golden_chain;
use elastic_fpga::metrics::LatencyRecorder;
use elastic_fpga::runtime::RuntimeThread;
use elastic_fpga::util::SplitMix64;
use elastic_fpga::workload::{generate, WorkloadSpec};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = SystemConfig::paper_defaults();
    let runtime = RuntimeThread::spawn(elastic_fpga::DEFAULT_ARTIFACT_DIR).ok();
    if runtime.is_none() {
        eprintln!("note: artifacts missing; on-server stages use the golden model");
    }

    let spec = WorkloadSpec::mixed();
    let trace = generate(&spec, 77);
    println!(
        "replaying {} requests ({} tenants, mixed sizes/chains) on 3 nodes",
        trace.len(),
        spec.tenants
    );

    for policy in [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::MostAvailable,
        PlacementPolicy::FirstFullFit,
    ] {
        let mut cluster = Cluster::launch(
            3,
            &cfg,
            runtime.as_ref().map(|t| t.handle()),
            policy,
        );
        let mut churn = SplitMix64::new(5);
        let mut modelled = LatencyRecorder::new();
        let mut fpga_stage_total = 0u64;
        let mut stage_total = 0u64;

        for (i, ev) in trace.iter().enumerate() {
            // Node churn: other tenants grab/release regions.
            if i % 7 == 0 {
                for node in 0..3 {
                    cluster.node_mut(node).manager_mut().unfence_all();
                    let fenced = churn.below(3) as usize;
                    cluster.node_mut(node).manager_mut().fence_regions(fenced);
                }
            }
            let (_, report) = cluster.execute(&ev.request)?;
            assert!(report.verified);
            assert_eq!(
                report.output,
                golden_chain(&ev.request.stages, &ev.request.data)
            );
            modelled.record_us((report.cost.total_ms() * 1000.0) as u64);
            fpga_stage_total += report.fpga_stages as u64;
            stage_total += ev.request.stages.len() as u64;
        }

        let served: Vec<u64> = cluster.nodes().iter().map(|n| n.served).collect();
        println!(
            "policy {:>14?}: modelled p50 {:.2} ms, p99 {:.2} ms | \
             FPGA-stage share {:.0}% | per-node load {:?}",
            policy,
            modelled.percentile_us(0.50) as f64 / 1000.0,
            modelled.percentile_us(0.99) as f64 / 1000.0,
            100.0 * fpga_stage_total as f64 / stage_total as f64,
            served
        );
    }
    println!("trace_replay OK");
    Ok(())
}
