//! Bench: reproduce **§V.E** — communication overhead.  Measures
//! time-to-grant and request-completion latency on the 4x4 crossbar,
//! best case (idle slave) and worst case (3 masters on one slave),
//! 8 packages each — the numbers must equal the paper's *exactly*.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::experiments;

fn main() {
    let cfg = SystemConfig::paper_defaults();
    harness::section("§V.E — communication overhead (cycle-exact)");
    let r = experiments::comm_overhead(&cfg);
    println!("{}", experiments::overhead_render(&r));

    let mut claims = harness::Claims::new();
    claims.check(r.best_time_to_grant == 4, "best-case time-to-grant = 4 cc");
    claims.check(r.best_completion_8 == 13, "best-case completion = 13 cc");
    claims.check(r.worst_time_to_grant == 28, "worst-case time-to-grant = 28 cc");
    claims.check(r.worst_completion_8 == 37, "worst-case completion = 37 cc");
    claims.finish();

    harness::section("measurement-harness micro-bench");
    let mut s = harness::bench("comm_overhead scenario pair", 10, 500, || {
        experiments::comm_overhead(&cfg)
    });
    harness::report(&mut s);
}
