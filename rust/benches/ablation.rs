//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Decentralized vs centralized arbitration** (§IV.E.1's choice):
//!    under disjoint parallel traffic, per-slave arbiters grant
//!    concurrently while a shared decision unit staggers grants.
//! 2. **Bridge request policy** (§IV.G): half-full vs full end to end on
//!    a 16 KB stream (not just the 15 vs 19 cc single-burst numbers).
//! 3. **WRR budget sweep**: the §V.D dial at more points, showing
//!    diminishing returns (the reason the paper picks packet counts
//!    rather than unlimited bursts).

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::{CrossbarConfig, SystemConfig};
use elastic_fpga::crossbar::central::CentralizedCrossbar;
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::experiments;
use elastic_fpga::fabric::Fabric;
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::util::SplitMix64;
use elastic_fpga::wishbone::Job;
use elastic_fpga::xdma::{H2cBurst, RequestPolicy};

/// All disjoint pairs (i -> i+n/2) request simultaneously; returns the
/// max time-to-grant for each arbitration scheme.
fn arbitration_ablation(n: usize) -> (u64, u64) {
    // Decentralized.
    let mut xb = Crossbar::new(n, CrossbarConfig::default());
    let all = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
    for m in 0..n {
        xb.set_allowed_slaves(m, all);
    }
    for m in 0..n / 2 {
        xb.push_job(m, Job::new(encode_onehot((m + n / 2) as u32), vec![0; 8], 0));
    }
    let mut clk = Clock::new();
    let mut decentralized = 0;
    for _ in 0..10_000 {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..n {
            xb.drain_rx(s, usize::MAX);
        }
        for e in xb.take_events() {
            decentralized = decentralized.max(e.time_to_grant());
        }
        if xb.quiescent() {
            break;
        }
    }
    // Centralized.
    let mut cx = CentralizedCrossbar::new(n, CrossbarConfig::default());
    for m in 0..n / 2 {
        cx.push_job(m, Job::new(encode_onehot((m + n / 2) as u32), vec![0; 8], 0));
    }
    let mut clk = Clock::new();
    let mut centralized = 0;
    for _ in 0..10_000 {
        let c = clk.advance();
        cx.tick(c);
        for e in cx.take_events() {
            centralized = centralized.max(e.time_to_grant());
        }
        if cx.quiescent() {
            break;
        }
    }
    (decentralized, centralized)
}

/// Stream 16 KB through the 3-stage pipeline with a bridge policy;
/// returns fabric cycles.
fn bridge_policy_cycles(policy: RequestPolicy) -> u64 {
    let cfg = SystemConfig::paper_defaults();
    let mut f = Fabric::new(cfg);
    f.axi2wb.policy = policy;
    let ports = [1usize, 2, 3];
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.regfile.set_allowed_slaves(0, 0b0010).unwrap();
    for (i, &p) in ports.iter().enumerate() {
        let next = ports.get(i + 1).copied().unwrap_or(0);
        f.regfile.set_pr_destination(p, 1 << next).unwrap();
        f.regfile.set_allowed_slaves(p, 1 << next).unwrap();
    }
    for (&p, &k) in ports.iter().zip(ModuleKind::pipeline().iter()) {
        f.install_static_module(p, k, 0);
    }
    let mut rng = SplitMix64::new(1);
    let mut data = vec![0u32; 4096];
    rng.fill_u32(&mut data);
    for chunk in data.chunks(8) {
        f.h2c_push(0, H2cBurst { app_id: 0, words: chunk.to_vec() })
            .expect("channel 0 in range");
    }
    f.run_until_idle(10_000_000).unwrap()
}

fn main() {
    let mut claims = harness::Claims::new();

    harness::section("ablation 1 — decentralized vs centralized arbitration");
    println!("| ports | disjoint pairs | decentralized max ttg | centralized max ttg |");
    for n in [4usize, 8, 16] {
        let (dec, cen) = arbitration_ablation(n);
        println!("| {:>5} | {:>14} | {:>21} | {:>19} |", n, n / 2, dec, cen);
        claims.check(
            dec == 4,
            &format!("{n}-port decentralized grants all disjoint pairs at 4 cc"),
        );
        claims.check(
            cen > dec,
            &format!("{n}-port centralized staggers grants ({cen} > {dec} cc)"),
        );
    }

    harness::section("ablation 2 — bridge request policy, 16 KB end to end");
    let half = bridge_policy_cycles(RequestPolicy::HalfFull);
    let full = bridge_policy_cycles(RequestPolicy::Full);
    println!("  half-full: {half} cycles   full: {full} cycles");
    claims.check(
        half <= full,
        "half-full policy never loses end to end (overlapped grant latency)",
    );

    harness::section("ablation 3 — WRR budget sweep (1 accelerator, 16 KB)");
    println!("| packages/grant | fabric cycles |");
    let mut prev: Option<u64> = None;
    let mut improvements = Vec::new();
    for budget in [8u32, 16, 32, 64, 128, 255] {
        let row = experiments::bandwidth_case(1, budget, 4096).unwrap();
        println!("| {:>14} | {:>13} |", budget, row.fabric_cycles);
        if let Some(p) = prev {
            improvements.push((p as f64 - row.fabric_cycles as f64) / p as f64);
        }
        prev = Some(row.fabric_cycles);
    }
    claims.check(
        improvements.iter().all(|&i| i >= -0.001),
        "bigger budgets never slow the stream down",
    );
    claims.check(
        improvements.first().copied().unwrap_or(0.0)
            > improvements.last().copied().unwrap_or(0.0),
        "diminishing returns: early doublings help most",
    );
    claims.finish();
}
