//! End-to-end fabric serving bench: the busy-period horizon fast-path
//! (DESIGN.md §12) vs the cycle-by-cycle oracle on a diurnal serving
//! trace, at 4 and 16 ports, with ICAP-timed installs on every request.
//! Emits `BENCH_fabric.json` — executed-vs-skipped cycle accounting and
//! requests/sec — so the perf trajectory has an end-to-end number next
//! to `BENCH_crossbar.json`, plus `BENCH_fabric_metrics.json`, the same
//! accounting as a schema-versioned metrics snapshot (DESIGN.md §14).
//!
//! The two modes are cycle-exact (pinned by
//! `tests/fastpath_equivalence.rs`); this bench cross-checks that on
//! its own trace — identical outputs, costs and total virtual cycles —
//! and claims the fast path executes >= 5x fewer ticks than the oracle.
//! Each case also runs a plan-fidelity mini-measurement (DESIGN.md §15)
//! and reports `h2c_share_error`: the relative error between a 2-tenant
//! bandwidth plan's contracted completion ratio and the ratio measured
//! at the C2H FIFOs under bridge saturation (claimed <= 5%).
//!
//! ```bash
//! cargo bench --bench fabric_serving            # full run
//! cargo bench --bench fabric_serving -- --smoke # CI smoke mode
//! ```

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::ElasticManager;
use elastic_fpga::metrics::CycleThroughput;
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::qos::BandwidthPlan;
use elastic_fpga::sim::Tick;
use elastic_fpga::telemetry::MetricsRegistry;
use elastic_fpga::workload::{diurnal_tenants, generate_profiled, TraceEvent};
use elastic_fpga::xdma::{H2cBurst, C2H_CHANNELS, H2C_CHANNELS};

/// One mode's run over a trace: total wall seconds, executed/skipped
/// fabric cycles, total virtual cycles, and the per-request service
/// summaries used for the oracle cross-check.
struct ModeRun {
    wall_s: f64,
    executed_cycles: u64,
    skipped_cycles: u64,
    virtual_cycles: u64,
    /// `(app_id, fabric cycles, reconfig cycles, output checksum)`.
    summaries: Vec<(u32, u64, u64, u32)>,
}

fn run_mode(cfg: &SystemConfig, trace: &[TraceEvent], fast: bool) -> ModeRun {
    let mut mgr = ElasticManager::new(cfg.clone(), None);
    mgr.use_icap = true;
    mgr.fast_path = fast;
    let mut summaries = Vec::with_capacity(trace.len());
    let t0 = std::time::Instant::now();
    for ev in trace {
        let rep = mgr.execute(&ev.request).expect("request failed");
        assert!(rep.verified, "fabric output failed golden verification");
        let checksum = rep
            .output
            .iter()
            .fold(0u32, |acc, &w| acc.rotate_left(1) ^ w);
        summaries.push((
            rep.app_id,
            rep.timeline.fabric_cycles,
            rep.timeline.reconfig_cycles,
            checksum,
        ));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let fabric = mgr.fabric();
    ModeRun {
        wall_s,
        executed_cycles: fabric.executed_cycles,
        skipped_cycles: fabric.skipped_cycles,
        virtual_cycles: fabric.now(),
        summaries,
    }
}

/// Plan-fidelity mini-run (DESIGN.md §15): two tenants with exact
/// integer-ratio shares saturate the bridge; returns the relative error
/// between the completed-words ratio measured at the C2H FIFOs and the
/// contracted ratio.  Mirrors `tests/qos_e2e.rs` at bench scale.
fn h2c_share_error(ports: usize) -> f64 {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.fabric.num_ports = ports;
    cfg.fabric.num_pr_regions = ports - 1;
    cfg.manager.bitstream_bytes = 4096;
    cfg.crossbar.grant_timeout = 1_000_000;
    let (chain1, chain2, shares, expect): (&[usize], &[usize], _, f64) =
        if ports >= 16 {
            (&[1, 2, 3], &[4], [(1u32, 750u32), (2, 250)], 3.0)
        } else {
            (&[1, 2], &[3], [(1u32, 600u32), (2, 300)], 2.0)
        };
    let mut m = ElasticManager::new(cfg, None);
    for &r in chain1 {
        m.reserve_region(1, ModuleKind::Multiplier, r).unwrap();
    }
    for &r in chain2 {
        m.reserve_region(2, ModuleKind::Multiplier, r).unwrap();
    }
    m.program_app_chain(1, chain1).unwrap();
    m.program_app_chain(2, chain2).unwrap();
    let plan = BandwidthPlan::with_shares(&shares).unwrap();
    m.set_bandwidth_plan(plan).unwrap();
    // `program_app_chain` narrows bridge port 0 to its own chain head;
    // concurrent tenants need the union.
    let heads = (1u32 << chain1[0]) | (1u32 << chain2[0]);
    m.fabric_mut().regfile.set_allowed_slaves(0, heads).unwrap();
    let fabric = m.fabric_mut();
    const BURSTS: usize = 600;
    for i in 0..BURSTS {
        for app in [1u32, 2] {
            fabric
                .h2c_push(
                    app as usize % H2C_CHANNELS,
                    H2cBurst { app_id: app, words: vec![i as u32; 8] },
                )
                .unwrap();
        }
    }
    let mut cycle = fabric.now();
    for _ in 0..8_000 {
        cycle += 1;
        Tick::tick(&mut *fabric, cycle);
    }
    // Saturation must hold for the whole window, or the measured ratio
    // is the workload's rather than the scheduler's.
    let granted = fabric.xdma.h2c_app_words();
    assert!(granted[&1] < (BURSTS * 8) as u64, "app 1 backlog ran dry");
    assert!(granted[&2] < (BURSTS * 8) as u64, "app 2 backlog ran dry");
    let mut per_app = [0u64; 2];
    for ch in 0..C2H_CHANNELS {
        for (app, _word) in fabric.xdma.c2h_drain(ch).unwrap() {
            per_app[(app - 1) as usize] += 1;
        }
    }
    let ratio = per_app[0] as f64 / per_app[1].max(1) as f64;
    (ratio - expect).abs() / expect
}

/// One manager-level configuration-cache run (DESIGN.md §16): repeated
/// same-shape pipeline requests with ICAP-timed installs.
struct CacheRun {
    virtual_cycles: u64,
    hits: u64,
    misses: u64,
    elided: u64,
}

fn run_cache_mode(cache: usize, requests: usize) -> CacheRun {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.manager.bitstream_bytes = 256 * 1024;
    cfg.manager.config_cache_regions = cache;
    let mut mgr = ElasticManager::new(cfg, None);
    mgr.use_icap = true;
    for i in 0..requests {
        let data = vec![i as u32; 64];
        let rep = mgr
            .execute(&elastic_fpga::manager::AppRequest::pipeline(
                (i % 2) as u32,
                data,
            ))
            .expect("request failed");
        assert!(rep.verified, "fabric output failed golden verification");
    }
    let (hits, misses, elided) = mgr.config_cache_stats();
    CacheRun { virtual_cycles: mgr.fabric().now(), hits, misses, elided }
}

struct CaseResult {
    name: &'static str,
    ports: usize,
    requests: usize,
    oracle_executed: u64,
    fast_executed: u64,
    fast_skipped: u64,
    virtual_cycles: u64,
    executed_ratio: f64,
    /// Wall-clock-independent throughput: requests per million virtual
    /// cycles, identical in both modes (they share the virtual clock).
    virtual_req_per_mcycle: f64,
    oracle_req_per_s: f64,
    fast_req_per_s: f64,
    /// Relative error of the plan-fidelity mini-run (DESIGN.md §15).
    h2c_share_error: f64,
}

fn run_case(
    name: &'static str,
    ports: usize,
    tenants: u32,
    requests: usize,
    claims: &mut harness::Claims,
) -> CaseResult {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.fabric.num_ports = ports;
    cfg.fabric.num_pr_regions = ports - 1;
    // A realistic-but-benchable partial bitstream (64K words -> 128K
    // cycles of ICAP streaming per region) keeps the oracle runnable
    // while leaving the horizon plenty to skip.
    cfg.manager.bitstream_bytes = 256 * 1024;
    // Diurnal anti-phase tenants on the Fig-5 pipeline: the serving
    // trace the autoscaler line of work uses.
    let specs = diurnal_tenants(tenants, 40.0, 400.0, 60.0, 64);
    let trace = generate_profiled(&specs, 0xD1_0B_5EED, requests);

    let fast = run_mode(&cfg, &trace, true);
    let oracle = run_mode(&cfg, &trace, false);

    // Oracle cross-check: byte-identical service summaries (outputs,
    // fabric cycles, reconfig cycles) and total virtual time.
    claims.check(
        fast.summaries == oracle.summaries,
        &format!("{name}: fast-path summaries byte-identical to oracle"),
    );
    claims.check(
        fast.virtual_cycles == oracle.virtual_cycles,
        &format!("{name}: same virtual cycle count in both modes"),
    );
    claims.check(
        fast.executed_cycles + fast.skipped_cycles == fast.virtual_cycles,
        &format!("{name}: executed + skipped accounts every cycle"),
    );
    let ratio = oracle.executed_cycles as f64 / fast.executed_cycles.max(1) as f64;
    claims.check(
        ratio >= 5.0,
        &format!("{name}: fast path executes >= 5x fewer cycles ({ratio:.1}x)"),
    );

    // Plan fidelity at this port count: the compiled bandwidth plan must
    // hold host-to-completion within 5% (DESIGN.md §15).
    let share_err = h2c_share_error(ports);
    claims.check(
        share_err <= 0.05,
        &format!("{name}: H2C share error within 5% ({share_err:.4})"),
    );

    let mut tp = CycleThroughput::new();
    tp.record_items(requests as u64, 0);
    tp.set_cycles(fast.virtual_cycles);
    let result = CaseResult {
        name,
        ports,
        requests,
        oracle_executed: oracle.executed_cycles,
        fast_executed: fast.executed_cycles,
        fast_skipped: fast.skipped_cycles,
        virtual_cycles: fast.virtual_cycles,
        executed_ratio: ratio,
        virtual_req_per_mcycle: tp.items_per_mcycle(),
        oracle_req_per_s: requests as f64 / oracle.wall_s.max(1e-9),
        fast_req_per_s: requests as f64 / fast.wall_s.max(1e-9),
        h2c_share_error: share_err,
    };
    println!(
        "  {:<10} oracle {:>12} cc executed | fast {:>9} cc executed + {:>12} skipped ({:>6.1}x) | {:>8.0} vs {:>8.0} req/s",
        result.name,
        result.oracle_executed,
        result.fast_executed,
        result.fast_skipped,
        result.executed_ratio,
        result.oracle_req_per_s,
        result.fast_req_per_s,
    );
    result
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let requests = if smoke { 24 } else { 200 };
    harness::section(if smoke {
        "fabric serving: horizon fast-path vs oracle (smoke)"
    } else {
        "fabric serving: horizon fast-path vs oracle"
    });

    let mut claims = harness::Claims::new();
    let cases = [
        run_case("ports4", 4, 3, requests, &mut claims),
        run_case("ports16", 16, 6, requests, &mut claims),
    ];

    // Resident-module configuration cache, manager level (DESIGN.md
    // §16): the same repeated pipeline shape cold vs warm.  Every warm
    // request after the first rebinds the parked chain, so the ICAP
    // restreams disappear from the virtual timeline.
    let cache_requests = if smoke { 16 } else { 64 };
    let cache_cold = run_cache_mode(0, cache_requests);
    let cache_warm = run_cache_mode(3, cache_requests);
    claims.check(
        cache_cold.hits == 0 && cache_cold.elided == 0,
        "cache off: no hits, nothing elided",
    );
    claims.check(
        cache_warm.hits > 0 && cache_warm.elided > 0,
        "warm cache rebinds parked modules and elides ICAP cycles",
    );
    claims.check(
        cache_warm.virtual_cycles < cache_cold.virtual_cycles,
        "elision shortens the virtual timeline",
    );
    let cache_hit_rate = cache_warm.hits as f64
        / (cache_warm.hits + cache_warm.misses).max(1) as f64;
    claims.check(
        (0.0..=1.0).contains(&cache_hit_rate),
        "config cache hit rate is a fraction",
    );
    println!(
        "  config cache: cold {} cc vs warm {} cc | hit rate {:.3} | \
         {} ICAP cycles elided",
        cache_cold.virtual_cycles,
        cache_warm.virtual_cycles,
        cache_hit_rate,
        cache_warm.elided,
    );

    // Machine-readable trajectory point.  Cycle counts are
    // deterministic; the req/s rates are wall-clock and vary run to run
    // (the committed baseline is compared structurally — see
    // python/tools/bench_diff.py).
    let mut json = String::from("{\n  \"bench\": \"fabric_serving\",\n");
    json.push_str(&format!("  \"requests_per_case\": {requests},\n"));
    json.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"ports\": {}, \"requests\": {}, \
             \"oracle_executed_cycles\": {}, \"fast_executed_cycles\": {}, \
             \"fast_skipped_cycles\": {}, \"virtual_cycles\": {}, \
             \"executed_ratio\": {:.2}, \"virtual_req_per_mcycle\": {:.3}, \
             \"oracle_requests_per_s\": {:.1}, \
             \"fast_requests_per_s\": {:.1}, \
             \"h2c_share_error\": {:.4}}}{}\n",
            c.name,
            c.ports,
            c.requests,
            c.oracle_executed,
            c.fast_executed,
            c.fast_skipped,
            c.virtual_cycles,
            c.executed_ratio,
            c.virtual_req_per_mcycle,
            c.oracle_req_per_s,
            c.fast_req_per_s,
            c.h2c_share_error,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"config_cache\": {{\"cache_regions\": 3, \"requests\": {}, \
         \"cold_virtual_cycles\": {}, \"warm_virtual_cycles\": {}, \
         \"config_cache_hit_rate\": {:.4}, \"icap_cycles_elided\": {}}}\n",
        cache_requests,
        cache_cold.virtual_cycles,
        cache_warm.virtual_cycles,
        cache_hit_rate,
        cache_warm.elided,
    ));
    json.push_str("}\n");
    std::fs::write("BENCH_fabric.json", &json).expect("write BENCH_fabric.json");
    println!("  wrote BENCH_fabric.json");

    // Companion metrics snapshot (DESIGN.md §14): the deterministic
    // cycle accounting as a schema-versioned labeled registry, so the
    // export path is exercised by CI on every bench run.
    let mut metrics = MetricsRegistry::new();
    for c in &cases {
        let labels: &[(&str, &str)] = &[("case", c.name)];
        metrics.inc("fabric_requests_total", labels, c.requests as u64);
        metrics.inc("fabric_oracle_executed_cycles_total", labels, c.oracle_executed);
        metrics.inc("fabric_fast_executed_cycles_total", labels, c.fast_executed);
        metrics.inc("fabric_fast_skipped_cycles_total", labels, c.fast_skipped);
        metrics.set_gauge("fabric_virtual_cycles", labels, c.virtual_cycles as f64);
        metrics.set_gauge("fabric_executed_ratio", labels, c.executed_ratio);
        metrics.set_gauge(
            "fabric_virtual_req_per_mcycle",
            labels,
            c.virtual_req_per_mcycle,
        );
        metrics.set_gauge("fabric_h2c_share_error", labels, c.h2c_share_error);
    }
    metrics.set_gauge("fabric_config_cache_hit_rate", &[], cache_hit_rate);
    metrics.inc("fabric_icap_cycles_elided_total", &[], cache_warm.elided);
    std::fs::write("BENCH_fabric_metrics.json", metrics.to_json())
        .expect("write BENCH_fabric_metrics.json");
    println!("  wrote BENCH_fabric_metrics.json");
    claims.finish();
}
