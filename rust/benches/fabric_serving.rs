//! End-to-end fabric serving bench: the busy-period horizon fast-path
//! (DESIGN.md §12) vs the cycle-by-cycle oracle on a diurnal serving
//! trace, at 4 and 16 ports, with ICAP-timed installs on every request.
//! Emits `BENCH_fabric.json` — executed-vs-skipped cycle accounting and
//! requests/sec — so the perf trajectory has an end-to-end number next
//! to `BENCH_crossbar.json`, plus `BENCH_fabric_metrics.json`, the same
//! accounting as a schema-versioned metrics snapshot (DESIGN.md §14).
//!
//! The two modes are cycle-exact (pinned by
//! `tests/fastpath_equivalence.rs`); this bench cross-checks that on
//! its own trace — identical outputs, costs and total virtual cycles —
//! and claims the fast path executes >= 5x fewer ticks than the oracle.
//!
//! ```bash
//! cargo bench --bench fabric_serving            # full run
//! cargo bench --bench fabric_serving -- --smoke # CI smoke mode
//! ```

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::manager::ElasticManager;
use elastic_fpga::metrics::CycleThroughput;
use elastic_fpga::telemetry::MetricsRegistry;
use elastic_fpga::workload::{diurnal_tenants, generate_profiled, TraceEvent};

/// One mode's run over a trace: total wall seconds, executed/skipped
/// fabric cycles, total virtual cycles, and the per-request service
/// summaries used for the oracle cross-check.
struct ModeRun {
    wall_s: f64,
    executed_cycles: u64,
    skipped_cycles: u64,
    virtual_cycles: u64,
    /// `(app_id, fabric cycles, reconfig cycles, output checksum)`.
    summaries: Vec<(u32, u64, u64, u32)>,
}

fn run_mode(cfg: &SystemConfig, trace: &[TraceEvent], fast: bool) -> ModeRun {
    let mut mgr = ElasticManager::new(cfg.clone(), None);
    mgr.use_icap = true;
    mgr.fast_path = fast;
    let mut summaries = Vec::with_capacity(trace.len());
    let t0 = std::time::Instant::now();
    for ev in trace {
        let rep = mgr.execute(&ev.request).expect("request failed");
        assert!(rep.verified, "fabric output failed golden verification");
        let checksum = rep
            .output
            .iter()
            .fold(0u32, |acc, &w| acc.rotate_left(1) ^ w);
        summaries.push((
            rep.app_id,
            rep.timeline.fabric_cycles,
            rep.timeline.reconfig_cycles,
            checksum,
        ));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let fabric = mgr.fabric();
    ModeRun {
        wall_s,
        executed_cycles: fabric.executed_cycles,
        skipped_cycles: fabric.skipped_cycles,
        virtual_cycles: fabric.now(),
        summaries,
    }
}

struct CaseResult {
    name: &'static str,
    ports: usize,
    requests: usize,
    oracle_executed: u64,
    fast_executed: u64,
    fast_skipped: u64,
    virtual_cycles: u64,
    executed_ratio: f64,
    /// Wall-clock-independent throughput: requests per million virtual
    /// cycles, identical in both modes (they share the virtual clock).
    virtual_req_per_mcycle: f64,
    oracle_req_per_s: f64,
    fast_req_per_s: f64,
}

fn run_case(
    name: &'static str,
    ports: usize,
    tenants: u32,
    requests: usize,
    claims: &mut harness::Claims,
) -> CaseResult {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.fabric.num_ports = ports;
    cfg.fabric.num_pr_regions = ports - 1;
    // A realistic-but-benchable partial bitstream (64K words -> 128K
    // cycles of ICAP streaming per region) keeps the oracle runnable
    // while leaving the horizon plenty to skip.
    cfg.manager.bitstream_bytes = 256 * 1024;
    // Diurnal anti-phase tenants on the Fig-5 pipeline: the serving
    // trace the autoscaler line of work uses.
    let specs = diurnal_tenants(tenants, 40.0, 400.0, 60.0, 64);
    let trace = generate_profiled(&specs, 0xD1_0B_5EED, requests);

    let fast = run_mode(&cfg, &trace, true);
    let oracle = run_mode(&cfg, &trace, false);

    // Oracle cross-check: byte-identical service summaries (outputs,
    // fabric cycles, reconfig cycles) and total virtual time.
    claims.check(
        fast.summaries == oracle.summaries,
        &format!("{name}: fast-path summaries byte-identical to oracle"),
    );
    claims.check(
        fast.virtual_cycles == oracle.virtual_cycles,
        &format!("{name}: same virtual cycle count in both modes"),
    );
    claims.check(
        fast.executed_cycles + fast.skipped_cycles == fast.virtual_cycles,
        &format!("{name}: executed + skipped accounts every cycle"),
    );
    let ratio = oracle.executed_cycles as f64 / fast.executed_cycles.max(1) as f64;
    claims.check(
        ratio >= 5.0,
        &format!("{name}: fast path executes >= 5x fewer cycles ({ratio:.1}x)"),
    );

    let mut tp = CycleThroughput::new();
    tp.record_items(requests as u64, 0);
    tp.set_cycles(fast.virtual_cycles);
    let result = CaseResult {
        name,
        ports,
        requests,
        oracle_executed: oracle.executed_cycles,
        fast_executed: fast.executed_cycles,
        fast_skipped: fast.skipped_cycles,
        virtual_cycles: fast.virtual_cycles,
        executed_ratio: ratio,
        virtual_req_per_mcycle: tp.items_per_mcycle(),
        oracle_req_per_s: requests as f64 / oracle.wall_s.max(1e-9),
        fast_req_per_s: requests as f64 / fast.wall_s.max(1e-9),
    };
    println!(
        "  {:<10} oracle {:>12} cc executed | fast {:>9} cc executed + {:>12} skipped ({:>6.1}x) | {:>8.0} vs {:>8.0} req/s",
        result.name,
        result.oracle_executed,
        result.fast_executed,
        result.fast_skipped,
        result.executed_ratio,
        result.oracle_req_per_s,
        result.fast_req_per_s,
    );
    result
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let requests = if smoke { 24 } else { 200 };
    harness::section(if smoke {
        "fabric serving: horizon fast-path vs oracle (smoke)"
    } else {
        "fabric serving: horizon fast-path vs oracle"
    });

    let mut claims = harness::Claims::new();
    let cases = [
        run_case("ports4", 4, 3, requests, &mut claims),
        run_case("ports16", 16, 6, requests, &mut claims),
    ];

    // Machine-readable trajectory point.  Cycle counts are
    // deterministic; the req/s rates are wall-clock and vary run to run
    // (the committed baseline is compared structurally — see
    // python/tools/bench_diff.py).
    let mut json = String::from("{\n  \"bench\": \"fabric_serving\",\n");
    json.push_str(&format!("  \"requests_per_case\": {requests},\n"));
    json.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"ports\": {}, \"requests\": {}, \
             \"oracle_executed_cycles\": {}, \"fast_executed_cycles\": {}, \
             \"fast_skipped_cycles\": {}, \"virtual_cycles\": {}, \
             \"executed_ratio\": {:.2}, \"virtual_req_per_mcycle\": {:.3}, \
             \"oracle_requests_per_s\": {:.1}, \
             \"fast_requests_per_s\": {:.1}}}{}\n",
            c.name,
            c.ports,
            c.requests,
            c.oracle_executed,
            c.fast_executed,
            c.fast_skipped,
            c.virtual_cycles,
            c.executed_ratio,
            c.virtual_req_per_mcycle,
            c.oracle_req_per_s,
            c.fast_req_per_s,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_fabric.json", &json).expect("write BENCH_fabric.json");
    println!("  wrote BENCH_fabric.json");

    // Companion metrics snapshot (DESIGN.md §14): the deterministic
    // cycle accounting as a schema-versioned labeled registry, so the
    // export path is exercised by CI on every bench run.
    let mut metrics = MetricsRegistry::new();
    for c in &cases {
        let labels: &[(&str, &str)] = &[("case", c.name)];
        metrics.inc("fabric_requests_total", labels, c.requests as u64);
        metrics.inc("fabric_oracle_executed_cycles_total", labels, c.oracle_executed);
        metrics.inc("fabric_fast_executed_cycles_total", labels, c.fast_executed);
        metrics.inc("fabric_fast_skipped_cycles_total", labels, c.fast_skipped);
        metrics.set_gauge("fabric_virtual_cycles", labels, c.virtual_cycles as f64);
        metrics.set_gauge("fabric_executed_ratio", labels, c.executed_ratio);
        metrics.set_gauge(
            "fabric_virtual_req_per_mcycle",
            labels,
            c.virtual_req_per_mcycle,
        );
    }
    std::fs::write("BENCH_fabric_metrics.json", metrics.to_json())
        .expect("write BENCH_fabric_metrics.json");
    println!("  wrote BENCH_fabric_metrics.json");
    claims.finish();
}
