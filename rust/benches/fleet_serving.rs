//! Fleet sharded-execution bench: the trace-driven fleet simulator at
//! 1/2/4/8 execution threads over 8 fabrics (DESIGN.md §13).  Emits
//! `BENCH_fleet.json` — requests/sec and virtual makespan per thread
//! count — so the scaling trajectory has a committed number next to
//! `BENCH_fabric.json`, plus `BENCH_fleet_metrics.json`, the serial
//! run's schema-versioned metrics snapshot (DESIGN.md §14).
//!
//! The workload is deliberately shape-heavy (32 payload sizes x 4 stage
//! chains ≈ 128 distinct request shapes): the first-of-shape
//! cycle-accurate oracle measurements are the expensive part of a fleet
//! run, and they are exactly what the sharded executor fans out across
//! the boards.  Every thread count must reproduce the single-threaded
//! schedule byte for byte, and the all-oracle mode (every request
//! cycle-by-cycle) is cross-checked at 1 vs 4 threads.
//!
//! ```bash
//! cargo bench --bench fleet_serving            # full run
//! cargo bench --bench fleet_serving -- --smoke # CI smoke mode
//! ```

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::fleet::{AdmissionPolicy, Fleet, FleetReport};
use elastic_fpga::metrics::CycleThroughput;
use elastic_fpga::modules::ModuleKind;
use elastic_fpga::workload::{generate_count, TraceEvent, WorkloadSpec};

const FABRICS: usize = 8;

/// High-cardinality serving mix: many distinct shapes, so oracle
/// measurements dominate and shard.
fn high_cardinality_spec() -> WorkloadSpec {
    WorkloadSpec {
        rate_per_s: 800.0,
        duration_s: 3600.0,
        size_mix: (1..=32usize).map(|i| (8 * i, 1.0)).collect(),
        stage_mix: vec![
            (ModuleKind::pipeline().to_vec(), 0.4),
            (vec![ModuleKind::Multiplier], 0.25),
            (vec![ModuleKind::HammingEncoder], 0.2),
            (
                vec![ModuleKind::HammingEncoder, ModuleKind::HammingDecoder],
                0.15,
            ),
        ],
        tenants: 4,
    }
}

struct Run {
    wall_s: f64,
    report: FleetReport,
}

fn run_fleet(
    cfg: &SystemConfig,
    trace: &[TraceEvent],
    threads: usize,
    fast: bool,
    batch_window: usize,
) -> Run {
    let mut fleet =
        Fleet::launch(FABRICS, cfg, None, AdmissionPolicy::LeastLoaded, fast);
    fleet.execution_threads = threads;
    fleet.batch_window = batch_window;
    let t0 = std::time::Instant::now();
    let report = fleet.run_trace(trace).expect("fleet run failed");
    Run { wall_s: t0.elapsed().as_secs_f64(), report }
}

/// A fleet run with ICAP-timed installs and the resident-module
/// configuration cache at `cache` regions per board (DESIGN.md §16).
fn run_fleet_cached(
    cfg: &SystemConfig,
    trace: &[TraceEvent],
    threads: usize,
    cache: usize,
) -> Run {
    let mut cfg = cfg.clone();
    cfg.manager.config_cache_regions = cache;
    let mut fleet =
        Fleet::launch(FABRICS, &cfg, None, AdmissionPolicy::LeastLoaded, true);
    fleet.execution_threads = threads;
    fleet.set_use_icap(true);
    let t0 = std::time::Instant::now();
    let report = fleet.run_trace(trace).expect("fleet run failed");
    Run { wall_s: t0.elapsed().as_secs_f64(), report }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let requests = if smoke { 160 } else { 4000 };
    let oracle_requests = if smoke { 60 } else { 300 };
    harness::section(if smoke {
        "fleet serving: sharded execution scaling (smoke)"
    } else {
        "fleet serving: sharded execution scaling"
    });

    let mut claims = harness::Claims::new();
    let cfg = SystemConfig::paper_defaults();
    let spec = high_cardinality_spec();
    let trace = generate_count(&spec, 0xF1EE7, requests);

    let thread_counts = [1usize, 2, 4, 8];
    let mut runs: Vec<(usize, Run)> = Vec::new();
    for &t in &thread_counts {
        let r = run_fleet(&cfg, &trace, t, true, 1);
        println!(
            "  threads {t}: {} requests in {:.3}s ({:>8.0} req/s) | \
             makespan {:.1} ms | {} oracle runs, {} cache hits",
            requests,
            r.wall_s,
            requests as f64 / r.wall_s.max(1e-9),
            cfg.cycles_to_ms(r.report.makespan_cycles),
            r.report.oracle_runs,
            r.report.fast_path_hits,
        );
        runs.push((t, r));
    }

    // Determinism-merge contract: every thread count reproduces the
    // single-threaded schedule exactly.
    let base = &runs[0].1;
    for (t, r) in &runs[1..] {
        claims.check(
            r.report.outcomes == base.report.outcomes,
            &format!("threads {t}: outcomes byte-identical to serial"),
        );
        claims.check(
            r.report.makespan_cycles == base.report.makespan_cycles
                && r.report.oracle_runs == base.report.oracle_runs
                && r.report.fast_path_hits == base.report.fast_path_hits,
            &format!("threads {t}: makespan and counters match serial"),
        );
    }

    // Oracle cross-check: with the fast-path off every request runs
    // cycle-by-cycle; 1 vs 4 threads must still agree exactly.
    let otrace = generate_count(&spec, 0xF1EE7, oracle_requests);
    let o1 = run_fleet(&cfg, &otrace, 1, false, 1);
    let o4 = run_fleet(&cfg, &otrace, 4, false, 1);
    claims.check(
        o1.report.outcomes == o4.report.outcomes
            && o1.report.makespan_cycles == o4.report.makespan_cycles,
        "oracle mode byte-identical at 1 vs 4 threads",
    );
    println!(
        "  oracle cross-check: {} requests, 1 vs 4 threads ({:.3}s vs {:.3}s)",
        oracle_requests, o1.wall_s, o4.wall_s
    );

    // Same-app coalescing (DESIGN.md §15): a bursty trace (each arrival
    // duplicated 3x) under batch windows 1 and 4.  Followers skip the
    // per-request reconfiguration round, so the batched run's virtual
    // makespan can only improve while the schedule stays deterministic
    // and thread-identical.
    let bursty: Vec<TraceEvent> = trace
        .iter()
        .flat_map(|e| std::iter::repeat(e.clone()).take(3))
        .collect();
    let b1 = run_fleet(&cfg, &bursty, 1, true, 1);
    let b4 = run_fleet(&cfg, &bursty, 1, true, 4);
    let b4_threads = run_fleet(&cfg, &bursty, 4, true, 4);
    claims.check(
        b1.report.batches_formed == 0,
        "window 1 never coalesces (legacy schedule)",
    );
    claims.check(
        b4.report.batched_requests > 0,
        "window 4 coalesces followers on a bursty trace",
    );
    claims.check(
        b4.report.outcomes == b4_threads.report.outcomes
            && b4.report.batches_formed == b4_threads.report.batches_formed,
        "batched schedule byte-identical at 1 vs 4 threads",
    );
    claims.check(
        b4.report.makespan_cycles <= b1.report.makespan_cycles,
        "coalescing never stretches the virtual makespan",
    );
    let batch_runs = [(1usize, &b1), (4usize, &b4)];
    for (w, r) in &batch_runs {
        println!(
            "  batch window {w}: {} requests | makespan {:.1} ms | \
             {} batches, {} coalesced",
            bursty.len(),
            cfg.cycles_to_ms(r.report.makespan_cycles),
            r.report.batches_formed,
            r.report.batched_requests,
        );
    }

    // Resident-module configuration cache (DESIGN.md §16): the same
    // repeated-shape bursty trace with ICAP-timed installs, cold
    // (cache off) vs warm (3 regions per board).  Warm leaders rebind
    // parked modules instead of restreaming bitstreams, so whole ICAP
    // programmings are elided from the virtual schedule — which must
    // stay deterministic and thread-identical.
    let cold = run_fleet_cached(&cfg, &bursty, 1, 0);
    let warm = run_fleet_cached(&cfg, &bursty, 1, 3);
    let warm_threads = run_fleet_cached(&cfg, &bursty, 4, 3);
    claims.check(
        cold.report.config_cache_hits == 0
            && cold.report.icap_cycles_elided == 0,
        "cache off: nothing elided (legacy ICAP schedule)",
    );
    claims.check(
        warm.report.config_cache_hits > 0
            && warm.report.icap_cycles_elided > 0,
        "warm cache elides ICAP restreams on repeated shapes",
    );
    claims.check(
        warm.report.makespan_cycles < cold.report.makespan_cycles,
        "elision shortens the virtual makespan",
    );
    claims.check(
        warm.report.outcomes == warm_threads.report.outcomes
            && warm.report.config_cache_hits
                == warm_threads.report.config_cache_hits
            && warm.report.icap_cycles_elided
                == warm_threads.report.icap_cycles_elided,
        "warm schedule byte-identical at 1 vs 4 threads",
    );
    let cache_runs = [("cold", 0usize, &cold), ("warm", 3usize, &warm)];
    for (name, regions, r) in &cache_runs {
        let hits = r.report.config_cache_hits;
        let misses = r.report.config_cache_misses;
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        println!(
            "  config cache {name} ({regions} regions): makespan {:.1} ms | \
             hit rate {:.3} | {} ICAP cycles elided",
            cfg.cycles_to_ms(r.report.makespan_cycles),
            hit_rate,
            r.report.icap_cycles_elided,
        );
    }

    // Kernel zoo (DESIGN.md §17): two config-declared table kernels in
    // the serving mix.  Zoo shapes must memoize, shard, and batch
    // exactly like seed shapes, and the sharded schedule must stay
    // byte-identical with the extra registry entries live.
    let zoo = {
        let decls = SystemConfig::parse(
            "[kernels.bench-zoo-mul5]\nop = \"mul\"\noperand = 5\n\
             latency_base = 2\nlatency_per_word = 1\n\n\
             [kernels.bench-zoo-rot11]\nop = \"rotl\"\noperand = 11\n\
             mask = 0x00FFFFFF\nlatency_base = 3\n",
        )
        .expect("zoo declarations parse")
        .kernels;
        elastic_fpga::kernels::install_declared(&decls, None)
            .expect("zoo declarations validate")
    };
    let zoo_trace =
        generate_count(&WorkloadSpec::zoo_mix(&zoo), 0x200, requests);
    let z1 = run_fleet(&cfg, &zoo_trace, 1, true, 4);
    let z4 = run_fleet(&cfg, &zoo_trace, 4, true, 4);
    let zoo_requests = zoo_trace
        .iter()
        .filter(|e| e.request.stages.iter().any(|k| zoo.contains(k)))
        .count();
    let zoo_fraction = zoo_requests as f64 / zoo_trace.len() as f64;
    claims.check(
        z1.report.completed == zoo_trace.len() as u64,
        "zoo trace fully served",
    );
    claims.check(zoo_requests > 0, "zoo mix emits zoo-kernel requests");
    claims.check(
        z1.report.outcomes == z4.report.outcomes
            && z1.report.makespan_cycles == z4.report.makespan_cycles,
        "zoo schedule byte-identical at 1 vs 4 threads",
    );
    println!(
        "  kernel zoo: {} requests ({zoo_requests} on zoo kernels) | \
         makespan {:.1} ms | {} distinct shapes",
        zoo_trace.len(),
        cfg.cycles_to_ms(z1.report.makespan_cycles),
        z1.report.oracle_runs,
    );

    if !smoke {
        // Wall-clock scaling claim only in the full run: CI smoke boxes
        // are too small/noisy to pin a speedup.
        let wall_1 = base.wall_s;
        let wall_4 = runs.iter().find(|(t, _)| *t == 4).expect("4-thread run").1.wall_s;
        let speedup = wall_1 / wall_4.max(1e-9);
        claims.check(
            speedup >= 1.5,
            &format!("4 threads >= 1.5x faster than 1 ({speedup:.2}x)"),
        );
    }

    // Machine-readable trajectory point.  The req/s rates are wall-clock
    // and vary run to run (the committed baseline is compared
    // structurally — see python/tools/bench_diff.py).
    let mut json = String::from("{\n  \"bench\": \"fleet_serving\",\n");
    json.push_str(&format!("  \"fabrics\": {FABRICS},\n"));
    json.push_str(&format!("  \"requests\": {requests},\n"));
    json.push_str(&format!(
        "  \"distinct_shapes_measured\": {},\n",
        base.report.oracle_runs
    ));
    json.push_str(&format!(
        "  \"oracle_crosscheck_requests\": {oracle_requests},\n"
    ));
    json.push_str("  \"cases\": [\n");
    let wall_1 = base.wall_s;
    for (i, (t, r)) in runs.iter().enumerate() {
        // Virtual throughput (requests per million fabric cycles) is
        // wall-clock-independent: identical at every thread count, so it
        // is the number a baseline diff can actually pin.
        let mut tp = CycleThroughput::new();
        tp.record_items(r.report.completed, 0);
        tp.set_cycles(r.report.makespan_cycles);
        json.push_str(&format!(
            "    {{\"name\": \"threads{}\", \"threads\": {}, \
             \"requests_per_s\": {:.1}, \"wall_s\": {:.4}, \
             \"speedup_vs_serial\": {:.2}, \"makespan_ms\": {:.2}, \
             \"virtual_req_per_mcycle\": {:.3}, \
             \"oracle_runs\": {}, \"fast_path_hits\": {}}}{}\n",
            t,
            t,
            requests as f64 / r.wall_s.max(1e-9),
            r.wall_s,
            wall_1 / r.wall_s.max(1e-9),
            cfg.cycles_to_ms(r.report.makespan_cycles),
            tp.items_per_mcycle(),
            r.report.oracle_runs,
            r.report.fast_path_hits,
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"batching\": [\n");
    for (i, (w, r)) in batch_runs.iter().enumerate() {
        let mut tp = CycleThroughput::new();
        tp.record_items(r.report.completed, 0);
        tp.set_cycles(r.report.makespan_cycles);
        let efficiency = r.report.batched_requests as f64
            / (r.report.completed.max(1)) as f64;
        json.push_str(&format!(
            "    {{\"name\": \"window{}\", \"batch_window\": {}, \
             \"requests\": {}, \"requests_per_s\": {:.1}, \
             \"makespan_ms\": {:.2}, \"virtual_req_per_mcycle\": {:.3}, \
             \"batches\": {}, \"batched_requests\": {}, \
             \"batch_efficiency\": {:.4}}}{}\n",
            w,
            w,
            bursty.len(),
            bursty.len() as f64 / r.wall_s.max(1e-9),
            cfg.cycles_to_ms(r.report.makespan_cycles),
            tp.items_per_mcycle(),
            r.report.batches_formed,
            r.report.batched_requests,
            efficiency,
            if i + 1 < batch_runs.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"config_cache\": [\n");
    for (i, (name, regions, r)) in cache_runs.iter().enumerate() {
        let hits = r.report.config_cache_hits;
        let misses = r.report.config_cache_misses;
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"cache_regions\": {}, \
             \"requests\": {}, \"requests_per_s\": {:.1}, \
             \"makespan_ms\": {:.2}, \"config_cache_hit_rate\": {:.4}, \
             \"icap_cycles_elided\": {}}}{}\n",
            name,
            regions,
            bursty.len(),
            bursty.len() as f64 / r.wall_s.max(1e-9),
            cfg.cycles_to_ms(r.report.makespan_cycles),
            hit_rate,
            r.report.icap_cycles_elided,
            if i + 1 < cache_runs.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"kernel_zoo\": [\n");
    {
        let mut tp = CycleThroughput::new();
        tp.record_items(z1.report.completed, 0);
        tp.set_cycles(z1.report.makespan_cycles);
        json.push_str(&format!(
            "    {{\"name\": \"zoo\", \"requests\": {}, \
             \"requests_per_s\": {:.1}, \"makespan_ms\": {:.2}, \
             \"virtual_req_per_mcycle\": {:.3}, \
             \"zoo_stage_fraction\": {:.4}, \"distinct_shapes\": {}}}\n",
            zoo_trace.len(),
            zoo_trace.len() as f64 / z1.wall_s.max(1e-9),
            cfg.cycles_to_ms(z1.report.makespan_cycles),
            tp.items_per_mcycle(),
            zoo_fraction,
            z1.report.oracle_runs,
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_fleet.json", &json).expect("write BENCH_fleet.json");
    println!("  wrote BENCH_fleet.json");

    // Companion metrics snapshot (DESIGN.md §14): the serial run's full
    // per-tenant registry, schema-versioned for bench_diff --validate.
    let mut metrics = base.report.metrics(&cfg);
    std::fs::write("BENCH_fleet_metrics.json", metrics.to_json())
        .expect("write BENCH_fleet_metrics.json");
    println!("  wrote BENCH_fleet_metrics.json");
    claims.finish();
}
