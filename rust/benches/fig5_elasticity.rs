//! Bench: reproduce **Fig 5** — execution time of the 16 KB
//! multiplier->encoder->decoder use case as PR regions become available
//! (3 cases, 10 repetitions each, like the paper).
//!
//! Prints the same series the paper plots and checks the claims:
//! case1 > case2 > case3, with the calibrated endpoints within 10% of
//! 16.9 ms / 10.87 ms.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::experiments;

fn main() {
    let cfg = SystemConfig::paper_defaults();
    harness::section("Fig 5 — resource elasticity execution time (16 KB, 10 reps)");

    // PJRT runtime if artifacts exist (on-server stages then run for real).
    let runtime = elastic_fpga::runtime::RuntimeThread::spawn(
        elastic_fpga::DEFAULT_ARTIFACT_DIR,
    )
    .ok();
    if runtime.is_some() {
        println!("  (on-server stages execute through PJRT)");
    } else {
        println!("  (artifacts missing; on-server stages use the golden model)");
    }

    let t0 = std::time::Instant::now();
    let rows = experiments::fig5(
        &cfg,
        runtime.as_ref().map(|t| t.handle()),
        4096,
        10,
    )
    .expect("fig5 run failed");
    println!("{}", experiments::fig5_render(&rows));
    println!("  (bench wall time: {:.2?})", t0.elapsed());

    let mut claims = harness::Claims::new();
    claims.check(
        rows[0].mean_ms > rows[1].mean_ms && rows[1].mean_ms > rows[2].mean_ms,
        "execution time decreases as PR regions become available",
    );
    claims.check(
        (rows[0].mean_ms - 16.9).abs() / 16.9 < 0.10,
        "case 1 within 10% of the paper's 16.9 ms",
    );
    claims.check(
        (rows[2].mean_ms - 10.87).abs() / 10.87 < 0.10,
        "case 3 within 10% of the paper's 10.87 ms",
    );
    claims.check(
        rows.iter().all(|r| r.fabric_ms < 1.0),
        "fabric streaming is not the bottleneck (sub-ms at 250 MHz)",
    );
    claims.finish();
}
