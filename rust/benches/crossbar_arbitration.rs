//! Crossbar arbitration hot-path bench: cycles simulated per second for
//! the WRR decision pipeline at 4 and 16 ports, contended (every master
//! fighting over one slave — arbitration dominates) and uncontended
//! (distinct destinations — pure datapath).  Also emits
//! `BENCH_crossbar.json` so the perf trajectory is machine-readable
//! across PRs.
//!
//! ```bash
//! cargo bench --bench crossbar_arbitration            # full run
//! cargo bench --bench crossbar_arbitration -- --smoke # CI smoke mode
//! ```

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::CrossbarConfig;
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::qos::BandwidthPlan;
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::wishbone::Job;

/// One measured case: `ports` crossbar, all masters busy for `cycles`.
fn run_case(ports: usize, contended: bool, cycles: u64) -> f64 {
    let cfg = CrossbarConfig {
        grant_timeout: u64::MAX / 2,
        ..CrossbarConfig::default()
    };
    let mut xb = Crossbar::new(ports, cfg);
    let all = if ports == 32 { u32::MAX } else { (1u32 << ports) - 1 };
    for m in 0..ports {
        xb.set_allowed_slaves(m, all);
    }
    // An app-aware rotation (every port its own app) exercises the
    // permuted-walk path the bandwidth plane added to the arbiter.
    let mut plan = BandwidthPlan::new();
    let mut port_app = vec![None; ports];
    for p in 1..ports {
        plan.set_share((p - 1) as u32, (1000 / ports) as u32).unwrap();
        port_app[p] = Some((p - 1) as u32);
    }
    let prog = plan.compile(&port_app, 64, 8).unwrap();
    for (m, &b) in prog.budgets.iter().enumerate() {
        for s in 0..ports {
            xb.set_allowed_packages(s, m, b).unwrap();
        }
    }
    xb.set_rotation_order(&prog.rotation).unwrap();
    for m in 0..ports {
        let dest = if contended { 0 } else { (m + 1) % ports } as u32;
        xb.push_job(m, Job::new(encode_onehot(dest), vec![0xA5; 1 << 20], m as u32));
    }
    let mut clk = Clock::new();
    let t0 = std::time::Instant::now();
    for _ in 0..cycles {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..ports {
            xb.drain_rx(s, usize::MAX);
        }
    }
    cycles as f64 / t0.elapsed().as_secs_f64() / 1e6
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let cycles: u64 = if smoke { 50_000 } else { 1_000_000 };
    harness::section(if smoke {
        "crossbar arbitration hot path (smoke)"
    } else {
        "crossbar arbitration hot path"
    });

    let cases = [
        ("xbar4_contended", 4usize, true),
        ("xbar4_uncontended", 4, false),
        ("xbar16_contended", 16, true),
        ("xbar16_uncontended", 16, false),
    ];
    let mut rows = Vec::new();
    for (name, ports, contended) in cases {
        let mcps = run_case(ports, contended, cycles);
        println!("  {name:<24} {mcps:>8.2} Mcycles/s");
        rows.push((name, mcps));
    }

    // Floors: half the post-optimization rates observed in CI-class
    // containers; generous enough to absorb machine noise, tight enough
    // to catch a hot-path regression.  Skipped in smoke mode (CI boxes
    // share cores).
    if !smoke {
        let mut claims = harness::Claims::new();
        for &(name, mcps) in &rows {
            claims.check(mcps > 0.5, &format!("{name} above 0.5 Mcycles/s"));
        }
        claims.finish();
    }

    // Machine-readable trajectory point.
    let mut json = String::from("{\n  \"bench\": \"crossbar_arbitration\",\n");
    json.push_str(&format!("  \"cycles_per_case\": {cycles},\n"));
    json.push_str("  \"results\": [\n");
    for (i, (name, mcps)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"mcycles_per_s\": {mcps:.3}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_crossbar.json", &json)
        .expect("write BENCH_crossbar.json");
    println!("  wrote BENCH_crossbar.json");
}
