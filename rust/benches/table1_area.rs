//! Bench: reproduce **Table I** — area usage of all components on the
//! XCKU115, from the calibrated area model, including the derived %
//! columns and the totals row.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::area::{self, table1};
use elastic_fpga::fabric::DeviceModel;

fn main() {
    harness::section("Table I — area usage of all components");
    println!("{}", elastic_fpga::experiments::table1_render());

    let device = DeviceModel::kcu1500_prototype();
    let mut claims = harness::Claims::new();

    // Totals row matches the paper (composite row excluded from totals).
    let mut total_luts = 0u64;
    let mut total_ffs = 0u64;
    let mut total_brams = 0.0f64;
    for (_, a, counted) in table1::ROWS {
        if counted {
            total_luts += a.luts;
            total_ffs += a.ffs;
            total_brams += a.brams;
        }
    }
    claims.check(total_luts == 36_348, "total LUTs = 36,348");
    claims.check(total_ffs == 36_948, "total FFs = 36,948");
    claims.check(total_brams == 89.0, "total BRAMs = 89");

    // Percentages quoted in §V.F.
    claims.check(
        (device.lut_pct(total_luts) - 5.47).abs() < 0.02,
        "whole-system LUT utilization ~5.47%",
    );
    claims.check(
        (device.lut_pct(table1::WB_CROSSBAR.luts) - 0.07).abs() < 0.005,
        "WB crossbar = 0.07% of device LUTs",
    );
    claims.check(
        (device.lut_pct(table1::XDMA_IP.luts) - 5.04).abs() < 0.01,
        "XDMA IP = 5.04% of device LUTs",
    );

    // §V.F: averaged interface numbers.
    let avg_master_luts = (table1::WB_MASTER_IF.luts + 196) / 2 >= 196;
    let _ = avg_master_luts;
    claims.check(
        table1::WB_CROSSBAR.luts == 475 && table1::WB_CROSSBAR.ffs == 60,
        "crossbar row = 475 LUT / 60 FF (the headline area)",
    );

    // Register-file scaling (§V.G: 3 registers per extra PR region).
    claims.check(
        area::regfile_registers(3) == 20 && area::regfile_registers(4) == 23,
        "register file grows by 3 registers per PR region",
    );
    claims.finish();
}
