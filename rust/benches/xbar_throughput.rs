//! Perf bench (EXPERIMENTS.md §Perf): raw simulator throughput.
//!
//! Not a paper figure — this is the L3 hot path the performance pass
//! optimizes: cycles simulated per second for (a) a saturated 4x4
//! crossbar, (b) the full fabric streaming the 16 KB pipeline, and
//! (c) end-to-end manager executions per second.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::{CrossbarConfig, SystemConfig};
use elastic_fpga::crossbar::Crossbar;
use elastic_fpga::manager::{AppRequest, ElasticManager};
use elastic_fpga::sim::{Clock, Tick};
use elastic_fpga::util::onehot::encode_onehot;
use elastic_fpga::util::SplitMix64;
use elastic_fpga::wishbone::Job;

const XBAR_CYCLES: u64 = 1_000_000;

fn saturated_crossbar_mcps() -> f64 {
    // All four masters stream big jobs to rotating destinations.
    let mut cfg = CrossbarConfig::default();
    cfg.grant_timeout = u64::MAX / 2;
    let mut xb = Crossbar::new(4, cfg);
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    for m in 0..4usize {
        xb.push_job(
            m,
            Job::new(encode_onehot(((m + 1) % 4) as u32), vec![0xA5; 1 << 20], 0),
        );
    }
    let mut clk = Clock::new();
    let t0 = std::time::Instant::now();
    for _ in 0..XBAR_CYCLES {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..4 {
            xb.drain_rx(s, usize::MAX);
        }
    }
    XBAR_CYCLES as f64 / t0.elapsed().as_secs_f64() / 1e6
}

fn fabric_pipeline_mcps() -> (f64, u64) {
    let cfg = SystemConfig::paper_defaults();
    let mut mgr = ElasticManager::new(cfg, None);
    let mut rng = SplitMix64::new(3);
    let mut data = vec![0u32; 4096];
    rng.fill_u32(&mut data);
    let t0 = std::time::Instant::now();
    let rep = mgr.execute(&AppRequest::pipeline(0, data)).unwrap();
    let cycles = rep.timeline.fabric_cycles;
    (cycles as f64 / t0.elapsed().as_secs_f64() / 1e6, cycles)
}

fn main() {
    harness::section("L3 perf — simulator throughput (the optimization target)");

    let mcps = saturated_crossbar_mcps();
    println!("  saturated 4x4 crossbar: {mcps:.1} Mcycles/s");

    let (fmcps, fcycles) = fabric_pipeline_mcps();
    println!(
        "  full fabric, 16 KB pipeline: {fmcps:.1} Mcycles/s ({fcycles} cycles/run)"
    );

    let mut s = harness::bench("manager.execute(16 KB pipeline)", 2, 10, || {
        let cfg = SystemConfig::paper_defaults();
        let mut mgr = ElasticManager::new(cfg, None);
        let mut rng = SplitMix64::new(4);
        let mut data = vec![0u32; 4096];
        rng.fill_u32(&mut data);
        mgr.execute(&AppRequest::pipeline(0, data)).unwrap()
    });
    harness::report(&mut s);

    // Regression floors (half of the measured post-optimization rates;
    // see EXPERIMENTS.md §Perf).
    let mut claims = harness::Claims::new();
    claims.check(mcps > 5.0, "crossbar sim >= 5 Mcycles/s");
    claims.check(fmcps > 2.0, "fabric sim >= 2 Mcycles/s");
    claims.finish();
}
