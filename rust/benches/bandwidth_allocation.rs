//! Bench: reproduce **§V.D** — dynamic bandwidth allocation.  The 16 KB
//! stream runs through 1..=3 accelerators at 16 vs 128 packages per
//! grant (programmed through the Table-III register file); larger
//! budgets amortize arbitration and must improve completion, more so
//! with more accelerators chained.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::experiments;

fn main() {
    harness::section("§V.D — dynamic bandwidth allocation (16 vs 128 packages)");
    let t0 = std::time::Instant::now();
    let rows = experiments::bandwidth_sweep(4096).expect("sweep failed");
    println!("{}", experiments::bandwidth_render(&rows));
    println!("  (bench wall time: {:.2?})", t0.elapsed());

    let imps = experiments::bandwidth_improvements(&rows);
    let mut claims = harness::Claims::new();
    for (accs, imp) in &imps {
        claims.check(
            *imp > 0.0,
            &format!("{accs} accelerator(s): 128-package budget is faster ({imp:.2}%)"),
        );
    }
    claims.check(
        imps[2].1 > imps[0].1,
        "improvement grows with the number of chained accelerators \
         (paper: 5.24% at 1 acc -> 6% at 3 accs)",
    );
    claims.check(
        imps.iter().all(|(_, imp)| *imp < 35.0),
        "improvement stays single/low-double digit (arbitration amortization, \
         not a different algorithm)",
    );
    claims.finish();
}
