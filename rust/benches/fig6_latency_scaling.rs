//! Bench: reproduce **Fig 6** — worst-case latency vs the number of PR
//! regions (all N-1 masters target one slave, 8 data words each).
//!
//! The paper's claim: "the worst case latency increase would be linear".
//! We sweep the crossbar generically from 3 to 16 ports, compare the
//! simulated worst case against the analytic 12(N-2)+4, and check
//! linearity (constant 12 cc/port slope).

#[path = "harness.rs"]
mod harness;

use elastic_fpga::config::SystemConfig;
use elastic_fpga::experiments;

fn main() {
    let cfg = SystemConfig::paper_defaults();
    harness::section("Fig 6 — number of PRs vs worst-case latency");

    let ports: Vec<usize> = vec![3, 4, 5, 6, 8, 10, 12, 14, 16];
    let t0 = std::time::Instant::now();
    let rows = experiments::fig6(&cfg, &ports);
    println!("{}", experiments::fig6_render(&rows));
    println!("  (bench wall time: {:.2?})", t0.elapsed());

    let mut claims = harness::Claims::new();
    claims.check(
        rows.iter().all(|r| r.worst_time_to_grant == r.analytic_ttg),
        "simulated worst case equals the analytic 12(N-2)+4 at every point",
    );
    // Linearity: successive differences per added port are exactly 12.
    let mut linear = true;
    for w in rows.windows(2) {
        let dp = (w[1].ports - w[0].ports) as u64;
        if w[1].worst_time_to_grant - w[0].worst_time_to_grant != 12 * dp {
            linear = false;
        }
    }
    claims.check(linear, "latency grows linearly at 12 cc per extra PR region");
    claims.check(
        rows.iter().find(|r| r.ports == 4).map(|r| r.worst_time_to_grant)
            == Some(28),
        "the 4-port point is the paper's 28 cc worst case",
    );
    claims.finish();
}
