//! Bench: reproduce **Table II** — comparison with the NoC of [16] and
//! the E-WB shared bus of [21]: area, power, and the measured
//! request-completion latency of each interconnect on the same 8-word
//! workload.
//!
//! Claims checked (paper §I + §V.G): 61% fewer LUTs and 95% fewer FFs
//! than the NoC, 80x less power, 69% fewer cycles per request, +48.6%
//! LUTs / -46.4% FFs vs 4x E-WB.

#[path = "harness.rs"]
mod harness;

use elastic_fpga::area;
use elastic_fpga::baselines::noc;
use elastic_fpga::config::SystemConfig;
use elastic_fpga::experiments;

fn main() {
    let cfg = SystemConfig::paper_defaults();
    harness::section("Table II — comparison with existing work");
    println!("{}", experiments::table2_render(&cfg));

    let h = area::headline_claims();
    let overhead = experiments::comm_overhead(&cfg);
    let noc_cc = noc::uncontended_completion(2, 8);

    let mut claims = harness::Claims::new();
    claims.check(
        (h.lut_savings_vs_noc_pct - 61.0).abs() < 1.0,
        "61% fewer LUTs than the 2x2 NoC",
    );
    claims.check(
        (h.ff_savings_vs_noc_pct - 95.0).abs() < 0.5,
        "95% fewer FFs than the 2x2 NoC",
    );
    claims.check(
        (h.power_ratio_vs_noc - 80.0).abs() < 0.1,
        "80x less power than the NoC",
    );
    claims.check(
        (h.lut_overhead_vs_ewb_pct - 48.6).abs() < 0.5,
        "+48.6% LUTs vs 4x E-WB shared bus",
    );
    claims.check(
        (h.ff_savings_vs_ewb_pct - 46.4).abs() < 0.5,
        "-46.4% FFs vs 4x E-WB shared bus",
    );
    claims.check(
        overhead.best_completion_8 == 13 && noc_cc == 22,
        "8-word request: 13 cc on the crossbar vs 22 cc on the NoC",
    );
    let adv = (noc_cc as f64 - overhead.best_completion_8 as f64)
        / overhead.best_completion_8 as f64
        * 100.0;
    claims.check((adv - 69.0).abs() < 1.0, "69% fewer cycles per request");
    claims.finish();

    // Micro-bench: simulator throughput for the three interconnects.
    harness::section("simulator micro-bench (same 8-word request)");
    let mut s = harness::bench("crossbar 8-word request sim", 10, 200, || {
        experiments::comm_overhead(&cfg)
    });
    harness::report(&mut s);
}
