//! Shared bench harness (criterion is unavailable offline — DESIGN.md
//! §7): warmup + timed repetitions with mean/p50/p99, plus table
//! printing helpers.  Each bench binary (`harness = false`) drives this.

use std::time::{Duration, Instant};

/// One measurement series.
pub struct Series {
    pub name: String,
    samples_ns: Vec<u128>,
}

impl Series {
    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u128>() as f64 / self.samples_ns.len() as f64
    }

    pub fn percentile_ns(&mut self, q: f64) -> u128 {
        if self.samples_ns.is_empty() {
            return 0;
        }
        self.samples_ns.sort_unstable();
        let rank = ((q * self.samples_ns.len() as f64).ceil() as usize)
            .clamp(1, self.samples_ns.len());
        self.samples_ns[rank - 1]
    }
}

/// Time `f` with `warmup` unmeasured and `reps` measured repetitions.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Series {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos());
    }
    Series { name: name.to_string(), samples_ns: samples }
}

/// Render a series line.
pub fn report(series: &mut Series) {
    let mean = Duration::from_nanos(series.mean_ns() as u64);
    let p50 = Duration::from_nanos(series.percentile_ns(0.50) as u64);
    let p99 = Duration::from_nanos(series.percentile_ns(0.99) as u64);
    println!(
        "  {:<44} mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}",
        series.name, mean, p50, p99
    );
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Assert helper that prints rather than panicking mid-bench, then
/// panics at the end if any claim failed.
pub struct Claims {
    failed: Vec<String>,
}

impl Claims {
    pub fn new() -> Self {
        Self { failed: Vec::new() }
    }

    pub fn check(&mut self, ok: bool, what: &str) {
        if ok {
            println!("  claim OK: {what}");
        } else {
            println!("  claim FAILED: {what}");
            self.failed.push(what.to_string());
        }
    }

    pub fn finish(self) {
        if !self.failed.is_empty() {
            panic!("failed claims: {:?}", self.failed);
        }
    }
}
