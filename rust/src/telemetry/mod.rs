//! Cycle-stamped telemetry plane (DESIGN.md §14): shell-wide tracing,
//! per-tenant metrics export, and a bounded flight recorder.
//!
//! Everything here is stamped from **virtual clocks** (fabric cycles,
//! lane clocks, fleet admission cycles), never from wall time, so a
//! trace captured at `--threads 8` is byte-identical to the serial one
//! (`tests/fleet_threads.rs` pins this).  The three pieces:
//!
//! * [`Tracer`] — an `Option`-free enum-dispatch sink.  Disabled mode
//!   is a single discriminant branch per emission site; event
//!   construction goes through [`Tracer::emit_with`] so the disabled
//!   path never even builds the event.
//! * [`FlightRecorder`] — a bounded ring that always keeps the last N
//!   events; [`Tracer::dump`] snapshots the window into a
//!   [`FlightDump`] when an [`crate::ElasticError`] or app-error spill
//!   needs its preceding context.
//! * [`MetricsRegistry`] — labeled counters / gauges / cycle
//!   histograms, snapshotted to Prometheus-style text and JSON (both
//!   carry [`SCHEMA_VERSION`]).
//!
//! [`RequestSpan`] decomposes one request's latency into queue-wait /
//! bridge / ICAP / fabric / CPU cycles such that the components sum
//! *exactly* to [`crate::fleet::service_cycles`] — the cuts are
//! differences of monotone rounded cumulative sums, so no cycle is
//! ever lost to independent rounding.

use std::collections::BTreeMap;

use crate::config::SystemConfig;
use crate::metrics::CycleRecorder;
use crate::timing::CostBreakdown;
use crate::wishbone::WbError;

/// Version stamped into every metric / trace JSON snapshot.  Bump when
/// the snapshot shape changes; `python/tools/bench_diff.py --validate`
/// rejects snapshots without it.
pub const SCHEMA_VERSION: u32 = 1;

/// Default per-lane flight-recorder window (events kept per lane).
pub const DEFAULT_FLIGHT_CAPACITY: usize = 64;

/// Stable snake_case name for a Wishbone error, for trace labels.
pub fn wb_error_name(err: WbError) -> &'static str {
    match err {
        WbError::InvalidDestination => "invalid_destination",
        WbError::GrantTimeout => "grant_timeout",
        WbError::AckTimeout => "ack_timeout",
        WbError::PortInReset => "port_in_reset",
        WbError::ContractViolation => "contract_violation",
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One structured, cycle-stamped event.  Every variant's `cycle` comes
/// from the emitter's virtual clock — fabric cycle, lane clock, or
/// fleet admission cycle — never wall time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// Fleet/server admitted a request onto a node.
    RequestAdmitted { cycle: u64, app: u32, node: usize },
    /// Request had to wait behind the node's busy horizon.
    RequestQueued { cycle: u64, app: u32, node: usize, wait_cycles: u64 },
    /// Service started.
    RequestDispatched { cycle: u64, app: u32, node: usize },
    /// Service finished.
    RequestCompleted { cycle: u64, app: u32, node: usize, service_cycles: u64 },
    /// ICAP began streaming a partial bitstream into a region.
    IcapStart { cycle: u64, app: u32, region: usize, words: u64 },
    /// ICAP finished (ok or aborted).
    IcapDone { cycle: u64, app: u32, region: usize, ok: bool },
    /// Crossbar arbiter granted a master to a slave port.
    GrantIssued { cycle: u64, app: u32, slave: usize, master: usize, words: u32 },
    /// Isolation mask converted a stray access into a typed error.
    ViolationMasked { cycle: u64, app: u32, port: usize, err: &'static str },
    /// Fleet moved a request off its preferred node.
    Migration { cycle: u64, app: u32, from: usize, to: usize },
    /// Autoscaler grew an app by `regions` regions on `node`.
    ScaleUp { cycle: u64, node: usize, regions: usize },
    /// Autoscaler retired `regions` regions on `node`.
    ScaleDown { cycle: u64, node: usize, regions: usize },
    /// A bandwidth plan was lowered onto the arbiter.
    PlanApplied { cycle: u64, masters: usize },
    /// Fleet/server coalesced `size` same-app requests into one fabric
    /// stream (DESIGN.md §15); emitted only for batches of 2+.
    BatchFormed { cycle: u64, app: u32, node: usize, size: usize },
    /// The bridge's plan-weighted H2C descriptor scheduler granted an
    /// app's burst onto the crossbar (DESIGN.md §15).
    H2cScheduled { cycle: u64, app: u32, channel: usize, words: usize },
    /// A configuration-cache hit rebound a resident region to `app`
    /// through the register file alone, eliding `cycles` ICAP cycles
    /// (DESIGN.md §16).
    IcapElided { cycle: u64, app: u32, node: usize, region: usize, cycles: u64 },
    /// LRU eviction blanked a resident region's cached `kind`
    /// (DESIGN.md §16).
    CacheEvict { cycle: u64, node: usize, region: usize, kind: &'static str },
}

impl TraceEvent {
    /// The virtual-clock stamp.
    pub fn cycle(&self) -> u64 {
        match *self {
            TraceEvent::RequestAdmitted { cycle, .. }
            | TraceEvent::RequestQueued { cycle, .. }
            | TraceEvent::RequestDispatched { cycle, .. }
            | TraceEvent::RequestCompleted { cycle, .. }
            | TraceEvent::IcapStart { cycle, .. }
            | TraceEvent::IcapDone { cycle, .. }
            | TraceEvent::GrantIssued { cycle, .. }
            | TraceEvent::ViolationMasked { cycle, .. }
            | TraceEvent::Migration { cycle, .. }
            | TraceEvent::ScaleUp { cycle, .. }
            | TraceEvent::ScaleDown { cycle, .. }
            | TraceEvent::PlanApplied { cycle, .. }
            | TraceEvent::BatchFormed { cycle, .. }
            | TraceEvent::H2cScheduled { cycle, .. }
            | TraceEvent::IcapElided { cycle, .. }
            | TraceEvent::CacheEvict { cycle, .. } => cycle,
        }
    }

    /// Stable kind tag for JSON / labels.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::RequestAdmitted { .. } => "request_admitted",
            TraceEvent::RequestQueued { .. } => "request_queued",
            TraceEvent::RequestDispatched { .. } => "request_dispatched",
            TraceEvent::RequestCompleted { .. } => "request_completed",
            TraceEvent::IcapStart { .. } => "icap_start",
            TraceEvent::IcapDone { .. } => "icap_done",
            TraceEvent::GrantIssued { .. } => "grant_issued",
            TraceEvent::ViolationMasked { .. } => "violation_masked",
            TraceEvent::Migration { .. } => "migration",
            TraceEvent::ScaleUp { .. } => "scale_up",
            TraceEvent::ScaleDown { .. } => "scale_down",
            TraceEvent::PlanApplied { .. } => "plan_applied",
            TraceEvent::BatchFormed { .. } => "batch_formed",
            TraceEvent::H2cScheduled { .. } => "h2c_scheduled",
            TraceEvent::IcapElided { .. } => "icap_elided",
            TraceEvent::CacheEvict { .. } => "cache_evict",
        }
    }

    /// One-line JSON object for this event.
    pub fn to_json(&self) -> String {
        let head = |cycle: u64| format!("{{\"kind\": \"{}\", \"cycle\": {cycle}", self.kind());
        match *self {
            TraceEvent::RequestAdmitted { cycle, app, node } => {
                format!("{}, \"app\": {app}, \"node\": {node}}}", head(cycle))
            }
            TraceEvent::RequestQueued { cycle, app, node, wait_cycles } => format!(
                "{}, \"app\": {app}, \"node\": {node}, \"wait_cycles\": {wait_cycles}}}",
                head(cycle)
            ),
            TraceEvent::RequestDispatched { cycle, app, node } => {
                format!("{}, \"app\": {app}, \"node\": {node}}}", head(cycle))
            }
            TraceEvent::RequestCompleted { cycle, app, node, service_cycles } => format!(
                "{}, \"app\": {app}, \"node\": {node}, \"service_cycles\": {service_cycles}}}",
                head(cycle)
            ),
            TraceEvent::IcapStart { cycle, app, region, words } => format!(
                "{}, \"app\": {app}, \"region\": {region}, \"words\": {words}}}",
                head(cycle)
            ),
            TraceEvent::IcapDone { cycle, app, region, ok } => format!(
                "{}, \"app\": {app}, \"region\": {region}, \"ok\": {ok}}}",
                head(cycle)
            ),
            TraceEvent::GrantIssued { cycle, app, slave, master, words } => format!(
                "{}, \"app\": {app}, \"slave\": {slave}, \"master\": {master}, \
                 \"words\": {words}}}",
                head(cycle)
            ),
            TraceEvent::ViolationMasked { cycle, app, port, err } => format!(
                "{}, \"app\": {app}, \"port\": {port}, \"err\": \"{err}\"}}",
                head(cycle)
            ),
            TraceEvent::Migration { cycle, app, from, to } => format!(
                "{}, \"app\": {app}, \"from\": {from}, \"to\": {to}}}",
                head(cycle)
            ),
            TraceEvent::ScaleUp { cycle, node, regions } => format!(
                "{}, \"node\": {node}, \"regions\": {regions}}}",
                head(cycle)
            ),
            TraceEvent::ScaleDown { cycle, node, regions } => format!(
                "{}, \"node\": {node}, \"regions\": {regions}}}",
                head(cycle)
            ),
            TraceEvent::PlanApplied { cycle, masters } => {
                format!("{}, \"masters\": {masters}}}", head(cycle))
            }
            TraceEvent::BatchFormed { cycle, app, node, size } => format!(
                "{}, \"app\": {app}, \"node\": {node}, \"size\": {size}}}",
                head(cycle)
            ),
            TraceEvent::H2cScheduled { cycle, app, channel, words } => format!(
                "{}, \"app\": {app}, \"channel\": {channel}, \"words\": {words}}}",
                head(cycle)
            ),
            TraceEvent::IcapElided { cycle, app, node, region, cycles } => format!(
                "{}, \"app\": {app}, \"node\": {node}, \"region\": {region}, \
                 \"cycles\": {cycles}}}",
                head(cycle)
            ),
            TraceEvent::CacheEvict { cycle, node, region, kind } => format!(
                "{}, \"node\": {node}, \"region\": {region}, \"kind\": \"{kind}\"}}",
                head(cycle)
            ),
        }
    }
}

/// Serialize an event stream to a JSON document with a schema version.
pub fn trace_to_json(events: &[TraceEvent]) -> String {
    let mut out = format!(
        "{{\n  \"schema_version\": {SCHEMA_VERSION},\n  \"events\": [\n"
    );
    for (i, ev) in events.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&ev.to_json());
        out.push_str(if i + 1 < events.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// A dump of the flight-recorder window, taken at an error site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlightDump {
    /// Why the dump was taken (error text, spill context).
    pub context: String,
    /// The window at dump time, oldest event first.
    pub window: Vec<TraceEvent>,
}

impl FlightDump {
    /// Human-readable rendering (one event per line).
    pub fn render(&self) -> String {
        let mut out = format!("flight dump ({}): {} events\n", self.context, self.window.len());
        for ev in &self.window {
            out.push_str(&format!("  [{:>10}] {}\n", ev.cycle(), ev.to_json()));
        }
        out
    }

    /// JSON object with the context and window.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"context\": \"{}\", \"window\": [",
            json_escape(&self.context)
        );
        for (i, ev) in self.window.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&ev.to_json());
        }
        out.push_str("]}");
        out
    }
}

/// Bounded ring that always keeps the last `capacity` events pushed.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    buf: Vec<TraceEvent>,
    head: usize,
    capacity: usize,
    dumps: Vec<FlightDump>,
}

impl FlightRecorder {
    /// A recorder keeping the last `capacity` events (min 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self { buf: Vec::with_capacity(capacity), head: 0, capacity, dumps: Vec::new() }
    }

    /// Window size.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push one event, evicting the oldest once full.
    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Current window, oldest event first.
    pub fn window(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// Snapshot the window into a [`FlightDump`] tagged with `context`.
    pub fn dump(&mut self, context: &str) {
        let dump = FlightDump { context: context.to_string(), window: self.window() };
        self.dumps.push(dump);
    }

    /// Dumps taken so far, in order.
    pub fn dumps(&self) -> &[FlightDump] {
        &self.dumps
    }

    /// Drain the collected dumps.
    pub fn take_dumps(&mut self) -> Vec<FlightDump> {
        std::mem::take(&mut self.dumps)
    }
}

/// Full in-order event log plus a trailing flight window.
#[derive(Debug, Clone)]
pub struct TraceLog {
    events: Vec<TraceEvent>,
    flight: FlightRecorder,
}

/// `Option`-free enum-dispatch trace sink.  [`Tracer::Off`] costs one
/// discriminant branch per emission site; there is no `dyn` call and
/// (via [`Tracer::emit_with`]) no event construction on the disabled
/// path.
#[derive(Debug, Clone, Default)]
pub enum Tracer {
    /// Disabled: every emission is a single branch, nothing is stored.
    #[default]
    Off,
    /// Flight-recorder only: keeps the last N events, no full log.
    Flight(FlightRecorder),
    /// Full log (plus a flight window for dumps).
    Full(Box<TraceLog>),
}

impl Tracer {
    /// Disabled sink.
    pub fn off() -> Self {
        Tracer::Off
    }

    /// Flight-recorder-only sink keeping the last `capacity` events.
    pub fn flight(capacity: usize) -> Self {
        Tracer::Flight(FlightRecorder::new(capacity))
    }

    /// Full event log (flight window sized [`DEFAULT_FLIGHT_CAPACITY`]).
    pub fn full() -> Self {
        Tracer::Full(Box::new(TraceLog {
            events: Vec::new(),
            flight: FlightRecorder::new(DEFAULT_FLIGHT_CAPACITY),
        }))
    }

    /// Whether emissions are recorded at all.
    #[inline]
    pub fn enabled(&self) -> bool {
        !matches!(self, Tracer::Off)
    }

    /// Emit an already-built event.
    #[inline]
    pub fn emit(&mut self, ev: TraceEvent) {
        match self {
            Tracer::Off => {}
            Tracer::Flight(ring) => ring.push(ev),
            Tracer::Full(log) => {
                log.flight.push(ev.clone());
                log.events.push(ev);
            }
        }
    }

    /// Emit lazily: `build` only runs when the sink is enabled, so the
    /// disabled path never constructs the event.
    #[inline]
    pub fn emit_with(&mut self, build: impl FnOnce() -> TraceEvent) {
        if self.enabled() {
            self.emit(build());
        }
    }

    /// The full event log (empty unless [`Tracer::Full`]).
    pub fn events(&self) -> &[TraceEvent] {
        match self {
            Tracer::Full(log) => &log.events,
            _ => &[],
        }
    }

    /// Drain the full event log (empty unless [`Tracer::Full`]).
    pub fn take_events(&mut self) -> Vec<TraceEvent> {
        match self {
            Tracer::Full(log) => std::mem::take(&mut log.events),
            _ => Vec::new(),
        }
    }

    /// Snapshot the current flight window into a dump (no-op when off).
    pub fn dump(&mut self, context: &str) {
        match self {
            Tracer::Off => {}
            Tracer::Flight(ring) => ring.dump(context),
            Tracer::Full(log) => log.flight.dump(context),
        }
    }

    /// Dumps taken so far.
    pub fn dumps(&self) -> &[FlightDump] {
        match self {
            Tracer::Off => &[],
            Tracer::Flight(ring) => ring.dumps(),
            Tracer::Full(log) => log.flight.dumps(),
        }
    }

    /// Drain the collected dumps.
    pub fn take_dumps(&mut self) -> Vec<FlightDump> {
        match self {
            Tracer::Off => Vec::new(),
            Tracer::Flight(ring) => ring.take_dumps(),
            Tracer::Full(log) => log.flight.take_dumps(),
        }
    }
}

/// Per-request latency decomposition in fabric cycles.
///
/// The service components (`bridge + icap + fabric + cpu`) sum
/// *exactly* to [`crate::fleet::service_cycles`] for the same cost:
/// each cut point is an independently rounded cumulative sum clamped
/// monotone, and the components are differences of those cuts, so the
/// total is the final cut by construction — the same float expression
/// `service_cycles` evaluates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestSpan {
    /// Cycles spent queued behind the node's busy horizon.
    pub queue_wait_cycles: u64,
    /// PCIe bridge crossings (descriptor rounds + bandwidth).
    pub bridge_cycles: u64,
    /// ICAP partial-reconfiguration streaming.
    pub icap_cycles: u64,
    /// Fabric streaming/compute.
    pub fabric_cycles: u64,
    /// On-server CPU stages.
    pub cpu_cycles: u64,
}

impl RequestSpan {
    /// Decompose a timing-model cost (plus a known queue wait) into a
    /// span whose service components sum exactly to
    /// [`crate::fleet::service_cycles`]`(cfg, cost)`.
    pub fn decompose(
        cfg: &SystemConfig,
        cost: &CostBreakdown,
        queue_wait_cycles: u64,
    ) -> Self {
        let rate = cfg.fabric.clock_mhz * 1000.0;
        // Bit-identical to fleet::service_cycles: same expression.
        let total = ((cost.total_ms() + cost.reconfig_ms) * rate).round() as u64;
        let cut = |ms: f64| (ms * rate).round() as u64;
        let c_bridge = cut(cost.pcie_ms).min(total);
        let c_icap = cut(cost.pcie_ms + cost.reconfig_ms).clamp(c_bridge, total);
        let c_fabric =
            cut(cost.pcie_ms + cost.reconfig_ms + cost.fabric_ms).clamp(c_icap, total);
        Self {
            queue_wait_cycles,
            bridge_cycles: c_bridge,
            icap_cycles: c_icap - c_bridge,
            fabric_cycles: c_fabric - c_icap,
            cpu_cycles: total - c_fabric,
        }
    }

    /// Service cycles: bridge + ICAP + fabric + CPU.
    pub fn total_cycles(&self) -> u64 {
        self.bridge_cycles + self.icap_cycles + self.fabric_cycles + self.cpu_cycles
    }

    /// End-to-end cycles including queue wait.
    pub fn end_to_end_cycles(&self) -> u64 {
        self.queue_wait_cycles + self.total_cycles()
    }
}

/// A metric identity: name + sorted label set.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    /// Metric name (without the `efpga_` export prefix).
    pub name: String,
    /// Label pairs, as given.
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        Self {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        }
    }

    fn label_suffix(&self) -> String {
        if self.labels.is_empty() {
            return String::new();
        }
        let body: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", json_escape(v)))
            .collect();
        format!("{{{}}}", body.join(","))
    }

    fn labels_json(&self) -> String {
        let body: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)))
            .collect();
        format!("{{{}}}", body.join(", "))
    }
}

/// Labeled counters, gauges, and cycle histograms with deterministic
/// (BTreeMap-ordered) Prometheus-style and JSON snapshots.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<MetricKey, u64>,
    gauges: BTreeMap<MetricKey, f64>,
    histograms: BTreeMap<MetricKey, CycleRecorder>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to a labeled counter.
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], by: u64) {
        *self.counters.entry(MetricKey::new(name, labels)).or_insert(0) += by;
    }

    /// Set a labeled gauge.
    pub fn set_gauge(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.gauges.insert(MetricKey::new(name, labels), value);
    }

    /// Record one sample into a labeled cycle histogram.
    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], cycles: u64) {
        self.histograms
            .entry(MetricKey::new(name, labels))
            .or_insert_with(CycleRecorder::new)
            .record(cycles);
    }

    /// Read a counter back (0 if absent) — mainly for tests.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.counters.get(&MetricKey::new(name, labels)).copied().unwrap_or(0)
    }

    /// Prometheus-style text exposition.  All metric names get an
    /// `efpga_` prefix.  Takes `&mut self` because histogram
    /// percentiles maintain an internal sorted cache.
    pub fn to_prometheus(&mut self) -> String {
        let mut out = String::new();
        for (key, value) in &self.counters {
            out.push_str(&format!(
                "# TYPE efpga_{} counter\nefpga_{}{} {}\n",
                key.name,
                key.name,
                key.label_suffix(),
                value
            ));
        }
        for (key, value) in &self.gauges {
            out.push_str(&format!(
                "# TYPE efpga_{} gauge\nefpga_{}{} {}\n",
                key.name,
                key.name,
                key.label_suffix(),
                value
            ));
        }
        for (key, rec) in self.histograms.iter_mut() {
            let base = format!("efpga_{}", key.name);
            out.push_str(&format!("# TYPE {base} summary\n"));
            for (q, v) in [(0.5, rec.percentile(0.5)), (0.99, rec.percentile(0.99))] {
                let mut labels = key.labels.clone();
                labels.push(("quantile".to_string(), format!("{q}")));
                let qkey = MetricKey { name: key.name.clone(), labels };
                out.push_str(&format!("{base}{} {}\n", qkey.label_suffix(), v));
            }
            out.push_str(&format!(
                "{base}_count{} {}\n",
                key.label_suffix(),
                rec.count()
            ));
        }
        out
    }

    /// JSON snapshot carrying [`SCHEMA_VERSION`].  Takes `&mut self`
    /// for the same histogram-percentile reason as
    /// [`MetricsRegistry::to_prometheus`].
    pub fn to_json(&mut self) -> String {
        let mut out = format!("{{\n  \"schema_version\": {SCHEMA_VERSION},\n");
        out.push_str("  \"counters\": [\n");
        let n = self.counters.len();
        for (i, (key, value)) in self.counters.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"labels\": {}, \"value\": {}}}{}\n",
                json_escape(&key.name),
                key.labels_json(),
                value,
                if i + 1 < n { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"gauges\": [\n");
        let n = self.gauges.len();
        for (i, (key, value)) in self.gauges.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"labels\": {}, \"value\": {}}}{}\n",
                json_escape(&key.name),
                key.labels_json(),
                value,
                if i + 1 < n { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"histograms\": [\n");
        let n = self.histograms.len();
        for (i, (key, rec)) in self.histograms.iter_mut().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"labels\": {}, \"count\": {}, \
                 \"mean\": {}, \"p50\": {}, \"p99\": {}, \"max\": {}}}{}\n",
                json_escape(&key.name),
                key.labels_json(),
                rec.count(),
                rec.mean(),
                rec.percentile(0.5),
                rec.percentile(0.99),
                rec.max(),
                if i + 1 < n { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64) -> TraceEvent {
        TraceEvent::RequestAdmitted { cycle, app: 1, node: 0 }
    }

    #[test]
    fn flight_ring_keeps_last_n_in_order() {
        let mut ring = FlightRecorder::new(4);
        for c in 0..10 {
            ring.push(ev(c));
        }
        let window = ring.window();
        assert_eq!(window.len(), 4);
        let cycles: Vec<u64> = window.iter().map(|e| e.cycle()).collect();
        assert_eq!(cycles, vec![6, 7, 8, 9]);
    }

    #[test]
    fn flight_ring_partial_fill_is_record_order() {
        let mut ring = FlightRecorder::new(8);
        for c in [3u64, 1, 4] {
            ring.push(ev(c));
        }
        let cycles: Vec<u64> = ring.window().iter().map(|e| e.cycle()).collect();
        assert_eq!(cycles, vec![3, 1, 4]);
    }

    #[test]
    fn disabled_tracer_never_builds_events() {
        let mut t = Tracer::off();
        let mut built = false;
        t.emit_with(|| {
            built = true;
            ev(0)
        });
        assert!(!built, "disabled tracer must not construct events");
        assert!(t.events().is_empty());
        assert!(t.dumps().is_empty());
    }

    #[test]
    fn full_tracer_logs_and_dumps() {
        let mut t = Tracer::full();
        for c in 0..3 {
            t.emit(ev(c));
        }
        assert_eq!(t.events().len(), 3);
        t.dump("unit test");
        assert_eq!(t.dumps().len(), 1);
        assert_eq!(t.dumps()[0].window.len(), 3);
        assert_eq!(t.dumps()[0].context, "unit test");
        let drained = t.take_events();
        assert_eq!(drained.len(), 3);
        assert!(t.events().is_empty());
    }

    #[test]
    fn span_components_sum_to_service_cycles() {
        let cfg = SystemConfig::paper_defaults();
        let cost = CostBreakdown {
            pcie_ms: 0.777,
            fabric_ms: 1.333,
            cpu_ms: 2.111,
            reconfig_ms: 0.499,
        };
        let span = RequestSpan::decompose(&cfg, &cost, 17);
        assert_eq!(span.total_cycles(), crate::fleet::service_cycles(&cfg, &cost));
        assert_eq!(span.end_to_end_cycles(), span.total_cycles() + 17);
    }

    #[test]
    fn registry_snapshots_are_deterministic_and_versioned() {
        let mut reg = MetricsRegistry::new();
        reg.inc("requests_total", &[("app", "1")], 3);
        reg.inc("requests_total", &[("app", "0")], 1);
        reg.set_gauge("queue_depth", &[("lane", "0")], 2.0);
        reg.observe("service_cycles", &[("app", "1")], 100);
        reg.observe("service_cycles", &[("app", "1")], 300);
        let text = reg.to_prometheus();
        // BTreeMap ordering: app="0" before app="1".
        let p0 = text.find("efpga_requests_total{app=\"0\"} 1").unwrap();
        let p1 = text.find("efpga_requests_total{app=\"1\"} 3").unwrap();
        assert!(p0 < p1);
        assert!(text.contains("efpga_queue_depth{lane=\"0\"} 2"));
        assert!(text.contains("efpga_service_cycles_count{app=\"1\"} 2"));
        let json = reg.to_json();
        assert!(json.contains(&format!("\"schema_version\": {SCHEMA_VERSION}")));
        assert_eq!(json, reg.to_json(), "snapshot must be reproducible");
        assert_eq!(reg.counter("requests_total", &[("app", "1")]), 3);
    }

    #[test]
    fn trace_json_has_schema_version() {
        let doc = trace_to_json(&[ev(5)]);
        assert!(doc.contains(&format!("\"schema_version\": {SCHEMA_VERSION}")));
        assert!(doc.contains("\"kind\": \"request_admitted\""));
    }
}
