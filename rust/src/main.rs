//! `elastic-fpga` — leader binary: CLI over the experiment drivers and
//! the serving loop.  See `elastic-fpga --help` / [`elastic_fpga::cli`].

use elastic_fpga::autoscale::{self, PolicyKind};
use elastic_fpga::cli::{Cli, USAGE};
use elastic_fpga::config::SystemConfig;
use elastic_fpga::experiments;
use elastic_fpga::fleet::{AdmissionPolicy, Fleet};
use elastic_fpga::manager::AppRequest;
use elastic_fpga::metrics::{LatencyRecorder, Throughput};
use elastic_fpga::runtime::RuntimeThread;
use elastic_fpga::server::{call, Server};
use elastic_fpga::util::SplitMix64;
use elastic_fpga::workload::{generate_count, WorkloadSpec};
use elastic_fpga::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        println!("{USAGE}");
        return;
    }
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn load_config(cli: &Cli) -> Result<SystemConfig> {
    let cfg = match cli.flags.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path))?,
        None => SystemConfig::paper_defaults(),
    };
    overlay_plan(cli, cfg)
}

/// Apply the `--plan app=share,...` flag over the configured `[qos]`
/// table (the CLI face of the per-app bandwidth plane).
fn overlay_plan(cli: &Cli, mut cfg: SystemConfig) -> Result<SystemConfig> {
    if let Some(spec) = cli.flags.get("plan") {
        let plan = elastic_fpga::qos::BandwidthPlan::parse(spec)?;
        cfg.qos.shares = plan.shares().to_vec();
    }
    Ok(cfg)
}

fn load_runtime(cli: &Cli) -> Result<Option<RuntimeThread>> {
    if cli.bool_or("no-pjrt", false)? {
        return Ok(None);
    }
    let dir = cli.str_or("artifacts", elastic_fpga::DEFAULT_ARTIFACT_DIR);
    let rt = RuntimeThread::spawn(dir)?;
    rt.handle().preload_all()?;
    Ok(Some(rt))
}

/// Install every declared kernel — `[kernels.<name>]` tables from the
/// config overlay plus a `--kernels FILE` overlay — into the process
/// registry before any subsystem resolves stage names (DESIGN.md §17).
/// A name declared in both places is refused rather than silently
/// shadowed; the artifact manifest is only opened when some declaration
/// actually binds an artifact.
fn install_kernels(cli: &Cli, cfg: &SystemConfig) -> Result<()> {
    let mut decls = cfg.kernels.clone();
    if let Some(path) = cli.flags.get("kernels") {
        let extra =
            elastic_fpga::config::SystemConfig::load_kernel_decls(std::path::Path::new(path))?;
        for d in extra {
            if decls.iter().any(|have| have.name == d.name) {
                return Err(elastic_fpga::ElasticError::Config(format!(
                    "kernel '{}' is declared both in the config overlay and \
                     in --kernels {path}; declare each kernel once",
                    d.name
                )));
            }
            decls.push(d);
        }
    }
    if decls.is_empty() {
        return Ok(());
    }
    let manifest;
    let manifest_ref = if decls.iter().any(|d| d.artifact.is_some()) {
        let dir = cli.str_or("artifacts", elastic_fpga::DEFAULT_ARTIFACT_DIR);
        manifest = elastic_fpga::runtime::ArtifactManifest::load(
            &std::path::Path::new(&dir).join("manifest.json"),
        )?;
        Some(&manifest)
    } else {
        None
    };
    let ids = elastic_fpga::kernels::install_declared(&decls, manifest_ref)?;
    println!(
        "installed {} declared kernel(s): {}",
        ids.len(),
        ids.iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
    );
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    let cfg = load_config(&cli)?;
    install_kernels(&cli, &cfg)?;
    match cli.command.as_str() {
        "quickstart" => quickstart(&cli, &cfg),
        "serve" => serve(&cli, &cfg),
        "fleet" => fleet_sim(&cli, &cfg),
        "autoscale" => autoscale_cmd(&cli),
        "fig5" => {
            let runtime = load_runtime(&cli)?;
            let reps = cli.usize_or("reps", 10)?;
            let rows = experiments::fig5(&cfg, runtime.as_ref().map(|t| t.handle()), 4096, reps)?;
            print!("{}", experiments::fig5_render(&rows));
            Ok(())
        }
        "fig6" => {
            let rows = experiments::fig6(&cfg, &[3, 4, 6, 8, 10, 12, 14, 16]);
            print!("{}", experiments::fig6_render(&rows));
            Ok(())
        }
        "table1" => {
            print!("{}", experiments::table1_render());
            Ok(())
        }
        "table2" => {
            print!("{}", experiments::table2_render(&cfg));
            Ok(())
        }
        "bandwidth" => {
            let words = cli.usize_or("words", 4096)?;
            let rows = experiments::bandwidth_sweep(words)?;
            print!("{}", experiments::bandwidth_render(&rows));
            Ok(())
        }
        "overhead" => {
            let r = experiments::comm_overhead(&cfg);
            print!("{}", experiments::overhead_render(&r));
            Ok(())
        }
        other => Err(elastic_fpga::ElasticError::Config(format!(
            "unknown subcommand '{other}'\n{USAGE}"
        ))),
    }
}

/// Parse `--batch-window`, holding the CLI to the same 1..=64 bound the
/// config loader enforces (`server.batch_window`, DESIGN.md §15).
fn batch_window_flag(cli: &Cli, default: usize) -> Result<usize> {
    let w = cli.usize_or("batch-window", default)?;
    if !(1..=64).contains(&w) {
        return Err(elastic_fpga::ElasticError::Config(format!(
            "--batch-window {w} must be 1..=64"
        )));
    }
    Ok(w)
}

/// Parse `--config-cache`, bounding the resident-module cache capacity
/// (`manager.config_cache_regions`, DESIGN.md §16) to the board's PR
/// region count — a larger cache could never fill.
fn config_cache_flag(
    cli: &Cli,
    cfg: &SystemConfig,
    default: usize,
) -> Result<usize> {
    let n = cli.usize_or("config-cache", default)?;
    if n > cfg.fabric.num_pr_regions {
        return Err(elastic_fpga::ElasticError::Config(format!(
            "--config-cache {n} exceeds the board's {} PR regions",
            cfg.fabric.num_pr_regions
        )));
    }
    Ok(n)
}

fn quickstart(cli: &Cli, cfg: &SystemConfig) -> Result<()> {
    let runtime = load_runtime(cli)?;
    println!("elastic-fpga quickstart — 16 KB through mult->enc->dec");
    let server = Server::start(cfg.clone(), runtime.as_ref().map(|t| t.handle()));
    let mut rng = SplitMix64::new(1);
    let mut data = vec![0u32; 4096];
    rng.fill_u32(&mut data);
    let report = call(&server, AppRequest::pipeline(0, data))?;
    println!(
        "done: {} words, {} FPGA stages, verified={}, modelled time {:.2} ms \
         (pcie {:.2} + fabric {:.3} + cpu {:.2})",
        report.output.len(),
        report.fpga_stages,
        report.verified,
        report.cost.total_ms(),
        report.cost.pcie_ms,
        report.cost.fabric_ms,
        report.cost.cpu_ms
    );
    server.shutdown();
    Ok(())
}

fn fleet_sim(cli: &Cli, cfg: &SystemConfig) -> Result<()> {
    let fabrics = cli.usize_or("fabrics", 8)?;
    let requests = cli.usize_or("requests", 10_000)?;
    let seed = cli.usize_or("seed", 1)? as u64;
    let oracle = cli.bool_or("oracle", false)?;
    let threads = cli.usize_or("threads", 1)?.max(1);
    let policy_name = cli.str_or("policy", "least");
    let policy = AdmissionPolicy::parse(&policy_name).ok_or_else(|| {
        elastic_fpga::ElasticError::Config(format!(
            "--policy expects least|sticky|bandwidth|weighted, \
             got '{policy_name}'"
        ))
    })?;
    let batch_window = batch_window_flag(cli, 1)?;
    let batch_cycles = cli.usize_or("batch-cycles", 0)? as u64;
    let mut cfg = cfg.clone();
    cfg.manager.config_cache_regions =
        config_cache_flag(cli, &cfg, cfg.manager.config_cache_regions)?;
    let cfg = &cfg;
    let trace_out = cli.flags.get("trace-out").cloned();
    let metrics_out = cli.flags.get("metrics-out").cloned();
    let tracing = cli.bool_or("trace", false)? || trace_out.is_some();
    println!(
        "fleet: {requests} requests over {fabrics} fabrics, policy {policy:?}, \
         {}, {threads} execution thread(s)",
        if oracle { "cycle-by-cycle oracle" } else { "event-driven fast-path" }
    );
    let trace = generate_count(&WorkloadSpec::fleet_mix(), seed, requests);
    let mut fleet = Fleet::launch(fabrics, cfg, None, policy, !oracle);
    fleet.execution_threads = threads;
    fleet.batch_window = batch_window;
    fleet.batch_cycles = batch_cycles;
    if tracing {
        fleet.tracer = elastic_fpga::telemetry::Tracer::full();
    }
    let t0 = std::time::Instant::now();
    let mut report = fleet.run_trace(&trace)?;
    let wall = t0.elapsed();
    println!(
        "completed {}/{} | virtual makespan {:.1} ms | {:.0} req/s virtual | \
         wall {:.2?} ({:.0} req/s simulated)",
        report.completed,
        requests,
        cfg.cycles_to_ms(report.makespan_cycles),
        report.throughput_per_s(cfg),
        wall,
        requests as f64 / wall.as_secs_f64()
    );
    println!(
        "queue wait p50 {} p99 {} cycles | latency p50 {} p99 {} cycles",
        report.queue_wait.percentile(0.50),
        report.queue_wait.percentile(0.99),
        report.latency.percentile(0.50),
        report.latency.percentile(0.99),
    );
    println!(
        "per-node served {:?} | migrated {} | oracle runs {} | fast-path hits {}",
        report.per_node_served,
        report.migrated,
        report.oracle_runs,
        report.fast_path_hits
    );
    if report.batches_formed > 0 {
        println!(
            "coalesced {} requests into {} batches (reconfig round skipped \
             for each follower)",
            report.batched_requests, report.batches_formed
        );
    }
    if report.config_cache_hits + report.config_cache_misses > 0 {
        println!(
            "config cache: {} hits / {} misses | {} ICAP cycles elided",
            report.config_cache_hits,
            report.config_cache_misses,
            report.icap_cycles_elided
        );
    }
    if tracing {
        println!("captured {} trace events", report.events.len());
    }
    if let Some(path) = &trace_out {
        std::fs::write(path, elastic_fpga::telemetry::trace_to_json(&report.events))?;
        println!("wrote trace to {path}");
    }
    if let Some(path) = &metrics_out {
        let mut metrics = report.metrics(cfg);
        std::fs::write(path, metrics.to_json())?;
        println!("wrote metrics snapshot to {path}");
    }
    Ok(())
}

fn autoscale_cmd(cli: &Cli) -> Result<()> {
    let nodes = cli.usize_or("fabrics", 5)?;
    let tenants = cli.usize_or("tenants", 4)? as u32;
    let requests = cli.usize_or("requests", 20_000)?;
    let period_s = cli.f64_or("period", 20.0)?;
    let seed = cli.usize_or("seed", 1)? as u64;
    let churn = cli.bool_or("churn", true)?;
    let policy_name = cli.str_or("policy", "depth");
    let policy = PolicyKind::parse(&policy_name).ok_or_else(|| {
        elastic_fpga::ElasticError::Config(format!(
            "--policy expects depth|slo|predictive, got '{policy_name}'"
        ))
    })?;
    // A --config overlay selects the board shape (e.g. scale16's 16-port
    // shells); the serving-profile timing knobs stay the autoscale
    // profile's so fabric lanes remain attractive.
    // The closed-loop engine owns the bandwidth plane (shares are
    // re-derived from footprints on every transition), so a --plan
    // overlay would be silently discarded — refuse it instead.
    if cli.flags.contains_key("plan") {
        return Err(elastic_fpga::ElasticError::Config(
            "--plan has no effect under `autoscale`: the engine derives \
             each app's share from its region footprint; use [qos.shares] \
             with quickstart/serve/fleet instead"
                .into(),
        ));
    }
    let cfg = match cli.flags.get("config") {
        Some(path) => autoscale::serving_profile_on(SystemConfig::load(
            std::path::Path::new(path),
        )?),
        None => autoscale::autoscale_profile(),
    };
    // App IDs are destination-register indices, one per crossbar port;
    // refuse impossible tenant counts with a typed error (the engine's
    // own bound is an assert).
    if tenants == 0 || tenants as usize > cfg.fabric.num_ports {
        return Err(elastic_fpga::ElasticError::Config(format!(
            "--tenants expects 1..={} on this board shape, got {tenants}",
            cfg.fabric.num_ports
        )));
    }
    println!(
        "autoscale: {requests} requests, {tenants} diurnal tenants over \
         {nodes} boards, policy {policy:?}, churn {churn}"
    );
    let t0 = std::time::Instant::now();
    let rep = autoscale::run_diurnal_scenario(
        &cfg, nodes, tenants, requests, period_s, seed, churn, policy,
    )?;
    println!("(simulated in {:.2?})", t0.elapsed());
    for (name, r) in [
        ("autoscaled", &rep.autoscaled),
        ("static    ", &rep.static_baseline),
    ] {
        let mut r2_wait = r.queue_wait.clone();
        println!(
            "{name}: util {:.1}% | queue wait p50 {:.2} ms p99 {:.2} ms | \
             SLO {:.1}% | fabric/cpu {}/{} | grows {} shrinks {} | \
             icap events {}",
            r.utilization * 100.0,
            cfg.cycles_to_ms(r2_wait.percentile(0.50)),
            cfg.cycles_to_ms(r2_wait.percentile(0.99)),
            r.slo_attainment * 100.0,
            r.fabric_requests,
            r.cpu_requests,
            r.grows,
            r.shrinks,
            r.icap_events.len(),
        );
    }
    Ok(())
}

fn serve(cli: &Cli, cfg: &SystemConfig) -> Result<()> {
    let runtime = load_runtime(cli)?;
    let requests = cli.usize_or("requests", 64)?;
    let words = cli.usize_or("words", 4096)?;
    let mut cfg = cfg.clone();
    cfg.server.batch_window = batch_window_flag(cli, cfg.server.batch_window)?;
    cfg.manager.config_cache_regions =
        config_cache_flag(cli, &cfg, cfg.manager.config_cache_regions)?;
    println!("serving {requests} requests of {words} words each...");
    let server = Server::start(cfg, runtime.as_ref().map(|t| t.handle()));
    let mut lat = LatencyRecorder::new();
    let mut thr = Throughput::start();
    let mut rng = SplitMix64::new(7);
    let mut pending = Vec::new();
    for i in 0..requests {
        let mut data = vec![0u32; words];
        rng.fill_u32(&mut data);
        pending.push(server.submit(AppRequest::pipeline((i % 4) as u32, data))?);
    }
    let mut ok = 0usize;
    for rx in pending {
        let resp = rx.recv().map_err(|_| {
            elastic_fpga::ElasticError::Server("response lost".into())
        })?;
        lat.record(resp.wall);
        if resp.report.is_ok() {
            ok += 1;
            thr.record((words * 4) as u64);
        }
    }
    println!(
        "{ok}/{requests} ok | wall latency mean {:.1} us p50 {} us p99 {} us | \
         {:.1} req/s, {:.1} MB/s",
        lat.mean_us(),
        lat.percentile_us(0.50),
        lat.percentile_us(0.99),
        thr.items_per_sec(),
        thr.mbytes_per_sec()
    );
    if let Some(path) = cli.flags.get("metrics-out") {
        let mut metrics = server.metrics_snapshot();
        std::fs::write(path, metrics.to_json())?;
        println!("wrote metrics snapshot to {path}");
    }
    let dumps = server.flight_dumps();
    if !dumps.is_empty() {
        eprintln!("{} flight-recorder dump(s) collected:", dumps.len());
        for d in &dumps {
            eprint!("{}", d.render());
        }
    }
    server.shutdown();
    Ok(())
}
