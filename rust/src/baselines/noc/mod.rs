//! The NoC baseline of [16] ("Architecture support for FPGA multi-tenancy
//! in the cloud", ASAP 2020): virtual regions connected by a mesh of
//! bufferless routers with no virtual channels.
//!
//! Flit-level model, following §V.G's accounting (after Dally & Towles
//! [17]): a packet carrying 8 data words consists of a head flit, 8 body
//! flits, and a tail flit — **10 flits**.  A router forwards the head
//! flit in 2 cycles (route computation + switch traversal); the
//! remaining flits follow pipelined at 1 cycle each.  Traversing source
//! and destination routers therefore costs `2*2 + 9*1*... ` — in the
//! paper's count, **22 cycles** for the two-router path, vs 13 cycles on
//! the WB crossbar (a 69% completion-latency advantage for 8 words...
//! (22-13)/13 ≈ 69%).
//!
//! The mesh uses dimension-ordered (XY) routing; contention is resolved
//! per-link in round-robin; bufferless deflection is modelled as a
//! 1-cycle stall of the entire upstream packet (no VCs, so a blocked
//! head stalls its whole wormhole).

use std::collections::VecDeque;

use crate::sim::Tick;

/// Cycles a router spends on a head flit (route + switch).
pub const HEAD_FLIT_CYCLES: u64 = 2;
/// Cycles per subsequent (body/tail) flit, pipelined.
pub const BODY_FLIT_CYCLES: u64 = 1;

/// Flits for a payload of `words` data words (head + body per word + tail).
pub fn packet_flits(words: usize) -> usize {
    words + 2
}

/// The paper's closed-form: completion cycles for one packet crossing
/// `routers` routers with `words` data words, uncontended.
///
/// §V.G's accounting: *per router*, the first flit takes 2 cc and each of
/// the remaining `flits-1` takes 1 cc (pipelined within the router, but
/// the bufferless routers of [16] do not cut through to the next hop), so
/// each router costs `2 + (flits-1)` and the total is the per-router cost
/// times the router count: 2 routers × (2 + 9) = **22 cc** for 8 words.
pub fn uncontended_completion(routers: usize, words: usize) -> u64 {
    routers as u64
        * (HEAD_FLIT_CYCLES + BODY_FLIT_CYCLES * (packet_flits(words) as u64 - 1))
}

/// One node's coordinates in the mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub x: usize,
    pub y: usize,
}

/// A packet in flight.
#[derive(Debug, Clone)]
pub struct Packet {
    pub id: u64,
    pub src: Coord,
    pub dst: Coord,
    /// Data words carried.
    pub words: Vec<u32>,
    /// Cycle the source NI injected the head flit.
    pub injected_at: u64,
}

/// A delivered packet with its completion stamp.
#[derive(Debug, Clone)]
pub struct Delivery {
    pub packet: Packet,
    pub done_cycle: u64,
}

impl Delivery {
    /// Cycles from injection to full delivery (incl. consume cycle).
    pub fn completion_latency(&self) -> u64 {
        self.done_cycle + 1 - self.packet.injected_at
    }
}

#[derive(Debug)]
struct FlightState {
    packet: Packet,
    /// Routers on the XY path, in order (including source and dest).
    path: Vec<Coord>,
    /// Progress: cycles of head latency still owed at each router.
    head_owed: u64,
    /// Body/tail flits still to drain after the head has arrived.
    flits_left: u64,
}

/// The mesh: flit-level wormhole simulation.
///
/// Links are modelled at packet granularity with per-link occupancy (a
/// bufferless wormhole holds every link on its path from head arrival to
/// tail departure — the key contention behaviour of [16]'s routers).
#[derive(Debug)]
pub struct MeshNoc {
    pub width: usize,
    pub height: usize,
    in_flight: Vec<FlightState>,
    /// Link occupancy: (from, to) -> packet id holding it.
    links: std::collections::HashMap<(Coord, Coord), u64>,
    waiting: VecDeque<Packet>,
    delivered: Vec<Delivery>,
    next_id: u64,
    cycle: u64,
    /// Total flit-cycles consumed (activity stats).
    pub flit_cycles: u64,
}

impl MeshNoc {
    /// A `width` x `height` mesh ([16] evaluates 2x2).
    pub fn new(width: usize, height: usize) -> Self {
        assert!(width >= 1 && height >= 1);
        Self {
            width,
            height,
            in_flight: Vec::new(),
            links: std::collections::HashMap::new(),
            waiting: VecDeque::new(),
            delivered: Vec::new(),
            next_id: 0,
            cycle: 0,
            flit_cycles: 0,
        }
    }

    /// XY route from `src` to `dst` (inclusive endpoints).
    pub fn xy_path(&self, src: Coord, dst: Coord) -> Vec<Coord> {
        let mut path = vec![src];
        let mut cur = src;
        while cur.x != dst.x {
            cur.x = if dst.x > cur.x { cur.x + 1 } else { cur.x - 1 };
            path.push(cur);
        }
        while cur.y != dst.y {
            cur.y = if dst.y > cur.y { cur.y + 1 } else { cur.y - 1 };
            path.push(cur);
        }
        path
    }

    /// Inject a packet (queued at the source NI until its path is free).
    pub fn inject(&mut self, src: Coord, dst: Coord, words: Vec<u32>) -> u64 {
        assert!(src.x < self.width && src.y < self.height);
        assert!(dst.x < self.width && dst.y < self.height);
        let id = self.next_id;
        self.next_id += 1;
        self.waiting.push_back(Packet {
            id,
            src,
            dst,
            words,
            injected_at: self.cycle + 1,
        });
        id
    }

    /// Take all deliveries so far.
    pub fn take_delivered(&mut self) -> Vec<Delivery> {
        std::mem::take(&mut self.delivered)
    }

    /// Anything still moving or queued?
    pub fn busy(&self) -> bool {
        !self.in_flight.is_empty() || !self.waiting.is_empty()
    }

    fn path_links(path: &[Coord]) -> Vec<(Coord, Coord)> {
        path.windows(2).map(|w| (w[0], w[1])).collect()
    }

    fn try_launch(&mut self) {
        // Bufferless, no VCs: a packet launches only when *every* link on
        // its path is free (wormhole holds the full path; a deflection-
        // free conservative model that matches [16]'s observation that
        // bufferless routing serializes conflicting flows).
        let mut remaining = VecDeque::new();
        while let Some(pkt) = self.waiting.pop_front() {
            let path = self.xy_path(pkt.src, pkt.dst);
            let links = Self::path_links(&path);
            let free = links.iter().all(|l| !self.links.contains_key(l));
            if free {
                for l in &links {
                    self.links.insert(*l, pkt.id);
                }
                let routers = path.len() as u64;
                let flits = packet_flits(pkt.words.len()) as u64;
                let mut p = pkt;
                if p.injected_at > self.cycle {
                    p.injected_at = self.cycle;
                }
                self.in_flight.push(FlightState {
                    packet: p,
                    path,
                    head_owed: HEAD_FLIT_CYCLES * routers,
                    flits_left: BODY_FLIT_CYCLES * (flits - 1) * routers,
                });
            } else {
                remaining.push_back(pkt);
            }
        }
        self.waiting = remaining;
    }
}

impl Tick for MeshNoc {
    fn tick(&mut self, cycle: u64) {
        self.cycle = cycle;
        self.try_launch();
        let mut done_idx = Vec::new();
        for (i, f) in self.in_flight.iter_mut().enumerate() {
            self.flit_cycles += 1;
            if f.head_owed > 0 {
                f.head_owed -= 1;
            } else if f.flits_left > 1 {
                f.flits_left -= 1;
            } else {
                // Last flit drains this cycle; +1 consume/status cycle is
                // accounted in `completion_latency`.
                done_idx.push(i);
            }
        }
        for &i in done_idx.iter().rev() {
            let f = self.in_flight.swap_remove(i);
            for l in Self::path_links(&f.path) {
                self.links.remove(&l);
            }
            self.delivered.push(Delivery { packet: f.packet, done_cycle: cycle });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;

    #[test]
    fn packet_of_8_words_is_10_flits() {
        // §V.G: "Sending 8 sets of data, as in our case, would require
        // sending 10 flits."
        assert_eq!(packet_flits(8), 10);
    }

    #[test]
    fn two_router_completion_is_22_cycles() {
        // §V.G: "traversing the flits only in source and destination
        // routers would take 22 ccs as opposed to 13 ccs in our case."
        assert_eq!(uncontended_completion(2, 8), 22);
    }

    #[test]
    fn simulated_adjacent_delivery_matches_closed_form() {
        let mut noc = MeshNoc::new(2, 2);
        let src = Coord { x: 0, y: 0 };
        let dst = Coord { x: 1, y: 0 };
        noc.inject(src, dst, vec![7; 8]);
        let mut clk = Clock::new();
        clk.run_until(&mut noc, 1000, |n| !n.busy()).unwrap();
        let d = noc.take_delivered();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].completion_latency(), uncontended_completion(2, 8));
    }

    #[test]
    fn longer_paths_cost_more_head_latency() {
        // 0,0 -> 1,1 crosses 3 routers in a 2x2 mesh (XY: E then N).
        let mut noc = MeshNoc::new(2, 2);
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 1 }, vec![0; 8]);
        let mut clk = Clock::new();
        clk.run_until(&mut noc, 1000, |n| !n.busy()).unwrap();
        let d = noc.take_delivered();
        assert_eq!(d[0].completion_latency(), uncontended_completion(3, 8));
        assert_eq!(d[0].completion_latency(), 33); // 3 routers x (2 + 9)
    }

    #[test]
    fn xy_routing_is_deterministic_dimension_ordered() {
        let noc = MeshNoc::new(3, 3);
        let path = noc.xy_path(Coord { x: 0, y: 0 }, Coord { x: 2, y: 2 });
        assert_eq!(
            path,
            vec![
                Coord { x: 0, y: 0 },
                Coord { x: 1, y: 0 },
                Coord { x: 2, y: 0 },
                Coord { x: 2, y: 1 },
                Coord { x: 2, y: 2 },
            ]
        );
    }

    #[test]
    fn conflicting_flows_serialize() {
        // Two packets sharing the (0,0)->(1,0) link: bufferless wormhole
        // must serialize them.
        let mut noc = MeshNoc::new(2, 2);
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 0 }, vec![1; 8]);
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 1 }, vec![2; 8]);
        let mut clk = Clock::new();
        clk.run_until(&mut noc, 1000, |n| !n.busy()).unwrap();
        let d = noc.take_delivered();
        assert_eq!(d.len(), 2);
        let l0 = d[0].completion_latency();
        let l1 = d[1].completion_latency();
        assert!(
            l1 > uncontended_completion(3, 8) || l0 > uncontended_completion(2, 8),
            "one of the packets must have waited: {l0} {l1}"
        );
    }

    #[test]
    fn disjoint_flows_proceed_in_parallel() {
        let mut noc = MeshNoc::new(2, 2);
        noc.inject(Coord { x: 0, y: 0 }, Coord { x: 1, y: 0 }, vec![1; 8]);
        noc.inject(Coord { x: 0, y: 1 }, Coord { x: 1, y: 1 }, vec![2; 8]);
        let mut clk = Clock::new();
        clk.run_until(&mut noc, 1000, |n| !n.busy()).unwrap();
        let d = noc.take_delivered();
        assert_eq!(d.len(), 2);
        for x in &d {
            assert_eq!(x.completion_latency(), uncontended_completion(2, 8));
        }
    }

    #[test]
    fn crossbar_beats_noc_by_69_pct_on_8_words() {
        // The paper's headline: "our solution takes 69% less ccs than NoC
        // based design [16] to complete a request" — 22 vs 13 cc.
        let noc = uncontended_completion(2, 8) as f64;
        let xbar = 13.0;
        let advantage = (noc - xbar) / xbar * 100.0;
        assert!((advantage - 69.0).abs() < 0.5, "advantage={advantage}");
    }
}
