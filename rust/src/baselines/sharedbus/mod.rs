//! The shared-bus baseline of [21] (Hagemeyer et al., FPL 2007): a
//! pipelined shared bus with encapsulated-WISHBONE (E-WB) interfaces for
//! PR regions.
//!
//! One bus, one transfer at a time: a single arbiter serializes *all*
//! masters regardless of destination — the flexibility/scalability
//! deficit the paper contrasts the crossbar against (§II.A, §III).  The
//! per-transaction protocol mirrors the WB crossbar's master path
//! (latch, issue, 2-cycle arbitration, 1 word/cc, status) so latency
//! differences isolate the *topology*, not the interface.
//!
//! Table II quotes four single master-slave E-WB communication
//! infrastructures at 1076 LUTs / 1484 FFs; [`crate::area::table2`]
//! carries those numbers.

use std::collections::VecDeque;

use crate::sim::Tick;

/// One queued bus transfer.
#[derive(Debug, Clone)]
pub struct BusJob {
    pub src: usize,
    pub dst: usize,
    pub words: usize,
    /// Cycle the master initiated the request.
    pub request_cycle: u64,
}

/// A completed transfer.
#[derive(Debug, Clone)]
pub struct BusDelivery {
    pub job: BusJob,
    pub granted_cycle: u64,
    pub done_cycle: u64,
}

impl BusDelivery {
    /// Cycles from initiation to first data (crossbar's time-to-grant
    /// analogue).
    pub fn time_to_grant(&self) -> u64 {
        self.granted_cycle + 1 - self.job.request_cycle
    }

    /// Cycles from initiation to status registration.
    pub fn completion_latency(&self) -> u64 {
        self.done_cycle + 1 - self.job.request_cycle
    }
}

#[derive(Debug)]
enum BusState {
    Free,
    /// Latch + issue + 2-cycle arbitration = 4 cc before first data, as
    /// on the crossbar's master path.
    Granting { job: BusJob, countdown: u64 },
    Transfer { job: BusJob, granted_cycle: u64, sent: usize },
    Status { job: BusJob, granted_cycle: u64 },
}

/// The shared bus.
#[derive(Debug)]
pub struct SharedBus {
    state: BusState,
    queue: VecDeque<BusJob>,
    delivered: Vec<BusDelivery>,
    cycle: u64,
    /// Cycles the bus spent occupied (utilization stats).
    pub busy_cycles: u64,
}

/// Pre-data protocol cycles: latch(1) + issue(1) + arbitrate(2).
pub const GRANT_CYCLES: u64 = 4;

impl SharedBus {
    /// New idle bus.
    pub fn new() -> Self {
        Self {
            state: BusState::Free,
            queue: VecDeque::new(),
            delivered: Vec::new(),
            cycle: 0,
            busy_cycles: 0,
        }
    }

    /// A master requests a transfer of `words` to `dst`.
    pub fn request(&mut self, src: usize, dst: usize, words: usize) {
        self.queue.push_back(BusJob {
            src,
            dst,
            words,
            request_cycle: self.cycle + 1,
        });
    }

    /// Completed transfers so far.
    pub fn take_delivered(&mut self) -> Vec<BusDelivery> {
        std::mem::take(&mut self.delivered)
    }

    /// Anything queued or in flight?
    pub fn busy(&self) -> bool {
        !matches!(self.state, BusState::Free) || !self.queue.is_empty()
    }

    /// Closed form: completion latency of the n-th of n simultaneous
    /// `words`-word requests — every predecessor holds the bus for its
    /// full grant+data+status window (no overlap: one bus; the next
    /// grant pipeline starts the cycle after the status cycle).
    pub fn nth_completion(n: u64, words: u64) -> u64 {
        n * (GRANT_CYCLES + words + 1)
    }
}

impl Default for SharedBus {
    fn default() -> Self {
        Self::new()
    }
}

impl Tick for SharedBus {
    fn tick(&mut self, cycle: u64) {
        self.cycle = cycle;
        if !matches!(self.state, BusState::Free) {
            self.busy_cycles += 1;
        }
        self.state = match std::mem::replace(&mut self.state, BusState::Free) {
            BusState::Free => {
                if let Some(mut job) = self.queue.pop_front() {
                    if job.request_cycle > cycle {
                        job.request_cycle = cycle;
                    }
                    self.busy_cycles += 1;
                    BusState::Granting { job, countdown: GRANT_CYCLES - 1 }
                } else {
                    BusState::Free
                }
            }
            BusState::Granting { job, countdown } => {
                if countdown > 1 {
                    BusState::Granting { job, countdown: countdown - 1 }
                } else {
                    BusState::Transfer { job, granted_cycle: cycle, sent: 0 }
                }
            }
            BusState::Transfer { job, granted_cycle, mut sent } => {
                sent += 1;
                if sent >= job.words {
                    BusState::Status { job, granted_cycle }
                } else {
                    BusState::Transfer { job, granted_cycle, sent }
                }
            }
            BusState::Status { job, granted_cycle } => {
                self.delivered.push(BusDelivery {
                    job,
                    granted_cycle,
                    done_cycle: cycle,
                });
                // Bus free next cycle; the next queued master re-arbitrates.
                BusState::Free
            }
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;

    #[test]
    fn single_transfer_matches_crossbar_best_case() {
        // Same interface protocol => same uncontended numbers (4 cc grant,
        // 13 cc completion for 8 words).
        let mut bus = SharedBus::new();
        bus.request(0, 1, 8);
        let mut clk = Clock::new();
        clk.run_until(&mut bus, 100, |b| !b.busy()).unwrap();
        let d = bus.take_delivered();
        assert_eq!(d[0].time_to_grant(), 4);
        assert_eq!(d[0].completion_latency(), 13);
    }

    #[test]
    fn disjoint_transfers_still_serialize() {
        // The crossbar's parallel-transmission advantage: on the bus,
        // 0->1 and 2->3 serialize even though they share no endpoints.
        let mut bus = SharedBus::new();
        bus.request(0, 1, 8);
        bus.request(2, 3, 8);
        let mut clk = Clock::new();
        clk.run_until(&mut bus, 200, |b| !b.busy()).unwrap();
        let d = bus.take_delivered();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].completion_latency(), 13);
        assert!(
            d[1].completion_latency() > 13,
            "second transfer must wait: {}",
            d[1].completion_latency()
        );
    }

    #[test]
    fn nth_completion_closed_form() {
        // 3 simultaneous 8-word transfers serialize into back-to-back
        // 13-cc windows: completions at 13, 26, 39.
        let mut bus = SharedBus::new();
        for m in 0..3 {
            bus.request(m, 3, 8);
        }
        let mut clk = Clock::new();
        clk.run_until(&mut bus, 200, |b| !b.busy()).unwrap();
        let d = bus.take_delivered();
        let lats: Vec<u64> = d.iter().map(|x| x.completion_latency()).collect();
        assert_eq!(lats, vec![13, 26, 39]);
        assert_eq!(*lats.last().unwrap(), SharedBus::nth_completion(3, 8));
    }

    #[test]
    fn utilization_counts_busy_cycles() {
        let mut bus = SharedBus::new();
        bus.request(0, 1, 8);
        let mut clk = Clock::new();
        clk.run_until(&mut bus, 100, |b| !b.busy()).unwrap();
        assert_eq!(bus.busy_cycles, 13);
    }
}
