//! Comparison baselines the paper evaluates against (Table II, §V.G):
//!
//! * [`noc`] — the 2x2 mesh NoC of Mbongue et al. [16]: bufferless
//!   3-port routers, no virtual channels, flit-level wormhole pipeline.
//! * [`sharedbus`] — the pipelined single-master E-WB shared bus of
//!   Hagemeyer et al. [21].
//!
//! Both are implemented to the level of detail the paper's claims rest
//! on: request-completion cycle counts for an 8-word payload, and area
//! numbers quoted from the respective publications.

pub mod noc;
pub mod sharedbus;
