//! Manager tests: allocation planning, the three Fig-5 cases, elastic
//! migration, and failure handling.  These run without PJRT (runtime =
//! None -> golden-model on-server path); the PJRT-coupled versions live
//! in `rust/tests/integration.rs`.

use super::*;
use crate::config::SystemConfig;
use crate::util::SplitMix64;

fn mgr() -> ElasticManager {
    ElasticManager::new(SystemConfig::paper_defaults(), None)
}

fn data(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = SplitMix64::new(seed);
    let mut v = vec![0u32; n];
    rng.fill_u32(&mut v);
    v
}

#[test]
fn plan_prefers_fpga_prefix() {
    let m = mgr();
    let plan = m.plan(&crate::modules::ModuleKind::pipeline());
    assert_eq!(plan.len(), 3);
    assert!(plan.iter().all(StagePlacement::is_fpga));
}

#[test]
fn plan_overflows_to_server_when_fenced() {
    let mut m = mgr();
    assert_eq!(m.fence_regions(2), 2);
    assert_eq!(m.available_regions(), 1);
    let plan = m.plan(&crate::modules::ModuleKind::pipeline());
    assert!(plan[0].is_fpga());
    assert!(!plan[1].is_fpga());
    assert!(!plan[2].is_fpga());
}

#[test]
fn fig5_case1_multiplier_only_on_fpga() {
    let mut m = mgr();
    m.fence_regions(2);
    let req = AppRequest::pipeline(0, data(256, 1));
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 1);
    assert!(rep.verified);
    assert_eq!(rep.output, golden_pipeline(&req.data));
    assert_eq!(rep.timeline.cpu_stages.len(), 2);
}

#[test]
fn fig5_case2_two_stages_on_fpga() {
    let mut m = mgr();
    m.fence_regions(1);
    let req = AppRequest::pipeline(0, data(256, 2));
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 2);
    assert!(rep.verified);
    assert_eq!(rep.output, golden_pipeline(&req.data));
    assert_eq!(rep.timeline.cpu_stages.len(), 1);
}

#[test]
fn fig5_case3_all_on_fpga() {
    let mut m = mgr();
    let req = AppRequest::pipeline(0, data(256, 3));
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 3);
    assert!(rep.verified);
    assert_eq!(rep.output, golden_pipeline(&req.data));
    assert!(rep.timeline.cpu_stages.is_empty());
}

#[test]
fn fig5_ordering_case1_slowest_case3_fastest() {
    // The paper's Fig 5 claim, from the model: more FPGA stages = less
    // total time (16 KB payload).
    let mut totals = Vec::new();
    for fenced in [2usize, 1, 0] {
        let mut m = mgr();
        m.fence_regions(fenced);
        let req = AppRequest::pipeline(0, data(4096, 4));
        let rep = m.execute(&req).unwrap();
        totals.push(rep.cost.total_ms());
    }
    assert!(
        totals[0] > totals[1] && totals[1] > totals[2],
        "fig5 ordering violated: {totals:?}"
    );
    // Calibration endpoints (±10%).
    assert!((totals[0] - 16.9).abs() / 16.9 < 0.10, "case1 = {}", totals[0]);
    assert!((totals[2] - 10.87).abs() / 10.87 < 0.10, "case3 = {}", totals[2]);
}

#[test]
fn regions_released_after_execution() {
    let mut m = mgr();
    let req = AppRequest::pipeline(0, data(64, 5));
    m.execute(&req).unwrap();
    assert_eq!(m.available_regions(), 3, "regions must be reusable");
    // And reusable: run again.
    let rep = m.execute(&req).unwrap();
    assert!(rep.verified);
}

#[test]
fn elastic_migration_grows_fpga_share_per_segment() {
    let mut m = mgr();
    m.fence_regions(2); // start with 1 region
    let req = AppRequest::pipeline(0, data(768, 6));
    let reports = m.execute_elastic(&req, 3).unwrap();
    let fpga: Vec<usize> = reports.iter().map(|r| r.fpga_stages).collect();
    assert_eq!(fpga, vec![1, 2, 3], "one more FPGA stage per segment");
    // Stitched output must equal the golden pipeline of the whole buffer.
    let stitched: Vec<u32> =
        reports.iter().flat_map(|r| r.output.iter().copied()).collect();
    assert_eq!(stitched, golden_pipeline(&req.data));
    // Costs must be non-increasing as stages migrate on.
    let costs: Vec<f64> = reports.iter().map(|r| r.cost.total_ms()).collect();
    assert!(costs[0] > costs[1] && costs[1] > costs[2], "{costs:?}");
}

#[test]
fn unaligned_payload_rejected() {
    let mut m = mgr();
    let req = AppRequest::pipeline(0, vec![0; 13]);
    assert!(m.execute(&req).is_err());
}

#[test]
fn explicit_placement_rejects_taken_region() {
    let mut m = mgr();
    let req = AppRequest::pipeline(0, data(64, 7));
    let placement = vec![
        StagePlacement::Fpga { kind: crate::modules::ModuleKind::Multiplier, region: 1 },
        StagePlacement::Fpga { kind: crate::modules::ModuleKind::HammingEncoder, region: 1 },
        StagePlacement::OnServer { kind: crate::modules::ModuleKind::HammingDecoder },
    ];
    assert!(m.execute_placed(&req, &placement).is_err(), "region 1 reused");
}

#[test]
fn icap_path_reports_reconfig_cost_separately() {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.manager.bitstream_bytes = 4096; // keep the test fast (1024 words)
    let mut m = ElasticManager::new(cfg, None);
    m.use_icap = true;
    let req = AppRequest::pipeline(0, data(64, 8));
    let rep = m.execute(&req).unwrap();
    assert!(rep.verified);
    assert!(rep.cost.reconfig_ms > 0.0, "ICAP time must be accounted");
    assert_eq!(rep.output, golden_pipeline(&req.data));
    // Three regions programmed serially through one ICAP: at least
    // 3 * words * 2 cycles.
    assert!(rep.timeline.reconfig_cycles >= 3 * 1024 * 2);
}

#[test]
fn zero_regions_runs_everything_on_server() {
    let mut m = mgr();
    m.fence_regions(3);
    let req = AppRequest::pipeline(1, data(64, 9));
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 0);
    assert!(rep.verified);
    assert_eq!(rep.output, golden_pipeline(&req.data));
    // No PCIe crossings on the pure-server path.
    assert!(rep.timeline.h2c_transfers.is_empty());
    assert!(rep.timeline.c2h_transfers.is_empty());
}

#[test]
fn regions_beyond_table3_window_now_execute() {
    // The PR-2 behavior this refactor removes: a 5-stage chain on an
    // 8-port shell used to fail with RegfileWindow because regions 4 and
    // 5 had no Table III registers.  The banked layout programs them.
    let mut cfg = SystemConfig::paper_defaults();
    cfg.fabric.num_ports = 8;
    cfg.fabric.num_pr_regions = 7;
    let mut m = ElasticManager::new(cfg, None);
    let req = AppRequest {
        app_id: 0,
        data: data(64, 20),
        stages: vec![crate::modules::ModuleKind::Multiplier; 5],
    };
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 5, "all five stages hosted on fabric");
    assert!(rep.verified);
    assert_eq!(rep.output, golden_chain(&req.stages, &req.data));
    assert_eq!(m.available_regions(), 7, "regions released after execute");
}

#[test]
fn regions_beyond_the_configured_layout_get_typed_error() {
    // RegfileWindow survives, but only past the *configured* layout: an
    // explicit placement naming a region the shell does not have.
    let mut m = mgr(); // 4 ports
    let req = AppRequest {
        app_id: 0,
        data: data(64, 22),
        stages: vec![crate::modules::ModuleKind::Multiplier],
    };
    let placement = vec![StagePlacement::Fpga {
        kind: crate::modules::ModuleKind::Multiplier,
        region: 7,
    }];
    match m.execute_placed(&req, &placement) {
        Err(crate::ElasticError::RegfileWindow(_)) => {}
        other => panic!("expected RegfileWindow error, got {other:?}"),
    }
    assert_eq!(m.available_regions(), 3, "nothing leaked");
}

#[test]
fn sixteen_port_manager_programs_all_fifteen_regions() {
    // The scale16 shape end to end: reserve every region, verify the
    // register image carries destinations + isolation + WRR budgets for
    // all 15 PR regions, then run a chain spanning high regions.
    let mut cfg = SystemConfig::paper_defaults();
    cfg.fabric.num_ports = 16;
    cfg.fabric.num_pr_regions = 15;
    cfg.manager.bitstream_bytes = 4096; // keep the timed ICAP fast
    let mut m = ElasticManager::new(cfg, None);
    for r in 1..=15usize {
        let app = (r % 4) as u32;
        m.reserve_region(app, crate::modules::ModuleKind::Multiplier, r)
            .unwrap();
    }
    assert_eq!(m.available_regions(), 0);
    // Contract each app 200/1000 of the bandwidth plane; the compiler —
    // not the chain-programming call — decides every budget field.
    let plan =
        crate::qos::BandwidthPlan::with_shares(&[(0, 200), (1, 200), (2, 200), (3, 200)])
            .unwrap();
    m.set_bandwidth_plan(plan).unwrap();
    for app in 0..4u32 {
        let chain: Vec<usize> =
            (1..=15).filter(|r| r % 4 == app as usize).collect();
        m.program_app_chain(app, &chain).unwrap();
    }
    let prog = m.apply_plan().unwrap();
    let rf = &m.fabric().regfile;
    for r in 1..=15usize {
        assert_ne!(rf.pr_destination(r).unwrap(), 0, "region {r} dest");
        assert_ne!(rf.allowed_slaves(r).unwrap(), 0, "region {r} mask");
    }
    // The budget banks hold exactly the compiled plan: T=64 at 200/1000
    // is 13 packages per app, largest-remainder over its masters.
    assert_eq!(rf.master_budgets(), prog.budgets);
    assert_eq!(rf.allowed_packages(1, 0).unwrap(), 64, "bridge quantum");
    assert_eq!(rf.allowed_packages(0, 4).unwrap(), 5, "app 0 first master");
    assert_eq!(rf.allowed_packages(0, 8).unwrap(), 4);
    assert_eq!(rf.allowed_packages(0, 12).unwrap(), 4);
    // Same-app masters sit adjacent in the arbiter rotation.
    assert_eq!(&m.fabric().xbar.rotation_order()[..4], &[0, 4, 8, 12]);
    // And the manager reports the allocation in share terms.
    let shares = m.bandwidth_shares();
    assert_eq!(shares.len(), 4);
    for &(app, ppu) in &shares {
        assert_eq!(ppu, 13 * 1000 / 64, "app {app} effective share");
    }
    for app in 0..4u32 {
        m.release_app(app);
    }
    assert_eq!(m.available_regions(), 15);
    assert_eq!(m.bandwidth_in_use(), 0, "released apps hold no share");

    // A 6-stage chain — impossible under Table III — now executes.
    let req = AppRequest {
        app_id: 0,
        data: data(64, 23),
        stages: vec![crate::modules::ModuleKind::Multiplier; 6],
    };
    let rep = m.execute(&req).unwrap();
    assert_eq!(rep.fpga_stages, 6);
    assert!(rep.verified);
    // Beyond the configured 16 ports the typed refusal still applies.
    assert!(matches!(
        m.program_app_chain(0, &[16]),
        Err(crate::ElasticError::RegfileWindow(_))
    ));
    assert!(matches!(
        m.program_app_chain(16, &[1]),
        Err(crate::ElasticError::RegfileWindow(_))
    ));
}

#[test]
fn reserve_and_blank_regions_hold_allocations_through_icap() {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.manager.bitstream_bytes = 4096; // 1024 words, keeps the test fast
    let mut m = ElasticManager::new(cfg, None);
    let spent = m
        .reserve_region(1, crate::modules::ModuleKind::Multiplier, 2)
        .unwrap();
    assert!(spent >= 2 * 1024, "ICAP time unaccounted: {spent}");
    assert_eq!(m.available_regions(), 2);
    assert!(matches!(
        m.regions()[2],
        RegionState::Allocated { app_id: 1, .. }
    ));
    // The module is really instantiated on the fabric.
    assert!(m.fabric().module_at(2).is_some());
    // Double-reserve and out-of-layout regions are refused.
    assert!(m
        .reserve_region(1, crate::modules::ModuleKind::Multiplier, 2)
        .is_err());
    assert!(matches!(
        m.reserve_region(0, crate::modules::ModuleKind::Multiplier, 9),
        Err(crate::ElasticError::RegfileWindow(_))
    ));
    // Blanking goes back through the timed ICAP and frees the region.
    let blank = m.blank_region(2).unwrap();
    assert!(blank >= 2 * 1024);
    assert_eq!(m.available_regions(), 3);
    assert!(m.fabric().module_at(2).is_none());
    assert!(m.blank_region(2).is_err(), "already free");
}

#[test]
fn program_app_chain_writes_destinations_and_compiled_weights() {
    let mut m = mgr();
    let plan = crate::qos::BandwidthPlan::with_shares(&[(2, 500)]).unwrap();
    m.set_bandwidth_plan(plan).unwrap();
    m.program_app_chain(2, &[1, 3]).unwrap();
    let rf = &m.fabric().regfile;
    assert_eq!(rf.app_destination(2).unwrap(), 1 << 1);
    assert_eq!(rf.pr_destination(1).unwrap(), 1 << 3);
    assert_eq!(rf.pr_destination(3).unwrap(), 1 << 0);
    // T=64 at 500/1000 = 32 packages over masters {1, 3}: 16 each, at
    // every slave bank; the bridge carries the full quantum.
    assert_eq!(rf.allowed_packages(3, 1).unwrap(), 16);
    assert_eq!(rf.allowed_packages(0, 3).unwrap(), 16);
    assert_eq!(rf.allowed_packages(1, 0).unwrap(), 64, "bridge quantum");
    // The unowned region keeps the default budget.
    assert_eq!(rf.allowed_packages(0, 2).unwrap(), 8);
    // App 2's masters are adjacent right after the bridge.
    assert_eq!(m.fabric().xbar.rotation_order(), &[0, 1, 3, 2]);
    assert_eq!(m.bandwidth_shares(), vec![(2, 500)]);
    assert!(m.program_app_chain(4, &[1]).is_err(), "app beyond window");
    assert!(m.program_app_chain(0, &[4]).is_err(), "region beyond window");
}

#[test]
fn unfence_regions_partially_restores() {
    let mut m = mgr();
    assert_eq!(m.fence_regions(3), 3);
    assert_eq!(m.unfence_regions(2), 2);
    assert_eq!(m.available_regions(), 2);
    assert_eq!(m.unfence_regions(5), 1, "only one region was still offline");
    assert_eq!(m.available_regions(), 3);
}

#[test]
fn two_sequential_apps_isolated() {
    let mut m = mgr();
    let a = AppRequest::pipeline(0, data(64, 10));
    let b = AppRequest {
        app_id: 1,
        data: data(64, 11),
        stages: vec![crate::modules::ModuleKind::HammingEncoder],
    };
    let ra = m.execute(&a).unwrap();
    let rb = m.execute(&b).unwrap();
    assert_eq!(ra.output, golden_pipeline(&a.data));
    assert_eq!(rb.output, crate::hamming::encode_buf(&b.data));
}
