//! FPGA Elastic Resource Manager (§IV.A) — the paper's coordination
//! contribution.
//!
//! The manager keeps track of which PR regions are available and which
//! are allocated to which application.  For each acceleration request it
//! expresses the application as a chain of computation modules, assigns
//! as many as fit onto free PR regions, and runs the remainder **on the
//! server** (here: the *same* AOT-compiled JAX/Pallas artifacts executed
//! through PJRT).  When a region frees up, the next on-server module
//! migrates onto the FPGA and the upstream module's destination register
//! is updated so traffic flows to the newly configured region — that is
//! the elasticity mechanism.
//!
//! Reconfiguration can run through the ICAP model (timed, serialized) or
//! the paper's own prototype path of statically installed modules
//! (§V.B); Fig 5's execution times exclude reconfiguration either way.
//!
//! Two allocation disciplines share the region map:
//!
//! * **per-request** ([`ElasticManager::execute`]) — regions are taken at
//!   request start and released at completion (the Fig-5 primitive);
//! * **reserved** ([`ElasticManager::reserve_region`] /
//!   [`ElasticManager::blank_region`]) — regions belong to an app across
//!   requests, programmed and blanked through the timed ICAP; this is
//!   what the closed-loop autoscaler ([`crate::autoscale`]) actuates.

mod app;

pub use app::{AppReport, AppRequest, StagePlacement};

use crate::config::SystemConfig;
use crate::fabric::Fabric;
use crate::hamming;
use crate::modules::ModuleKind;
use crate::qos::{BandwidthPlan, PlanProgram, SHARE_UNIT};
use crate::runtime::RuntimeHandle;
use crate::timing::{evaluate, CostBreakdown, ExecutionTimeline};
use crate::xdma::H2cBurst;
use crate::{ElasticError, Result};

/// Ownership state of one PR region.
#[derive(Debug, Clone, PartialEq)]
pub enum RegionState {
    /// Free for allocation.
    Available,
    /// Allocated to an app, hosting one module stage.
    Allocated { app_id: u32, kind: ModuleKind },
    /// Released but still holding `kind`'s bitstream in the
    /// configuration cache (DESIGN.md §16): the module's architectural
    /// state is scrubbed and the port is isolated, but a later request
    /// needing the same kind rebinds it through the register file alone
    /// — zero ICAP cycles.  Only exists when
    /// `manager.config_cache_regions > 0`.
    Resident { kind: ModuleKind },
    /// Administratively offline (fenced by the operator / churn model).
    Offline,
}

/// The manager: fabric + (optional) PJRT runtime + region bookkeeping.
pub struct ElasticManager {
    fabric: Fabric,
    runtime: Option<RuntimeHandle>,
    regions: Vec<RegionState>, // index 0 unused; 1..=N are PR regions
    /// The board's bandwidth contract; every allocation transition
    /// recompiles it into per-master budgets ([`Self::apply_plan`]).
    plan: BandwidthPlan,
    /// Chain ownership programmed directly via
    /// [`Self::program_app_chain`] (index = crossbar port) — regions the
    /// allocation map does not track but the bandwidth compiler must.
    chain_owner: Vec<Option<u32>>,
    /// The last program this manager wrote, so per-request allocation
    /// events skip the N²-register rewrite when nothing changed.
    applied_program: Option<PlanProgram>,
    cfg: SystemConfig,
    /// Use the ICAP timing model when installing modules (otherwise the
    /// §V.B static path).
    pub use_icap: bool,
    /// Drive the fabric with busy-period horizon skipping
    /// ([`Fabric::run_until_idle_fast`], DESIGN.md §12) instead of the
    /// cycle-by-cycle oracle.  Both modes are cycle-exact — identical
    /// reports, costs and ICAP cycle counts (pinned by
    /// `tests/fastpath_equivalence.rs`) — so the fast path is on by
    /// default; the fleet's oracle mode switches it off to keep a pure
    /// every-cycle reference run available.
    pub fast_path: bool,
    /// Per-region LRU stamp for `Resident` entries (index = region; 0
    /// unused).  Stamps come from [`Self::cache_clock`] — a monotone
    /// virtual counter bumped at sequential release points, never wall
    /// time — so eviction order is deterministic at any thread count.
    resident_stamp: Vec<u64>,
    /// Virtual LRU clock for the configuration cache.
    cache_clock: u64,
    /// Requests whose FPGA stage rebound a resident region (cache on).
    cache_hits: u64,
    /// FPGA stages programmed cold while the cache was enabled.
    cache_misses: u64,
    /// ICAP fabric cycles elided by cache-hit rebinds.
    icap_cycles_elided: u64,
}

impl ElasticManager {
    /// Build a manager over a fresh fabric.  `runtime` enables real PJRT
    /// execution of on-server stages and result verification.  The
    /// `[qos]` plan from `cfg` is compiled and applied immediately.
    pub fn new(cfg: SystemConfig, runtime: Option<RuntimeHandle>) -> Self {
        let fabric = Fabric::new(cfg.clone());
        let n = cfg.fabric.num_pr_regions;
        // Construction contract (like the port-count asserts in
        // `Fabric::new`): the config must carry a valid [qos] table.
        // Parsed configs always do — `SystemConfig::from_doc` refuses
        // overcommitted shares and out-of-range quanta with typed
        // errors; only hand-built configs can trip these expects.
        let plan = cfg
            .qos
            .plan()
            .expect("SystemConfig.qos.shares must not overcommit SHARE_UNIT");
        let mut mgr = Self {
            fabric,
            runtime,
            regions: (0..=n).map(|_| RegionState::Available).collect(),
            plan,
            chain_owner: vec![None; cfg.fabric.num_ports],
            applied_program: None,
            cfg,
            use_icap: false,
            fast_path: true,
            resident_stamp: vec![0; n + 1],
            cache_clock: 0,
            cache_hits: 0,
            cache_misses: 0,
            icap_cycles_elided: 0,
        };
        mgr.apply_plan().expect(
            "SystemConfig.qos.rotation_packages and \
             crossbar.default_packages must be 1..=255",
        );
        mgr
    }

    /// Region states (1-indexed; entry 0 is a placeholder).
    pub fn regions(&self) -> &[RegionState] {
        &self.regions
    }

    /// Number of regions a new request can claim: free regions plus
    /// cache-resident ones (a `Resident` region rebinds or blanks at
    /// allocation time, so it is available capacity either way).
    pub fn available_regions(&self) -> usize {
        self.regions[1..]
            .iter()
            .filter(|r| {
                matches!(r, RegionState::Available | RegionState::Resident { .. })
            })
            .count()
    }

    /// Is the configuration cache on for this manager?
    fn cache_enabled(&self) -> bool {
        self.cfg.manager.config_cache_regions > 0
    }

    /// Cache-resident regions as `(region, kind)`, lowest index first.
    pub fn resident_regions(&self) -> Vec<(usize, ModuleKind)> {
        (1..self.regions.len())
            .filter_map(|r| match self.regions[r] {
                RegionState::Resident { kind } => Some((r, kind)),
                _ => None,
            })
            .collect()
    }

    /// Configuration-cache counters:
    /// `(cache_hits, cache_misses, icap_cycles_elided)`.
    pub fn config_cache_stats(&self) -> (u64, u64, u64) {
        (self.cache_hits, self.cache_misses, self.icap_cycles_elided)
    }

    /// Evict one resident region: physically clear it (module out, port
    /// isolated — free in the PR model, like `clear_region`) and emit
    /// [`TraceEvent::CacheEvict`].
    ///
    /// [`TraceEvent::CacheEvict`]: crate::telemetry::TraceEvent::CacheEvict
    fn evict_resident(&mut self, region: usize) {
        if let RegionState::Resident { kind } = self.regions[region] {
            self.fabric.clear_region(region);
            self.regions[region] = RegionState::Available;
            let cycle = self.fabric.now();
            self.fabric.telemetry.emit_with(|| {
                crate::telemetry::TraceEvent::CacheEvict {
                    cycle,
                    node: 0,
                    region,
                    kind: kind.name(),
                }
            });
        }
    }

    /// Trim the resident set to the configured capacity, oldest LRU
    /// stamp first (ties broken by lowest region index — stamps are
    /// unique, but the order must be stated).
    fn trim_residents(&mut self) {
        let cap = self.cfg.manager.config_cache_regions;
        loop {
            let mut residents: Vec<(u64, usize)> = (1..self.regions.len())
                .filter(|&r| {
                    matches!(self.regions[r], RegionState::Resident { .. })
                })
                .map(|r| (self.resident_stamp[r], r))
                .collect();
            if residents.len() <= cap {
                return;
            }
            residents.sort_unstable();
            let (_, oldest) = residents[0];
            self.evict_resident(oldest);
        }
    }

    /// Fence `count` regions offline (churn injection for elasticity
    /// experiments); returns how many were actually fenced.  Free
    /// regions fence first (highest index first, the legacy order);
    /// cache-resident regions are evicted LRU-first only when free ones
    /// run out.
    pub fn fence_regions(&mut self, count: usize) -> usize {
        let mut fenced = 0;
        for r in (1..self.regions.len()).rev() {
            if fenced == count {
                break;
            }
            if self.regions[r] == RegionState::Available {
                self.regions[r] = RegionState::Offline;
                fenced += 1;
            }
        }
        while fenced < count {
            let mut residents: Vec<(u64, usize)> = (1..self.regions.len())
                .filter(|&r| {
                    matches!(self.regions[r], RegionState::Resident { .. })
                })
                .map(|r| (self.resident_stamp[r], r))
                .collect();
            if residents.is_empty() {
                break;
            }
            residents.sort_unstable();
            let (_, oldest) = residents[0];
            self.evict_resident(oldest);
            self.regions[oldest] = RegionState::Offline;
            fenced += 1;
        }
        fenced
    }

    /// Bring all offline regions back.
    pub fn unfence_all(&mut self) {
        for r in self.regions.iter_mut() {
            if *r == RegionState::Offline {
                *r = RegionState::Available;
            }
        }
    }

    /// Bring up to `n` offline regions back (lowest index first);
    /// returns how many were actually unfenced.
    pub fn unfence_regions(&mut self, n: usize) -> usize {
        let mut left = n;
        for r in 1..self.regions.len() {
            if left == 0 {
                break;
            }
            if self.regions[r] == RegionState::Offline {
                self.regions[r] = RegionState::Available;
                left -= 1;
            }
        }
        n - left
    }

    /// Direct fabric access (benches, tests).
    pub fn fabric_mut(&mut self) -> &mut Fabric {
        &mut self.fabric
    }

    /// Read-only fabric access.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// The configuration this manager runs under.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The board's bandwidth plan.
    pub fn bandwidth_plan(&self) -> &BandwidthPlan {
        &self.plan
    }

    /// Replace the bandwidth plan and recompile it into the register
    /// file and the arbiters immediately.
    pub fn set_bandwidth_plan(
        &mut self,
        plan: BandwidthPlan,
    ) -> Result<PlanProgram> {
        self.plan = plan;
        self.apply_plan()
    }

    /// Update one app's share contract **without** recompiling — for
    /// callers about to trigger an allocation event (which applies the
    /// plan anyway), so a transition costs one compile, not two.
    pub fn stage_bandwidth_share(&mut self, app: u32, ppu: u32) -> Result<()> {
        self.plan.set_share(app, ppu)
    }

    /// Which app owns each crossbar port's master: the allocation map
    /// first (reserved / executing regions), then chains programmed
    /// directly through [`Self::program_app_chain`].
    fn port_app_map(&self) -> Vec<Option<u32>> {
        let mut map = self.chain_owner.clone();
        for r in 1..self.regions.len() {
            if let RegionState::Allocated { app_id, .. } = self.regions[r] {
                map[r] = Some(app_id);
            }
        }
        map[0] = None; // the bridge serves every app
        map
    }

    /// Recompile the bandwidth plan against current port ownership and
    /// program the result: per-master package budgets into the banked
    /// register file (generation-bumped, so the fabric remirrors them
    /// into every arbiter) and the app-aware rotation order into the
    /// crossbar.  This is the single path by which WRR budgets are
    /// written — no layer hand-assembles them any more.
    pub fn apply_plan(&mut self) -> Result<PlanProgram> {
        let port_app = self.port_app_map();
        let prog = self.plan.compile(
            &port_app,
            self.cfg.qos.rotation_packages,
            self.cfg.crossbar.default_packages,
        )?;
        // Per-request allocation events (every `execute`) would
        // otherwise rewrite N² budget registers and force a full fabric
        // remirror even when the compiled image is unchanged — e.g. the
        // empty plan, where it is always the default image.
        if self.applied_program.as_ref() == Some(&prog) {
            return Ok(prog);
        }
        self.fabric.regfile.write_master_budgets(&prog.budgets)?;
        self.fabric.xbar.set_rotation_order(&prog.rotation)?;
        // Lower the same per-app shares into the bridge hop: the H2C
        // descriptor scheduler (DESIGN.md §15) serves per-app submit
        // queues in deficit-round-robin with these weights, so the
        // contract holds host-to-completion, not just past the crossbar.
        self.fabric.set_h2c_weights(&prog.app_packages);
        let cycle = self.fabric.now();
        let masters = prog.budgets.len();
        self.fabric
            .telemetry
            .emit_with(|| crate::telemetry::TraceEvent::PlanApplied { cycle, masters });
        self.applied_program = Some(prog.clone());
        Ok(prog)
    }

    /// Per-app bandwidth in use, **in share terms**: each resident
    /// app's effective fraction of the WRR rotation quantum in
    /// parts-per-[`SHARE_UNIT`], computed from the register-file view
    /// (the sum of its masters' programmed package budgets over the
    /// rotation quantum).  Best-effort apps report the share their
    /// default budgets actually occupy.
    pub fn bandwidth_shares(&self) -> Vec<(u32, u32)> {
        let quantum = self.cfg.qos.rotation_packages.max(1) as u64;
        // Sum packages per app first, convert to share once: summing
        // per-port floored shares would lose up to a ppu per master.
        let mut packages: Vec<(u32, u64)> = Vec::new();
        for (port, owner) in self.port_app_map().iter().enumerate() {
            let Some(app) = *owner else { continue };
            let budget = self
                .fabric
                .regfile
                .allowed_packages(0, port)
                .expect("owned port within layout");
            let budget = if budget == 0 {
                self.cfg.crossbar.default_packages
            } else {
                budget
            };
            match packages.iter_mut().find(|(a, _)| *a == app) {
                Some((_, pk)) => *pk += budget as u64,
                None => packages.push((app, budget as u64)),
            }
        }
        packages.sort_unstable_by_key(|&(a, _)| a);
        packages
            .into_iter()
            .map(|(a, pk)| (a, (pk * SHARE_UNIT as u64 / quantum) as u32))
            .collect()
    }

    /// Total bandwidth in use in share terms: the sum of
    /// [`Self::bandwidth_shares`], capped at [`SHARE_UNIT`].  Note that
    /// `execute` releases an app's regions on completion, so schedulers
    /// that score boards strictly *between* synchronous executes (the
    /// fleet and the threaded server both do) observe 0 here; a nonzero
    /// reading needs an allocation held across the scoring point.
    pub fn bandwidth_in_use(&self) -> u32 {
        self.bandwidth_shares()
            .iter()
            .map(|&(_, s)| s)
            .sum::<u32>()
            .min(SHARE_UNIT)
    }

    /// Share of the bandwidth plane available to new admissions, in
    /// parts-per-[`SHARE_UNIT`]: the plane not claimed by resident apps,
    /// scaled by the fraction of PR regions still free (a board whose
    /// regions are fenced or occupied can promise proportionally less,
    /// whatever its budget registers say).
    pub fn spare_share(&self) -> u32 {
        let total = self.cfg.fabric.num_pr_regions.max(1) as u64;
        let unclaimed =
            (SHARE_UNIT - self.bandwidth_in_use()) as u64;
        (unclaimed * self.available_regions() as u64 / total) as u32
    }

    // ------------------------------------------------------------------
    // allocation + programming
    // ------------------------------------------------------------------

    /// Plan the placement of `stages` given current availability: a
    /// maximal FPGA prefix, the rest on-server ("if there are not enough
    /// PR regions to host all modules, the remaining ones run on the
    /// server").
    /// Placement is cache-aware (DESIGN.md §16): each stage prefers, in
    /// order, the lowest-index resident region already holding its kind
    /// (rebind — zero ICAP), then the lowest-index free region, then
    /// the LRU-oldest non-matching resident (evict + restream), then
    /// the server.  With the cache off no region is ever `Resident`, so
    /// this degenerates to the legacy lowest-free-region-per-stage
    /// assignment exactly.
    pub fn plan(&self, stages: &[ModuleKind]) -> Vec<StagePlacement> {
        let mut claimed = vec![false; self.regions.len()];
        stages
            .iter()
            .map(|&kind| {
                let hit = (1..self.regions.len()).find(|&r| {
                    !claimed[r]
                        && self.regions[r] == RegionState::Resident { kind }
                });
                let free = || {
                    (1..self.regions.len()).find(|&r| {
                        !claimed[r]
                            && self.regions[r] == RegionState::Available
                    })
                };
                let lru_mismatch = || {
                    (1..self.regions.len())
                        .filter(|&r| {
                            !claimed[r]
                                && matches!(
                                    self.regions[r],
                                    RegionState::Resident { .. }
                                )
                        })
                        .min_by_key(|&r| (self.resident_stamp[r], r))
                };
                match hit.or_else(free).or_else(lru_mismatch) {
                    Some(region) => {
                        claimed[region] = true;
                        StagePlacement::Fpga { kind, region }
                    }
                    None => StagePlacement::OnServer { kind },
                }
            })
            .collect()
    }

    /// Program the register file for an app whose FPGA chain occupies
    /// `ports` in order: port0 -> ports[0] -> ... -> port0.  Errors with
    /// [`ElasticError::RegfileWindow`] when the app ID or any port falls
    /// outside the configured layout.
    fn program_chain(&mut self, app_id: u32, ports: &[usize]) -> Result<()> {
        let rf = &mut self.fabric.regfile;
        let first = ports.first().copied().unwrap_or(0);
        rf.set_app_destination(app_id as usize, 1 << first)?;
        rf.set_allowed_slaves(0, 1 << first)?;
        for (i, &p) in ports.iter().enumerate() {
            let next = ports.get(i + 1).copied().unwrap_or(0);
            rf.set_pr_destination(p, 1 << next)?;
            rf.set_allowed_slaves(p, 1 << next)?;
        }
        Ok(())
    }

    /// Program destinations for an app whose FPGA chain occupies
    /// `ports` in order (Table III destination registers), record the
    /// chain's port ownership, and **recompile the bandwidth plan** so
    /// the app's WRR budgets follow from its share contract rather than
    /// a caller-picked weight.  An empty `ports` detaches the app
    /// (destination = bridge, ownership cleared).
    ///
    /// This is the autoscaler's regfile-reprogram primitive: every
    /// grow/shrink transition re-runs it so traffic and bandwidth follow
    /// the new region map (§IV.A "updates the other module's destination
    /// addresses").
    pub fn program_app_chain(
        &mut self,
        app_id: u32,
        ports: &[usize],
    ) -> Result<()> {
        let layout = *self.fabric.regfile.layout();
        if !layout.covers_app(app_id as usize) {
            return Err(ElasticError::RegfileWindow(format!(
                "app {app_id} has no destination register in the \
                 configured {}-port layout",
                layout.num_ports()
            )));
        }
        for &p in ports {
            if !layout.covers_region(p) {
                return Err(ElasticError::RegfileWindow(format!(
                    "region {p} is outside the configured {}-port layout \
                     (regions 1..={})",
                    layout.num_ports(),
                    layout.num_pr_regions()
                )));
            }
        }
        self.program_chain(app_id, ports)?;
        for owner in self.chain_owner.iter_mut() {
            if *owner == Some(app_id) {
                *owner = None;
            }
        }
        for &p in ports {
            self.chain_owner[p] = Some(app_id);
        }
        self.apply_plan()?;
        Ok(())
    }

    /// Stream one region's bitstream through the timed ICAP model and
    /// drive the fabric until the module instantiates; returns the
    /// fabric cycles spent programming.  With [`Self::fast_path`] on,
    /// the deterministic word-streaming stretch fast-forwards through
    /// the busy-period horizon (DESIGN.md §12) — same cycle count, a
    /// handful of executed ticks — which is what makes the autoscaler's
    /// ICAP-timed actuation cheap at fleet scale.  The installed-module
    /// predicate is invariant over skipped stretches (installation
    /// happens only at the ICAP completion tick, which always executes),
    /// so both modes observe the identical completion cycle.
    fn program_region_icap(
        &mut self,
        region: usize,
        kind: ModuleKind,
        app_id: u32,
    ) -> Result<u64> {
        self.fabric.reconfigure(region, kind, app_id)?;
        let words = (self.cfg.manager.bitstream_bytes / 4) as u64;
        let budget = crate::icap::Icap::expected_cycles(words) + 16;
        let before = self.fabric.now();
        let installed = self.fabric.drive_until(
            before + budget,
            self.fast_path,
            |f| f.module_at(region).is_some(),
        );
        let spent = self.fabric.now() - before;
        if !installed {
            return Err(ElasticError::Allocation(format!(
                "reconfiguration of region {region} failed"
            )));
        }
        Ok(spent)
    }

    /// Install the FPGA stages of a placement; returns the chain ports
    /// and the ICAP cycles spent (0 on the static path and for every
    /// cache-hit rebind).
    fn install(
        &mut self,
        app_id: u32,
        placement: &[StagePlacement],
    ) -> Result<(Vec<usize>, u64)> {
        let mut ports = Vec::new();
        // Regions claimed through the cache hit path: already resident
        // with the required kind, rebound below via the register file
        // alone (DESIGN.md §16).
        let mut rebinds: Vec<usize> = Vec::new();
        let mut icap_cycles = 0u64;
        for p in placement {
            if let StagePlacement::Fpga { kind, region } = *p {
                let layout = self.fabric.regfile.layout();
                if !layout.covers_region(region) {
                    // A region the layout cannot program (explicit
                    // placements may name one) would run with power-on
                    // defaults; refuse with the typed error.
                    return Err(ElasticError::RegfileWindow(format!(
                        "region {region} is outside the configured \
                         {}-port layout (regions 1..={})",
                        layout.num_ports(),
                        layout.num_pr_regions()
                    )));
                }
                match self.regions[region] {
                    RegionState::Available => {}
                    RegionState::Resident { kind: res } if res == kind => {
                        rebinds.push(region);
                    }
                    RegionState::Resident { kind: res } => {
                        // A different kind needs this region: evict the
                        // cached configuration and restream cold.  The
                        // blanking is lazy (free) — the programming
                        // below overwrites the region either way.
                        let cycle = self.fabric.now();
                        self.fabric.telemetry.emit_with(|| {
                            crate::telemetry::TraceEvent::CacheEvict {
                                cycle,
                                node: 0,
                                region,
                                kind: res.name(),
                            }
                        });
                    }
                    _ => {
                        return Err(ElasticError::Allocation(format!(
                            "region {region} not available"
                        )));
                    }
                }
                self.regions[region] = RegionState::Allocated { app_id, kind };
                ports.push(region);
            }
        }
        // Destinations first, so module install sees the right regfile;
        // then the plan, so the chain's masters carry the app's share
        // (not power-on defaults) for the whole execution.
        self.program_chain(app_id, &ports)?;
        self.apply_plan()?;
        for p in placement {
            if let StagePlacement::Fpga { kind, region } = *p {
                if rebinds.contains(&region) {
                    // Cache hit: scrub + rebind through the register
                    // file alone.  A fresh module instance carries zero
                    // architectural state from the previous tenant, the
                    // per-region error latch is cleared, and no ICAP
                    // traffic is issued.
                    self.fabric.install_static_module(region, kind, app_id);
                    self.fabric.regfile.set_pr_error(region, None)?;
                    self.cache_hits += 1;
                    let words =
                        (self.cfg.manager.bitstream_bytes / 4) as u64;
                    let elided = if self.use_icap {
                        crate::icap::Icap::expected_cycles(words)
                    } else {
                        0
                    };
                    self.icap_cycles_elided += elided;
                    let cycle = self.fabric.now();
                    self.fabric.telemetry.emit_with(|| {
                        crate::telemetry::TraceEvent::IcapElided {
                            cycle,
                            app: app_id,
                            node: 0,
                            region,
                            cycles: elided,
                        }
                    });
                } else {
                    if self.cache_enabled() {
                        self.cache_misses += 1;
                    }
                    if self.use_icap {
                        icap_cycles +=
                            self.program_region_icap(region, kind, app_id)?;
                    } else {
                        self.fabric.install_static_module(region, kind, app_id);
                    }
                }
            }
        }
        Ok((ports, icap_cycles))
    }

    /// Reserve `region` for `app_id` and program `kind` into it through
    /// the timed, serialized ICAP model; returns the fabric cycles the
    /// programming took.  Unlike [`execute`](Self::execute), the
    /// reservation is **held** until [`blank_region`](Self::blank_region)
    /// or [`release_app`](Self::release_app) — this is the allocation
    /// primitive of the closed-loop autoscaler ([`crate::autoscale`]),
    /// where PR regions belong to an app across many requests.
    pub fn reserve_region(
        &mut self,
        app_id: u32,
        kind: ModuleKind,
        region: usize,
    ) -> Result<u64> {
        if !self.fabric.regfile.layout().covers_region(region) {
            return Err(ElasticError::RegfileWindow(format!(
                "region {region} is outside the configured {}-port layout",
                self.fabric.regfile.layout().num_ports()
            )));
        }
        match self.regions[region] {
            RegionState::Available => {}
            RegionState::Resident { kind: res } if res == kind => {
                // Cache hit: the region already holds this kind's
                // bitstream — rebind through the register file, no ICAP
                // streaming, zero cycles spent.
                self.regions[region] = RegionState::Allocated { app_id, kind };
                self.fabric.install_static_module(region, kind, app_id);
                self.fabric.regfile.set_pr_error(region, None)?;
                self.cache_hits += 1;
                let words = (self.cfg.manager.bitstream_bytes / 4) as u64;
                let elided = crate::icap::Icap::expected_cycles(words);
                self.icap_cycles_elided += elided;
                let cycle = self.fabric.now();
                self.fabric.telemetry.emit_with(|| {
                    crate::telemetry::TraceEvent::IcapElided {
                        cycle,
                        app: app_id,
                        node: 0,
                        region,
                        cycles: elided,
                    }
                });
                return Ok(0);
            }
            RegionState::Resident { kind: res } => {
                // Wrong kind resident: evict (lazy — the ICAP stream
                // below overwrites the region) and program cold.
                let cycle = self.fabric.now();
                self.fabric.telemetry.emit_with(|| {
                    crate::telemetry::TraceEvent::CacheEvict {
                        cycle,
                        node: 0,
                        region,
                        kind: res.name(),
                    }
                });
            }
            _ => {
                return Err(ElasticError::Allocation(format!(
                    "region {region} not available"
                )));
            }
        }
        self.regions[region] = RegionState::Allocated { app_id, kind };
        if self.cache_enabled() {
            self.cache_misses += 1;
        }
        match self.program_region_icap(region, kind, app_id) {
            Ok(cycles) => Ok(cycles),
            Err(e) => {
                self.fabric.clear_region(region);
                self.regions[region] = RegionState::Available;
                Err(e)
            }
        }
    }

    /// Release a reserved region by streaming a blanking (grey-box)
    /// bitstream through the ICAP — the PR practice for decoupling a
    /// region — then freeing it; returns the ICAP fabric cycles spent.
    pub fn blank_region(&mut self, region: usize) -> Result<u64> {
        if region == 0 || region >= self.regions.len() {
            return Err(ElasticError::Allocation(format!(
                "region {region} out of range"
            )));
        }
        let (app_id, kind) = match &self.regions[region] {
            RegionState::Allocated { app_id, kind } => (*app_id, *kind),
            other => {
                return Err(ElasticError::Allocation(format!(
                    "region {region} not allocated (state {other:?})"
                )))
            }
        };
        // The blanking bitstream is modeled at the same size as a module
        // bitstream; the ICAP serializes it like any other programming.
        let spent = self.program_region_icap(region, kind, app_id)?;
        self.fabric.clear_region(region);
        self.regions[region] = RegionState::Available;
        Ok(spent)
    }

    /// Release an app's regions and drop its chain ownership.  Budget
    /// registers keep the last compiled image; the next allocation
    /// event recompiles the plan over the new ownership map.
    ///
    /// With the configuration cache on, regions whose module was
    /// actually programmed are **parked** `Resident { kind }` instead of
    /// cleared (DESIGN.md §16): the fabric scrubs the module's
    /// architectural state and isolates the port, but the bitstream
    /// identity survives so the next request needing the same kind
    /// rebinds for free.  Regions whose programming never completed
    /// (install-failure rollback) always clear — caching them would
    /// poison the hit path.  The resident set is then LRU-trimmed to
    /// `manager.config_cache_regions`.
    pub fn release_app(&mut self, app_id: u32) {
        for r in 1..self.regions.len() {
            if let RegionState::Allocated { app_id: a, kind } = self.regions[r]
            {
                if a != app_id {
                    continue;
                }
                if self.cache_enabled() && self.fabric.module_at(r).is_some()
                {
                    self.fabric.park_region(r, kind);
                    self.regions[r] = RegionState::Resident { kind };
                    self.cache_clock += 1;
                    self.resident_stamp[r] = self.cache_clock;
                } else {
                    self.fabric.clear_region(r);
                    self.regions[r] = RegionState::Available;
                }
            }
        }
        for owner in self.chain_owner.iter_mut() {
            if *owner == Some(app_id) {
                *owner = None;
            }
        }
        if self.cache_enabled() {
            self.trim_residents();
        }
    }

    /// Park one allocated region into the configuration cache without
    /// any ICAP traffic — the autoscaler's retire path with the cache
    /// on (the cache-off path stays [`Self::blank_region`]).
    pub fn park_region(&mut self, region: usize) -> Result<()> {
        if region == 0 || region >= self.regions.len() {
            return Err(ElasticError::Allocation(format!(
                "region {region} out of range"
            )));
        }
        if !self.cache_enabled() {
            return Err(ElasticError::Allocation(
                "configuration cache is off (manager.config_cache_regions = 0)"
                    .into(),
            ));
        }
        match self.regions[region] {
            RegionState::Allocated { kind, .. } => {
                self.fabric.park_region(region, kind);
                self.regions[region] = RegionState::Resident { kind };
                self.cache_clock += 1;
                self.resident_stamp[region] = self.cache_clock;
                self.trim_residents();
                Ok(())
            }
            ref other => Err(ElasticError::Allocation(format!(
                "region {region} not allocated (state {other:?})"
            ))),
        }
    }

    // ------------------------------------------------------------------
    // execution
    // ------------------------------------------------------------------

    /// Execute an application request end to end under the *current*
    /// availability.  This is the Fig-5 primitive: the FPGA prefix runs
    /// on the fabric simulator (cycle-accurately), the on-server suffix
    /// runs through PJRT, and the returned report carries the timing
    /// model's cost breakdown plus verification against the golden model.
    pub fn execute(&mut self, req: &AppRequest) -> Result<AppReport> {
        let placement = self.plan(&req.stages);
        self.execute_placed(req, &placement)
    }

    /// Execute with an explicit placement (benches pin cases this way).
    pub fn execute_placed(
        &mut self,
        req: &AppRequest,
        placement: &[StagePlacement],
    ) -> Result<AppReport> {
        if req.data.len() % crate::xdma::BRIDGE_BUFFER_WORDS != 0 {
            return Err(ElasticError::Server(format!(
                "payload length {} not a multiple of the {}-word burst",
                req.data.len(),
                crate::xdma::BRIDGE_BUFFER_WORDS
            )));
        }
        let mut tl = ExecutionTimeline::new();
        let (ports, icap_cycles) = match self.install(req.app_id, placement) {
            Ok(x) => x,
            Err(e) => {
                // Roll back any regions taken before the failure.
                self.release_app(req.app_id);
                return Err(e);
            }
        };
        tl.reconfig(icap_cycles);
        let fpga_stages = ports.len();
        let bytes = req.data.len() * 4;

        // ---- FPGA prefix ----
        let mut intermediate: Vec<u32>;
        if fpga_stages > 0 {
            tl.h2c(bytes);
            // Host-driver policy: all of an app's bursts go to one H2C
            // channel (app_id % channels).  Cross-channel service order at
            // the bridge is round-robin and would permute bursts of a
            // single app spread over channels; per-app affinity preserves
            // intra-app order exactly as a real XDMA driver would by
            // pinning a stream to a descriptor ring.
            let channel = req.app_id as usize % crate::xdma::H2C_CHANNELS;
            for chunk in req.data.chunks(crate::xdma::BRIDGE_BUFFER_WORDS) {
                if let Err(e) = self.fabric.h2c_push(
                    channel,
                    H2cBurst { app_id: req.app_id, words: chunk.to_vec() },
                ) {
                    self.release_app(req.app_id);
                    return Err(e);
                }
            }
            let before = self.fabric.now();
            // Horizon fast-path and oracle are cycle-exact, so the
            // memoized service costs the fleet derives from this run are
            // identical either way (`tests/fastpath_equivalence.rs`).
            if self.fast_path {
                self.fabric.run_until_idle_fast(100_000_000)?;
            } else {
                self.fabric.run_until_idle(100_000_000)?;
            }
            tl.fabric(self.fabric.now() - before);
            self.fabric.flush_c2h();
            intermediate = self.fabric.take_app_output(req.app_id);
            tl.c2h(bytes);
            if let Some(err) = crate::fabric::app_error(&self.fabric, req.app_id) {
                // App-error spill: capture the preceding event window so
                // the masked violation arrives with its context.
                self.fabric.telemetry.dump(&format!(
                    "app {} spilled {}",
                    req.app_id,
                    crate::telemetry::wb_error_name(err)
                ));
                self.release_app(req.app_id);
                return Err(ElasticError::Wishbone(err));
            }
            if intermediate.len() != req.data.len() {
                self.release_app(req.app_id);
                return Err(ElasticError::Verify(format!(
                    "fabric returned {} of {} words",
                    intermediate.len(),
                    req.data.len()
                )));
            }
        } else {
            intermediate = req.data.clone();
        }

        // ---- on-server suffix (real compute via PJRT) ----
        for p in placement {
            if let StagePlacement::OnServer { kind } = *p {
                let t0 = std::time::Instant::now();
                intermediate = self.run_stage_on_server(kind, &intermediate)?;
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                tl.cpu_stage(kind.name(), Some(wall_ms));
            }
        }

        // ---- verify against the golden model ----
        let expected = golden_chain(&req.stages, &req.data);
        let verified = intermediate == expected;
        if self.cfg.manager.verify_results && !verified {
            self.fabric
                .telemetry
                .dump(&format!("app {} output mismatch vs golden model", req.app_id));
            self.release_app(req.app_id);
            return Err(ElasticError::Verify(format!(
                "app {} output mismatch vs golden model",
                req.app_id
            )));
        }

        let cost: CostBreakdown = evaluate(&self.cfg, &tl);
        let span = crate::telemetry::RequestSpan::decompose(&self.cfg, &cost, 0);
        self.release_app(req.app_id);
        Ok(AppReport {
            app_id: req.app_id,
            output: intermediate,
            placement: placement.to_vec(),
            fpga_stages,
            cost,
            span,
            timeline: tl,
            verified,
        })
    }

    /// Elastic execution: begin with the current availability; after each
    /// entry of `release_after_segments` more data has flowed, one more
    /// region becomes available and the next on-server stage migrates
    /// onto the FPGA (§IV.A's "checks again if there are any PR regions
    /// released [...] and updates the other module's destination
    /// addresses").  Returns one report per segment.
    pub fn execute_elastic(
        &mut self,
        req: &AppRequest,
        segments: usize,
    ) -> Result<Vec<AppReport>> {
        // Typed refusals, not asserts: a bad caller must not be able to
        // panic the shell (a zero segment count would also divide by
        // zero, then `chunks(0)` would panic below).
        if segments == 0 {
            return Err(ElasticError::Server(
                "elastic execution needs at least one segment".into(),
            ));
        }
        if req.data.len() % segments != 0 {
            return Err(ElasticError::Server(format!(
                "payload of {} words does not split into {segments} \
                 equal segments",
                req.data.len()
            )));
        }
        let seg_words = req.data.len() / segments;
        if seg_words == 0 || seg_words % crate::xdma::BRIDGE_BUFFER_WORDS != 0
        {
            return Err(ElasticError::Server(format!(
                "segment length {seg_words} must stay a nonzero multiple \
                 of the {}-word burst",
                crate::xdma::BRIDGE_BUFFER_WORDS
            )));
        }
        let mut reports = Vec::new();
        for (i, seg) in req.data.chunks(seg_words).enumerate() {
            let sub = AppRequest {
                app_id: req.app_id,
                data: seg.to_vec(),
                stages: req.stages.clone(),
            };
            reports.push(self.execute(&sub)?);
            // A region frees between segments (elasticity event).
            if i + 1 < segments {
                self.unfence_regions(1);
            }
        }
        Ok(reports)
    }

    /// Run one stage on the server.  PJRT-eligible kernels (the seeds
    /// and artifact-backed registrations) use the AOT artifact when its
    /// geometry matches (the real compute path); table-driven kernels
    /// and geometry mismatches run the registered behavior directly
    /// (also the runtime-less unit-test path).
    fn run_stage_on_server(
        &self,
        kind: ModuleKind,
        data: &[u32],
    ) -> Result<Vec<u32>> {
        if let (Some(rt), Some(artifact)) =
            (&self.runtime, kind.pjrt_artifact())
        {
            if let Some(out) = rt.run(artifact, data.to_vec())? {
                return Ok(out);
            }
        }
        Ok(kind.apply_buf(data))
    }
}

/// Golden reference for a stage chain.
pub fn golden_chain(stages: &[ModuleKind], data: &[u32]) -> Vec<u32> {
    let mut cur = data.to_vec();
    for &s in stages {
        cur = s.apply_buf(&cur);
    }
    cur
}

/// Convenience: the Fig-5 pipeline golden result.
pub fn golden_pipeline(data: &[u32]) -> Vec<u32> {
    hamming::pipeline_buf(data, hamming::MULT_CONSTANT)
}

#[cfg(test)]
mod tests;
