//! Application request/report types.

use crate::modules::ModuleKind;
use crate::telemetry::RequestSpan;
use crate::timing::{CostBreakdown, ExecutionTimeline};

/// Where one stage of an application runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagePlacement {
    /// On the fabric, in PR region `region` (= crossbar port).
    Fpga { kind: ModuleKind, region: usize },
    /// On the server (PJRT execution of the same artifact).
    OnServer { kind: ModuleKind },
}

impl StagePlacement {
    /// The stage's module kind regardless of placement.
    pub fn kind(&self) -> ModuleKind {
        match *self {
            StagePlacement::Fpga { kind, .. } => kind,
            StagePlacement::OnServer { kind } => kind,
        }
    }

    /// Is this stage on the FPGA?
    pub fn is_fpga(&self) -> bool {
        matches!(self, StagePlacement::Fpga { .. })
    }
}

/// One acceleration request: a payload and its stage chain.
#[derive(Debug, Clone)]
pub struct AppRequest {
    /// Application ID — an index into the register file's app-ID
    /// destination bank (one register per crossbar port).
    pub app_id: u32,
    /// Payload words (length must be a multiple of the 8-word burst).
    pub data: Vec<u32>,
    /// Stage chain; defaults to the Fig-5 pipeline.
    pub stages: Vec<ModuleKind>,
}

impl AppRequest {
    /// The paper's use case: `data` through multiplier -> encoder ->
    /// decoder.
    pub fn pipeline(app_id: u32, data: Vec<u32>) -> Self {
        Self { app_id, data, stages: ModuleKind::pipeline().to_vec() }
    }
}

/// The result of executing one request.
#[derive(Debug, Clone)]
pub struct AppReport {
    pub app_id: u32,
    /// Final output words.
    pub output: Vec<u32>,
    /// Where each stage ran.
    pub placement: Vec<StagePlacement>,
    /// Number of stages that ran on the fabric.
    pub fpga_stages: usize,
    /// Timing-model cost breakdown.
    pub cost: CostBreakdown,
    /// Cycle-exact latency decomposition of `cost` (DESIGN.md §14):
    /// the service components sum to
    /// [`crate::fleet::service_cycles`]`(cfg, &cost)` exactly.
    pub span: RequestSpan,
    /// Raw timed events.
    pub timeline: ExecutionTimeline,
    /// Output matched the golden model?
    pub verified: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_request_has_three_stages() {
        let r = AppRequest::pipeline(0, vec![0; 8]);
        assert_eq!(r.stages.len(), 3);
        assert_eq!(r.stages[0], ModuleKind::Multiplier);
        assert_eq!(r.stages[2], ModuleKind::HammingDecoder);
    }

    #[test]
    fn placement_accessors() {
        let f = StagePlacement::Fpga { kind: ModuleKind::Multiplier, region: 1 };
        let s = StagePlacement::OnServer { kind: ModuleKind::HammingEncoder };
        assert!(f.is_fpga() && !s.is_fpga());
        assert_eq!(f.kind(), ModuleKind::Multiplier);
        assert_eq!(s.kind(), ModuleKind::HammingEncoder);
    }
}
