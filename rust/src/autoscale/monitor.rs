//! Per-app demand monitor: the sensing half of the control loop.
//!
//! Fed from [`crate::metrics`] primitives at dispatch time, it closes a
//! window at every control tick and emits the [`DemandSignals`] the
//! scaling policies act on: instantaneous queue depth, an EWMA of the
//! arrival rate, and the window's queue-wait distribution (p99 / mean /
//! EWMA trend).

use crate::metrics::{CycleRecorder, Ewma};

/// The demand observed for one app over the last control window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandSignals {
    /// Requests dispatched but not yet started at the tick instant.
    pub queue_depth: usize,
    /// EWMA of the per-window arrival rate (requests per second).
    pub arrival_rate_ewma: f64,
    /// Change of the arrival-rate EWMA since the previous window
    /// (req/s per window): the feed-forward signal the predictive
    /// policy extrapolates.  Positive = demand ramping up.
    pub arrival_rate_slope: f64,
    /// p99 queue wait over the window, in fabric cycles.
    pub p99_wait_cycles: u64,
    /// Mean queue wait over the window, in fabric cycles.
    pub mean_wait_cycles: f64,
    /// EWMA trend of queue waits in record order, in fabric cycles.
    pub wait_ewma_cycles: f64,
    /// Arrivals observed in the window.
    pub arrivals: u64,
}

/// Windowed per-app demand sensor.
#[derive(Debug, Clone)]
pub struct DemandMonitor {
    alpha: f64,
    /// Start cycles of dispatched requests that may still be queued.
    outstanding: Vec<u64>,
    arrivals_window: u64,
    wait_window: CycleRecorder,
    rate_ewma: Ewma,
}

impl DemandMonitor {
    /// New monitor with EWMA smoothing factor `alpha`.
    pub fn new(alpha: f64) -> Self {
        Self {
            alpha,
            outstanding: Vec::new(),
            arrivals_window: 0,
            wait_window: CycleRecorder::with_ewma(alpha),
            rate_ewma: Ewma::new(alpha),
        }
    }

    /// Record one dispatched request: its scheduled start cycle and the
    /// queue wait it will experience.
    pub fn on_dispatch(&mut self, start_cycle: u64, wait_cycles: u64) {
        self.outstanding.push(start_cycle);
        self.arrivals_window += 1;
        self.wait_window.record(wait_cycles);
    }

    /// Close the window at cycle `now` (a window of `window_s` seconds):
    /// compute the signals and reset for the next window.
    pub fn observe(&mut self, now: u64, window_s: f64) -> DemandSignals {
        self.outstanding.retain(|&s| s > now);
        let prev_rate = if self.rate_ewma.is_primed() {
            Some(self.rate_ewma.value())
        } else {
            None
        };
        let rate =
            self.rate_ewma.update(self.arrivals_window as f64 / window_s);
        let signals = DemandSignals {
            queue_depth: self.outstanding.len(),
            arrival_rate_ewma: rate,
            // First window: no history, slope 0 (never extrapolate from
            // a single sample).
            arrival_rate_slope: prev_rate.map(|p| rate - p).unwrap_or(0.0),
            p99_wait_cycles: self.wait_window.percentile(0.99),
            mean_wait_cycles: self.wait_window.mean(),
            wait_ewma_cycles: self.wait_window.ewma().unwrap_or(0.0),
            arrivals: self.arrivals_window,
        };
        self.arrivals_window = 0;
        self.wait_window = CycleRecorder::with_ewma(self.alpha);
        signals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_reset_but_rate_ewma_persists() {
        let mut m = DemandMonitor::new(0.5);
        // Window 1: two requests, one still queued at the tick.
        m.on_dispatch(50, 0);
        m.on_dispatch(200, 150);
        let s1 = m.observe(100, 1.0);
        assert_eq!(s1.queue_depth, 1, "start 200 > now 100 is still queued");
        assert_eq!(s1.arrivals, 2);
        assert!((s1.arrival_rate_ewma - 2.0).abs() < 1e-12);
        assert_eq!(s1.p99_wait_cycles, 150);
        // Window 2: empty; the wait window resets, the rate EWMA decays.
        let s2 = m.observe(300, 1.0);
        assert_eq!(s2.queue_depth, 0, "request 200 started by now");
        assert_eq!(s2.p99_wait_cycles, 0);
        assert!((s2.arrival_rate_ewma - 1.0).abs() < 1e-12, "EWMA of 2 then 0");
    }

    #[test]
    fn slope_tracks_the_rate_ramp() {
        let mut m = DemandMonitor::new(0.5);
        // Window 1: 2 req/s.  No history yet -> slope 0.
        m.on_dispatch(1, 0);
        m.on_dispatch(2, 0);
        let s1 = m.observe(10, 1.0);
        assert_eq!(s1.arrival_rate_slope, 0.0, "no slope from one sample");
        // Window 2: 6 req/s.  EWMA 2 -> 4; slope +2 per window.
        for i in 0..6 {
            m.on_dispatch(20 + i, 0);
        }
        let s2 = m.observe(30, 1.0);
        assert!((s2.arrival_rate_ewma - 4.0).abs() < 1e-12);
        assert!((s2.arrival_rate_slope - 2.0).abs() < 1e-12, "ramp up");
        // Window 3: silence.  EWMA 4 -> 2; slope -2 per window.
        let s3 = m.observe(50, 1.0);
        assert!((s3.arrival_rate_slope + 2.0).abs() < 1e-12, "ramp down");
    }
}
