//! Oracle-backed service-cost model for the control plane.
//!
//! The engine serves traces in virtual time, so it needs the service
//! time of "`stages` over `words` payload words with `fpga` stages on
//! fabric" without running every request through the cycle simulator.
//! Fabric timing is data-independent (the fleet's fast-path relies on
//! the same fact), so each distinct shape is executed **once** on a
//! scratch [`ElasticManager`] — cycle-accurately, verified against the
//! golden model — and the measured cost is memoized.  This mirrors
//! [`crate::fleet`]'s shape cache, but with the on-fabric stage count as
//! an explicit knob: the autoscaler prices *partial* slices (chain
//! prefix on fabric, suffix on the server CPU) and pure-CPU service.

use std::collections::HashMap;

use crate::config::SystemConfig;
use crate::manager::{AppRequest, ElasticManager};
use crate::modules::ModuleKind;
use crate::util::SplitMix64;
use crate::Result;

/// A service shape: everything that determines its timing.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CostKey {
    stages: Vec<ModuleKind>,
    words: usize,
    fpga_stages: usize,
}

/// Memoizing cost oracle.
pub struct CostModel {
    manager: ElasticManager,
    cache: HashMap<CostKey, u64>,
    /// Cycle-accurate executions performed (one per distinct shape).
    pub oracle_runs: u64,
}

impl CostModel {
    /// A scratch single-board oracle under `cfg` (static module installs:
    /// reconfiguration time is charged by the actuator at transition
    /// time, not per request).
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            manager: ElasticManager::new(cfg.clone(), None),
            cache: HashMap::new(),
            oracle_runs: 0,
        }
    }

    /// Service time in fabric cycles for `stages` over a `words`-word
    /// payload with the first `fpga` stages hosted on fabric (clamped to
    /// the chain length and the board's region count).
    pub fn service_cycles(
        &mut self,
        cfg: &SystemConfig,
        stages: &[ModuleKind],
        words: usize,
        fpga: usize,
    ) -> Result<u64> {
        let total = cfg.fabric.num_pr_regions;
        let fpga = fpga.min(stages.len()).min(total);
        let key = CostKey { stages: stages.to_vec(), words, fpga_stages: fpga };
        if let Some(&cycles) = self.cache.get(&key) {
            return Ok(cycles);
        }
        // Shape availability so exactly `fpga` regions are free, then run
        // the cycle-accurate oracle once.  Payload values are irrelevant
        // to timing; a seeded buffer keeps the golden-model verification
        // meaningful.
        self.manager.unfence_all();
        let fenced = self.manager.fence_regions(total - fpga);
        debug_assert_eq!(fenced, total - fpga);
        let mut data = vec![0u32; words];
        SplitMix64::new(0xC057 ^ words as u64).fill_u32(&mut data);
        let req = AppRequest { app_id: 0, data, stages: stages.to_vec() };
        let report = self.manager.execute(&req)?;
        self.oracle_runs += 1;
        debug_assert!(report.verified, "oracle run failed golden verification");
        debug_assert_eq!(report.fpga_stages, fpga);
        let cycles = crate::fleet::service_cycles(cfg, &report.cost);
        self.cache.insert(key, cycles);
        Ok(cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_fabric_shapes_price_monotonically() {
        // With the paper's heavy 5.36 ms descriptor round, entering the
        // fabric at all costs two PCIe rounds; once on fabric, more FPGA
        // stages displace 3.06 ms CPU stages and get strictly cheaper.
        let cfg = SystemConfig::paper_defaults();
        let mut cm = CostModel::new(&cfg);
        let chain = ModuleKind::pipeline().to_vec();
        let costs: Vec<u64> = (0..=3)
            .map(|fpga| cm.service_cycles(&cfg, &chain, 64, fpga).unwrap())
            .collect();
        assert!(costs[1] > costs[2] && costs[2] > costs[3], "{costs:?}");
        assert!(costs[0] > 0);
        assert_eq!(cm.oracle_runs, 4);
        // Memoized: replays are free of oracle executions.
        let again = cm.service_cycles(&cfg, &chain, 64, 3).unwrap();
        assert_eq!(again, costs[3]);
        assert_eq!(cm.oracle_runs, 4);
        // Requests larger than the chain clamp.
        let clamped = cm.service_cycles(&cfg, &chain, 64, 9).unwrap();
        assert_eq!(clamped, costs[3]);
    }
}
