//! Closed-loop elasticity control plane: the paper's *envisioned
//! resource manager* ("can increase or decrease the number of PR regions
//! allocated to an application based on its acceleration requirements
//! and PR regions' availability", §VI), realized as a demand-driven
//! autoscaler above the board [`crate::cluster`] and its per-board
//! [`crate::manager`]s (the same substrate [`crate::fleet`] schedules;
//! the threaded [`crate::server`] runs the lane-level on-line variant).
//!
//! The loop has the classic four parts (DESIGN.md §9):
//!
//! 1. **Monitor** ([`DemandMonitor`]) — per-app windowed signals from
//!    [`crate::metrics`]: queue depth at the tick, arrival-rate EWMA,
//!    p99 / mean / EWMA queue waits.
//! 2. **Policy** ([`ScalingPolicy`]) — threshold + hysteresis decisions
//!    mapping demand to a target PR-region count; three implementations
//!    ship: reactive [`TargetQueueDepth`] and [`LatencySlo`], and the
//!    feed-forward [`Predictive`] driven by the arrival-rate EWMA slope.
//! 3. **Actuator** — steps allocations toward the target: every grow
//!    programs regions through the **timed, serialized ICAP model**
//!    ([`crate::manager::ElasticManager::reserve_region`]) and every
//!    shrink drains then blanks them
//!    ([`crate::manager::ElasticManager::blank_region`]); every
//!    transition reprograms the register file's destination addresses
//!    and **recompiles the per-app bandwidth plan** — the app's share
//!    contract follows its footprint and the [`crate::qos`] compiler
//!    lowers it to WRR budgets
//!    ([`crate::manager::ElasticManager::program_app_chain`]).  Grows
//!    prefer topping up partial slices (defragmentation) before opening
//!    a chain on a new board; churn re-placement migrates lost chains
//!    across fabrics.
//! 4. **Churn** ([`ChurnTrace`]) — boards leaving/joining and regions
//!    fenced `Offline` mid-trace, applied gracefully (dispatched work
//!    drains; nothing is preempted).
//!
//! Serving runs in virtual fabric cycles between control ticks, exactly
//! like the fleet simulator: each app owns *slices* (a chain of reserved
//! regions on one board, at most one slice per board) plus one on-server
//! CPU lane; a request goes to the lane that completes it earliest, with
//! service times from the memoized cycle-accurate oracle ([`CostModel`]).
//! A static-allocation baseline (same engine, `reactive = false`, even
//! region split) quantifies what the closed loop buys: strictly higher
//! PR-region utilization at equal-or-better p99 queue wait on
//! diurnal-with-churn traces — pinned by `rust/tests/autoscale.rs` and
//! demonstrated at 100k-request scale by `examples/autoscale_serving.rs`.

mod churn;
mod cost;
mod monitor;
mod policy;

pub use churn::{ChurnEvent, ChurnTrace};
pub use cost::CostModel;
pub use monitor::{DemandMonitor, DemandSignals};
pub use policy::{
    DemandSnapshot, LatencySlo, PolicyKind, Predictive, ScalingPolicy,
    StaticPolicy, TargetQueueDepth,
};

use std::cmp::Ordering;

use crate::cluster::{Cluster, PlacementPolicy};
use crate::config::SystemConfig;
use crate::manager::{AppRequest, RegionState};
use crate::metrics::CycleRecorder;
use crate::modules::ModuleKind;
use crate::telemetry::{TraceEvent as TelemetryEvent, Tracer};
use crate::workload::{self, TraceEvent};
use crate::Result;

/// What a recorded allocation transition was.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionKind {
    /// Regions added to an app (policy decision or churn re-placement).
    Grow,
    /// Regions drained, blanked and returned to the pool.
    Shrink,
    /// Hardware-driven change (board loss, static re-install on rejoin).
    Churn,
}

/// One recorded grow/shrink/churn transition: the placement history.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    /// Virtual cycle the decision was actuated at.
    pub at_cycle: u64,
    /// Application whose allocation changed.
    pub app_id: u32,
    /// Board the regions live on.
    pub node: usize,
    /// Regions added (grow) or removed (shrink/churn).
    pub regions: Vec<usize>,
    /// Transition kind.
    pub kind: TransitionKind,
    /// Indices into [`AutoscaleReport::icap_events`] for the ICAP
    /// programmings this transition scheduled.
    pub icap_events: Vec<usize>,
    /// Node regfile write-generation before/after: `after > before`
    /// proves the transition reprogrammed destinations + WRR weights.
    pub regfile_before: u64,
    pub regfile_after: u64,
}

/// What an ICAP programming event wrote.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IcapEventKind {
    /// A module bitstream instantiating `ModuleKind`.
    Program(ModuleKind),
    /// A blanking (grey-box) bitstream decoupling the region.
    Blank,
}

/// One serialized ICAP programming on one board.
#[derive(Debug, Clone, PartialEq)]
pub struct IcapEvent {
    /// Board whose single ICAP port served the programming.
    pub node: usize,
    /// Target PR region.
    pub region: usize,
    /// Owning application.
    pub app_id: u32,
    /// Bitstream kind.
    pub kind: IcapEventKind,
    /// Virtual cycle the ICAP began streaming (respects the port's
    /// serialization: never overlaps another event on the same node).
    pub start_cycle: u64,
    /// Virtual cycle programming completed.
    pub end_cycle: u64,
}

/// Engine knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Control-loop period in virtual milliseconds.
    pub tick_ms: f64,
    /// Ticks an app must wait between policy-driven transitions.
    pub cooldown_ticks: u64,
    /// Full slices per app at t = 0 in reactive mode.
    pub initial_full_slices: usize,
    /// Explicit per-app initial region count (overrides the mode rule:
    /// reactive starts at `initial_full_slices` chains, static splits
    /// the fleet's regions evenly).
    pub initial_regions_per_app: Option<usize>,
    /// Queue-wait SLO for the attainment metric, in milliseconds.
    pub slo_wait_ms: f64,
    /// EWMA smoothing factor for the demand monitor.
    pub ewma_alpha: f64,
    /// `false` = static baseline: no policy actuation, no churn
    /// re-placement (lost boards restore their original slices on
    /// rejoin, as a fixed partitioning would).
    pub reactive: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            tick_ms: 100.0,
            cooldown_ticks: 2,
            initial_full_slices: 1,
            initial_regions_per_app: None,
            slo_wait_ms: 25.0,
            ewma_alpha: 0.3,
            reactive: true,
        }
    }
}

/// Aggregate result of one engine run.
#[derive(Debug)]
pub struct AutoscaleReport {
    /// Policy that drove the run.
    pub policy: String,
    /// Requests served (all of them; the engine loses none).
    pub completed: u64,
    /// Virtual cycle of the last completion.
    pub makespan_cycles: u64,
    /// Queue-wait distribution (start - arrival).
    pub queue_wait: CycleRecorder,
    /// End-to-end latency distribution (completion - arrival).
    pub latency: CycleRecorder,
    /// Fraction of requests whose queue wait met the SLO.
    pub slo_attainment: f64,
    /// Region-cycles held by in-service work over alive region-cycles:
    /// the PR-region utilization the autoscaler maximizes.
    pub utilization: f64,
    /// Numerator of [`utilization`](Self::utilization).
    pub busy_region_cycles: u64,
    /// Denominator of [`utilization`](Self::utilization).
    pub capacity_region_cycles: u64,
    /// Region-cycles spent parked `Resident` (DESIGN.md §16): the
    /// scale-to-zero power model's resident-but-idle term.  A parked
    /// region is powered and configured but streams nothing, so these
    /// cycles are what the configuration cache trades against the ICAP
    /// restreams it elides.  Always 0 with the cache off.
    pub resident_region_cycles: u64,
    /// Requests served on a fabric slice / on the app's CPU lane.
    pub fabric_requests: u64,
    pub cpu_requests: u64,
    /// Policy-driven grow / shrink transitions actuated.
    pub grows: u64,
    pub shrinks: u64,
    /// Full placement history, in actuation order.
    pub transitions: Vec<Transition>,
    /// Every ICAP programming, serialized per board.
    pub icap_events: Vec<IcapEvent>,
    /// Final region map per node (index 0 is the unused placeholder).
    pub final_regions: Vec<Vec<RegionState>>,
    /// Cycle-accurate oracle executions the cost model needed.
    pub oracle_runs: u64,
}

/// One reserved chain on one board.
#[derive(Debug, Clone)]
struct Slice {
    node: usize,
    /// Regions in chain order (stage i runs in `regions[i]`).
    regions: Vec<usize>,
    /// Virtual cycle the slice's backlog drains.
    busy_until: u64,
    /// Virtual cycle its last ICAP programming completes.
    available_at: u64,
}

/// Per-app control-plane state.
struct AppState {
    chain: Vec<ModuleKind>,
    slices: Vec<Slice>,
    cpu_busy_until: u64,
    monitor: DemandMonitor,
    cooldown_until_tick: u64,
}

/// The closed-loop engine.
pub struct Engine {
    cfg: SystemConfig,
    cluster: Cluster,
    cost: CostModel,
    policy: Box<dyn ScalingPolicy>,
    opts: EngineOptions,
    apps: Vec<AppState>,
    node_alive: Vec<bool>,
    /// Per-node virtual cycle the single ICAP port frees.
    icap_free_at: Vec<u64>,
    /// Per-(node, region) virtual cycle a blanked region becomes
    /// reprogrammable.
    region_free_at: Vec<Vec<u64>>,
    initial_layout: Vec<(u32, usize, usize)>,
    transitions: Vec<Transition>,
    icap_events: Vec<IcapEvent>,
    queue_wait: CycleRecorder,
    latency: CycleRecorder,
    busy_region_cycles: u64,
    capacity_marks: Vec<(u64, usize)>,
    /// Stepwise `(cycle, regions)` marks of how many regions sit parked
    /// `Resident` fleet-wide — the scale-to-zero power model's
    /// resident-but-idle term (DESIGN.md §16).  Always empty-to-zero
    /// with the configuration cache off.
    resident_marks: Vec<(u64, usize)>,
    /// Drain-tail region-cycles of boards that left while backlogged:
    /// their dispatched work completes during the graceful drain, so
    /// those region-cycles stay in the utilization denominator even
    /// though the capacity marks drop at the outage instant.
    capacity_extra: u64,
    makespan: u64,
    fabric_requests: u64,
    cpu_requests: u64,
    grows: u64,
    shrinks: u64,
    slo_ok: u64,
    slo_cycles: u64,
    tick_index: u64,
    ran: bool,
    /// Structured scale-event sink (DESIGN.md §14): every grow/shrink
    /// transition emits a [`TelemetryEvent::ScaleUp`]/`ScaleDown`
    /// stamped with its virtual transition cycle.  `Off` by default.
    pub tracer: Tracer,
}

impl Engine {
    /// Build a control plane over `nodes` boards serving `tenants` apps.
    pub fn new(
        cfg: &SystemConfig,
        nodes: usize,
        tenants: usize,
        policy: Box<dyn ScalingPolicy>,
        opts: EngineOptions,
    ) -> Self {
        assert!(nodes >= 1, "need at least one board");
        // App IDs are destination-register indices: the banked layout
        // provides one per crossbar port.
        assert!(
            tenants >= 1 && tenants <= cfg.fabric.num_ports,
            "tenants {} exceed the {}-port layout's app-ID registers",
            tenants,
            cfg.fabric.num_ports
        );
        let mut cluster =
            Cluster::launch(nodes, cfg, None, PlacementPolicy::MostAvailable);
        // The closed loop owns the bandwidth plane: shares are derived
        // from footprints on every transition, so static [qos] contracts
        // are cleared up front (left in place they would fight — and on
        // small boards overcommit against — the loop's recompilation).
        for node in 0..nodes {
            cluster
                .node_mut(node)
                .manager_mut()
                .set_bandwidth_plan(crate::qos::BandwidthPlan::new())
                .expect("the empty plan compiles on a fresh board");
        }
        let apps = (0..tenants)
            .map(|_| AppState {
                chain: ModuleKind::pipeline().to_vec(),
                slices: Vec::new(),
                cpu_busy_until: 0,
                monitor: DemandMonitor::new(opts.ewma_alpha),
                cooldown_until_tick: 0,
            })
            .collect();
        Self {
            cost: CostModel::new(cfg),
            cluster,
            policy,
            opts,
            apps,
            node_alive: vec![true; nodes],
            icap_free_at: vec![0; nodes],
            region_free_at: vec![
                vec![0; cfg.fabric.num_pr_regions + 1];
                nodes
            ],
            initial_layout: Vec::new(),
            transitions: Vec::new(),
            icap_events: Vec::new(),
            queue_wait: CycleRecorder::new(),
            latency: CycleRecorder::new(),
            busy_region_cycles: 0,
            capacity_marks: Vec::new(),
            resident_marks: Vec::new(),
            capacity_extra: 0,
            makespan: 0,
            fabric_requests: 0,
            cpu_requests: 0,
            grows: 0,
            shrinks: 0,
            slo_ok: 0,
            slo_cycles: 0,
            tick_index: 0,
            ran: false,
            tracer: Tracer::default(),
            cfg: cfg.clone(),
        }
    }

    /// The underlying board cluster (read-only).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Run an arrival-ordered trace under a churn schedule to completion.
    /// One-shot: build a fresh engine per run.
    pub fn run(
        &mut self,
        trace: &[TraceEvent],
        churn: &ChurnTrace,
    ) -> Result<AutoscaleReport> {
        assert!(!self.ran, "engines are one-shot; build a fresh one per run");
        self.ran = true;
        let cycles_per_ms = self.cfg.fabric.clock_mhz * 1000.0;
        self.slo_cycles = (self.opts.slo_wait_ms * cycles_per_ms).round() as u64;
        self.infer_chains(trace);
        self.initial_allocation()?;
        self.capacity_marks.push((0, self.alive_region_capacity()));
        self.resident_marks.push((0, self.resident_region_count()));

        let tick_cycles = (self.opts.tick_ms * cycles_per_ms).round().max(1.0) as u64;
        let mut churn_events = churn.events.clone();
        churn_events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut next_churn = 0usize;
        let mut next_tick = tick_cycles;
        for ev in trace {
            let arrival = (ev.arrival_ms * cycles_per_ms).round() as u64;
            while next_tick <= arrival {
                self.apply_churn(&churn_events, &mut next_churn, next_tick, cycles_per_ms)?;
                self.control_tick(next_tick)?;
                next_tick += tick_cycles;
            }
            self.dispatch(arrival, &ev.request)?;
        }
        // Drain churn scheduled between the last control tick and trace
        // end, so the final region map honors the whole schedule.
        self.apply_churn(&churn_events, &mut next_churn, u64::MAX, cycles_per_ms)?;
        Ok(self.build_report())
    }

    // ------------------------------------------------------------------
    // serving (virtual time)
    // ------------------------------------------------------------------

    /// Route one request to the lane (fabric slice or the app's CPU
    /// lane) that completes it earliest, charging virtual time.
    fn dispatch(&mut self, arrival: u64, req: &AppRequest) -> Result<()> {
        let app_idx = req.app_id as usize;
        assert!(app_idx < self.apps.len(), "app {} beyond tenants", req.app_id);
        let words = req.data.len();
        // (completion, start, lane, service, regions_held); lane = None
        // is the CPU lane.  Fabric candidates are scanned first so exact
        // ties prefer the fabric.
        let mut best: Option<(u64, u64, Option<usize>, u64, u64)> = None;
        let lanes: Vec<(usize, usize, u64, u64)> = self.apps[app_idx]
            .slices
            .iter()
            .map(|s| (s.node, s.regions.len(), s.busy_until, s.available_at))
            .collect();
        for (i, &(node, held, busy_until, available_at)) in
            lanes.iter().enumerate()
        {
            if !self.node_alive[node] {
                continue;
            }
            let fpga = held.min(req.stages.len());
            let service =
                self.cost.service_cycles(&self.cfg, &req.stages, words, fpga)?;
            let start = arrival.max(busy_until).max(available_at);
            let completion = start + service;
            let better = match best {
                None => true,
                Some((bc, bs, _, _, _)) => (completion, start) < (bc, bs),
            };
            if better {
                best = Some((completion, start, Some(i), service, held as u64));
            }
        }
        let cpu_service =
            self.cost.service_cycles(&self.cfg, &req.stages, words, 0)?;
        let cpu_start = arrival.max(self.apps[app_idx].cpu_busy_until);
        let cpu_completion = cpu_start + cpu_service;
        let cpu_better = match best {
            None => true,
            Some((bc, bs, _, _, _)) => (cpu_completion, cpu_start) < (bc, bs),
        };
        if cpu_better {
            best = Some((cpu_completion, cpu_start, None, cpu_service, 0));
        }

        let (completion, start, lane, service, held) =
            best.expect("at least the CPU lane exists");
        match lane {
            Some(i) => {
                self.apps[app_idx].slices[i].busy_until = completion;
                self.busy_region_cycles += service * held;
                self.fabric_requests += 1;
            }
            None => {
                self.apps[app_idx].cpu_busy_until = completion;
                self.cpu_requests += 1;
            }
        }
        let wait = start - arrival;
        self.queue_wait.record(wait);
        self.latency.record(completion - arrival);
        if wait <= self.slo_cycles {
            self.slo_ok += 1;
        }
        self.apps[app_idx].monitor.on_dispatch(start, wait);
        if completion > self.makespan {
            self.makespan = completion;
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // control loop
    // ------------------------------------------------------------------

    fn control_tick(&mut self, t: u64) -> Result<()> {
        self.tick_index += 1;
        let window_s = self.opts.tick_ms / 1e3;
        for app in 0..self.apps.len() {
            let signals = self.apps[app].monitor.observe(t, window_s);
            let (slices, regions, chain_len) = {
                let a = &self.apps[app];
                (
                    a.slices.len(),
                    a.slices.iter().map(|s| s.regions.len()).sum::<usize>(),
                    a.chain.len(),
                )
            };
            let snap = DemandSnapshot {
                app_id: app as u32,
                signals,
                slices,
                regions,
                chain_len,
            };
            let target = self.policy.target_regions(&snap);
            if !self.opts.reactive
                || self.tick_index < self.apps[app].cooldown_until_tick
            {
                continue;
            }
            match target.cmp(&regions) {
                Ordering::Greater => {
                    let got = self.grow(
                        t,
                        app as u32,
                        target - regions,
                        TransitionKind::Grow,
                    )?;
                    if got > 0 {
                        // Counted here, not in the actuator: `grows` is
                        // the number of *policy* decisions that landed
                        // (t=0 installs and churn re-placement record
                        // transitions but are not loop decisions).
                        self.grows += 1;
                        self.apps[app].cooldown_until_tick =
                            self.tick_index + self.opts.cooldown_ticks;
                    }
                }
                Ordering::Less => {
                    if self.shrink(t, app as u32, regions - target)? > 0 {
                        self.shrinks += 1;
                        self.apps[app].cooldown_until_tick =
                            self.tick_index + self.opts.cooldown_ticks;
                    }
                }
                Ordering::Equal => {}
            }
        }
        Ok(())
    }

    fn infer_chains(&mut self, trace: &[TraceEvent]) {
        for ev in trace {
            let app = ev.request.app_id as usize;
            assert!(app < self.apps.len(), "trace app beyond tenants");
            if ev.request.stages.len() > self.apps[app].chain.len() {
                self.apps[app].chain = ev.request.stages.clone();
            }
        }
    }

    fn initial_allocation(&mut self) -> Result<()> {
        let total = self.cluster.node_count()
            * self.cfg.fabric.num_pr_regions;
        for app in 0..self.apps.len() {
            let chain_len = self.apps[app].chain.len();
            let want = self.opts.initial_regions_per_app.unwrap_or(
                if self.opts.reactive {
                    self.opts.initial_full_slices * chain_len
                } else {
                    total / self.apps.len()
                },
            );
            self.grow(0, app as u32, want, TransitionKind::Grow)?;
        }
        let mut layout = Vec::new();
        for (a, app) in self.apps.iter().enumerate() {
            for s in &app.slices {
                layout.push((a as u32, s.node, s.regions.len()));
            }
        }
        self.initial_layout = layout;
        Ok(())
    }

    // ------------------------------------------------------------------
    // actuator
    // ------------------------------------------------------------------

    /// Add up to `want` regions to `app`: top up partial slices first
    /// (defragmentation), then open chains on boards with free regions.
    /// Returns how many regions were actually added.
    fn grow(
        &mut self,
        t: u64,
        app: u32,
        want: usize,
        kind: TransitionKind,
    ) -> Result<usize> {
        let mut remaining = want;
        let chain_len = self.apps[app as usize].chain.len();
        for i in 0..self.apps[app as usize].slices.len() {
            if remaining == 0 {
                break;
            }
            let (node, len) = {
                let s = &self.apps[app as usize].slices[i];
                (s.node, s.regions.len())
            };
            if !self.node_alive[node] || len >= chain_len {
                continue;
            }
            let take = (chain_len - len).min(remaining);
            remaining -= self.extend_slice(t, app, i, take, kind)?;
        }
        while remaining > 0 {
            let Some(node) = self.pick_node_for_new_slice(app) else {
                break;
            };
            let take = remaining.min(chain_len);
            let got = self.create_slice_on(t, app, node, take, kind)?;
            if got == 0 {
                break;
            }
            remaining -= got;
        }
        Ok(want - remaining)
    }

    /// The alive board with the most free regions that doesn't already
    /// host a slice of `app` (one slice per board per app).
    fn pick_node_for_new_slice(&self, app: u32) -> Option<usize> {
        let a = &self.apps[app as usize];
        let mut best: Option<(usize, usize)> = None; // (avail, node)
        for node in 0..self.cluster.node_count() {
            if !self.node_alive[node]
                || a.slices.iter().any(|s| s.node == node)
            {
                continue;
            }
            let avail = self.cluster.nodes()[node].available_regions();
            if avail == 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some((ba, _)) => avail > ba,
            };
            if better {
                best = Some((avail, node));
            }
        }
        best.map(|(_, node)| node)
    }

    fn create_slice_on(
        &mut self,
        t: u64,
        app: u32,
        node: usize,
        count: usize,
        kind: TransitionKind,
    ) -> Result<usize> {
        self.apps[app as usize].slices.push(Slice {
            node,
            regions: Vec::new(),
            busy_until: 0,
            available_at: 0,
        });
        let idx = self.apps[app as usize].slices.len() - 1;
        let got = self.extend_slice(t, app, idx, count, kind)?;
        if got == 0 {
            self.apps[app as usize].slices.pop();
        }
        Ok(got)
    }

    /// Program `count` more regions into an existing slice through the
    /// node's serialized ICAP, then reprogram the chain's destinations
    /// and WRR weights.  Returns the number of regions added.
    fn extend_slice(
        &mut self,
        t: u64,
        app: u32,
        slice_idx: usize,
        count: usize,
        kind: TransitionKind,
    ) -> Result<usize> {
        let node = self.apps[app as usize].slices[slice_idx].node;
        let rf_before = self.node_regfile_generation(node);
        let mut picks = Vec::with_capacity(count);
        let mut ev_idx = Vec::with_capacity(count);
        let mut last_end = t;
        for _ in 0..count {
            let mk = {
                let a = &self.apps[app as usize];
                let pos = a.slices[slice_idx].regions.len();
                a.chain[pos.min(a.chain.len() - 1)]
            };
            let Some(r) = self.pick_region_for(node, mk) else { break };
            // A configuration-cache hit rebinds the parked module: the
            // manager returns 0 spent cycles, so the recorded ICAP event
            // is zero-length and the slice is available immediately.
            let spent = self
                .cluster
                .node_mut(node)
                .manager_mut()
                .reserve_region(app, mk, r)?;
            let start = t
                .max(self.icap_free_at[node])
                .max(self.region_free_at[node][r]);
            let end = start + spent;
            self.icap_free_at[node] = end;
            self.icap_events.push(IcapEvent {
                node,
                region: r,
                app_id: app,
                kind: IcapEventKind::Program(mk),
                start_cycle: start,
                end_cycle: end,
            });
            ev_idx.push(self.icap_events.len() - 1);
            last_end = end;
            self.apps[app as usize].slices[slice_idx].regions.push(r);
            picks.push(r);
        }
        if picks.is_empty() {
            return Ok(0);
        }
        let chain_regions =
            self.apps[app as usize].slices[slice_idx].regions.clone();
        self.program_slice_chain(app, node, &chain_regions)?;
        {
            let s = &mut self.apps[app as usize].slices[slice_idx];
            s.available_at = s.available_at.max(last_end);
        }
        let rf_after = self.node_regfile_generation(node);
        let added = picks.len();
        self.transitions.push(Transition {
            at_cycle: t,
            app_id: app,
            node,
            regions: picks,
            kind,
            icap_events: ev_idx,
            regfile_before: rf_before,
            regfile_after: rf_after,
        });
        self.tracer.emit_with(|| TelemetryEvent::ScaleUp {
            cycle: t,
            node,
            regions: added,
        });
        self.mark_residents(t);
        Ok(added)
    }

    /// Cache-aware region choice for one programming (DESIGN.md §16): a
    /// parked module of the right kind (rebind, zero ICAP) beats a
    /// blank `Available` region, which beats evicting a mismatched
    /// resident — lowest index within each class keeps the actuation
    /// deterministic.  With the cache off no region is ever `Resident`,
    /// so this degenerates to the legacy lowest-available scan.
    fn pick_region_for(&self, node: usize, mk: ModuleKind) -> Option<usize> {
        self.cluster.nodes()[node]
            .manager()
            .regions()
            .iter()
            .enumerate()
            .skip(1)
            .filter_map(|(i, st)| {
                let class = match st {
                    RegionState::Resident { kind } if *kind == mk => 0usize,
                    RegionState::Available => 1,
                    RegionState::Resident { .. } => 2,
                    _ => return None,
                };
                Some((class, i))
            })
            .min()
            .map(|(_, i)| i)
    }

    /// Remove up to `want` regions from `app`, smallest slices first
    /// (consolidating toward full chains): drain, blank through the
    /// ICAP, reprogram the surviving chain.  Returns regions removed.
    fn shrink(&mut self, t: u64, app: u32, want: usize) -> Result<usize> {
        let mut remaining = want;
        while remaining > 0 {
            let idx = {
                let a = &self.apps[app as usize];
                (0..a.slices.len()).min_by_key(|&i| {
                    (
                        a.slices[i].regions.len(),
                        std::cmp::Reverse(a.slices[i].node),
                    )
                })
            };
            let Some(idx) = idx else { break };
            let len = self.apps[app as usize].slices[idx].regions.len();
            let k = remaining.min(len);
            if k == 0 {
                break;
            }
            self.retire_regions(t, app, idx, k)?;
            remaining -= k;
        }
        Ok(want - remaining)
    }

    /// Drain + blank the last `count` regions of one slice.
    fn retire_regions(
        &mut self,
        t: u64,
        app: u32,
        slice_idx: usize,
        count: usize,
    ) -> Result<()> {
        let (node, drain_done, removed) = {
            let s = &mut self.apps[app as usize].slices[slice_idx];
            let keep = s.regions.len() - count;
            (
                s.node,
                t.max(s.busy_until).max(s.available_at),
                s.regions.split_off(keep),
            )
        };
        let rf_before = self.node_regfile_generation(node);
        let mut ev_idx = Vec::with_capacity(removed.len());
        let cache_on = self.cfg.manager.config_cache_regions > 0;
        for &r in &removed {
            // Scale-to-zero with the configuration cache on parks the
            // drained module (zero ICAP; it may rebind on the next
            // grow) instead of streaming a blanking bitstream.  The
            // recorded Blank event is zero-length — the region-cycles
            // it stays resident are charged to the power model through
            // `resident_region_cycles` (DESIGN.md §16).
            let spent = if cache_on {
                self.cluster.node_mut(node).manager_mut().park_region(r)?;
                0
            } else {
                self.cluster.node_mut(node).manager_mut().blank_region(r)?
            };
            let start = drain_done.max(self.icap_free_at[node]);
            let end = start + spent;
            self.icap_free_at[node] = end;
            self.region_free_at[node][r] = end;
            self.icap_events.push(IcapEvent {
                node,
                region: r,
                app_id: app,
                kind: IcapEventKind::Blank,
                start_cycle: start,
                end_cycle: end,
            });
            ev_idx.push(self.icap_events.len() - 1);
        }
        let chain_regions =
            self.apps[app as usize].slices[slice_idx].regions.clone();
        self.program_slice_chain(app, node, &chain_regions)?;
        if chain_regions.is_empty() {
            self.apps[app as usize].slices.remove(slice_idx);
        }
        let rf_after = self.node_regfile_generation(node);
        let retired = removed.len();
        self.transitions.push(Transition {
            at_cycle: t,
            app_id: app,
            node,
            regions: removed,
            kind: TransitionKind::Shrink,
            icap_events: ev_idx,
            regfile_before: rf_before,
            regfile_after: rf_after,
        });
        self.tracer.emit_with(|| TelemetryEvent::ScaleDown {
            cycle: t,
            node,
            regions: retired,
        });
        self.mark_residents(t);
        Ok(())
    }

    /// Recompile the node's bandwidth plan on every scale transition:
    /// the app's share contract follows its region footprint
    /// (`SHARE_UNIT · regions / ports`), and the plan compiler — not an
    /// ad-hoc weight — lowers it to per-master budgets and an app-aware
    /// rotation order.  Budgets are never reset to defaults mid-flight.
    /// The same recompilation installs the plan's per-app package counts
    /// as the bridge's H2C descriptor-scheduler weights (DESIGN.md §15),
    /// so the host-side hop tracks every footprint change with no extra
    /// actuator step — pinned by `bridge_weights_follow_scale_events`.
    fn program_slice_chain(
        &mut self,
        app: u32,
        node: usize,
        regions: &[usize],
    ) -> Result<()> {
        let share = (crate::qos::SHARE_UNIT as u64 * regions.len() as u64
            / self.cfg.fabric.num_ports as u64) as u32;
        let mgr = self.cluster.node_mut(node).manager_mut();
        mgr.stage_bandwidth_share(app, share)?;
        mgr.program_app_chain(app, regions)
    }

    fn node_regfile_generation(&self, node: usize) -> u64 {
        self.cluster.nodes()[node].manager().fabric().regfile.generation()
    }

    // ------------------------------------------------------------------
    // churn
    // ------------------------------------------------------------------

    fn apply_churn(
        &mut self,
        events: &[(f64, ChurnEvent)],
        next: &mut usize,
        upto_cycle: u64,
        cycles_per_ms: f64,
    ) -> Result<()> {
        while *next < events.len() {
            let (at_ms, ev) = events[*next];
            let at = (at_ms * cycles_per_ms).round() as u64;
            if at > upto_cycle {
                break;
            }
            *next += 1;
            match ev {
                ChurnEvent::NodeDown { node } => {
                    if node >= self.node_alive.len()
                        || !self.node_alive[node]
                        || self.node_alive.iter().filter(|a| **a).count() <= 1
                    {
                        continue;
                    }
                    let lost = self.node_down(at, node);
                    if self.opts.reactive {
                        for (app, count) in lost {
                            self.grow(at, app, count, TransitionKind::Grow)?;
                        }
                    }
                }
                ChurnEvent::NodeUp { node } => {
                    if node < self.node_alive.len() && !self.node_alive[node] {
                        self.node_up(at, node)?;
                    }
                }
                ChurnEvent::Fence { node, regions } => {
                    if node < self.node_alive.len() && self.node_alive[node] {
                        self.cluster
                            .node_mut(node)
                            .manager_mut()
                            .fence_regions(regions);
                        self.capacity_marks
                            .push((at, self.alive_region_capacity()));
                        // Fencing may have evicted parked residents.
                        self.mark_residents(at);
                    }
                }
                ChurnEvent::Unfence { node, regions } => {
                    if node < self.node_alive.len() && self.node_alive[node] {
                        self.cluster
                            .node_mut(node)
                            .manager_mut()
                            .unfence_regions(regions);
                        self.capacity_marks
                            .push((at, self.alive_region_capacity()));
                    }
                }
            }
        }
        Ok(())
    }

    /// Graceful board loss: every slice on the node drains (dispatched
    /// work completes), reservations release, regions fence `Offline`.
    /// Returns `(app, regions_lost)` for re-placement.
    fn node_down(&mut self, at: u64, node: usize) -> Vec<(u32, usize)> {
        self.node_alive[node] = false;
        let mut lost = Vec::new();
        for app in 0..self.apps.len() {
            let Some(idx) =
                self.apps[app].slices.iter().position(|s| s.node == node)
            else {
                continue;
            };
            let slice = self.apps[app].slices.remove(idx);
            // The drain tail: dispatched work still completes on the
            // leaving board after its regions drop out of the capacity
            // marks, so keep those region-cycles in the denominator.
            if slice.busy_until > at {
                self.capacity_extra +=
                    (slice.busy_until - at) * slice.regions.len() as u64;
            }
            let g = self.node_regfile_generation(node);
            let mgr = self.cluster.node_mut(node).manager_mut();
            mgr.release_app(app as u32);
            // Retire the lost app's share contract, or the board would
            // rejoin with a stale (possibly overcommitting) plan.  No
            // recompile needed: the board is fenced, and any rejoin
            // goes through an allocation event that applies the plan.
            mgr.stage_bandwidth_share(app as u32, 0)
                .expect("share removal never overcommits");
            lost.push((app as u32, slice.regions.len()));
            self.transitions.push(Transition {
                at_cycle: at,
                app_id: app as u32,
                node,
                regions: slice.regions,
                kind: TransitionKind::Churn,
                icap_events: Vec::new(),
                regfile_before: g,
                regfile_after: g,
            });
        }
        let mgr = self.cluster.node_mut(node).manager_mut();
        let avail = mgr.available_regions();
        mgr.fence_regions(avail);
        self.capacity_marks.push((at, self.alive_region_capacity()));
        // The dead board's parked residents leave the powered set.
        self.mark_residents(at);
        lost
    }

    /// A board rejoins empty.  The static baseline re-installs its
    /// original slices (a fixed partitioning follows the hardware); the
    /// reactive engine leaves re-growth to the policy.
    fn node_up(&mut self, at: u64, node: usize) -> Result<()> {
        self.node_alive[node] = true;
        self.cluster.node_mut(node).manager_mut().unfence_all();
        self.capacity_marks.push((at, self.alive_region_capacity()));
        if !self.opts.reactive {
            let restores: Vec<(u32, usize)> = self
                .initial_layout
                .iter()
                .filter(|&&(_, n, _)| n == node)
                .map(|&(a, _, c)| (a, c))
                .collect();
            for (app, count) in restores {
                if self.apps[app as usize]
                    .slices
                    .iter()
                    .any(|s| s.node == node)
                {
                    continue;
                }
                self.create_slice_on(at, app, node, count, TransitionKind::Churn)?;
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // accounting
    // ------------------------------------------------------------------

    /// Regions parked `Resident` across alive boards: powered,
    /// configured, but idle — the quantity the scale-to-zero power
    /// model charges separately from busy region-cycles.
    fn resident_region_count(&self) -> usize {
        self.cluster
            .nodes()
            .iter()
            .enumerate()
            .filter(|&(n, _)| self.node_alive[n])
            .map(|(_, node)| {
                node.manager()
                    .regions()
                    .iter()
                    .skip(1)
                    .filter(|r| matches!(r, RegionState::Resident { .. }))
                    .count()
            })
            .sum()
    }

    /// Record a resident-count mark if the count changed (stepwise
    /// integral input, mirroring `capacity_marks`).
    fn mark_residents(&mut self, at: u64) {
        let now = self.resident_region_count();
        if self.resident_marks.last().map(|&(_, c)| c) != Some(now) {
            self.resident_marks.push((at, now));
        }
    }

    /// Regions not fenced `Offline` across the fleet (a dead board has
    /// every region fenced).
    fn alive_region_capacity(&self) -> usize {
        self.cluster
            .nodes()
            .iter()
            .map(|n| {
                n.manager()
                    .regions()
                    .iter()
                    .skip(1)
                    .filter(|r| **r != RegionState::Offline)
                    .count()
            })
            .sum()
    }

    fn build_report(&mut self) -> AutoscaleReport {
        let capacity = capacity_integral(&self.capacity_marks, self.makespan)
            + self.capacity_extra;
        let completed = self.queue_wait.count() as u64;
        AutoscaleReport {
            policy: self.policy.name().to_string(),
            completed,
            makespan_cycles: self.makespan,
            queue_wait: std::mem::take(&mut self.queue_wait),
            latency: std::mem::take(&mut self.latency),
            slo_attainment: if completed > 0 {
                self.slo_ok as f64 / completed as f64
            } else {
                1.0
            },
            utilization: if capacity > 0 {
                self.busy_region_cycles as f64 / capacity as f64
            } else {
                0.0
            },
            busy_region_cycles: self.busy_region_cycles,
            capacity_region_cycles: capacity,
            resident_region_cycles: capacity_integral(
                &self.resident_marks,
                self.makespan,
            ),
            fabric_requests: self.fabric_requests,
            cpu_requests: self.cpu_requests,
            grows: self.grows,
            shrinks: self.shrinks,
            transitions: std::mem::take(&mut self.transitions),
            icap_events: std::mem::take(&mut self.icap_events),
            final_regions: self
                .cluster
                .nodes()
                .iter()
                .map(|n| n.manager().regions().to_vec())
                .collect(),
            oracle_runs: self.cost.oracle_runs,
        }
    }
}

/// Integrate alive-region capacity over `[0, makespan)` from the
/// stepwise marks (time-ordered `(cycle, regions)` pairs).
fn capacity_integral(marks: &[(u64, usize)], makespan: u64) -> u64 {
    let mut total = 0u64;
    for (i, &(start, cap)) in marks.iter().enumerate() {
        if start >= makespan {
            break;
        }
        let end = marks
            .get(i + 1)
            .map(|&(c, _)| c)
            .unwrap_or(makespan)
            .min(makespan);
        total += (end.saturating_sub(start)) * cap as u64;
    }
    total
}

// ---------------------------------------------------------------------
// canned scenario: diurnal tenants + churn, autoscaled vs static
// ---------------------------------------------------------------------

/// Autoscaled run and its static-allocation baseline over one trace.
#[derive(Debug)]
pub struct ScenarioReport {
    /// The closed-loop run.
    pub autoscaled: AutoscaleReport,
    /// Same trace, same churn, fixed even region split.
    pub static_baseline: AutoscaleReport,
}

/// A serving profile where the fabric clearly beats the host for a full
/// chain (lighter 2 ms descriptor rounds than Fig 5's 16 KB testbed) and
/// partial bitstreams are region-sized (256 KB ≈ 0.5 ms of ICAP time).
pub fn autoscale_profile() -> SystemConfig {
    let mut cfg = SystemConfig::paper_defaults();
    cfg.timing.xdma_round_ms = 2.0;
    cfg.manager.bitstream_bytes = 256 * 1024;
    cfg
}

/// Overlay the serving-profile knobs of [`autoscale_profile`] onto an
/// arbitrary board shape (e.g. `configs/scale16.toml`): timing and the
/// partial-bitstream size come from the profile, everything else —
/// ports, PR regions, crossbar, server — from `cfg`.  Shared by the
/// `autoscale --config` CLI path and `examples/scale_out_serving.rs` so
/// both drive the same board model.
pub fn serving_profile_on(mut cfg: SystemConfig) -> SystemConfig {
    let profile = autoscale_profile();
    cfg.timing = profile.timing;
    cfg.manager.bitstream_bytes = profile.manager.bitstream_bytes;
    cfg
}

/// Run the diurnal-with-churn comparison: `tenants` anti-phase diurnal
/// streams (30..450 req/s, `period_s`) over `nodes` boards, autoscaled
/// under `policy` versus the static even split.  Churn (when enabled) is
/// seeded from `seed` and shared by both runs.
#[allow(clippy::too_many_arguments)]
pub fn run_diurnal_scenario(
    cfg: &SystemConfig,
    nodes: usize,
    tenants: u32,
    requests: usize,
    period_s: f64,
    seed: u64,
    churn: bool,
    policy: PolicyKind,
) -> Result<ScenarioReport> {
    let specs = workload::diurnal_tenants(tenants, 30.0, 450.0, period_s, 64);
    let trace = workload::generate_profiled(&specs, seed, requests);
    let duration_ms = trace.last().map(|e| e.arrival_ms).unwrap_or(0.0);
    let churn_trace = if churn {
        ChurnTrace::generate(seed ^ 0xC0FFEE, nodes, duration_ms)
    } else {
        ChurnTrace::none()
    };
    let mut auto_engine = Engine::new(
        cfg,
        nodes,
        tenants as usize,
        policy.build(),
        EngineOptions::default(),
    );
    let autoscaled = auto_engine.run(&trace, &churn_trace)?;
    let mut static_engine = Engine::new(
        cfg,
        nodes,
        tenants as usize,
        Box::new(StaticPolicy),
        EngineOptions { reactive: false, ..EngineOptions::default() },
    );
    let static_baseline = static_engine.run(&trace, &churn_trace)?;
    Ok(ScenarioReport { autoscaled, static_baseline })
}

/// Run the autoscaled-vs-static comparison on explicit tenant specs
/// instead of the canned diurnal profile.  This is the kernel-registry
/// face of the scenario driver (DESIGN.md §17): tenants may chain any
/// registered [`ModuleKind`] — seed, `[kernels]`-table or
/// artifact-backed — and the engine infers each app's chain from the
/// trace, so a config-declared kernel flows through monitor, policy,
/// ICAP actuation and bandwidth-plan recompilation with no special
/// casing (`examples/kernel_zoo_serving.rs`).
pub fn run_tenant_scenario(
    cfg: &SystemConfig,
    nodes: usize,
    tenants: &[workload::TenantSpec],
    requests: usize,
    seed: u64,
    churn: bool,
    policy: PolicyKind,
) -> Result<ScenarioReport> {
    assert!(!tenants.is_empty(), "run_tenant_scenario needs >= 1 tenant");
    let trace = workload::generate_profiled(tenants, seed, requests);
    let duration_ms = trace.last().map(|e| e.arrival_ms).unwrap_or(0.0);
    let churn_trace = if churn {
        ChurnTrace::generate(seed ^ 0xC0FFEE, nodes, duration_ms)
    } else {
        ChurnTrace::none()
    };
    let mut auto_engine = Engine::new(
        cfg,
        nodes,
        tenants.len(),
        policy.build(),
        EngineOptions::default(),
    );
    let autoscaled = auto_engine.run(&trace, &churn_trace)?;
    let mut static_engine = Engine::new(
        cfg,
        nodes,
        tenants.len(),
        Box::new(StaticPolicy),
        EngineOptions { reactive: false, ..EngineOptions::default() },
    );
    let static_baseline = static_engine.run(&trace, &churn_trace)?;
    Ok(ScenarioReport { autoscaled, static_baseline })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> SystemConfig {
        let mut cfg = autoscale_profile();
        cfg.manager.bitstream_bytes = 16 * 1024; // 8192 cycles of ICAP
        cfg
    }

    #[test]
    fn engine_scales_up_under_a_burst_and_back_down() {
        let cfg = fast_cfg();
        // One tenant bursting far beyond a single slice's throughput,
        // then going quiet: the loop must grow, then shrink to the floor.
        let tenants = vec![crate::workload::TenantSpec {
            app_id: 0,
            stages: ModuleKind::pipeline().to_vec(),
            words: 64,
            profile: crate::workload::RateProfile::Bursty {
                burst_per_s: 600.0,
                idle_per_s: 10.0,
                burst_s: 1.5,
                idle_s: 1.5,
                phase_s: 0.0,
            },
        }];
        let trace = crate::workload::generate_profiled(&tenants, 5, 1200);
        let mut engine = Engine::new(
            &cfg,
            3,
            1,
            PolicyKind::TargetQueueDepth.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 1200);
        assert!(report.grows > 0, "no grow under a 600 req/s burst");
        assert!(report.shrinks > 0, "no shrink after the burst");
        assert!(report.fabric_requests > 0);
        assert!(report.utilization > 0.0 && report.utilization <= 1.0);
        assert!(report.slo_attainment > 0.0 && report.slo_attainment <= 1.0);
        // Every policy transition carries ICAP events + a regfile bump.
        for tr in &report.transitions {
            if matches!(tr.kind, TransitionKind::Grow | TransitionKind::Shrink)
            {
                assert!(!tr.icap_events.is_empty(), "{tr:?}");
                assert!(tr.regfile_after > tr.regfile_before, "{tr:?}");
            }
        }
    }

    #[test]
    fn config_cache_parks_retired_regions_and_rebinds_on_grow() {
        // Same burst/idle/burst tenant as the scale-up test, with the
        // configuration cache on: the idle shrink parks modules (zero-
        // length Blank events), the second burst's grow rebinds them
        // (zero-length Program events, manager cache hits), and the
        // parked interval is charged to the power model.
        let mut cfg = fast_cfg();
        cfg.manager.config_cache_regions = 3;
        let tenants = vec![crate::workload::TenantSpec {
            app_id: 0,
            stages: ModuleKind::pipeline().to_vec(),
            words: 64,
            profile: crate::workload::RateProfile::Bursty {
                burst_per_s: 600.0,
                idle_per_s: 10.0,
                burst_s: 1.5,
                idle_s: 1.5,
                phase_s: 0.0,
            },
        }];
        let trace = crate::workload::generate_profiled(&tenants, 5, 1200);
        let mut engine = Engine::new(
            &cfg,
            3,
            1,
            PolicyKind::TargetQueueDepth.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 1200);
        assert!(report.grows > 0 && report.shrinks > 0);
        // Retires park instead of streaming a blanking bitstream.
        let blanks: Vec<_> = report
            .icap_events
            .iter()
            .filter(|e| e.kind == IcapEventKind::Blank)
            .collect();
        assert!(!blanks.is_empty(), "no shrink ever retired a region");
        assert!(
            blanks.iter().all(|e| e.end_cycle == e.start_cycle),
            "cache on: a retire streamed a blanking bitstream"
        );
        // A later grow rebound a parked module for free.
        assert!(
            report.icap_events.iter().any(|e| {
                matches!(e.kind, IcapEventKind::Program(_))
                    && e.end_cycle == e.start_cycle
                    && e.start_cycle > 0
            }),
            "no grow ever rebound a parked module"
        );
        let hits: u64 = (0..engine.cluster().node_count())
            .map(|n| {
                engine.cluster().nodes()[n].manager().config_cache_stats().0
            })
            .sum();
        assert!(hits > 0, "no node manager recorded a cache hit");
        // The resident-but-idle interval shows up in the power term.
        assert!(report.resident_region_cycles > 0, "parked cycles uncharged");
    }

    #[test]
    fn cache_off_run_charges_no_resident_cycles() {
        let cfg = fast_cfg();
        let specs = workload::diurnal_tenants(1, 20.0, 300.0, 2.0, 64);
        let trace = workload::generate_profiled(&specs, 3, 400);
        let mut engine = Engine::new(
            &cfg,
            2,
            1,
            PolicyKind::TargetQueueDepth.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 400);
        assert_eq!(report.resident_region_cycles, 0);
    }

    #[test]
    fn predictive_engine_rides_a_ramp() {
        let cfg = fast_cfg();
        // One tenant ramping 20 -> 500 req/s over a diurnal half-period:
        // the feed-forward policy must grow (on the slope) and shrink
        // again on the way down, serving everything.
        let specs = workload::diurnal_tenants(1, 20.0, 500.0, 3.0, 64);
        let trace = workload::generate_profiled(&specs, 11, 1500);
        let mut engine = Engine::new(
            &cfg,
            3,
            1,
            PolicyKind::Predictive.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 1500);
        assert_eq!(report.policy, "predictive-ewma");
        assert!(report.grows > 0, "no grow on a 25x rate ramp");
        assert!(report.shrinks > 0, "no shrink after the peak");
        for tr in &report.transitions {
            if matches!(tr.kind, TransitionKind::Grow | TransitionKind::Shrink)
            {
                assert!(tr.regfile_after > tr.regfile_before, "{tr:?}");
            }
        }
    }

    #[test]
    fn sixteen_port_board_exposes_all_regions_to_the_engine() {
        // A single scale-out board: 15 PR regions, 5 tenants (beyond the
        // old 4-app window).  The initial allocation alone needs
        // placements past region 3, which PR 2 refused with
        // RegfileWindow.
        let mut cfg = fast_cfg();
        cfg.fabric.num_ports = 16;
        cfg.fabric.num_pr_regions = 15;
        let specs = workload::diurnal_tenants(5, 20.0, 200.0, 2.0, 64);
        let trace = workload::generate_profiled(&specs, 13, 1000);
        let mut engine = Engine::new(
            &cfg,
            1,
            5,
            PolicyKind::TargetQueueDepth.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 1000);
        let high_region = report
            .transitions
            .iter()
            .flat_map(|t| t.regions.iter())
            .any(|&r| r > crate::regfile::MAX_PR_REGIONS);
        assert!(high_region, "no placement ever used a region beyond port 3");
    }

    #[test]
    fn static_engine_never_reacts() {
        let cfg = fast_cfg();
        let specs = workload::diurnal_tenants(2, 20.0, 300.0, 2.0, 64);
        let trace = workload::generate_profiled(&specs, 9, 600);
        let mut engine = Engine::new(
            &cfg,
            2,
            2,
            Box::new(StaticPolicy),
            EngineOptions { reactive: false, ..EngineOptions::default() },
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 600);
        // Only the t=0 installs appear; nothing after.
        assert!(report.transitions.iter().all(|t| t.at_cycle == 0));
        assert_eq!(report.shrinks, 0);
    }

    #[test]
    fn bridge_weights_follow_scale_events() {
        // Every grow/shrink recompiles the board plan, and apply_plan
        // lowers the plan's package counts into the bridge's H2C
        // scheduler — so after a run with real transitions, each board's
        // installed weights must list exactly the apps still holding
        // regions there (DESIGN.md §15).
        let cfg = fast_cfg();
        let specs = workload::diurnal_tenants(2, 20.0, 300.0, 2.0, 64);
        let trace = workload::generate_profiled(&specs, 9, 800);
        let mut engine = Engine::new(
            &cfg,
            2,
            2,
            PolicyKind::TargetQueueDepth.build(),
            EngineOptions::default(),
        );
        let report = engine.run(&trace, &ChurnTrace::none()).unwrap();
        assert_eq!(report.completed, 800);
        assert!(report.grows > 0, "no transitions to propagate");
        let mut any_weights = false;
        for node in 0..engine.cluster().node_count() {
            let mut expect: Vec<u32> = Vec::new();
            for (a, app) in engine.apps.iter().enumerate() {
                let held: usize = app
                    .slices
                    .iter()
                    .filter(|s| s.node == node)
                    .map(|s| s.regions.len())
                    .sum();
                if held > 0 {
                    expect.push(a as u32);
                }
            }
            let weights = engine.cluster().nodes()[node]
                .manager()
                .fabric()
                .xdma
                .h2c_weights()
                .to_vec();
            let apps: Vec<u32> = weights.iter().map(|&(a, _)| a).collect();
            assert_eq!(
                apps, expect,
                "node {node}: bridge weights out of sync with footprints"
            );
            assert!(weights.iter().all(|&(_, w)| w > 0));
            any_weights = any_weights || !weights.is_empty();
        }
        assert!(any_weights, "no board ended with an installed plan");
    }

    #[test]
    fn capacity_integral_is_stepwise() {
        let marks = vec![(0u64, 10usize), (100, 5), (300, 8)];
        // 0..100 @10 + 100..300 @5 + 300..400 @8
        assert_eq!(capacity_integral(&marks, 400), 1000 + 1000 + 800);
        // Clipped at the makespan.
        assert_eq!(capacity_integral(&marks, 50), 500);
        assert_eq!(capacity_integral(&marks, 0), 0);
    }
}
