//! Scaling policies: the decision half of the control loop.
//!
//! A [`ScalingPolicy`] maps a per-app [`DemandSnapshot`] to a target
//! PR-region count.  Policies are pure functions (all hysteresis state
//! lives in the snapshot + the engine's cooldown), so runs replay
//! deterministically.  Two concrete policies ship, both threshold +
//! hysteresis as the paper's envisioned resource manager implies:
//!
//! * [`TargetQueueDepth`] — grow when the backlog per serving slice
//!   exceeds a threshold, shrink only when the queue is empty *and* the
//!   window's waits are calm (the hysteresis band);
//! * [`LatencySlo`] — grow when the window's p99 queue wait violates the
//!   SLO, shrink only well under it with an empty queue;
//! * [`Predictive`] — feed-forward: extrapolate the arrival-rate EWMA
//!   slope one horizon ahead and size the allocation for the *predicted*
//!   rate, so capacity is programmed through the (slow, serialized) ICAP
//!   before the backlog materializes.  Reuses the reactive policies'
//!   hysteresis shape (calm-band shrink, floor) and the engine's
//!   cooldown.

use super::monitor::DemandSignals;

/// Everything a policy may consult for one app at one control tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandSnapshot {
    /// The app the decision is for.
    pub app_id: u32,
    /// Windowed demand signals from the monitor.
    pub signals: DemandSignals,
    /// Serving slices currently held (chains on distinct boards).
    pub slices: usize,
    /// PR regions currently reserved across the fleet.
    pub regions: usize,
    /// The app's chain length (regions in one full slice).
    pub chain_len: usize,
}

/// A pluggable grow/shrink decision function.
pub trait ScalingPolicy {
    /// Human-readable policy name (reports, CLI).
    fn name(&self) -> &'static str;

    /// Target PR-region count for the app.  The engine steps toward the
    /// target subject to availability, the per-node slice limit and the
    /// cooldown; it never preempts in-flight work.
    fn target_regions(&self, s: &DemandSnapshot) -> usize;
}

/// Grow when the queue per serving slice exceeds `grow_above`; shrink
/// (one chain at a time, never below `min_slices`) only when the queue
/// is empty and the window's p99 wait is under `calm_wait_cycles`.
#[derive(Debug, Clone, Copy)]
pub struct TargetQueueDepth {
    /// Queued requests per slice that trigger a grow.
    pub grow_above: f64,
    /// p99 window wait (cycles) below which an idle app may shrink.
    pub calm_wait_cycles: u64,
    /// Minimum full slices an app keeps (its guaranteed share).
    pub min_slices: usize,
}

impl Default for TargetQueueDepth {
    fn default() -> Self {
        // 3 queued requests per slice ≈ one service time of headroom;
        // calm = 2 ms at the 250 MHz fabric clock.
        Self { grow_above: 3.0, calm_wait_cycles: 500_000, min_slices: 1 }
    }
}

impl ScalingPolicy for TargetQueueDepth {
    fn name(&self) -> &'static str {
        "target-queue-depth"
    }

    fn target_regions(&self, s: &DemandSnapshot) -> usize {
        let floor = self.min_slices * s.chain_len;
        let lanes = s.slices.max(1) as f64;
        if s.signals.queue_depth as f64 / lanes > self.grow_above {
            return (s.regions + s.chain_len).max(floor);
        }
        if s.signals.queue_depth == 0
            && s.signals.p99_wait_cycles <= self.calm_wait_cycles
            && s.regions > floor
        {
            return s.regions.saturating_sub(s.chain_len).max(floor);
        }
        s.regions.max(floor)
    }
}

/// Grow when the window's p99 queue wait exceeds `slo_wait_cycles`;
/// shrink only when idle and under `shrink_frac` of the SLO.
#[derive(Debug, Clone, Copy)]
pub struct LatencySlo {
    /// The queue-wait SLO in fabric cycles.
    pub slo_wait_cycles: u64,
    /// Shrink only below this fraction of the SLO (hysteresis band).
    pub shrink_frac: f64,
    /// Minimum full slices an app keeps.
    pub min_slices: usize,
}

impl Default for LatencySlo {
    fn default() -> Self {
        // 25 ms queue-wait SLO at the 250 MHz fabric clock.
        Self { slo_wait_cycles: 6_250_000, shrink_frac: 0.2, min_slices: 1 }
    }
}

impl ScalingPolicy for LatencySlo {
    fn name(&self) -> &'static str {
        "latency-slo"
    }

    fn target_regions(&self, s: &DemandSnapshot) -> usize {
        let floor = self.min_slices * s.chain_len;
        if s.signals.p99_wait_cycles > self.slo_wait_cycles {
            return (s.regions + s.chain_len).max(floor);
        }
        let calm = self.slo_wait_cycles as f64 * self.shrink_frac;
        if s.signals.queue_depth == 0
            && (s.signals.p99_wait_cycles as f64) < calm
            && s.regions > floor
        {
            return s.regions.saturating_sub(s.chain_len).max(floor);
        }
        s.regions.max(floor)
    }
}

/// Feed-forward scaling from the arrival-rate EWMA slope (the ROADMAP
/// "predictive policies from the arrival EWMA" item).
///
/// Reactive policies pay one full control period of backlog before they
/// grow — and the grow itself then waits on the serialized ICAP.  This
/// policy extrapolates the monitor's rate EWMA `horizon_windows` ahead
/// and targets enough slices for the **predicted** rate:
///
/// ```text
/// predicted = max(ewma, ewma + slope * horizon_windows)
/// slices    = ceil(predicted / slice_rate_per_s)
/// ```
///
/// A backlog trigger borrowed from [`TargetQueueDepth`] stays in as a
/// safety net (mispredictions must still be corrected reactively), and
/// the shrink side keeps the same hysteresis band: only when the queue
/// is empty, the window's p99 wait is calm, *and* the prediction — not
/// just the instantaneous rate — has fallen.
#[derive(Debug, Clone, Copy)]
pub struct Predictive {
    /// Control windows of lookahead to extrapolate the EWMA slope over.
    pub horizon_windows: f64,
    /// Sustainable request rate of one full slice (req/s); sizes the
    /// target from the predicted rate.
    pub slice_rate_per_s: f64,
    /// Reactive safety net: backlog per slice that forces a grow even
    /// when the slope predicts none.
    pub grow_above: f64,
    /// p99 window wait (cycles) below which an idle app may shrink.
    pub calm_wait_cycles: u64,
    /// Minimum full slices an app keeps.
    pub min_slices: usize,
}

impl Default for Predictive {
    fn default() -> Self {
        // Two windows of lookahead covers the engine's default cooldown
        // (2 ticks); 120 req/s per slice matches the autoscale profile's
        // full-chain service rate within a factor of two, which is all
        // the safety-net needs.  Calm = 2 ms at 250 MHz.
        Self {
            horizon_windows: 2.0,
            slice_rate_per_s: 120.0,
            grow_above: 3.0,
            calm_wait_cycles: 500_000,
            min_slices: 1,
        }
    }
}

impl ScalingPolicy for Predictive {
    fn name(&self) -> &'static str {
        "predictive-ewma"
    }

    fn target_regions(&self, s: &DemandSnapshot) -> usize {
        let floor = self.min_slices * s.chain_len;
        let ewma = s.signals.arrival_rate_ewma;
        let predicted =
            ewma.max(ewma + s.signals.arrival_rate_slope * self.horizon_windows);
        let want_slices =
            (predicted / self.slice_rate_per_s).ceil().max(0.0) as usize;
        let predicted_target = (want_slices * s.chain_len).max(floor);
        // Feed-forward grow: provision ahead of the predicted rate.
        if predicted_target > s.regions {
            return predicted_target;
        }
        // Reactive safety net against misprediction.
        let lanes = s.slices.max(1) as f64;
        if s.signals.queue_depth as f64 / lanes > self.grow_above {
            return (s.regions + s.chain_len).max(floor);
        }
        // Hysteresis band on the way down: idle, calm, and predicted
        // demand below the current allocation.
        if s.signals.queue_depth == 0
            && s.signals.p99_wait_cycles <= self.calm_wait_cycles
            && predicted_target < s.regions
            && s.regions > floor
        {
            return s.regions.saturating_sub(s.chain_len).max(floor);
        }
        s.regions.max(floor)
    }
}

/// The non-policy: whatever is allocated stays allocated.  Used by the
/// static-baseline engine (which also disables churn re-placement).
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticPolicy;

impl ScalingPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn target_regions(&self, s: &DemandSnapshot) -> usize {
        s.regions
    }
}

/// CLI-facing policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`TargetQueueDepth`] with defaults.
    TargetQueueDepth,
    /// [`LatencySlo`] with defaults.
    LatencySlo,
    /// [`Predictive`] with defaults.
    Predictive,
}

impl PolicyKind {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "depth" | "queue-depth" | "target-queue-depth" => {
                Some(PolicyKind::TargetQueueDepth)
            }
            "slo" | "latency" | "latency-slo" => Some(PolicyKind::LatencySlo),
            "predictive" | "feedforward" | "predictive-ewma" => {
                Some(PolicyKind::Predictive)
            }
            _ => None,
        }
    }

    /// Instantiate the policy with its defaults.
    pub fn build(self) -> Box<dyn ScalingPolicy> {
        match self {
            PolicyKind::TargetQueueDepth => {
                Box::new(TargetQueueDepth::default())
            }
            PolicyKind::LatencySlo => Box::new(LatencySlo::default()),
            PolicyKind::Predictive => Box::new(Predictive::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(depth: usize, p99: u64, slices: usize, regions: usize) -> DemandSnapshot {
        DemandSnapshot {
            app_id: 0,
            signals: DemandSignals {
                queue_depth: depth,
                arrival_rate_ewma: 0.0,
                arrival_rate_slope: 0.0,
                p99_wait_cycles: p99,
                mean_wait_cycles: 0.0,
                wait_ewma_cycles: 0.0,
                arrivals: depth as u64,
            },
            slices,
            regions,
            chain_len: 3,
        }
    }

    fn rate_snap(
        ewma: f64,
        slope: f64,
        depth: usize,
        slices: usize,
        regions: usize,
    ) -> DemandSnapshot {
        let mut s = snap(depth, 0, slices, regions);
        s.signals.arrival_rate_ewma = ewma;
        s.signals.arrival_rate_slope = slope;
        s
    }

    #[test]
    fn queue_depth_policy_has_a_hysteresis_band() {
        let p = TargetQueueDepth::default();
        // Deep backlog on one slice: grow by one chain.
        assert_eq!(p.target_regions(&snap(10, 0, 1, 3)), 6);
        // Same backlog spread over three slices: within threshold, hold.
        assert_eq!(p.target_regions(&snap(9, 1_000_000, 3, 9)), 9);
        // Idle and calm: shrink one chain, never below the floor.
        assert_eq!(p.target_regions(&snap(0, 0, 3, 9)), 6);
        assert_eq!(p.target_regions(&snap(0, 0, 1, 3)), 3, "floor holds");
        // Idle but waits not calm yet: hold (the hysteresis band).
        assert_eq!(p.target_regions(&snap(0, 1_000_000, 3, 9)), 9);
        // Below the floor (post-churn shortfall): grow back to it.
        assert_eq!(p.target_regions(&snap(0, 0, 0, 0)), 3);
    }

    #[test]
    fn latency_slo_policy_tracks_the_slo() {
        let p = LatencySlo::default();
        assert_eq!(p.target_regions(&snap(1, 7_000_000, 1, 3)), 6, "violation");
        assert_eq!(p.target_regions(&snap(1, 3_000_000, 2, 6)), 6, "inside band");
        assert_eq!(p.target_regions(&snap(0, 100, 2, 6)), 3, "calm: shrink");
        assert_eq!(p.target_regions(&snap(0, 100, 1, 3)), 3, "floor");
    }

    #[test]
    fn predictive_policy_provisions_ahead_of_the_ramp() {
        let p = Predictive::default(); // 120 req/s per slice, 2 windows
        // Flat 100 req/s, empty queue: one slice suffices, hold.
        assert_eq!(p.target_regions(&rate_snap(100.0, 0.0, 0, 1, 3)), 3);
        // Same rate but ramping +100 req/s per window: predicted 300
        // req/s -> 3 slices, *before* any backlog exists.
        assert_eq!(p.target_regions(&rate_snap(100.0, 100.0, 0, 1, 3)), 9);
        // Falling slope never extrapolates below the current EWMA on the
        // grow side: predicted = max(ewma, ...) -> 250 req/s still needs
        // 3 slices.
        assert_eq!(p.target_regions(&rate_snap(250.0, -50.0, 0, 3, 9)), 9);
        // Reactive safety net: deep backlog grows even with zero slope.
        assert_eq!(p.target_regions(&rate_snap(10.0, 0.0, 9, 1, 3)), 6);
        // Shrink only when idle, calm, and the prediction has fallen.
        assert_eq!(p.target_regions(&rate_snap(50.0, -30.0, 0, 3, 9)), 6);
        // Idle but the prediction still fills the allocation: hold.
        assert_eq!(p.target_regions(&rate_snap(260.0, 0.0, 0, 3, 9)), 9);
        // Floor holds.
        assert_eq!(p.target_regions(&rate_snap(0.0, -10.0, 0, 1, 3)), 3);
    }

    #[test]
    fn policy_kind_parses_and_builds() {
        assert_eq!(PolicyKind::parse("depth"), Some(PolicyKind::TargetQueueDepth));
        assert_eq!(PolicyKind::parse("latency-slo"), Some(PolicyKind::LatencySlo));
        assert_eq!(PolicyKind::parse("predictive"), Some(PolicyKind::Predictive));
        assert_eq!(PolicyKind::parse("feedforward"), Some(PolicyKind::Predictive));
        assert_eq!(PolicyKind::parse("nope"), None);
        assert_eq!(PolicyKind::TargetQueueDepth.build().name(), "target-queue-depth");
        assert_eq!(PolicyKind::LatencySlo.build().name(), "latency-slo");
        assert_eq!(PolicyKind::Predictive.build().name(), "predictive-ewma");
        assert_eq!(StaticPolicy.target_regions(&snap(50, 9_999_999, 1, 3)), 3);
    }
}
