//! Churn model: boards joining/leaving and regions fenced offline
//! mid-trace — the k8s-style dynamics the ROADMAP calls for.
//!
//! A [`ChurnTrace`] is a time-ordered list of events the engine applies
//! at its control-tick boundaries (the cadence at which a real control
//! plane would observe node heartbeats).  Semantics are **graceful**:
//! work already dispatched to a leaving board completes (drain), the
//! board's reservations are then released and fenced, and — in reactive
//! mode — the actuator re-places the lost capacity on surviving boards.

use crate::util::SplitMix64;

/// One churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// The board leaves: slices drain, regions fence `Offline`.
    NodeDown {
        /// Fleet node index.
        node: usize,
    },
    /// The board rejoins empty (all its regions unfenced).
    NodeUp {
        /// Fleet node index.
        node: usize,
    },
    /// Fence up to `regions` *available* regions on a live board
    /// (reserved regions are never ripped out from under an app).
    Fence {
        /// Fleet node index.
        node: usize,
        /// Regions to fence.
        regions: usize,
    },
    /// Unfence up to `regions` churn-fenced regions on a live board.
    Unfence {
        /// Fleet node index.
        node: usize,
        /// Regions to restore.
        regions: usize,
    },
}

/// A deterministic, time-ordered churn schedule (times in trace ms).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnTrace {
    /// `(at_ms, event)` pairs, non-decreasing in time.
    pub events: Vec<(f64, ChurnEvent)>,
}

impl ChurnTrace {
    /// No churn.
    pub fn none() -> Self {
        Self::default()
    }

    /// One outage of `node`: down at `down_ms`, back at `up_ms`.
    pub fn outage(node: usize, down_ms: f64, up_ms: f64) -> Self {
        assert!(down_ms < up_ms);
        Self {
            events: vec![
                (down_ms, ChurnEvent::NodeDown { node }),
                (up_ms, ChurnEvent::NodeUp { node }),
            ],
        }
    }

    /// Seeded synthetic churn over `duration_ms`: 1-2 board outages
    /// (never node 0, so the fleet keeps a capacity floor) plus 1-2
    /// region fence/unfence windows, all bounded inside the trace.
    pub fn generate(seed: u64, nodes: usize, duration_ms: f64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut events = Vec::new();
        if nodes > 1 {
            let outages = 1 + rng.below(2) as usize;
            for _ in 0..outages {
                let node = 1 + rng.below_usize(nodes - 1);
                let start = rng.unit_f64() * 0.6 * duration_ms;
                let len = (0.1 + 0.2 * rng.unit_f64()) * duration_ms;
                events.push((start, ChurnEvent::NodeDown { node }));
                events.push((
                    (start + len).min(duration_ms * 0.95),
                    ChurnEvent::NodeUp { node },
                ));
            }
        }
        let fences = 1 + rng.below(2) as usize;
        for _ in 0..fences {
            let node = rng.below_usize(nodes);
            let regions = 1 + rng.below_usize(2);
            let start = rng.unit_f64() * 0.7 * duration_ms;
            let len = (0.1 + 0.2 * rng.unit_f64()) * duration_ms;
            events.push((start, ChurnEvent::Fence { node, regions }));
            events.push((
                (start + len).min(duration_ms * 0.95),
                ChurnEvent::Unfence { node, regions },
            ));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Self { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_churn_is_deterministic_ordered_and_bounded() {
        let a = ChurnTrace::generate(11, 5, 10_000.0);
        let b = ChurnTrace::generate(11, 5, 10_000.0);
        assert_eq!(a, b);
        assert!(!a.events.is_empty());
        for w in a.events.windows(2) {
            assert!(w[0].0 <= w[1].0, "events out of order");
        }
        for (at, ev) in &a.events {
            assert!(*at >= 0.0 && *at <= 10_000.0);
            match *ev {
                ChurnEvent::NodeDown { node } | ChurnEvent::NodeUp { node } => {
                    assert!((1..5).contains(&node), "node 0 must stay up");
                }
                ChurnEvent::Fence { node, regions }
                | ChurnEvent::Unfence { node, regions } => {
                    assert!(node < 5);
                    assert!((1..=2).contains(&regions));
                }
            }
        }
        // Different seeds differ.
        assert_ne!(a, ChurnTrace::generate(12, 5, 10_000.0));
    }

    #[test]
    fn outage_helper_orders_events() {
        let t = ChurnTrace::outage(2, 100.0, 400.0);
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0], (100.0, ChurnEvent::NodeDown { node: 2 }));
        assert_eq!(t.events[1], (400.0, ChurnEvent::NodeUp { node: 2 }));
        assert!(ChurnTrace::none().events.is_empty());
    }
}
