//! Area & power model (§V.F, Tables I and II; Fig 6's companion).
//!
//! Vivado post-synthesis utilization cannot be re-run here (no FPGA
//! toolchain), so the model is **anchored on the paper's measured
//! values** (Table I) and extended with the *scaling laws* the paper
//! cites: the LZC-based arbiter's area grows quadratically with port
//! count but with a lower rate than priority-encoder designs [32]; the
//! register file grows by three registers per extra PR region (§V.G);
//! and the comparison baselines come from [16] (NoC routers) and [21]
//! (E-WB shared bus) exactly as Table II quotes them.

use crate::fabric::DeviceModel;

/// LUT/FF/BRAM/power usage of one component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentArea {
    pub luts: u64,
    pub ffs: u64,
    /// BRAM tiles (36Kb); halves appear as .5.
    pub brams: f64,
    /// Dynamic power estimate in mW (None where the paper gives none).
    pub power_mw: Option<f64>,
}

impl ComponentArea {
    const fn new(luts: u64, ffs: u64, brams: f64, power_mw: Option<f64>) -> Self {
        Self { luts, ffs, brams, power_mw }
    }

    /// Component-wise sum.
    pub fn plus(self, o: ComponentArea) -> ComponentArea {
        ComponentArea {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            brams: self.brams + o.brams,
            power_mw: match (self.power_mw, o.power_mw) {
                (Some(a), Some(b)) => Some(a + b),
                (a, b) => a.or(b),
            },
        }
    }

    /// Scale all resources by an integer factor.
    pub fn times(self, k: u64) -> ComponentArea {
        ComponentArea {
            luts: self.luts * k,
            ffs: self.ffs * k,
            brams: self.brams * k as f64,
            power_mw: self.power_mw.map(|p| p * k as f64),
        }
    }
}

/// Table I rows: the paper's measured per-component utilization.
pub mod table1 {
    use super::ComponentArea;

    pub const XDMA_IP: ComponentArea = ComponentArea::new(33_441, 30_843, 62.0, None);
    pub const WB_CROSSBAR: ComponentArea = ComponentArea::new(475, 60, 0.0, Some(1.0));
    pub const WB_HAMMING_DECODER: ComponentArea = ComponentArea::new(432, 646, 0.0, None);
    pub const WB_MASTER_IF: ComponentArea = ComponentArea::new(213, 27, 0.0, Some(1.0));
    pub const WB_SLAVE_IF: ComponentArea = ComponentArea::new(115, 220, 0.0, Some(0.9));
    pub const HAMMING_DECODER: ComponentArea = ComponentArea::new(104, 399, 0.0, None);
    pub const WB_HAMMING_ENCODER: ComponentArea = ComponentArea::new(233, 99, 0.0, None);
    pub const WB_MULTIPLIER: ComponentArea = ComponentArea::new(138, 624, 0.0, None);
    pub const AXI_WB_FIFO: ComponentArea = ComponentArea::new(975, 1_842, 13.5, None);
    pub const WB_AXI_FIFO: ComponentArea = ComponentArea::new(389, 2_274, 13.5, None);
    pub const REGISTER_FILE: ComponentArea = ComponentArea::new(265, 560, 0.0, None);

    /// Table I's reported totals row.
    pub const TOTAL: ComponentArea = ComponentArea::new(36_348, 36_948, 89.0, None);

    /// All rows in table order: (name, area, counted-in-total).  The
    /// "WB Hamming Decoder" row is a *composite* (= WB Master Interface
    /// + WB Slave Interface + Hamming Decoder: 213+115+104 = 432 LUTs,
    /// 27+220+399 = 646 FFs) and the paper's Total excludes it to avoid
    /// double counting.
    pub const ROWS: [(&str, ComponentArea, bool); 11] = [
        ("XDMA IP Core", XDMA_IP, true),
        ("WB Crossbar", WB_CROSSBAR, true),
        ("WB Hamming Decoder", WB_HAMMING_DECODER, false),
        ("WB Master Interface", WB_MASTER_IF, true),
        ("WB Slave Interface", WB_SLAVE_IF, true),
        ("Hamming Decoder", HAMMING_DECODER, true),
        ("WB Hamming Encoder", WB_HAMMING_ENCODER, true),
        ("WB Multiplier", WB_MULTIPLIER, true),
        ("AXI-WB-FIFO System", AXI_WB_FIFO, true),
        ("WB-AXI-FIFO System", WB_AXI_FIFO, true),
        ("Register File", REGISTER_FILE, true),
    ];
}

/// Per-kernel area from the registry (DESIGN.md §17): the seed kernels
/// keep their Table I-measured rows; table/artifact-backed kernels
/// report the LUT/FF cost their declaration carried.  This is the
/// bridge the autoscaler and Table-scaling benches use to cost a
/// registered kernel without a closed enum match.
pub fn module_area(kind: crate::modules::ModuleKind) -> ComponentArea {
    use crate::modules::ModuleKind;
    match kind {
        ModuleKind::Multiplier => table1::WB_MULTIPLIER,
        ModuleKind::HammingEncoder => table1::WB_HAMMING_ENCODER,
        ModuleKind::HammingDecoder => table1::HAMMING_DECODER,
        other => {
            let spec = other.spec();
            ComponentArea::new(spec.luts, spec.ffs, 0.0, None)
        }
    }
}

/// Area of a stage chain: the sum of its kernels' areas.
pub fn chain_area(stages: &[crate::modules::ModuleKind]) -> ComponentArea {
    stages
        .iter()
        .map(|&k| module_area(k))
        .fold(ComponentArea::new(0, 0, 0.0, None), ComponentArea::plus)
}

/// Table II rows: prior-art comparison points as quoted by the paper.
pub mod table2 {
    use super::ComponentArea;

    /// 4x4 WB crossbar (this work).
    pub const WB_CROSSBAR_4X4: ComponentArea = ComponentArea::new(475, 60, 0.0, Some(1.0));
    /// 2x2 NoC with four 3-port routers [16] serving 4 modules.
    pub const NOC_2X2_3PORT: ComponentArea =
        ComponentArea::new(1_220, 1_240, 0.0, Some(80.0));
    /// 4x4 WB crossbar interconnection *system* (crossbar + 4 master +
    /// 4 slave interfaces).
    pub const WB_SYSTEM_4X4: ComponentArea = ComponentArea::new(1_599, 796, 0.0, None);
    /// Four single master-slave E-WB communication infrastructures [21].
    pub const EWB_X4: ComponentArea = ComponentArea::new(1_076, 1_484, 0.0, None);
}

/// Analytic scaling of the crossbar with port count `n`, anchored at the
/// measured 4x4 point.
///
/// * LUTs: dominated by the per-slave-port arbitration + mux tree, each
///   of which sees all `n` masters — O(n^2) total, so
///   `lut(n) = lut(4) * (n/4)^2` (the paper: "the area overhead of the
///   LZC based arbiter increases quadratically with the number of
///   ports").
/// * FFs: per-port grant/state registers plus per-pair package counters'
///   control bits — the 4x4 point (60 FF = 3.75/port-pair) scales with
///   n^2 pairs as well, but the dominant term at small n is the per-port
///   state, so we scale linearly per port: `ff(n) = ff(4) * n / 4`.
pub fn crossbar_area(n: usize) -> ComponentArea {
    let n = n as f64;
    let luts = (table2::WB_CROSSBAR_4X4.luts as f64 * (n / 4.0).powi(2)).round() as u64;
    let ffs = (table2::WB_CROSSBAR_4X4.ffs as f64 * (n / 4.0)).round() as u64;
    ComponentArea {
        luts,
        ffs,
        brams: 0.0,
        power_mw: Some(1.0 * (n / 4.0).powi(2)),
    }
}

/// The crossbar interconnection *system* for `n` ports: crossbar plus a
/// WB master+slave interface pair per port.
pub fn crossbar_system_area(n: usize) -> ComponentArea {
    let per_port = table1::WB_MASTER_IF.plus(table1::WB_SLAVE_IF);
    crossbar_area(n).plus(per_port.times(n as u64))
}

/// §V.G: register-file growth — "for each new coming PR region, three
/// more registers has to be added: allowed addresses register, allowed
/// package numbers register, and destination address register."
pub fn regfile_registers(pr_regions: usize) -> usize {
    // The Table III file serves 3 PR regions with 20 registers.
    20 + 3 * pr_regions.saturating_sub(3)
}

/// Register count of the **banked layout v2** actually implemented in
/// [`crate::regfile::RegfileLayout`].  The paper's §V.G rule keeps the
/// package-number register at four 8-bit fields, which stops being
/// programmable past 4 masters; the banked layout instead spills budget
/// and error fields across ⌈N/4⌉-register banks, so growth is mildly
/// superlinear (20 regs at 4 ports, 122 at 16).  Identical to §V.G's
/// count at the paper's own 4-port point.
pub fn banked_regfile_registers(num_ports: usize) -> usize {
    crate::regfile::RegfileLayout::new(num_ports).num_regs()
}

/// Area of a `regs`-register file, scaled from the measured 20-register
/// Table I point.
fn regfile_area_for(regs: usize) -> ComponentArea {
    let scale = regs as f64 / 20.0;
    ComponentArea {
        luts: (table1::REGISTER_FILE.luts as f64 * scale).round() as u64,
        ffs: (table1::REGISTER_FILE.ffs as f64 * scale).round() as u64,
        brams: 0.0,
        power_mw: None,
    }
}

/// Register-file area under the paper's §V.G growth rule.
pub fn regfile_area(pr_regions: usize) -> ComponentArea {
    regfile_area_for(regfile_registers(pr_regions))
}

/// Banked-layout register-file area, scaled from the same measured
/// 20-register Table I point as [`regfile_area`].
pub fn banked_regfile_area(num_ports: usize) -> ComponentArea {
    regfile_area_for(banked_regfile_registers(num_ports))
}

/// Vivado-style utilization report for the whole shell (Table I format).
pub fn table1_report(device: &DeviceModel) -> String {
    let mut out = String::new();
    out.push_str(
        "| Component            |   LUT |    % |    FF |      % | BRAM |    % |\n",
    );
    out.push_str(
        "|----------------------|-------|------|-------|--------|------|------|\n",
    );
    let mut total = ComponentArea::new(0, 0, 0.0, None);
    for (name, a, counted) in table1::ROWS {
        if counted {
            total = total.plus(a);
        }
        out.push_str(&format!(
            "| {:<20} | {:>5} | {:>4.2} | {:>5} | {:>6.3} | {:>4} | {:>4.2} |\n",
            name,
            a.luts,
            device.lut_pct(a.luts),
            a.ffs,
            device.ff_pct(a.ffs),
            a.brams,
            device.bram_pct(a.brams),
        ));
    }
    out.push_str(&format!(
        "| {:<20} | {:>5} | {:>4.2} | {:>5} | {:>6.3} | {:>4} | {:>4.2} |\n",
        "Total",
        total.luts,
        device.lut_pct(total.luts),
        total.ffs,
        device.ff_pct(total.ffs),
        total.brams,
        device.bram_pct(total.brams),
    ));
    out
}

/// NoC area scaled to serve `n` modules, anchored at [16]'s 2x2 mesh of
/// four 3-port routers (1220 LUTs / 1240 FFs for 4 modules).  A mesh
/// needs one router per module; router area is per-unit constant (ports
/// per router stay 3-5 regardless of mesh size), so NoC area scales
/// *linearly* — the asymptotic advantage the paper concedes to NoCs.
pub fn noc_area(n: usize) -> ComponentArea {
    let per_module_luts = table2::NOC_2X2_3PORT.luts as f64 / 4.0;
    let per_module_ffs = table2::NOC_2X2_3PORT.ffs as f64 / 4.0;
    ComponentArea {
        luts: (per_module_luts * n as f64).round() as u64,
        ffs: (per_module_ffs * n as f64).round() as u64,
        brams: 0.0,
        power_mw: Some(80.0 / 4.0 * n as f64),
    }
}

/// §VI future work ("assessing the overhead in detail when scaling our
/// crossbar architecture"): the crossbar's quadratic LUT growth
/// eventually crosses the NoC's linear growth.  Returns the smallest
/// port count at which the crossbar stops being the smaller design.
pub fn crossbar_noc_crossover() -> usize {
    for n in 4..=64 {
        if crossbar_area(n).luts >= noc_area(n).luts {
            return n;
        }
    }
    usize::MAX
}

/// The paper's headline area claims (§I, §V.G), derived from the model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeadlineClaims {
    /// LUT savings vs the 2x2 NoC of [16] (paper: 61%).
    pub lut_savings_vs_noc_pct: f64,
    /// FF savings vs the NoC (paper: 95%).
    pub ff_savings_vs_noc_pct: f64,
    /// Power ratio NoC / crossbar (paper: 80x).
    pub power_ratio_vs_noc: f64,
    /// Extra LUTs vs 4x scaled E-WB shared bus (paper: +48.6%).
    pub lut_overhead_vs_ewb_pct: f64,
    /// FF savings vs E-WB (paper: 46.4%).
    pub ff_savings_vs_ewb_pct: f64,
}

/// Compute the headline claims from the component numbers.
pub fn headline_claims() -> HeadlineClaims {
    let xbar = table2::WB_CROSSBAR_4X4;
    let noc = table2::NOC_2X2_3PORT;
    let system = table2::WB_SYSTEM_4X4;
    let ewb = table2::EWB_X4;
    HeadlineClaims {
        lut_savings_vs_noc_pct: 100.0 * (1.0 - xbar.luts as f64 / noc.luts as f64),
        ff_savings_vs_noc_pct: 100.0 * (1.0 - xbar.ffs as f64 / noc.ffs as f64),
        power_ratio_vs_noc: noc.power_mw.unwrap() / xbar.power_mw.unwrap(),
        lut_overhead_vs_ewb_pct: 100.0 * (system.luts as f64 / ewb.luts as f64 - 1.0),
        ff_savings_vs_ewb_pct: 100.0 * (1.0 - system.ffs as f64 / ewb.ffs as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::DeviceModel;

    #[test]
    fn table1_total_matches_paper_row() {
        let mut total = ComponentArea::new(0, 0, 0.0, None);
        for (_, a, counted) in table1::ROWS {
            if counted {
                total = total.plus(a);
            }
        }
        assert_eq!(total.luts, table1::TOTAL.luts);
        assert_eq!(total.ffs, table1::TOTAL.ffs);
        assert_eq!(total.brams, 89.0);
    }

    #[test]
    fn composite_row_is_sum_of_its_parts() {
        // "WB Hamming Decoder" = WB master IF + WB slave IF + decoder.
        let parts = table1::WB_MASTER_IF
            .plus(table1::WB_SLAVE_IF)
            .plus(table1::HAMMING_DECODER);
        assert_eq!(parts.luts, table1::WB_HAMMING_DECODER.luts);
        assert_eq!(parts.ffs, table1::WB_HAMMING_DECODER.ffs);
    }

    #[test]
    fn crossbar_anchor_matches_measured_4x4() {
        let a = crossbar_area(4);
        assert_eq!(a.luts, 475);
        assert_eq!(a.ffs, 60);
        assert_eq!(a.power_mw, Some(1.0));
    }

    #[test]
    fn crossbar_scaling_is_quadratic_luts_linear_ffs() {
        let a8 = crossbar_area(8);
        assert_eq!(a8.luts, 475 * 4);
        assert_eq!(a8.ffs, 120);
        let a16 = crossbar_area(16);
        assert_eq!(a16.luts, 475 * 16);
    }

    #[test]
    fn system_area_matches_table2() {
        // 475 + 4*(213+115) = 1787... the paper reports 1599: its system
        // row uses the *averaged* interfaces (§V.F: "on average master
        // and slave interfaces have 196 and 85 LUTs"), i.e. 475 +
        // 4*(196+85) = 1599.  Reproduce that accounting.
        let avg_master = ComponentArea::new(196, 117, 0.0, None);
        let avg_slave = ComponentArea::new(85, 628, 0.0, None);
        let system = crossbar_area(4)
            .plus(avg_master.times(4))
            .plus(avg_slave.times(4));
        assert_eq!(system.luts, table2::WB_SYSTEM_4X4.luts);
        // FF accounting: 60 + 4*(117+628) = 3040 vs the paper's 796.
        // The paper's system row evidently counts only the *prototype's*
        // three module interface pairs' control FFs, not the averaged
        // data registers; we keep the quoted value as the comparison
        // anchor and note the discrepancy here.
        assert_eq!(table2::WB_SYSTEM_4X4.ffs, 796);
    }

    #[test]
    fn headline_claims_match_paper() {
        let h = headline_claims();
        assert!((h.lut_savings_vs_noc_pct - 61.0).abs() < 1.0, "{h:?}");
        assert!((h.ff_savings_vs_noc_pct - 95.0).abs() < 0.5, "{h:?}");
        assert!((h.power_ratio_vs_noc - 80.0).abs() < 0.1, "{h:?}");
        assert!((h.lut_overhead_vs_ewb_pct - 48.6).abs() < 0.5, "{h:?}");
        assert!((h.ff_savings_vs_ewb_pct - 46.4).abs() < 0.5, "{h:?}");
    }

    #[test]
    fn noc_scales_linearly_from_its_anchor() {
        assert_eq!(noc_area(4).luts, 1220);
        assert_eq!(noc_area(4).ffs, 1240);
        assert_eq!(noc_area(8).luts, 2440);
        assert_eq!(noc_area(8).power_mw, Some(160.0));
    }

    #[test]
    fn crossover_analysis_matches_the_papers_tradeoff() {
        // At the prototype scale the crossbar wins by far; quadratic LUT
        // growth crosses the NoC's linear growth at ~10 ports — i.e. the
        // paper's "small number of small PR regions" regime is exactly
        // where the crossbar is the right choice (§II.A's area-vs-
        // scalability trade-off, quantified).
        let n = crossbar_noc_crossover();
        assert!(
            (8..=12).contains(&n),
            "crossover at {n} ports (expected ~10)"
        );
        assert!(crossbar_area(4).luts < noc_area(4).luts / 2);
        assert!(crossbar_area(16).luts > noc_area(16).luts);
    }

    #[test]
    fn regfile_growth_three_regs_per_region() {
        assert_eq!(regfile_registers(3), 20);
        assert_eq!(regfile_registers(4), 23);
        assert_eq!(regfile_registers(10), 41);
        let a3 = regfile_area(3);
        let a4 = regfile_area(4);
        assert_eq!(a3.luts, 265);
        assert!(a4.luts > a3.luts);
    }

    #[test]
    fn banked_regfile_matches_table3_at_four_ports_and_spills_beyond() {
        // At the paper's own point the banked layout is Table III.
        assert_eq!(banked_regfile_registers(4), regfile_registers(3));
        assert_eq!(banked_regfile_area(4).luts, regfile_area(3).luts);
        // Beyond it, the budget/error spill makes v2 strictly larger
        // than §V.G's 3-per-region rule (full programmability costs).
        assert_eq!(banked_regfile_registers(16), 122);
        assert!(banked_regfile_registers(16) > regfile_registers(15));
        assert!(banked_regfile_area(16).luts > regfile_area(15).luts);
    }

    #[test]
    fn module_area_covers_seeds_and_registered_kernels() {
        use crate::modules::ModuleKind;
        assert_eq!(module_area(ModuleKind::Multiplier), table1::WB_MULTIPLIER);
        assert_eq!(
            module_area(ModuleKind::HammingEncoder),
            table1::WB_HAMMING_ENCODER
        );
        assert_eq!(
            module_area(ModuleKind::HammingDecoder),
            table1::HAMMING_DECODER
        );
        let id = crate::kernels::register(
            crate::kernels::KernelDecl {
                name: "area-test-k".into(),
                op: Some("xor".into()),
                luts: 777,
                ffs: 333,
                ..crate::kernels::KernelDecl::default()
            },
            None,
        )
        .unwrap();
        let a = module_area(id);
        assert_eq!((a.luts, a.ffs), (777, 333));
        // Chain area sums component-wise.
        let chain = chain_area(&[ModuleKind::Multiplier, id]);
        assert_eq!(chain.luts, table1::WB_MULTIPLIER.luts + 777);
        assert_eq!(chain.ffs, table1::WB_MULTIPLIER.ffs + 333);
    }

    #[test]
    fn report_renders_all_rows() {
        let d = DeviceModel::kcu1500_prototype();
        let r = table1_report(&d);
        for (name, _, _) in table1::ROWS {
            assert!(r.contains(name), "missing {name}");
        }
        assert!(r.contains("Total"));
    }
}
