//! Decentralized per-slave-port Weighted-Round-Robin arbiter (§IV.E.1).
//!
//! "To support bandwidth requirements of different accelerators, we
//! propose a Weighted Round Robin (WRR) arbiter based on leading zero
//! counters (LZC) [...].  It tracks the number of packages rather than
//! the time period via package counter, which looks up the registers
//! holding the maximum number of packages each master is allowed to
//! send.  When the maximum number of packages is reached, it switches
//! the grant to the next master."
//!
//! Each slave port owns one arbiter, making the scheme decentralized —
//! there is no global arbitration state, which is what keeps the
//! crossbar's area low (§II.A notes arbitration logic dominates crossbar
//! area) and simplifies multicast management.
//!
//! # App-aware rotation order
//!
//! The WRR rotation walks a *programmable permutation* of the master
//! ports ([`Arbiter::set_rotation_order`]), not raw port-index order.
//! The bandwidth-plan compiler ([`crate::qos`]) places every app's
//! masters adjacently in that permutation, so a multi-region app's
//! per-rotation share is contiguous and stays proportional even when
//! the app spans more than 4 masters.  The power-on order is the
//! identity permutation — exactly the classic index-order WRR.
//!
//! Programming errors (zero budgets, out-of-range masters, malformed
//! permutations) surface as typed [`ElasticError`] results, consistent
//! with the register file's `Result` accessors: a bad host-programmed
//! value must never crash the shell model.
//!
//! Timing: a request raised in cycle `t` is first *seen* in cycle `t+1`
//! and granted at the end of cycle `t+2` — the paper's "an arbiter spends
//! 2 ccs to grant the request and enable the slave interface".

use crate::util::lzc::lzc_select;
use crate::{ElasticError, Result};

/// Arbiter FSM state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterState {
    /// Bus free, no decision in progress.
    Free,
    /// Decision cycle 1 of 2 completed for `candidate`.
    Deciding { candidate: usize },
    /// `master` holds the bus.
    Granted { master: usize },
}

/// Weighted-Round-Robin arbiter for one slave port.
#[derive(Debug)]
pub struct Arbiter {
    n: usize,
    state: ArbiterState,
    /// Pending request bits, indexed by master port.
    requests: u32,
    /// WRR pointer: last master granted.
    last_grant: Option<u32>,
    /// Per-master package budget per grant (Table III regs 9-12).
    budgets: Vec<u32>,
    /// Rotation permutation: `order[pos]` = master port at rotation
    /// position `pos` (identity at power-on).
    order: Vec<usize>,
    /// Inverse permutation: `pos_of[port]` = rotation position.
    pos_of: Vec<u32>,
    /// Port held in reset (no grant decisions — §IV.C).
    pub in_reset: bool,
}

impl Arbiter {
    /// New free arbiter with a uniform default package budget and the
    /// identity rotation order.  Errors on a zero budget or a port
    /// count outside 1..=32.
    pub fn new(n: usize, default_budget: u32) -> Result<Self> {
        if default_budget == 0 {
            return Err(ElasticError::Config(
                "package budget must be positive".into(),
            ));
        }
        if n == 0 || n > 32 {
            return Err(ElasticError::Config(format!(
                "arbiter width {n} outside 1..=32"
            )));
        }
        Ok(Self {
            n,
            state: ArbiterState::Free,
            requests: 0,
            last_grant: None,
            budgets: vec![default_budget; n],
            order: (0..n).collect(),
            pos_of: (0..n as u32).collect(),
            in_reset: false,
        })
    }

    /// Current FSM state.
    pub fn state(&self) -> ArbiterState {
        self.state
    }

    /// The master currently holding the bus, if any.
    pub fn granted_master(&self) -> Option<usize> {
        match self.state {
            ArbiterState::Granted { master } => Some(master),
            _ => None,
        }
    }

    /// Is the bus free (no grant, no decision in progress)?
    pub fn is_free(&self) -> bool {
        self.state == ArbiterState::Free
    }

    /// Raise master `m`'s request line.
    pub fn raise_request(&mut self, m: usize) {
        debug_assert!(m < self.n);
        self.requests |= 1 << m;
    }

    /// Drop master `m`'s request line (withdrawal or completion).
    pub fn drop_request(&mut self, m: usize) {
        self.requests &= !(1 << m);
    }

    /// Is master `m` currently requesting?
    pub fn is_requesting(&self, m: usize) -> bool {
        self.requests >> m & 1 == 1
    }

    /// Per-grant package budget for master `m`.
    pub fn budget(&self, m: usize) -> u32 {
        self.budgets[m]
    }

    /// Program master `m`'s package budget (register-file write).
    /// Typed refusal — never a panic — on a zero budget or a master
    /// outside this arbiter's width.
    pub fn set_budget(&mut self, m: usize, packages: u32) -> Result<()> {
        if packages == 0 {
            return Err(ElasticError::Config(
                "package budget must be positive".into(),
            ));
        }
        if m >= self.n {
            return Err(ElasticError::Config(format!(
                "master {m} outside the {}-port arbiter", self.n
            )));
        }
        self.budgets[m] = packages;
        Ok(())
    }

    /// Program the WRR rotation order: `order[pos]` names the master
    /// port visited at rotation position `pos`.  Must be a permutation
    /// of `0..n`.  The in-flight grant and pending requests are
    /// unaffected; only future rotation decisions follow the new order.
    pub fn set_rotation_order(&mut self, order: &[usize]) -> Result<()> {
        if order.len() != self.n {
            return Err(ElasticError::Config(format!(
                "rotation order names {} ports, arbiter has {}",
                order.len(),
                self.n
            )));
        }
        let mut pos_of = vec![u32::MAX; self.n];
        for (pos, &port) in order.iter().enumerate() {
            if port >= self.n || pos_of[port] != u32::MAX {
                return Err(ElasticError::Config(format!(
                    "rotation order is not a permutation of 0..{}",
                    self.n
                )));
            }
            pos_of[port] = pos as u32;
        }
        self.order = order.to_vec();
        self.pos_of = pos_of;
        Ok(())
    }

    /// The rotation order in force (`order[pos]` = master port).
    pub fn rotation_order(&self) -> &[usize] {
        &self.order
    }

    /// LZC-select the next requester in WRR order, walking the
    /// programmed rotation permutation.
    fn select(&self) -> Option<usize> {
        // Map the request vector into rotation-position space, pick the
        // first position after the last grantee's, map back to a port.
        let mut pos_requests = 0u32;
        let mut req = self.requests & ((1u64 << self.n) - 1) as u32;
        while req != 0 {
            let m = req.trailing_zeros() as usize;
            pos_requests |= 1 << self.pos_of[m];
            req &= req - 1;
        }
        let last_pos = self.last_grant.map(|m| self.pos_of[m as usize]);
        lzc_select(pos_requests, self.n as u32, last_pos)
            .map(|pos| self.order[pos as usize])
    }

    /// Release the bus (registered: called by the crossbar at the start of
    /// the cycle *after* the last word).
    pub fn release(&mut self) {
        if let ArbiterState::Granted { master } = self.state {
            self.last_grant = Some(master as u32);
        }
        self.state = ArbiterState::Free;
    }

    /// Full reset (§IV.C): drop requests and any grant; keep budgets and
    /// the rotation order (they live in the configuration plane and
    /// survive module reconfiguration).
    pub fn reset(&mut self) {
        self.state = ArbiterState::Free;
        self.requests = 0;
        self.last_grant = None;
    }

    /// One clock: advance the 2-cycle decision pipeline.
    pub fn tick(&mut self) {
        if self.in_reset {
            return;
        }
        match self.state {
            ArbiterState::Free => {
                // Decision cycle 1: LZC-select the next requester in WRR
                // order.
                if let Some(winner) = self.select() {
                    self.state = ArbiterState::Deciding { candidate: winner };
                }
            }
            ArbiterState::Deciding { candidate } => {
                // Decision cycle 2: commit the grant — unless the candidate
                // withdrew in between (e.g. its watchdog fired), in which
                // case re-decide.
                if self.is_requesting(candidate) {
                    self.state = ArbiterState::Granted { master: candidate };
                } else if let Some(winner) = self.select() {
                    self.state = ArbiterState::Deciding { candidate: winner };
                } else {
                    self.state = ArbiterState::Free;
                }
            }
            ArbiterState::Granted { .. } => {
                // Held until the crossbar calls release().
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arb(n: usize, budget: u32) -> Arbiter {
        Arbiter::new(n, budget).unwrap()
    }

    #[test]
    fn grant_takes_exactly_two_ticks() {
        let mut a = arb(4, 8);
        a.raise_request(2);
        assert!(a.is_free());
        a.tick(); // decision cycle 1
        assert_eq!(a.granted_master(), None);
        a.tick(); // decision cycle 2
        assert_eq!(a.granted_master(), Some(2));
    }

    #[test]
    fn wrr_order_rotates_from_last_grant() {
        let mut a = arb(4, 8);
        a.raise_request(0);
        a.raise_request(2);
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(0));
        a.drop_request(0);
        a.release();
        a.raise_request(0); // 0 asks again, but 2 is next in WRR order
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(2));
    }

    #[test]
    fn withdrawal_during_decision_reevaluates() {
        let mut a = arb(4, 8);
        a.raise_request(1);
        a.tick(); // deciding on 1
        a.drop_request(1);
        a.raise_request(3);
        a.tick(); // 1 gone; re-decide on 3
        assert_eq!(a.granted_master(), None);
        a.tick();
        assert_eq!(a.granted_master(), Some(3));
    }

    #[test]
    fn withdrawal_with_no_others_returns_to_free() {
        let mut a = arb(4, 8);
        a.raise_request(1);
        a.tick();
        a.drop_request(1);
        a.tick();
        assert!(a.is_free());
    }

    #[test]
    fn reset_holds_off_grants() {
        let mut a = arb(4, 8);
        a.in_reset = true;
        a.raise_request(0);
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), None, "no grant decisions in reset");
        a.in_reset = false;
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(0));
    }

    #[test]
    fn budgets_are_programmable_per_master() {
        let mut a = arb(4, 8);
        assert_eq!(a.budget(3), 8);
        a.set_budget(3, 128).unwrap();
        assert_eq!(a.budget(3), 128);
        assert_eq!(a.budget(2), 8);
    }

    #[test]
    fn bad_programming_errors_instead_of_panicking() {
        assert!(matches!(
            Arbiter::new(4, 0),
            Err(ElasticError::Config(_))
        ));
        assert!(matches!(
            Arbiter::new(33, 8),
            Err(ElasticError::Config(_))
        ));
        let mut a = arb(4, 8);
        assert!(matches!(a.set_budget(0, 0), Err(ElasticError::Config(_))));
        assert!(matches!(a.set_budget(4, 8), Err(ElasticError::Config(_))));
        assert_eq!(a.budget(0), 8, "refused write left the budget alone");
        assert!(a.set_rotation_order(&[0, 1, 2]).is_err(), "wrong length");
        assert!(a.set_rotation_order(&[0, 1, 2, 2]).is_err(), "duplicate");
        assert!(a.set_rotation_order(&[0, 1, 2, 4]).is_err(), "out of range");
        assert_eq!(a.rotation_order(), &[0, 1, 2, 3], "order unchanged");
    }

    #[test]
    fn programmed_rotation_order_drives_the_walk() {
        // Order 0,2,3,1: after 0's grant, 2 precedes 1 even though 1 has
        // the lower port index.
        let mut a = arb(4, 8);
        a.set_rotation_order(&[0, 2, 3, 1]).unwrap();
        for m in 0..4 {
            a.raise_request(m);
        }
        let mut grants = Vec::new();
        for _ in 0..4 {
            a.tick();
            a.tick();
            let g = a.granted_master().unwrap();
            grants.push(g);
            a.drop_request(g);
            a.release();
            a.raise_request(g); // stay saturated
        }
        assert_eq!(grants, vec![0, 2, 3, 1]);
    }

    #[test]
    fn rotation_order_survives_reset() {
        let mut a = arb(4, 8);
        a.set_rotation_order(&[3, 2, 1, 0]).unwrap();
        a.reset();
        assert_eq!(a.rotation_order(), &[3, 2, 1, 0]);
    }
}
