//! Decentralized per-slave-port Weighted-Round-Robin arbiter (§IV.E.1).
//!
//! "To support bandwidth requirements of different accelerators, we
//! propose a Weighted Round Robin (WRR) arbiter based on leading zero
//! counters (LZC) [...].  It tracks the number of packages rather than
//! the time period via package counter, which looks up the registers
//! holding the maximum number of packages each master is allowed to
//! send.  When the maximum number of packages is reached, it switches
//! the grant to the next master."
//!
//! Each slave port owns one arbiter, making the scheme decentralized —
//! there is no global arbitration state, which is what keeps the
//! crossbar's area low (§II.A notes arbitration logic dominates crossbar
//! area) and simplifies multicast management.
//!
//! Timing: a request raised in cycle `t` is first *seen* in cycle `t+1`
//! and granted at the end of cycle `t+2` — the paper's "an arbiter spends
//! 2 ccs to grant the request and enable the slave interface".

use crate::util::lzc::lzc_select;

/// Arbiter FSM state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterState {
    /// Bus free, no decision in progress.
    Free,
    /// Decision cycle 1 of 2 completed for `candidate`.
    Deciding { candidate: usize },
    /// `master` holds the bus.
    Granted { master: usize },
}

/// Weighted-Round-Robin arbiter for one slave port.
#[derive(Debug)]
pub struct Arbiter {
    n: usize,
    state: ArbiterState,
    /// Pending request bits, indexed by master port.
    requests: u32,
    /// WRR pointer: last master granted.
    last_grant: Option<u32>,
    /// Per-master package budget per grant (Table III regs 9-12).
    budgets: Vec<u32>,
    /// Port held in reset (no grant decisions — §IV.C).
    pub in_reset: bool,
}

impl Arbiter {
    /// New free arbiter with a uniform default package budget.
    pub fn new(n: usize, default_budget: u32) -> Self {
        assert!(default_budget > 0, "package budget must be positive");
        Self {
            n,
            state: ArbiterState::Free,
            requests: 0,
            last_grant: None,
            budgets: vec![default_budget; n],
            in_reset: false,
        }
    }

    /// Current FSM state.
    pub fn state(&self) -> ArbiterState {
        self.state
    }

    /// The master currently holding the bus, if any.
    pub fn granted_master(&self) -> Option<usize> {
        match self.state {
            ArbiterState::Granted { master } => Some(master),
            _ => None,
        }
    }

    /// Is the bus free (no grant, no decision in progress)?
    pub fn is_free(&self) -> bool {
        self.state == ArbiterState::Free
    }

    /// Raise master `m`'s request line.
    pub fn raise_request(&mut self, m: usize) {
        debug_assert!(m < self.n);
        self.requests |= 1 << m;
    }

    /// Drop master `m`'s request line (withdrawal or completion).
    pub fn drop_request(&mut self, m: usize) {
        self.requests &= !(1 << m);
    }

    /// Is master `m` currently requesting?
    pub fn is_requesting(&self, m: usize) -> bool {
        self.requests >> m & 1 == 1
    }

    /// Per-grant package budget for master `m`.
    pub fn budget(&self, m: usize) -> u32 {
        self.budgets[m]
    }

    /// Program master `m`'s package budget (register-file write).
    pub fn set_budget(&mut self, m: usize, packages: u32) {
        assert!(packages > 0, "package budget must be positive");
        self.budgets[m] = packages;
    }

    /// Release the bus (registered: called by the crossbar at the start of
    /// the cycle *after* the last word).
    pub fn release(&mut self) {
        if let ArbiterState::Granted { master } = self.state {
            self.last_grant = Some(master as u32);
        }
        self.state = ArbiterState::Free;
    }

    /// Full reset (§IV.C): drop requests and any grant; keep budgets (they
    /// live in the register file and survive module reconfiguration).
    pub fn reset(&mut self) {
        self.state = ArbiterState::Free;
        self.requests = 0;
        self.last_grant = None;
    }

    /// One clock: advance the 2-cycle decision pipeline.
    pub fn tick(&mut self) {
        if self.in_reset {
            return;
        }
        match self.state {
            ArbiterState::Free => {
                // Decision cycle 1: LZC-select the next requester in WRR
                // order.
                if let Some(winner) =
                    lzc_select(self.requests, self.n as u32, self.last_grant)
                {
                    self.state = ArbiterState::Deciding { candidate: winner as usize };
                }
            }
            ArbiterState::Deciding { candidate } => {
                // Decision cycle 2: commit the grant — unless the candidate
                // withdrew in between (e.g. its watchdog fired), in which
                // case re-decide.
                if self.is_requesting(candidate) {
                    self.state = ArbiterState::Granted { master: candidate };
                } else if let Some(winner) =
                    lzc_select(self.requests, self.n as u32, self.last_grant)
                {
                    self.state = ArbiterState::Deciding { candidate: winner as usize };
                } else {
                    self.state = ArbiterState::Free;
                }
            }
            ArbiterState::Granted { .. } => {
                // Held until the crossbar calls release().
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grant_takes_exactly_two_ticks() {
        let mut a = Arbiter::new(4, 8);
        a.raise_request(2);
        assert!(a.is_free());
        a.tick(); // decision cycle 1
        assert_eq!(a.granted_master(), None);
        a.tick(); // decision cycle 2
        assert_eq!(a.granted_master(), Some(2));
    }

    #[test]
    fn wrr_order_rotates_from_last_grant() {
        let mut a = Arbiter::new(4, 8);
        a.raise_request(0);
        a.raise_request(2);
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(0));
        a.drop_request(0);
        a.release();
        a.raise_request(0); // 0 asks again, but 2 is next in WRR order
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(2));
    }

    #[test]
    fn withdrawal_during_decision_reevaluates() {
        let mut a = Arbiter::new(4, 8);
        a.raise_request(1);
        a.tick(); // deciding on 1
        a.drop_request(1);
        a.raise_request(3);
        a.tick(); // 1 gone; re-decide on 3
        assert_eq!(a.granted_master(), None);
        a.tick();
        assert_eq!(a.granted_master(), Some(3));
    }

    #[test]
    fn withdrawal_with_no_others_returns_to_free() {
        let mut a = Arbiter::new(4, 8);
        a.raise_request(1);
        a.tick();
        a.drop_request(1);
        a.tick();
        assert!(a.is_free());
    }

    #[test]
    fn reset_holds_off_grants() {
        let mut a = Arbiter::new(4, 8);
        a.in_reset = true;
        a.raise_request(0);
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), None, "no grant decisions in reset");
        a.in_reset = false;
        a.tick();
        a.tick();
        assert_eq!(a.granted_master(), Some(0));
    }

    #[test]
    fn budgets_are_programmable_per_master() {
        let mut a = Arbiter::new(4, 8);
        assert_eq!(a.budget(3), 8);
        a.set_budget(3, 128);
        assert_eq!(a.budget(3), 128);
        assert_eq!(a.budget(2), 8);
    }

    #[test]
    #[should_panic]
    fn zero_budget_rejected() {
        let mut a = Arbiter::new(4, 8);
        a.set_budget(0, 0);
    }
}
