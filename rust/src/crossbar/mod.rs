//! The paper's core contribution: a configurable NxN WISHBONE crossbar
//! switch with decentralized Weighted-Round-Robin arbitration (§IV.E).
//!
//! Each port pairs a master side ([`MasterIf`]) and a slave side
//! ([`SlaveIf`] + [`Arbiter`]).  The master port validates one-hot
//! destination addresses against its isolation mask; the slave port's
//! arbiter grants requests in WRR order with per-master package budgets
//! read from the register file.
//!
//! # Cycle walkthrough (§V.E, reproduced exactly)
//!
//! Best case, 8 packages, idle slave:
//!
//! ```text
//! cc1   module request latched by the master interface
//! cc2   master interface validates the address and issues the request
//! cc3-4 arbiter decides and enables the slave interface (grant at cc4)
//! cc5-12  eight data words, one per cycle
//! cc13  error/success status registered          -> completion = 13 cc
//! ```
//!
//! Worst case (3 masters target the same slave): the k-th master in WRR
//! order sees time-to-grant `12(k-1) + 4`, i.e. 4 / 16 / 28 cc, and the
//! last completion is 37 cc.  Contenders *withdraw* when they observe the
//! bus granted to another master and re-issue after release (1 cc
//! re-latch + 1 cc issue + 2 cc arbitration), which is where the paper's
//! "12 ccs for each previous master" comes from.
//!
//! The simulator commits state in a fixed order per cycle — slave ports
//! (arbiters) first, then master ports in index order — with releases
//! registered at cycle end, so the counts above are deterministic and
//! independent of port numbering.

mod arbiter;
pub mod central;
mod stats;

pub use arbiter::{Arbiter, ArbiterState};
pub use stats::XbarStats;

use crate::config::CrossbarConfig;
use crate::sim::{EventDriven, Tick};
use crate::util::onehot::{decode_onehot, isolation_permits};
use crate::wishbone::{Job, MasterIf, MasterState, SlaveIf, WbError};
use crate::Result;

/// One bus grant as recorded when grant recording is on (see
/// [`Crossbar::set_record_grants`]): which master held which slave's bus
/// and how many words it delivered before the bus rotated or the job
/// finished.  The WRR fairness properties are stated over this log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GrantRecord {
    /// Fabric cycle the grant was accounted (bus release / rotation).
    pub cycle: u64,
    /// App that held the bus (per-tenant attribution for telemetry).
    pub app_id: u32,
    /// Slave port whose bus was held.
    pub slave: usize,
    /// Master port that held it.
    pub master: usize,
    /// Words delivered during the grant.
    pub words: u32,
}

/// A completion or error notification for one master-port job.
#[derive(Debug, Clone, PartialEq)]
pub struct XbarEvent {
    /// Master port the job belonged to.
    pub port: usize,
    /// Destination slave port (decoded; usize::MAX if address malformed).
    pub dest: usize,
    /// Application ID tag.
    pub app_id: u32,
    /// Cycle the module initiated the request.
    pub request_cycle: u64,
    /// Cycle of the first grant (0 when never granted).
    pub grant_cycle: u64,
    /// Cycle the status was registered (completion).
    pub done_cycle: u64,
    /// Words delivered.
    pub words: usize,
    /// Outcome.
    pub result: Result<(), WbError>,
}

impl XbarEvent {
    /// §V.E metric: cycles from request initiation to the master starting
    /// to send the first data word.  The module initiates during the cycle
    /// *before* the latch (`request_cycle - 1`), so best case this is
    /// exactly 4: latch (1) + validate/issue (1) + arbitrate (2).
    pub fn time_to_grant(&self) -> u64 {
        (self.grant_cycle + 1).saturating_sub(self.request_cycle)
    }

    /// §V.E metric: cycles from request initiation to status registration
    /// (13 for a best-case 8-package request).
    pub fn completion_latency(&self) -> u64 {
        (self.done_cycle + 1).saturating_sub(self.request_cycle)
    }
}

/// The NxN crossbar switch.
pub struct Crossbar {
    n: usize,
    cfg: CrossbarConfig,
    masters: Vec<MasterIf>,
    slaves: Vec<SlaveIf>,
    arbiters: Vec<Arbiter>,
    /// Per-slave released-this-cycle flag; committed to Free on the
    /// *next* slave tick so contenders re-latch one cycle after release.
    release_pending: Vec<bool>,
    events: Vec<XbarEvent>,
    stats: XbarStats,
    /// Opt-in per-grant log (off by default: fleet-scale runs would grow
    /// it without bound).
    record_grants: bool,
    grant_log: Vec<GrantRecord>,
    cycle: u64,
}

impl Crossbar {
    /// Build an NxN crossbar.  All masters start fully isolated
    /// (allowed_slaves = 0) until the register file programs them, mirroring
    /// the paper's configuration flow — use [`Crossbar::set_allowed_slaves`].
    pub fn new(n: usize, cfg: CrossbarConfig) -> Self {
        assert!(n >= 2 && n <= 32, "port count must be in 2..=32");
        assert!(
            cfg.default_packages > 0,
            "default package budget must be positive"
        );
        Self {
            n,
            masters: (0..n).map(|_| MasterIf::new(0)).collect(),
            slaves: (0..n)
                .map(|_| SlaveIf::new(cfg.slave_buffer_words))
                .collect(),
            arbiters: (0..n)
                .map(|_| {
                    Arbiter::new(n, cfg.default_packages)
                        .expect("width and default budget validated above")
                })
                .collect(),
            release_pending: vec![false; n],
            events: Vec::new(),
            stats: XbarStats::new(n),
            record_grants: false,
            grant_log: Vec::new(),
            cfg,
            cycle: 0,
        }
    }

    /// Port count.
    pub fn ports(&self) -> usize {
        self.n
    }

    /// Current cycle (last executed).
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Program a master port's isolation mask (Table III regs 5-8).
    pub fn set_allowed_slaves(&mut self, master: usize, mask: u32) {
        self.masters[master].allowed_slaves = mask;
    }

    /// Program per-master package budgets at a slave port (Table III regs
    /// 9-12: "package numbers allowed in port N for ports [3:0]").  A bad
    /// host-programmed budget (zero, or a master beyond the width) is
    /// refused with a typed error instead of crashing the shell model.
    pub fn set_allowed_packages(
        &mut self,
        slave: usize,
        master: usize,
        packages: u32,
    ) -> Result<()> {
        if slave >= self.n {
            return Err(crate::ElasticError::Config(format!(
                "slave {slave} outside the {}-port crossbar", self.n
            )));
        }
        self.arbiters[slave].set_budget(master, packages)
    }

    /// Program the app-aware WRR rotation order on **every** slave-port
    /// arbiter (rotation order is a property of the master plane; see
    /// [`crate::qos`]).  `order` must be a permutation of `0..N`.
    pub fn set_rotation_order(&mut self, order: &[usize]) -> Result<()> {
        for a in &mut self.arbiters {
            a.set_rotation_order(order)?;
        }
        Ok(())
    }

    /// The rotation order in force (identity unless a bandwidth plan
    /// programmed an app-aware order).
    pub fn rotation_order(&self) -> &[usize] {
        self.arbiters[0].rotation_order()
    }

    /// Assert/deassert reset on a port pair (Table III reg 4).  While in
    /// reset the master aborts its queue and the slave won't arbitrate.
    pub fn set_port_reset(&mut self, port: usize, in_reset: bool) {
        if in_reset {
            self.masters[port].reset();
            self.slaves[port].reset();
            self.arbiters[port].reset();
            self.release_pending[port] = false;
            // Scrub the port's footprint from every *other* slave port:
            // pending request lines drop, and any grant it holds is
            // released — otherwise a reset master would pin a remote
            // arbiter in Granted forever (§IV.C isolation).
            for s in 0..self.n {
                self.arbiters[s].drop_request(port);
                if self.arbiters[s].granted_master() == Some(port) {
                    self.arbiters[s].release();
                }
            }
        }
        self.masters[port].in_reset = in_reset;
        self.slaves[port].in_reset = in_reset;
        self.arbiters[port].in_reset = in_reset;
    }

    /// Enqueue a transfer job on a master port.  The request is latched on
    /// the *next* cycle (that latch is §V.E's first cc).
    pub fn push_job(&mut self, master: usize, job: Job) {
        self.masters[master].push_job(job);
    }

    /// Is a master port completely idle (no job queued or in flight)?
    pub fn master_idle(&self, master: usize) -> bool {
        self.masters[master].state == MasterState::Idle
            && self.masters[master].queue.is_empty()
    }

    /// All master ports idle (no jobs queued or in flight)?  Received
    /// words may still sit in slave rx buffers awaiting their consumer.
    pub fn quiescent(&self) -> bool {
        (0..self.n).all(|p| self.master_idle(p))
    }

    /// The module/bridge side reads words received at its slave port.
    pub fn drain_rx(&mut self, slave: usize, max: usize) -> Vec<(u32, usize)> {
        self.slaves[slave].drain(max)
    }

    /// Allocation-free variant for hot loops: append up to `max` received
    /// words into `out`, returning how many were moved.  (§Perf: the
    /// per-cycle `drain_rx` allocation was the fabric simulator's top
    /// bottleneck.)
    pub fn drain_rx_into(
        &mut self,
        slave: usize,
        max: usize,
        out: &mut Vec<(u32, usize)>,
    ) -> usize {
        let rx = &mut self.slaves[slave].rx;
        let take = max.min(rx.len());
        out.extend(rx.drain(..take));
        take
    }

    /// Words currently buffered at a slave port.
    pub fn rx_len(&self, slave: usize) -> usize {
        self.slaves[slave].rx.len()
    }

    /// Take all pending completion/error events.
    pub fn take_events(&mut self) -> Vec<XbarEvent> {
        std::mem::take(&mut self.events)
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> &XbarStats {
        &self.stats
    }

    /// Turn per-grant recording on/off (test observability for the WRR
    /// fairness properties).
    pub fn set_record_grants(&mut self, on: bool) {
        self.record_grants = on;
    }

    /// Recorded grants, in bus order (empty unless recording is on).
    pub fn grant_log(&self) -> &[GrantRecord] {
        &self.grant_log
    }

    /// Take (and clear) the recorded grants.
    pub fn take_grant_log(&mut self) -> Vec<GrantRecord> {
        std::mem::take(&mut self.grant_log)
    }

    /// A fixed point the event-driven fast-path may jump over: nothing
    /// in flight, every arbiter settled, no release pending.  Stricter
    /// than [`Crossbar::quiescent`], which tolerates in-progress arbiter
    /// state (a decision pipeline still draining after a withdrawal).
    pub fn stable_point(&self) -> bool {
        self.quiescent()
            && self.release_pending.iter().all(|pending| !pending)
            && self.arbiters.iter().all(|a| a.in_reset || a.is_free())
    }

    // ------------------------------------------------------------------
    // per-cycle evaluation
    // ------------------------------------------------------------------

    fn tick_slaves(&mut self) {
        for s in 0..self.n {
            // Commit releases registered at the end of the previous cycle.
            if self.release_pending[s] {
                self.arbiters[s].release();
                self.release_pending[s] = false;
            }
            self.arbiters[s].tick();
        }
    }

    fn finish_job(&mut self, m: usize, result: Result<(), WbError>) {
        // Enter the Status state; the status cycle itself is consumed on
        // the *next* tick (completion = that cycle).
        self.masters[m].pending_status = Some(result);
        self.masters[m].state = MasterState::Status;
    }

    fn tick_master(&mut self, m: usize) {
        let cycle = self.cycle;
        if self.masters[m].in_reset {
            return;
        }
        match self.masters[m].state {
            MasterState::Idle => {
                if let Some(job) = self.masters[m].job() {
                    let pre_latched = job.pre_latched;
                    // cc1: request reaches the master interface.
                    self.masters[m].state = MasterState::Latched;
                    self.masters[m].request_cycle = cycle;
                    self.masters[m].first_grant_cycle = 0;
                    self.masters[m].sent = 0;
                    self.masters[m].waited = 0;
                    if pre_latched {
                        // §IV.G: the request originates inside the master
                        // interface (AXI-WB bridge) — validate in this same
                        // cycle, saving the latch cc.
                        self.tick_master(m);
                    }
                }
            }
            MasterState::Latched => {
                // cc2: validate the one-hot address against the isolation
                // mask and issue the request to the slave port.
                let job = self.masters[m].job().expect("latched without job");
                let dest_onehot = job.dest_onehot;
                let allowed = self.masters[m].allowed_slaves;
                match decode_onehot(dest_onehot) {
                    Some(d)
                        if (d as usize) < self.n
                            && isolation_permits(dest_onehot, allowed) =>
                    {
                        let d = d as usize;
                        if self.arbiters[d].in_reset {
                            // §IV.C: a port in reset must not receive
                            // requests; error back to the module.
                            self.stats.isolation_rejects += 1;
                            self.finish_job(m, Err(WbError::PortInReset));
                        } else {
                            self.arbiters[d].raise_request(m);
                            self.masters[m].state = MasterState::WaitGrant;
                            self.masters[m].waited = 0;
                        }
                    }
                    _ => {
                        // Invalid or disallowed destination: "the input
                        // port sends an error signal to a master and does
                        // not issue any request to a slave" (§IV.E.2).
                        self.stats.isolation_rejects += 1;
                        self.finish_job(m, Err(WbError::InvalidDestination));
                    }
                }
            }
            MasterState::WaitGrant => {
                let d = self.dest_of(m);
                if self.arbiters[d].in_reset {
                    // The slave was put into reset while we waited (§IV.C).
                    self.arbiters[d].drop_request(m);
                    self.finish_job(m, Err(WbError::PortInReset));
                    return;
                }
                match self.arbiters[d].granted_master() {
                    Some(g) if g == m => {
                        // Grant observed this cycle (arbiters tick first):
                        // first data word goes out next cycle.
                        if self.masters[m].first_grant_cycle == 0 {
                            self.masters[m].first_grant_cycle = cycle;
                        }
                        self.masters[m].sent_in_grant = 0;
                        self.masters[m].state = MasterState::Sending;
                        self.stats.grants += 1;
                    }
                    Some(_) => {
                        // Busy with someone else: withdraw and wait for a
                        // free bus (the §V.E re-issue path).
                        self.arbiters[d].drop_request(m);
                        self.masters[m].state = MasterState::WaitFree;
                        self.stats.conflicts += 1;
                    }
                    None => {
                        // Still arbitrating.
                        self.masters[m].waited += 1;
                        if self.masters[m].waited > self.cfg.grant_timeout {
                            self.arbiters[d].drop_request(m);
                            self.finish_job(m, Err(WbError::GrantTimeout));
                        }
                    }
                }
            }
            MasterState::WaitFree => {
                let d = self.dest_of(m);
                if self.arbiters[d].in_reset {
                    self.finish_job(m, Err(WbError::PortInReset));
                    return;
                }
                if self.arbiters[d].is_free() {
                    // Re-latch (1 cc), then Validate re-issues next cycle.
                    self.masters[m].state = MasterState::Latched;
                } else {
                    self.masters[m].waited += 1;
                    if self.masters[m].waited > self.cfg.grant_timeout {
                        self.finish_job(m, Err(WbError::GrantTimeout));
                    }
                }
            }
            MasterState::Sending => {
                let d = self.dest_of(m);
                if self.arbiters[d].granted_master() != Some(m) {
                    // Grant vanished mid-burst: the slave port was reset
                    // during the transfer (§IV.C).  Abort with an error
                    // status; already-delivered words stay delivered.
                    self.finish_job(m, Err(WbError::PortInReset));
                    return;
                }
                if self.slaves[d].can_accept() {
                    let job = self.masters[m].job().expect("sending without job");
                    let word = job.words[self.masters[m].sent];
                    self.slaves[d].accept(word, m);
                    self.masters[m].sent += 1;
                    self.masters[m].sent_in_grant += 1;
                    self.masters[m].waited = 0;
                    self.stats.words += 1;
                    self.stats.port_words[m] += 1;
                    if self.masters[m].sent_in_grant > self.stats.port_max_burst[m] {
                        self.stats.port_max_burst[m] = self.masters[m].sent_in_grant;
                    }

                    let job_done =
                        self.masters[m].sent == self.masters[m].job().unwrap().words.len();
                    let budget = self.arbiters[d].budget(m);
                    let burst_done = self.masters[m].sent_in_grant >= budget;
                    if job_done {
                        // Bus released with the last word; the status cc
                        // only registers the outcome on the master side
                        // ("a master interface releases the bus as soon as
                        // it completes sending its packages").
                        self.log_grant(d, m);
                        self.release_pending[d] = true;
                        self.arbiters[d].drop_request(m);
                        self.finish_job(m, Ok(()));
                    } else if burst_done {
                        // WRR budget exhausted: rotate the grant (§IV.E.1
                        // "when the maximum number of packages is reached,
                        // it switches the grant to the next master").
                        self.log_grant(d, m);
                        self.release_pending[d] = true;
                        self.arbiters[d].drop_request(m);
                        self.masters[m].state = MasterState::WaitFree;
                        self.stats.wrr_rotations += 1;
                    }
                } else {
                    // Slave stalled: pause transmission (§IV.F.1).
                    self.masters[m].state = MasterState::Stalled;
                    self.masters[m].waited = 0;
                    self.slaves[d].stall_cycles += 1;
                    self.stats.stall_cycles += 1;
                }
            }
            MasterState::Stalled => {
                let d = self.dest_of(m);
                if self.arbiters[d].granted_master() != Some(m) {
                    self.finish_job(m, Err(WbError::PortInReset));
                    return;
                }
                if self.slaves[d].can_accept() {
                    // Resume; the resumed word itself is sent this cycle.
                    self.masters[m].state = MasterState::Sending;
                    self.tick_master(m);
                } else {
                    self.slaves[d].stall_cycles += 1;
                    self.stats.stall_cycles += 1;
                    self.masters[m].waited += 1;
                    if self.masters[m].waited > self.cfg.ack_timeout {
                        // "if the destination slave does not respond in a
                        // defined period, a timeout error happens."
                        self.log_grant(d, m);
                        self.release_pending[d] = true;
                        self.arbiters[d].drop_request(m);
                        self.finish_job(m, Err(WbError::AckTimeout));
                    }
                }
            }
            MasterState::Status => {
                // Final cc: register the outcome, emit the event, pop the
                // job, return to Idle.
                let job = self.masters[m].queue.pop_front().expect("status without job");
                let result = self.masters[m]
                    .pending_status
                    .take()
                    .expect("status without outcome");
                let dest = decode_onehot(job.dest_onehot)
                    .map(|d| d as usize)
                    .unwrap_or(usize::MAX);
                if result.is_err() {
                    self.stats.errors += 1;
                }
                self.events.push(XbarEvent {
                    port: m,
                    dest,
                    app_id: job.app_id,
                    request_cycle: self.masters[m].request_cycle,
                    grant_cycle: self.masters[m].first_grant_cycle,
                    done_cycle: cycle,
                    words: self.masters[m].sent,
                    result,
                });
                self.masters[m].state = MasterState::Idle;
                self.masters[m].sent = 0;
            }
        }
    }

    fn dest_of(&self, m: usize) -> usize {
        decode_onehot(self.masters[m].job().expect("no job").dest_onehot)
            .expect("validated address") as usize
    }

    /// Account one finished grant (bus released or budget rotation):
    /// per-app grant/package counters always, the per-grant log when
    /// recording is on.
    fn log_grant(&mut self, slave: usize, master: usize) {
        let words = self.masters[master].sent_in_grant;
        let app_id = self.masters[master]
            .job()
            .map(|j| j.app_id)
            .unwrap_or(0);
        self.stats.account_app_grant(app_id, words);
        if self.record_grants {
            self.grant_log.push(GrantRecord {
                cycle: self.cycle,
                app_id,
                slave,
                master,
                words,
            });
        }
    }
}

impl Tick for Crossbar {
    fn tick(&mut self, cycle: u64) {
        self.cycle = cycle;
        self.tick_slaves();
        for m in 0..self.n {
            self.tick_master(m);
        }
        self.stats.cycles += 1;
    }
}

impl EventDriven for Crossbar {
    fn stable(&self) -> bool {
        self.stable_point()
    }

    fn fast_forward(&mut self, to_cycle: u64) {
        // Idle cycles change nothing but the counters; account them so a
        // fast-path run's statistics equal the oracle's exactly.
        let skipped = to_cycle.saturating_sub(self.cycle);
        self.cycle = to_cycle;
        self.stats.cycles += skipped;
    }

    /// The crossbar never advertises a busy-period horizon beyond the
    /// next cycle (DESIGN.md §12): while any master is mid-transfer the
    /// datapath is *consumer-coupled* — each word's delivery depends on
    /// the receiving slave's buffer, which the attached module or bridge
    /// drains outside the crossbar's view, and each WRR rotation
    /// boundary re-enters the 2-cycle arbitration pipeline.  No
    /// arithmetic replay can be sound without knowledge of the
    /// consumers, so busy crossbar cycles always execute for real; the
    /// composition layer ([`crate::fabric`]) only skips when the whole
    /// crossbar sits at [`Crossbar::stable_point`].
    fn next_interesting_cycle(&self, now: u64) -> u64 {
        if self.stable_point() {
            crate::sim::HORIZON_NONE
        } else {
            now + 1
        }
    }
}

#[cfg(test)]
mod tests;
