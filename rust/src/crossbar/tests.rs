//! Crossbar unit tests — §V.E's clock-cycle claims are pinned here
//! *exactly*; if these numbers drift, the reproduction is wrong.

use super::*;
use crate::config::CrossbarConfig;
use crate::sim::Clock;
use crate::util::onehot::encode_onehot;

fn xbar4() -> Crossbar {
    let mut xb = Crossbar::new(4, CrossbarConfig::default());
    // Open isolation: every master may address every slave.
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    xb
}

fn run_to_quiescent(xb: &mut Crossbar, max: u64) -> Vec<XbarEvent> {
    let mut clk = Clock::new();
    clk.run_until(xb, max, |x| x.quiescent())
        .expect("crossbar did not quiesce");
    xb.take_events()
}

/// Run with an always-ready consumer at every slave (the §V.E walkthrough
/// assumes the modules read data as it arrives).  Returns the events and
/// the number of words drained per slave port.
fn run_draining(xb: &mut Crossbar, max: u64) -> (Vec<XbarEvent>, Vec<usize>) {
    let n = xb.ports();
    let mut clk = Clock::new();
    let mut events = Vec::new();
    let mut drained = vec![0usize; n];
    for _ in 0..max {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..n {
            drained[s] += xb.drain_rx(s, usize::MAX).len();
        }
        events.extend(xb.take_events());
        if xb.quiescent() {
            break;
        }
    }
    assert!(xb.quiescent(), "crossbar did not quiesce");
    (events, drained)
}

#[test]
fn best_case_time_to_grant_is_4_cc() {
    // §V.E: "It is 4 ccs in the best case, where the slave does not serve
    // any request concurrently."
    let mut xb = xbar4();
    xb.push_job(1, Job::new(encode_onehot(2), vec![0xA; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev.len(), 1);
    assert_eq!(ev[0].result, Ok(()));
    assert_eq!(ev[0].time_to_grant(), 4);
}

#[test]
fn best_case_8_package_completion_is_13_cc() {
    // §V.E: "If a computation module has 8 packages to deliver, the
    // request completion latency is therefore 13 ccs."
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(3), vec![7; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].completion_latency(), 13);
    assert_eq!(ev[0].words, 8);
}

#[test]
fn worst_case_three_masters_same_slave() {
    // §V.E: all 3 computation modules target the fourth simultaneously;
    // time-to-grant is 4 / 16 / 28 cc and the last request completes at
    // 37 cc.
    let mut xb = xbar4();
    for m in 0..3 {
        xb.push_job(m, Job::new(encode_onehot(3), vec![m as u32; 8], 0));
    }
    let (mut ev, drained) = run_draining(&mut xb, 200);
    ev.sort_by_key(|e| e.grant_cycle);
    let ttg: Vec<u64> = ev.iter().map(|e| e.time_to_grant()).collect();
    let done: Vec<u64> = ev.iter().map(|e| e.completion_latency()).collect();
    assert_eq!(ttg, vec![4, 16, 28]);
    assert_eq!(done, vec![13, 25, 37]);
    // WRR order: port 0 first (reset pointer), then 1, then 2.
    let order: Vec<usize> = ev.iter().map(|e| e.port).collect();
    assert_eq!(order, vec![0, 1, 2]);
    // All 24 words must have landed at slave 3.
    assert_eq!(drained[3], 24);
}

#[test]
fn two_masters_contention_grants_at_4_and_16() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(2), vec![1; 8], 0));
    xb.push_job(1, Job::new(encode_onehot(2), vec![2; 8], 0));
    let (mut ev, _) = run_draining(&mut xb, 100);
    ev.sort_by_key(|e| e.grant_cycle);
    assert_eq!(ev[0].time_to_grant(), 4);
    assert_eq!(ev[1].time_to_grant(), 16);
}

#[test]
fn parallel_disjoint_transfers_do_not_interfere() {
    // Crossbar advantage over a shared bus: 0->1 and 2->3 in parallel,
    // both at best-case latency.
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), vec![1; 8], 0));
    xb.push_job(2, Job::new(encode_onehot(3), vec![2; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev.len(), 2);
    for e in &ev {
        assert_eq!(e.time_to_grant(), 4, "port {} suffered interference", e.port);
        assert_eq!(e.completion_latency(), 13);
    }
}

#[test]
fn invalid_destination_rejected_without_bus_activity() {
    // §IV.E.2: isolation mask excludes slave 2 for master 0.
    let mut xb = xbar4();
    xb.set_allowed_slaves(0, 0b1010); // slaves 1 and 3 only
    xb.push_job(0, Job::new(encode_onehot(2), vec![9; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Err(WbError::InvalidDestination));
    assert_eq!(ev[0].words, 0);
    assert_eq!(ev[0].grant_cycle, 0, "no grant must have been issued");
    assert_eq!(xb.stats().isolation_rejects, 1);
    assert_eq!(xb.rx_len(2), 0);
}

#[test]
fn non_onehot_address_rejected() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(0b0110, vec![1], 0)); // two bits set
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Err(WbError::InvalidDestination));
}

#[test]
fn zero_address_rejected() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(0, vec![1], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Err(WbError::InvalidDestination));
}

#[test]
fn out_of_range_address_rejected() {
    // One-hot bit beyond the port count.
    let mut xb = xbar4();
    xb.set_allowed_slaves(0, u32::MAX);
    xb.push_job(0, Job::new(1 << 7, vec![1], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Err(WbError::InvalidDestination));
}

#[test]
fn isolation_error_costs_3_cycles() {
    // Validating on the master side avoids the arbiter round-trip the
    // paper calls out: latch (1) + validate (1) + status (1).
    let mut xb = xbar4();
    xb.set_allowed_slaves(0, 0);
    xb.push_job(0, Job::new(encode_onehot(1), vec![1], 7));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].completion_latency(), 3);
    assert_eq!(ev[0].app_id, 7);
}

#[test]
fn wrr_budget_chops_long_jobs() {
    // 32-word job with an 8-package budget: 4 grants, re-arbitrated after
    // each burst.
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), (0..32).collect(), 0));
    // Slave 1's consumer must drain or the 8-word buffer stalls the bus.
    let mut clk = Clock::new();
    let mut delivered = Vec::new();
    for _ in 0..400 {
        let c = clk.advance();
        xb.tick(c);
        for (w, _src) in xb.drain_rx(1, usize::MAX) {
            delivered.push(w);
        }
        if xb.quiescent() && !xb.take_events().is_empty() {
            break;
        }
    }
    assert_eq!(delivered, (0..32).collect::<Vec<u32>>());
    assert_eq!(xb.stats().wrr_rotations, 3, "3 rotations for 4 bursts");
    assert_eq!(xb.stats().grants, 4);
}

#[test]
fn wrr_budget_interleaves_two_masters_fairly() {
    // Two masters, 64 words each, budget 8: deliveries must alternate in
    // 8-word runs (bandwidth sharing, §V.D's mechanism).
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(2), vec![0xAA; 64], 0));
    xb.push_job(1, Job::new(encode_onehot(2), vec![0xBB; 64], 0));
    let mut clk = Clock::new();
    let mut sources = Vec::new();
    for _ in 0..2000 {
        let c = clk.advance();
        xb.tick(c);
        for (_w, src) in xb.drain_rx(2, usize::MAX) {
            sources.push(src);
        }
        if xb.quiescent() {
            break;
        }
    }
    assert_eq!(sources.len(), 128);
    // Runs of identical source must be exactly 8 long (the budget).
    let mut runs = Vec::new();
    let mut cur = (sources[0], 0usize);
    for &s in &sources {
        if s == cur.0 {
            cur.1 += 1;
        } else {
            runs.push(cur);
            cur = (s, 1);
        }
    }
    runs.push(cur);
    assert!(runs.iter().all(|&(_, len)| len == 8), "runs: {runs:?}");
    assert_eq!(runs.len(), 16);
    // And they alternate.
    for w in runs.windows(2) {
        assert_ne!(w[0].0, w[1].0);
    }
}

#[test]
fn larger_budget_reduces_total_cycles() {
    // The §V.D effect at crossbar level: 16 -> 128 packages per grant
    // lowers arbitration overhead for a long stream.
    let total_words = 4096usize;
    let mut cycles = Vec::new();
    for budget in [16u32, 128] {
        let mut xb = xbar4();
        xb.set_allowed_packages(1, 0, budget).unwrap();
        xb.push_job(0, Job::new(encode_onehot(1), vec![5; total_words], 0));
        let mut clk = Clock::new();
        let mut got = 0usize;
        for _ in 0..200_000 {
            let c = clk.advance();
            xb.tick(c);
            got += xb.drain_rx(1, usize::MAX).len();
            if xb.quiescent() {
                break;
            }
        }
        assert_eq!(got, total_words);
        cycles.push(clk.now());
    }
    assert!(
        cycles[1] < cycles[0],
        "budget 128 ({}) must beat budget 16 ({})",
        cycles[1],
        cycles[0]
    );
}

#[test]
fn slave_stall_pauses_and_resumes() {
    // Consumer never drains: the 8-word buffer fills, the 9th word stalls.
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), vec![3; 12], 0));
    let mut clk = Clock::new();
    clk.run(&mut xb, 40);
    assert_eq!(xb.rx_len(1), 8, "exactly the buffer capacity delivered");
    assert!(xb.stats().stall_cycles > 0);
    assert!(xb.take_events().is_empty(), "job must not have completed");
    // Drain and let it finish.
    let got = xb.drain_rx(1, usize::MAX);
    assert_eq!(got.len(), 8);
    clk.run_until(&mut xb, 100, |x| x.quiescent()).unwrap();
    let ev = xb.take_events();
    assert_eq!(ev[0].result, Ok(()));
    assert_eq!(ev[0].words, 12);
}

#[test]
fn ack_timeout_fires_on_permanently_full_slave() {
    let cfg =
        CrossbarConfig { ack_timeout: 20, ..CrossbarConfig::default() };
    let mut xb = Crossbar::new(4, cfg);
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    xb.push_job(0, Job::new(encode_onehot(1), vec![3; 16], 0));
    let mut clk = Clock::new();
    clk.run_until(&mut xb, 200, |x| x.quiescent()).unwrap();
    let ev = xb.take_events();
    assert_eq!(ev[0].result, Err(WbError::AckTimeout));
    assert_eq!(ev[0].words, 8, "buffer capacity went through before stall");
}

#[test]
fn request_to_port_in_reset_errors() {
    // §IV.C: "during the partial reconfiguration process [...] the
    // crossbar port would be prevented from making any grant decisions."
    let mut xb = xbar4();
    xb.set_port_reset(2, true);
    xb.push_job(0, Job::new(encode_onehot(2), vec![1; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Err(WbError::PortInReset));
    xb.set_port_reset(2, false);
    xb.push_job(0, Job::new(encode_onehot(2), vec![1; 8], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Ok(()));
}

#[test]
fn reset_aborts_in_flight_master() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), vec![1; 8], 0));
    let mut clk = Clock::new();
    clk.run(&mut xb, 6); // mid-burst
    xb.set_port_reset(0, true);
    clk.run(&mut xb, 10);
    assert!(xb.master_idle(0));
    // The slave keeps whatever words already landed; no completion event.
    assert!(xb.take_events().is_empty());
}

#[test]
fn back_to_back_jobs_on_one_master() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), vec![1; 8], 0));
    xb.push_job(0, Job::new(encode_onehot(2), vec![2; 8], 1));
    let ev = run_to_quiescent(&mut xb, 200);
    assert_eq!(ev.len(), 2);
    assert_eq!(ev[0].dest, 1);
    assert_eq!(ev[1].dest, 2);
    assert!(ev[1].request_cycle > ev[0].done_cycle, "strictly sequential");
    assert_eq!(xb.rx_len(1), 8);
    assert_eq!(xb.rx_len(2), 8);
}

#[test]
fn grant_timeout_when_slave_monopolized() {
    // Master 0 holds the bus forever: a huge WRR budget plus a consumer
    // that never drains leaves it stalled mid-grant.  Master 1's grant
    // watchdog must fire.
    let cfg = CrossbarConfig {
        grant_timeout: 30,
        ack_timeout: 10_000,
        ..CrossbarConfig::default()
    };
    let mut xb = Crossbar::new(4, cfg);
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    xb.set_allowed_packages(2, 0, 255).unwrap();
    xb.push_job(0, Job::new(encode_onehot(2), vec![1; 64], 0));
    xb.push_job(1, Job::new(encode_onehot(2), vec![2; 8], 0));
    let mut clk = Clock::new();
    clk.run(&mut xb, 100);
    let ev = xb.take_events();
    assert!(
        ev.iter()
            .any(|e| e.port == 1 && e.result == Err(WbError::GrantTimeout)),
        "events: {ev:?}"
    );
}

#[test]
fn words_arrive_in_order_with_source_tags() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(3), (100..108).collect(), 0));
    run_to_quiescent(&mut xb, 100);
    let got = xb.drain_rx(3, usize::MAX);
    let words: Vec<u32> = got.iter().map(|&(w, _)| w).collect();
    let srcs: Vec<usize> = got.iter().map(|&(_, s)| s).collect();
    assert_eq!(words, (100..108).collect::<Vec<u32>>());
    assert!(srcs.iter().all(|&s| s == 0));
}

#[test]
fn stats_account_words_and_grants() {
    let mut xb = xbar4();
    xb.push_job(0, Job::new(encode_onehot(1), vec![1; 8], 0));
    xb.push_job(2, Job::new(encode_onehot(3), vec![2; 8], 0));
    run_to_quiescent(&mut xb, 100);
    let s = xb.stats();
    assert_eq!(s.words, 16);
    assert_eq!(s.grants, 2);
    assert_eq!(s.port_words[0], 8);
    assert_eq!(s.port_words[2], 8);
    assert_eq!(s.errors, 0);
}

#[test]
fn self_send_is_permitted() {
    // A port may address its own slave side (loopback) — nothing in the
    // paper forbids it and the arbiter treats it like any master.
    let mut xb = xbar4();
    xb.push_job(1, Job::new(encode_onehot(1), vec![42; 4], 0));
    let ev = run_to_quiescent(&mut xb, 100);
    assert_eq!(ev[0].result, Ok(()));
    assert_eq!(xb.rx_len(1), 4);
}

#[test]
fn scaling_worst_case_is_linear_in_ports() {
    // Fig 6: all N-1 masters target the last port, 8 words each; the
    // last grant time grows by 12 cc per extra contender.
    for n in [4usize, 6, 8, 12, 16] {
        let mut xb = Crossbar::new(n, CrossbarConfig::default());
        for m in 0..n {
            xb.set_allowed_slaves(m, u32::MAX >> (32 - n as u32));
        }
        for m in 0..n - 1 {
            xb.push_job(m, Job::new(encode_onehot(n as u32 - 1), vec![0; 8], 0));
        }
        let mut clk = Clock::new();
        let mut events = Vec::new();
        for _ in 0..20_000 {
            let c = clk.advance();
            xb.tick(c);
            xb.drain_rx(n - 1, usize::MAX);
            events.extend(xb.take_events());
            if events.len() == n - 1 {
                break;
            }
        }
        let worst = events.iter().map(|e| e.time_to_grant()).max().unwrap();
        assert_eq!(worst as usize, 12 * (n - 2) + 4, "n={n}");
    }
}
