//! Aggregate crossbar statistics (observability for benches and the
//! §V.D bandwidth experiments), including the per-app grant/package
//! accounting the bandwidth plane ([`crate::qos`]) is audited against.

use std::collections::BTreeMap;

/// Counters accumulated across the crossbar's lifetime.
#[derive(Debug, Clone, PartialEq)]
pub struct XbarStats {
    /// Total fabric cycles executed.
    pub cycles: u64,
    /// Total grants issued across all slave ports.
    pub grants: u64,
    /// Data words delivered.
    pub words: u64,
    /// Words delivered per master port.
    pub port_words: Vec<u64>,
    /// Longest single-grant burst observed per master port (for checking
    /// WRR package budgets).
    pub port_max_burst: Vec<u32>,
    /// Times a master observed the bus granted to someone else.
    pub conflicts: u64,
    /// Grant rotations forced by WRR package budgets.
    pub wrr_rotations: u64,
    /// Cycles lost to slave-side stalls.
    pub stall_cycles: u64,
    /// Requests rejected by the isolation check (plus reset rejections).
    pub isolation_rejects: u64,
    /// Jobs that completed with an error.
    pub errors: u64,
    /// Finished grants per application ID (a grant interrupted by a port
    /// reset mid-burst is not counted — it never released cleanly).
    pub app_grants: BTreeMap<u32, u64>,
    /// Packages (words) delivered per application ID across finished
    /// grants — the observable the per-app bandwidth shares of
    /// [`crate::qos::BandwidthPlan`] are enforced over.
    pub app_packages: BTreeMap<u32, u64>,
}

impl XbarStats {
    /// Zeroed counters for an `n`-port crossbar.
    pub fn new(n: usize) -> Self {
        Self {
            cycles: 0,
            grants: 0,
            words: 0,
            port_words: vec![0; n],
            port_max_burst: vec![0; n],
            conflicts: 0,
            wrr_rotations: 0,
            stall_cycles: 0,
            isolation_rejects: 0,
            errors: 0,
            app_grants: BTreeMap::new(),
            app_packages: BTreeMap::new(),
        }
    }

    /// Fabric utilization: fraction of cycles that moved at least one word
    /// (upper-bounded by 1 per port; aggregate across ports may exceed 1,
    /// which is the crossbar's parallel-transmission advantage).
    pub fn words_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.words as f64 / self.cycles as f64
        }
    }

    /// Record one finished grant for `app_id` that delivered `words`
    /// packages (called by the crossbar at every bus release/rotation).
    pub(crate) fn account_app_grant(&mut self, app_id: u32, words: u32) {
        *self.app_grants.entry(app_id).or_insert(0) += 1;
        *self.app_packages.entry(app_id).or_insert(0) += words as u64;
    }

    /// Finished grants for `app_id`.
    pub fn app_grants(&self, app_id: u32) -> u64 {
        self.app_grants.get(&app_id).copied().unwrap_or(0)
    }

    /// Packages delivered for `app_id` across finished grants.
    pub fn app_packages(&self, app_id: u32) -> u64 {
        self.app_packages.get(&app_id).copied().unwrap_or(0)
    }

    /// `app_id`'s fraction of all packages delivered through finished
    /// grants (0.0 when nothing finished yet).
    pub fn app_package_share(&self, app_id: u32) -> f64 {
        let total: u64 = self.app_packages.values().sum();
        if total == 0 {
            0.0
        } else {
            self.app_packages(app_id) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_per_cycle_handles_zero() {
        let s = XbarStats::new(4);
        assert_eq!(s.words_per_cycle(), 0.0);
    }

    #[test]
    fn app_accounting_accumulates_and_shares() {
        let mut s = XbarStats::new(4);
        assert_eq!(s.app_grants(7), 0);
        assert_eq!(s.app_package_share(7), 0.0);
        s.account_app_grant(7, 16);
        s.account_app_grant(7, 16);
        s.account_app_grant(3, 32);
        assert_eq!(s.app_grants(7), 2);
        assert_eq!(s.app_packages(7), 32);
        assert_eq!(s.app_packages(3), 32);
        assert_eq!(s.app_package_share(7), 0.5);
    }
}
