//! Aggregate crossbar statistics (observability for benches and the
//! §V.D bandwidth experiments).

/// Counters accumulated across the crossbar's lifetime.
#[derive(Debug, Clone, PartialEq)]
pub struct XbarStats {
    /// Total fabric cycles executed.
    pub cycles: u64,
    /// Total grants issued across all slave ports.
    pub grants: u64,
    /// Data words delivered.
    pub words: u64,
    /// Words delivered per master port.
    pub port_words: Vec<u64>,
    /// Longest single-grant burst observed per master port (for checking
    /// WRR package budgets).
    pub port_max_burst: Vec<u32>,
    /// Times a master observed the bus granted to someone else.
    pub conflicts: u64,
    /// Grant rotations forced by WRR package budgets.
    pub wrr_rotations: u64,
    /// Cycles lost to slave-side stalls.
    pub stall_cycles: u64,
    /// Requests rejected by the isolation check (plus reset rejections).
    pub isolation_rejects: u64,
    /// Jobs that completed with an error.
    pub errors: u64,
}

impl XbarStats {
    /// Zeroed counters for an `n`-port crossbar.
    pub fn new(n: usize) -> Self {
        Self {
            cycles: 0,
            grants: 0,
            words: 0,
            port_words: vec![0; n],
            port_max_burst: vec![0; n],
            conflicts: 0,
            wrr_rotations: 0,
            stall_cycles: 0,
            isolation_rejects: 0,
            errors: 0,
        }
    }

    /// Fabric utilization: fraction of cycles that moved at least one word
    /// (upper-bounded by 1 per port; aggregate across ports may exceed 1,
    /// which is the crossbar's parallel-transmission advantage).
    pub fn words_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.words as f64 / self.cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_per_cycle_handles_zero() {
        let s = XbarStats::new(4);
        assert_eq!(s.words_per_cycle(), 0.0);
    }
}
