//! Ablation baseline: a *centralized* arbiter crossbar.
//!
//! The paper chooses **decentralized** arbitration — one WRR arbiter per
//! slave port — arguing it "simplifies the arbiter logic and management
//! of multicast data transmission" (§IV.E.1).  This module implements
//! the alternative the ablation bench compares against: a single shared
//! decision unit that can arbitrate **one slave port per decision slot**
//! (2 cc each, same latency as the per-port arbiter).  Requests to
//! *different* slaves therefore queue behind each other at the decision
//! unit, where the decentralized design grants them concurrently.
//!
//! Everything else (master-path cycle semantics, isolation, budgets) is
//! inherited by construction: the ablation isolates the arbitration
//! topology, nothing else.

use crate::config::CrossbarConfig;
use crate::sim::Tick;
use crate::util::lzc::lzc_select;
use crate::util::onehot::{decode_onehot, isolation_permits};
use crate::wishbone::{Job, MasterState, WbError};

/// A completed job notification (subset of [`super::XbarEvent`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CentralEvent {
    pub port: usize,
    pub dest: usize,
    pub request_cycle: u64,
    pub grant_cycle: u64,
    pub done_cycle: u64,
    pub result: Result<(), WbError>,
}

impl CentralEvent {
    /// Same metric definitions as the decentralized crossbar.
    pub fn time_to_grant(&self) -> u64 {
        (self.grant_cycle + 1).saturating_sub(self.request_cycle)
    }

    pub fn completion_latency(&self) -> u64 {
        (self.done_cycle + 1).saturating_sub(self.request_cycle)
    }
}

struct CentralMaster {
    state: MasterState,
    job: Option<Job>,
    sent: usize,
    request_cycle: u64,
    grant_cycle: u64,
    allowed_slaves: u32,
}

/// Crossbar with one shared arbitration unit.
pub struct CentralizedCrossbar {
    n: usize,
    cfg: CrossbarConfig,
    masters: Vec<CentralMaster>,
    /// Pending request bits per slave.
    requests: Vec<u32>,
    /// Busy slave -> granted master.
    granted: Vec<Option<usize>>,
    /// WRR pointer per slave.
    last_grant: Vec<Option<u32>>,
    /// The single decision unit: (slave, candidate, remaining cc).
    deciding: Option<(usize, usize, u8)>,
    /// Round-robin pointer over slaves for decision scheduling.
    next_slave: usize,
    events: Vec<CentralEvent>,
    cycle: u64,
}

impl CentralizedCrossbar {
    /// Build with all masters fully allowed (ablation default).
    pub fn new(n: usize, cfg: CrossbarConfig) -> Self {
        assert!((2..=32).contains(&n));
        let all = if n == 32 { u32::MAX } else { (1 << n) - 1 };
        Self {
            n,
            cfg,
            masters: (0..n)
                .map(|_| CentralMaster {
                    state: MasterState::Idle,
                    job: None,
                    sent: 0,
                    request_cycle: 0,
                    grant_cycle: 0,
                    allowed_slaves: all,
                })
                .collect(),
            requests: vec![0; n],
            granted: vec![None; n],
            last_grant: vec![None; n],
            deciding: None,
            next_slave: 0,
            events: Vec::new(),
            cycle: 0,
        }
    }

    /// Submit one job on a master port.
    pub fn push_job(&mut self, master: usize, job: Job) {
        assert!(self.masters[master].job.is_none(), "one job per master here");
        self.masters[master].job = Some(job);
    }

    /// All masters idle?
    pub fn quiescent(&self) -> bool {
        self.masters
            .iter()
            .all(|m| m.state == MasterState::Idle && m.job.is_none())
    }

    /// Drain events.
    pub fn take_events(&mut self) -> Vec<CentralEvent> {
        std::mem::take(&mut self.events)
    }

    fn dest_of(&self, m: usize) -> usize {
        decode_onehot(self.masters[m].job.as_ref().unwrap().dest_onehot).unwrap()
            as usize
    }

    /// The single decision unit: at most one slave arbitration in flight.
    fn tick_decision_unit(&mut self) {
        if let Some((slave, candidate, remaining)) = self.deciding {
            if remaining > 1 {
                self.deciding = Some((slave, candidate, remaining - 1));
            } else {
                if self.requests[slave] >> candidate & 1 == 1 {
                    self.granted[slave] = Some(candidate);
                    self.last_grant[slave] = Some(candidate as u32);
                }
                self.deciding = None;
            }
            return;
        }
        // Pick the next slave (RR) with pending requests and a free bus.
        for i in 0..self.n {
            let s = (self.next_slave + i) % self.n;
            if self.granted[s].is_none() && self.requests[s] != 0 {
                if let Some(winner) =
                    lzc_select(self.requests[s], self.n as u32, self.last_grant[s])
                {
                    self.deciding = Some((s, winner as usize, 1));
                    self.next_slave = (s + 1) % self.n;
                    return;
                }
            }
        }
    }

    fn tick_master(&mut self, m: usize) {
        let cycle = self.cycle;
        match self.masters[m].state {
            MasterState::Idle => {
                if self.masters[m].job.is_some() {
                    self.masters[m].state = MasterState::Latched;
                    self.masters[m].request_cycle = cycle;
                    self.masters[m].grant_cycle = 0;
                    self.masters[m].sent = 0;
                }
            }
            MasterState::Latched => {
                let job = self.masters[m].job.as_ref().unwrap();
                match decode_onehot(job.dest_onehot) {
                    Some(d)
                        if (d as usize) < self.n
                            && isolation_permits(
                                job.dest_onehot,
                                self.masters[m].allowed_slaves,
                            ) =>
                    {
                        self.requests[d as usize] |= 1 << m;
                        self.masters[m].state = MasterState::WaitGrant;
                    }
                    _ => {
                        self.finish(m, Err(WbError::InvalidDestination));
                    }
                }
            }
            MasterState::WaitGrant => {
                let d = self.dest_of(m);
                match self.granted[d] {
                    Some(g) if g == m => {
                        self.masters[m].grant_cycle = cycle;
                        self.masters[m].state = MasterState::Sending;
                    }
                    Some(_) => {
                        self.requests[d] &= !(1 << m);
                        self.masters[m].state = MasterState::WaitFree;
                    }
                    None => {}
                }
            }
            MasterState::WaitFree => {
                let d = self.dest_of(m);
                if self.granted[d].is_none() {
                    self.masters[m].state = MasterState::Latched;
                }
            }
            MasterState::Sending => {
                let d = self.dest_of(m);
                self.masters[m].sent += 1;
                let len = self.masters[m].job.as_ref().unwrap().words.len();
                if self.masters[m].sent == len {
                    self.granted[d] = None;
                    self.requests[d] &= !(1 << m);
                    self.finish(m, Ok(()));
                }
            }
            MasterState::Stalled | MasterState::Status => unreachable!(),
        }
    }

    fn finish(&mut self, m: usize, result: Result<(), WbError>) {
        // Status cycle is folded into the event stamp (+1 below) to keep
        // this baseline minimal; metrics match the decentralized design.
        let job = self.masters[m].job.take().unwrap();
        let dest = decode_onehot(job.dest_onehot).map(|d| d as usize).unwrap_or(usize::MAX);
        self.events.push(CentralEvent {
            port: m,
            dest,
            request_cycle: self.masters[m].request_cycle,
            grant_cycle: self.masters[m].grant_cycle,
            done_cycle: self.cycle + 1,
            result,
        });
        self.masters[m].state = MasterState::Idle;
    }

    /// Estimated area of a centralized design (for the ablation table):
    /// the shared unit needs the full request matrix and a slave-select
    /// mux on top of the same per-pair counters, historically costing
    /// more than distributed arbiters at the same port count [19][32];
    /// we charge the same quadratic LUT term plus an n-way select.
    pub fn estimated_luts(n: usize) -> u64 {
        crate::area::crossbar_area(n).luts + (n as u64) * 16
    }

    /// Watchdog config (unused fields kept for parity).
    pub fn config(&self) -> &CrossbarConfig {
        &self.cfg
    }
}

impl Tick for CentralizedCrossbar {
    fn tick(&mut self, cycle: u64) {
        self.cycle = cycle;
        self.tick_decision_unit();
        for m in 0..self.n {
            self.tick_master(m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;
    use crate::util::onehot::encode_onehot;

    fn run(xb: &mut CentralizedCrossbar, max: u64) -> Vec<CentralEvent> {
        let mut clk = Clock::new();
        let mut ev = Vec::new();
        for _ in 0..max {
            let c = clk.advance();
            xb.tick(c);
            ev.extend(xb.take_events());
            if xb.quiescent() {
                break;
            }
        }
        ev
    }

    #[test]
    fn single_request_matches_decentralized_best_case() {
        let mut xb = CentralizedCrossbar::new(4, CrossbarConfig::default());
        xb.push_job(0, Job::new(encode_onehot(2), vec![1; 8], 0));
        let ev = run(&mut xb, 100);
        assert_eq!(ev[0].time_to_grant(), 4);
        assert_eq!(ev[0].completion_latency(), 13);
    }

    #[test]
    fn disjoint_pairs_serialize_at_the_decision_unit() {
        // 0->1 and 2->3: decentralized grants both at cc4; centralized
        // must stagger the second grant by one decision slot.
        let mut xb = CentralizedCrossbar::new(4, CrossbarConfig::default());
        xb.push_job(0, Job::new(encode_onehot(1), vec![1; 8], 0));
        xb.push_job(2, Job::new(encode_onehot(3), vec![2; 8], 0));
        let mut ev = run(&mut xb, 200);
        ev.sort_by_key(|e| e.grant_cycle);
        assert_eq!(ev[0].time_to_grant(), 4);
        assert!(
            ev[1].time_to_grant() > 4,
            "second pair must queue at the shared unit: {:?}",
            ev[1]
        );
    }

    #[test]
    fn invalid_destination_still_rejected() {
        let mut xb = CentralizedCrossbar::new(4, CrossbarConfig::default());
        xb.push_job(0, Job::new(0b11, vec![1], 0));
        let ev = run(&mut xb, 100);
        assert_eq!(ev[0].result, Err(WbError::InvalidDestination));
    }

    #[test]
    fn centralized_area_estimate_exceeds_decentralized() {
        for n in [4usize, 8, 16] {
            assert!(
                CentralizedCrossbar::estimated_luts(n)
                    > crate::area::crossbar_area(n).luts
            );
        }
    }
}
