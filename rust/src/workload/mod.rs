//! Synthetic multi-tenant workload generation: arrival traces for the
//! serving experiments (the paper's cloud setting has tenants submitting
//! acceleration requests of varying shapes over time).
//!
//! Deterministic (SplitMix64-seeded) so every experiment is replayable;
//! arrivals are Bernoulli-per-slot (a discrete Poisson approximation),
//! payload sizes and stage chains are drawn from configurable mixes.

use crate::manager::AppRequest;
use crate::modules::ModuleKind;
use crate::util::SplitMix64;

/// One trace entry: a request and its arrival time.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Arrival offset from trace start, in milliseconds.
    pub arrival_ms: f64,
    /// The request itself.
    pub request: AppRequest,
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests per second).
    pub rate_per_s: f64,
    /// Trace duration (seconds of simulated arrival time).
    pub duration_s: f64,
    /// Payload sizes in words and their weights (sizes must be multiples
    /// of the 8-word burst).
    pub size_mix: Vec<(usize, f64)>,
    /// Stage-chain mixes and their weights.
    pub stage_mix: Vec<(Vec<ModuleKind>, f64)>,
    /// Number of tenant app IDs to cycle through (1..=4).
    pub tenants: u32,
}

impl WorkloadSpec {
    /// The paper's Fig-5 shape: 16 KB pipelines from up to 4 tenants.
    pub fn fig5_mix() -> Self {
        Self {
            rate_per_s: 50.0,
            duration_s: 2.0,
            size_mix: vec![(4096, 1.0)],
            stage_mix: vec![(ModuleKind::pipeline().to_vec(), 1.0)],
            tenants: 4,
        }
    }

    /// A heterogeneous mix: different sizes and partial chains, the
    /// "diverse applications" of the paper's intro.
    pub fn mixed() -> Self {
        Self {
            rate_per_s: 80.0,
            duration_s: 2.0,
            size_mix: vec![(256, 0.3), (1024, 0.3), (4096, 0.4)],
            stage_mix: vec![
                (ModuleKind::pipeline().to_vec(), 0.5),
                (vec![ModuleKind::Multiplier], 0.2),
                (vec![ModuleKind::HammingEncoder], 0.15),
                (
                    vec![ModuleKind::HammingEncoder, ModuleKind::HammingDecoder],
                    0.15,
                ),
            ],
            tenants: 4,
        }
    }

    /// Fleet-scale mix: small payloads at a high aggregate rate — the
    /// shape of a many-tenant serving front-end, where per-request fabric
    /// time is short and scheduling dominates.
    pub fn fleet_mix() -> Self {
        Self {
            // The 1 ms Bernoulli slots cap arrivals at 1000/s; 800/s is a
            // heavily-loaded front-end without degenerating to the cap.
            rate_per_s: 800.0,
            duration_s: 10.0,
            size_mix: vec![(8, 0.3), (16, 0.3), (32, 0.25), (64, 0.15)],
            stage_mix: vec![
                (ModuleKind::pipeline().to_vec(), 0.4),
                (vec![ModuleKind::Multiplier], 0.25),
                (vec![ModuleKind::HammingEncoder], 0.2),
                (
                    vec![ModuleKind::HammingEncoder, ModuleKind::HammingDecoder],
                    0.15,
                ),
            ],
            tenants: 4,
        }
    }
}

/// Draw an index from a weighted list.
fn weighted_pick<T>(rng: &mut SplitMix64, items: &[(T, f64)]) -> usize {
    let total: f64 = items.iter().map(|(_, w)| *w).sum();
    let mut x = rng.unit_f64() * total;
    for (i, (_, w)) in items.iter().enumerate() {
        if x < *w {
            return i;
        }
        x -= w;
    }
    items.len() - 1
}

/// Generate a deterministic trace over the spec's duration.
pub fn generate(spec: &WorkloadSpec, seed: u64) -> Vec<TraceEvent> {
    let slots = (spec.duration_s * 1000.0).ceil() as u64;
    generate_inner(spec, seed, Some(slots), None)
}

/// Generate a deterministic trace with exactly `count` arrivals,
/// extending past the spec's nominal duration if needed (the fleet
/// example asks for "100k requests", not "100 seconds").
pub fn generate_count(
    spec: &WorkloadSpec,
    seed: u64,
    count: usize,
) -> Vec<TraceEvent> {
    generate_inner(spec, seed, None, Some(count))
}

fn generate_inner(
    spec: &WorkloadSpec,
    seed: u64,
    max_slots: Option<u64>,
    max_events: Option<usize>,
) -> Vec<TraceEvent> {
    assert!(spec.tenants >= 1 && spec.tenants <= 4, "4 app IDs in the prototype");
    assert!(
        spec.size_mix.iter().all(|(s, _)| s % 8 == 0 && *s > 0),
        "sizes must be positive multiples of the 8-word burst"
    );
    assert!(
        max_slots.is_some() || max_events.is_some(),
        "unbounded trace requested"
    );
    assert!(
        max_slots.is_some() || spec.rate_per_s > 0.0,
        "count-bounded trace needs a positive arrival rate"
    );
    let mut rng = SplitMix64::new(seed);
    let mut events = Vec::new();
    // 1 ms slots; Bernoulli(rate * 1ms) arrivals per slot.
    let p = (spec.rate_per_s / 1000.0).min(1.0);
    let mut next_tenant = 0u32;
    let mut slot = 0u64;
    loop {
        if let Some(max) = max_slots {
            if slot >= max {
                break;
            }
        }
        if let Some(max) = max_events {
            if events.len() >= max {
                break;
            }
        }
        let arrived = rng.chance(p);
        if arrived {
            let jitter = rng.unit_f64();
            let size = spec.size_mix[weighted_pick(&mut rng, &spec.size_mix)].0;
            let stages = spec.stage_mix[weighted_pick(&mut rng, &spec.stage_mix)]
                .0
                .clone();
            let mut data = vec![0u32; size];
            rng.fill_u32(&mut data);
            events.push(TraceEvent {
                arrival_ms: slot as f64 + jitter,
                request: AppRequest {
                    app_id: next_tenant % spec.tenants,
                    data,
                    stages,
                },
            });
            next_tenant = next_tenant.wrapping_add(1);
        }
        slot += 1;
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let spec = WorkloadSpec::mixed();
        let a = generate(&spec, 9);
        let b = generate(&spec, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.request.data, y.request.data);
            assert_eq!(x.request.stages, y.request.stages);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = WorkloadSpec::mixed();
        let a = generate(&spec, 1);
        let b = generate(&spec, 2);
        assert_ne!(
            a.iter().map(|e| e.request.data.len()).collect::<Vec<_>>(),
            b.iter().map(|e| e.request.data.len()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rate_is_approximately_honored() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.rate_per_s = 100.0;
        spec.duration_s = 10.0;
        let trace = generate(&spec, 3);
        let expected = 1000.0;
        let got = trace.len() as f64;
        assert!(
            (got - expected).abs() / expected < 0.2,
            "expected ~{expected}, got {got}"
        );
    }

    #[test]
    fn arrivals_are_monotonic_and_bounded() {
        let spec = WorkloadSpec::mixed();
        let trace = generate(&spec, 4);
        for w in trace.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        assert!(trace
            .iter()
            .all(|e| e.arrival_ms <= spec.duration_s * 1000.0));
    }

    #[test]
    fn sizes_and_stages_come_from_the_mix() {
        let spec = WorkloadSpec::mixed();
        let trace = generate(&spec, 5);
        let sizes: Vec<usize> = spec.size_mix.iter().map(|(s, _)| *s).collect();
        for e in &trace {
            assert!(sizes.contains(&e.request.data.len()));
            assert!(!e.request.stages.is_empty());
            assert!(e.request.app_id < spec.tenants);
        }
    }

    #[test]
    fn tenants_rotate() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.rate_per_s = 500.0;
        let trace = generate(&spec, 6);
        let mut seen: Vec<u32> = trace.iter().map(|e| e.request.app_id).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }

    #[test]
    fn generate_count_yields_exactly_n() {
        let spec = WorkloadSpec::fleet_mix();
        let trace = generate_count(&spec, 11, 500);
        assert_eq!(trace.len(), 500);
        for w in trace.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
    }

    #[test]
    fn generate_count_is_a_prefix_extension_of_generate() {
        // Same seed: the duration-bounded trace is a prefix of the
        // count-bounded one (identical RNG stream per slot).
        let spec = WorkloadSpec::mixed();
        let by_duration = generate(&spec, 21);
        let by_count = generate_count(&spec, 21, by_duration.len() + 50);
        assert_eq!(by_count.len(), by_duration.len() + 50);
        for (a, b) in by_duration.iter().zip(&by_count) {
            assert_eq!(a.arrival_ms, b.arrival_ms);
            assert_eq!(a.request.data, b.request.data);
            assert_eq!(a.request.stages, b.request.stages);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_unaligned_sizes() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.size_mix = vec![(13, 1.0)];
        generate(&spec, 0);
    }
}
