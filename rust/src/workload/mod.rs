//! Synthetic multi-tenant workload generation: arrival traces for the
//! serving experiments (the paper's cloud setting has tenants submitting
//! acceleration requests of varying shapes over time).
//!
//! Deterministic (SplitMix64-seeded) so every experiment is replayable;
//! arrivals are Bernoulli-per-slot (a discrete Poisson approximation),
//! payload sizes and stage chains are drawn from configurable mixes.

use crate::manager::AppRequest;
use crate::modules::ModuleKind;
use crate::util::SplitMix64;
use crate::Result;

/// Resolve a chain of kernel names against the registry (DESIGN.md
/// §17).  Workload specs naming an unknown kernel are typed refusals —
/// no panic, no silent fallback to a seed kernel.
pub fn stages_by_name(names: &[&str]) -> Result<Vec<ModuleKind>> {
    names.iter().map(|n| crate::kernels::resolve(n)).collect()
}

/// One trace entry: a request and its arrival time.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Arrival offset from trace start, in milliseconds.
    pub arrival_ms: f64,
    /// The request itself.
    pub request: AppRequest,
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests per second).
    pub rate_per_s: f64,
    /// Trace duration (seconds of simulated arrival time).
    pub duration_s: f64,
    /// Payload sizes in words and their weights (sizes must be multiples
    /// of the 8-word burst).
    pub size_mix: Vec<(usize, f64)>,
    /// Stage-chain mixes and their weights.
    pub stage_mix: Vec<(Vec<ModuleKind>, f64)>,
    /// Number of tenant app IDs to cycle through (1..=4).
    pub tenants: u32,
}

impl WorkloadSpec {
    /// The paper's Fig-5 shape: 16 KB pipelines from up to 4 tenants.
    pub fn fig5_mix() -> Self {
        Self {
            rate_per_s: 50.0,
            duration_s: 2.0,
            size_mix: vec![(4096, 1.0)],
            stage_mix: vec![(ModuleKind::pipeline().to_vec(), 1.0)],
            tenants: 4,
        }
    }

    /// A heterogeneous mix: different sizes and partial chains, the
    /// "diverse applications" of the paper's intro.
    pub fn mixed() -> Self {
        Self {
            rate_per_s: 80.0,
            duration_s: 2.0,
            size_mix: vec![(256, 0.3), (1024, 0.3), (4096, 0.4)],
            stage_mix: vec![
                (ModuleKind::pipeline().to_vec(), 0.5),
                (vec![ModuleKind::Multiplier], 0.2),
                (vec![ModuleKind::HammingEncoder], 0.15),
                (
                    vec![ModuleKind::HammingEncoder, ModuleKind::HammingDecoder],
                    0.15,
                ),
            ],
            tenants: 4,
        }
    }

    /// Fleet-scale mix: small payloads at a high aggregate rate — the
    /// shape of a many-tenant serving front-end, where per-request fabric
    /// time is short and scheduling dominates.
    pub fn fleet_mix() -> Self {
        Self {
            // The 1 ms Bernoulli slots cap arrivals at 1000/s; 800/s is a
            // heavily-loaded front-end without degenerating to the cap.
            rate_per_s: 800.0,
            duration_s: 10.0,
            size_mix: vec![(8, 0.3), (16, 0.3), (32, 0.25), (64, 0.15)],
            stage_mix: vec![
                (ModuleKind::pipeline().to_vec(), 0.4),
                (vec![ModuleKind::Multiplier], 0.25),
                (vec![ModuleKind::HammingEncoder], 0.2),
                (
                    vec![ModuleKind::HammingEncoder, ModuleKind::HammingDecoder],
                    0.15,
                ),
            ],
            tenants: 4,
        }
    }

    /// Kernel-zoo mix (DESIGN.md §17): seed chains interleaved with
    /// registered zoo kernels — the mixed heavy/light tenant shape the
    /// batching and autoscale planes were never exercised on while the
    /// registry was a closed enum.  `zoo` kernels split 40% of the
    /// traffic evenly; the rest stays on the seed chains.
    pub fn zoo_mix(zoo: &[ModuleKind]) -> Self {
        assert!(!zoo.is_empty(), "zoo mix needs at least one zoo kernel");
        let mut stage_mix: Vec<(Vec<ModuleKind>, f64)> = vec![
            (ModuleKind::pipeline().to_vec(), 0.35),
            (vec![ModuleKind::Multiplier], 0.25),
        ];
        let share = 0.4 / zoo.len() as f64;
        for &k in zoo {
            stage_mix.push((vec![k], share));
        }
        Self {
            rate_per_s: 400.0,
            duration_s: 4.0,
            size_mix: vec![(8, 0.4), (32, 0.35), (64, 0.25)],
            stage_mix,
            tenants: 4,
        }
    }
}

/// Time-varying arrival-rate profile for one tenant's request stream —
/// the demand shapes the closed-loop autoscaler ([`crate::autoscale`])
/// reacts to.
#[derive(Debug, Clone)]
pub enum RateProfile {
    /// Flat rate (the original [`WorkloadSpec`] behavior).
    Constant {
        /// Requests per second.
        rate_per_s: f64,
    },
    /// Sinusoidal day/night cycle:
    /// `rate(t) = floor + (peak-floor)/2 * (1 + sin(2π(t/period + phase)))`.
    /// Anti-phase tenants (phase `k/n`) peak at different times — the
    /// consolidation opportunity a static split cannot exploit.
    Diurnal {
        /// Trough rate (requests per second).
        floor_per_s: f64,
        /// Peak rate (requests per second).
        peak_per_s: f64,
        /// Cycle length in seconds.
        period_s: f64,
        /// Phase offset in cycles (0.25 = peak a quarter-period earlier).
        phase: f64,
    },
    /// Square-wave on/off bursts.
    Bursty {
        /// Rate during a burst (requests per second).
        burst_per_s: f64,
        /// Rate between bursts (requests per second).
        idle_per_s: f64,
        /// Burst length in seconds.
        burst_s: f64,
        /// Idle length in seconds.
        idle_s: f64,
        /// Shift of the burst window start, in seconds.
        phase_s: f64,
    },
}

impl RateProfile {
    /// Instantaneous arrival rate (requests per second) at time `t_s`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            RateProfile::Constant { rate_per_s } => rate_per_s,
            RateProfile::Diurnal { floor_per_s, peak_per_s, period_s, phase } => {
                let x = std::f64::consts::TAU * (t_s / period_s + phase);
                floor_per_s + 0.5 * (peak_per_s - floor_per_s) * (1.0 + x.sin())
            }
            RateProfile::Bursty {
                burst_per_s,
                idle_per_s,
                burst_s,
                idle_s,
                phase_s,
            } => {
                let cycle = burst_s + idle_s;
                if (t_s + phase_s).rem_euclid(cycle) < burst_s {
                    burst_per_s
                } else {
                    idle_per_s
                }
            }
        }
    }
}

/// One tenant's stream: a fixed acceleration requirement (stage chain +
/// payload size) arriving under a [`RateProfile`].
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Application ID (0..=3 in the 4-port prototype).
    pub app_id: u32,
    /// The tenant's stage chain.
    pub stages: Vec<ModuleKind>,
    /// Payload size in words (multiple of the 8-word burst).
    pub words: usize,
    /// Arrival-rate profile.
    pub profile: RateProfile,
}

/// Anti-phase diurnal tenants running the Fig-5 pipeline: tenant `k` of
/// `n` is phase-shifted by `k/n` of a period, so peaks rotate around the
/// tenant set while the aggregate stays roughly flat.
pub fn diurnal_tenants(
    tenants: u32,
    floor_per_s: f64,
    peak_per_s: f64,
    period_s: f64,
    words: usize,
) -> Vec<TenantSpec> {
    assert!(
        (1..=32).contains(&tenants),
        "app IDs are one-hot destination-register indices (max 32)"
    );
    (0..tenants)
        .map(|i| TenantSpec {
            app_id: i,
            stages: ModuleKind::pipeline().to_vec(),
            words,
            profile: RateProfile::Diurnal {
                floor_per_s,
                peak_per_s,
                period_s,
                phase: i as f64 / tenants as f64,
            },
        })
        .collect()
}

/// Staggered on/off bursty tenants running the Fig-5 pipeline.
pub fn bursty_tenants(
    tenants: u32,
    burst_per_s: f64,
    idle_per_s: f64,
    burst_s: f64,
    idle_s: f64,
    words: usize,
) -> Vec<TenantSpec> {
    assert!(
        (1..=32).contains(&tenants),
        "app IDs are one-hot destination-register indices (max 32)"
    );
    let cycle = burst_s + idle_s;
    (0..tenants)
        .map(|i| TenantSpec {
            app_id: i,
            stages: ModuleKind::pipeline().to_vec(),
            words,
            profile: RateProfile::Bursty {
                burst_per_s,
                idle_per_s,
                burst_s,
                idle_s,
                phase_s: i as f64 * cycle / tenants as f64,
            },
        })
        .collect()
}

/// Anti-phase diurnal tenants over a kernel zoo: tenant `i` runs
/// `chains[i % chains.len()]`, so heavy and light kernels share the
/// board while peaks rotate around the tenant set (the scenario the
/// registry opens — seed and table-driven kernels in one fleet).
pub fn zoo_tenants(
    tenants: u32,
    chains: &[Vec<ModuleKind>],
    floor_per_s: f64,
    peak_per_s: f64,
    period_s: f64,
    words: usize,
) -> Vec<TenantSpec> {
    assert!(
        (1..=32).contains(&tenants),
        "app IDs are one-hot destination-register indices (max 32)"
    );
    assert!(!chains.is_empty(), "zoo tenants need at least one chain");
    assert!(
        chains.iter().all(|c| !c.is_empty()),
        "empty stage chain in the zoo"
    );
    (0..tenants)
        .map(|i| TenantSpec {
            app_id: i,
            stages: chains[i as usize % chains.len()].clone(),
            words,
            profile: RateProfile::Diurnal {
                floor_per_s,
                peak_per_s,
                period_s,
                phase: i as f64 / tenants as f64,
            },
        })
        .collect()
}

/// Generate a deterministic merged trace of exactly `count` arrivals
/// from per-tenant rate profiles (1 ms Bernoulli slots per tenant, like
/// [`generate`], so each tenant caps at 1000 req/s).
pub fn generate_profiled(
    tenants: &[TenantSpec],
    seed: u64,
    count: usize,
) -> Vec<TraceEvent> {
    assert!(!tenants.is_empty() && tenants.len() <= 32);
    assert!(count > 0);
    for t in tenants {
        assert!(
            t.app_id < 32,
            "app IDs are one-hot destination-register indices (max 32)"
        );
        assert!(
            t.words > 0 && t.words % 8 == 0,
            "payload must be a positive multiple of the 8-word burst"
        );
        assert!(!t.stages.is_empty(), "empty stage chain");
    }
    let mut rng = SplitMix64::new(seed);
    let mut events = Vec::with_capacity(count + tenants.len());
    let mut slot = 0u64;
    while events.len() < count {
        assert!(
            slot < 100_000_000,
            "profiled trace generation stalled (all rates ~0?)"
        );
        let t_s = slot as f64 / 1000.0;
        for spec in tenants {
            let p = (spec.profile.rate_at(t_s) / 1000.0).clamp(0.0, 1.0);
            if rng.chance(p) {
                let jitter = rng.unit_f64();
                let mut data = vec![0u32; spec.words];
                rng.fill_u32(&mut data);
                events.push(TraceEvent {
                    arrival_ms: slot as f64 + jitter,
                    request: AppRequest {
                        app_id: spec.app_id,
                        data,
                        stages: spec.stages.clone(),
                    },
                });
            }
        }
        slot += 1;
    }
    // Same-slot arrivals of different tenants carry independent jitter;
    // restore global arrival order before truncating to the count.
    events.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
    events.truncate(count);
    events
}

/// Draw an index from a weighted list.
fn weighted_pick<T>(rng: &mut SplitMix64, items: &[(T, f64)]) -> usize {
    let total: f64 = items.iter().map(|(_, w)| *w).sum();
    let mut x = rng.unit_f64() * total;
    for (i, (_, w)) in items.iter().enumerate() {
        if x < *w {
            return i;
        }
        x -= w;
    }
    items.len() - 1
}

/// Generate a deterministic trace over the spec's duration.
pub fn generate(spec: &WorkloadSpec, seed: u64) -> Vec<TraceEvent> {
    let slots = (spec.duration_s * 1000.0).ceil() as u64;
    generate_inner(spec, seed, Some(slots), None)
}

/// Generate a deterministic trace with exactly `count` arrivals,
/// extending past the spec's nominal duration if needed (the fleet
/// example asks for "100k requests", not "100 seconds").
pub fn generate_count(
    spec: &WorkloadSpec,
    seed: u64,
    count: usize,
) -> Vec<TraceEvent> {
    generate_inner(spec, seed, None, Some(count))
}

fn generate_inner(
    spec: &WorkloadSpec,
    seed: u64,
    max_slots: Option<u64>,
    max_events: Option<usize>,
) -> Vec<TraceEvent> {
    assert!(
        (1..=32).contains(&spec.tenants),
        "app IDs are one-hot destination-register indices (max 32)"
    );
    assert!(
        spec.size_mix.iter().all(|(s, _)| s % 8 == 0 && *s > 0),
        "sizes must be positive multiples of the 8-word burst"
    );
    assert!(
        max_slots.is_some() || max_events.is_some(),
        "unbounded trace requested"
    );
    assert!(
        max_slots.is_some() || spec.rate_per_s > 0.0,
        "count-bounded trace needs a positive arrival rate"
    );
    let mut rng = SplitMix64::new(seed);
    let mut events = Vec::new();
    // 1 ms slots; Bernoulli(rate * 1ms) arrivals per slot.
    let p = (spec.rate_per_s / 1000.0).min(1.0);
    let mut next_tenant = 0u32;
    let mut slot = 0u64;
    loop {
        if let Some(max) = max_slots {
            if slot >= max {
                break;
            }
        }
        if let Some(max) = max_events {
            if events.len() >= max {
                break;
            }
        }
        let arrived = rng.chance(p);
        if arrived {
            let jitter = rng.unit_f64();
            let size = spec.size_mix[weighted_pick(&mut rng, &spec.size_mix)].0;
            let stages = spec.stage_mix[weighted_pick(&mut rng, &spec.stage_mix)]
                .0
                .clone();
            let mut data = vec![0u32; size];
            rng.fill_u32(&mut data);
            events.push(TraceEvent {
                arrival_ms: slot as f64 + jitter,
                request: AppRequest {
                    app_id: next_tenant % spec.tenants,
                    data,
                    stages,
                },
            });
            next_tenant = next_tenant.wrapping_add(1);
        }
        slot += 1;
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let spec = WorkloadSpec::mixed();
        let a = generate(&spec, 9);
        let b = generate(&spec, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.request.data, y.request.data);
            assert_eq!(x.request.stages, y.request.stages);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = WorkloadSpec::mixed();
        let a = generate(&spec, 1);
        let b = generate(&spec, 2);
        assert_ne!(
            a.iter().map(|e| e.request.data.len()).collect::<Vec<_>>(),
            b.iter().map(|e| e.request.data.len()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rate_is_approximately_honored() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.rate_per_s = 100.0;
        spec.duration_s = 10.0;
        let trace = generate(&spec, 3);
        let expected = 1000.0;
        let got = trace.len() as f64;
        assert!(
            (got - expected).abs() / expected < 0.2,
            "expected ~{expected}, got {got}"
        );
    }

    #[test]
    fn arrivals_are_monotonic_and_bounded() {
        let spec = WorkloadSpec::mixed();
        let trace = generate(&spec, 4);
        for w in trace.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        assert!(trace
            .iter()
            .all(|e| e.arrival_ms <= spec.duration_s * 1000.0));
    }

    #[test]
    fn sizes_and_stages_come_from_the_mix() {
        let spec = WorkloadSpec::mixed();
        let trace = generate(&spec, 5);
        let sizes: Vec<usize> = spec.size_mix.iter().map(|(s, _)| *s).collect();
        for e in &trace {
            assert!(sizes.contains(&e.request.data.len()));
            assert!(!e.request.stages.is_empty());
            assert!(e.request.app_id < spec.tenants);
        }
    }

    #[test]
    fn tenants_rotate() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.rate_per_s = 500.0;
        let trace = generate(&spec, 6);
        let mut seen: Vec<u32> = trace.iter().map(|e| e.request.app_id).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }

    #[test]
    fn generate_count_yields_exactly_n() {
        let spec = WorkloadSpec::fleet_mix();
        let trace = generate_count(&spec, 11, 500);
        assert_eq!(trace.len(), 500);
        for w in trace.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
    }

    #[test]
    fn generate_count_is_a_prefix_extension_of_generate() {
        // Same seed: the duration-bounded trace is a prefix of the
        // count-bounded one (identical RNG stream per slot).
        let spec = WorkloadSpec::mixed();
        let by_duration = generate(&spec, 21);
        let by_count = generate_count(&spec, 21, by_duration.len() + 50);
        assert_eq!(by_count.len(), by_duration.len() + 50);
        for (a, b) in by_duration.iter().zip(&by_count) {
            assert_eq!(a.arrival_ms, b.arrival_ms);
            assert_eq!(a.request.data, b.request.data);
            assert_eq!(a.request.stages, b.request.stages);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_unaligned_sizes() {
        let mut spec = WorkloadSpec::fig5_mix();
        spec.size_mix = vec![(13, 1.0)];
        generate(&spec, 0);
    }

    #[test]
    fn diurnal_rate_oscillates_between_floor_and_peak() {
        let p = RateProfile::Diurnal {
            floor_per_s: 10.0,
            peak_per_s: 110.0,
            period_s: 8.0,
            phase: 0.0,
        };
        // sin(2π t/8): peak at t = 2 s, trough at t = 6 s.
        assert!((p.rate_at(2.0) - 110.0).abs() < 1e-9);
        assert!((p.rate_at(6.0) - 10.0).abs() < 1e-9);
        assert!((p.rate_at(0.0) - 60.0).abs() < 1e-9, "midpoint at phase 0");
        // A quarter-period phase shift moves the peak earlier.
        let shifted = RateProfile::Diurnal {
            floor_per_s: 10.0,
            peak_per_s: 110.0,
            period_s: 8.0,
            phase: 0.25,
        };
        assert!((shifted.rate_at(0.0) - 110.0).abs() < 1e-9);
    }

    #[test]
    fn bursty_rate_alternates() {
        let p = RateProfile::Bursty {
            burst_per_s: 500.0,
            idle_per_s: 5.0,
            burst_s: 1.0,
            idle_s: 3.0,
            phase_s: 0.0,
        };
        assert_eq!(p.rate_at(0.5), 500.0);
        assert_eq!(p.rate_at(2.0), 5.0);
        assert_eq!(p.rate_at(4.5), 500.0, "periodic");
        assert_eq!(RateProfile::Constant { rate_per_s: 7.0 }.rate_at(99.0), 7.0);
    }

    #[test]
    fn profiled_trace_is_deterministic_sorted_and_exact() {
        let tenants = diurnal_tenants(4, 30.0, 450.0, 4.0, 64);
        let a = generate_profiled(&tenants, 17, 800);
        let b = generate_profiled(&tenants, 17, 800);
        assert_eq!(a.len(), 800);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.request.app_id, y.request.app_id);
            assert_eq!(x.request.data, y.request.data);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        // All four tenants appear, with the agreed shape.
        let mut seen: Vec<u32> = a.iter().map(|e| e.request.app_id).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        for e in &a {
            assert_eq!(e.request.data.len(), 64);
            assert_eq!(e.request.stages.len(), 3);
        }
    }

    #[test]
    fn stages_by_name_resolves_and_refuses() {
        assert_eq!(
            stages_by_name(&["multiplier", "hamming_enc", "hamming_dec"])
                .unwrap(),
            ModuleKind::pipeline().to_vec()
        );
        assert!(matches!(
            stages_by_name(&["multiplier", "warp-drive"]),
            Err(crate::ElasticError::Config(_))
        ));
    }

    #[test]
    fn zoo_tenants_cycle_chains_with_rotating_phase() {
        let zoo = crate::kernels::register(
            crate::kernels::KernelDecl {
                name: "wl-zoo-add".into(),
                op: Some("add".into()),
                operand: 3,
                ..crate::kernels::KernelDecl::default()
            },
            None,
        )
        .unwrap();
        let chains =
            vec![ModuleKind::pipeline().to_vec(), vec![zoo]];
        let tenants = zoo_tenants(6, &chains, 20.0, 200.0, 4.0, 32);
        assert_eq!(tenants.len(), 6);
        assert_eq!(tenants[0].stages.len(), 3);
        assert_eq!(tenants[1].stages, vec![zoo]);
        assert_eq!(tenants[3].stages, vec![zoo], "chains cycle");
        // Traces over zoo tenants generate like any other profile.
        let trace = generate_profiled(&tenants, 23, 200);
        assert_eq!(trace.len(), 200);
        assert!(trace.iter().any(|e| e.request.stages == vec![zoo]));
    }

    #[test]
    fn profiled_trace_follows_the_demand_wave() {
        // One tenant, hard day/night: arrivals must concentrate in the
        // high-rate half-periods.
        let tenants = vec![TenantSpec {
            app_id: 0,
            stages: ModuleKind::pipeline().to_vec(),
            words: 8,
            profile: RateProfile::Bursty {
                burst_per_s: 400.0,
                idle_per_s: 4.0,
                burst_s: 1.0,
                idle_s: 1.0,
                phase_s: 0.0,
            },
        }];
        let trace = generate_profiled(&tenants, 3, 600);
        let (mut burst, mut idle) = (0usize, 0usize);
        for e in &trace {
            if (e.arrival_ms / 1000.0).rem_euclid(2.0) < 1.0 {
                burst += 1;
            } else {
                idle += 1;
            }
        }
        assert!(
            burst > idle * 10,
            "bursts not dominant: {burst} vs {idle}"
        );
    }
}
