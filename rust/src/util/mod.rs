//! Small hardware-flavoured helpers shared across the simulator:
//! leading-zero counting (the arbiter primitive of [31]/[32]), one-hot
//! codecs (the paper's slave-address encoding, §IV.E.2), bit utilities,
//! and the SHA-256 digest backing artifact-manifest verification.

pub mod bits;
pub mod lzc;
pub mod onehot;
pub mod rng;
pub mod sha256;

pub use bits::{parity_u32, popcount_u32};
pub use lzc::{leading_zeros_u32, lzc_select};
pub use onehot::{decode_onehot, encode_onehot, is_onehot};
pub use rng::SplitMix64;
pub use sha256::{sha256, sha256_hex};
