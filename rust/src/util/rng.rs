//! Deterministic PRNG for workload generation and the property-test
//! harness.  SplitMix64 (Steele et al.): tiny, fast, well-distributed,
//! and dependency-free (the `rand` crate is unavailable offline — see
//! DESIGN.md §7).

/// SplitMix64 PRNG.  Deterministic for a given seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform value in `[0, bound)`; bound must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping (slightly biased for huge
        // bounds; fine for workload generation).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Fill a buffer with random u32 words (payload generator).
    pub fn fill_u32(&mut self, buf: &mut [u32]) {
        for w in buf.iter_mut() {
            *w = self.next_u32();
        }
    }

    /// Fresh independent stream derived from this one (for sub-tasks).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn unit_f64_in_range_and_spread() {
        let mut r = SplitMix64::new(9);
        let vals: Vec<f64> = (0..1000).map(|_| r.unit_f64()).collect();
        assert!(vals.iter().all(|v| (0.0..1.0).contains(v)));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }
}
