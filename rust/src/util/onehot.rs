//! One-hot address codecs.
//!
//! §IV.E.2: "Slave addresses are sent in one-hot encoding form by a
//! master; for instance, to access slave 1, '0010' is sent.  This eases
//! the communication isolation as sent slave addresses and allowed
//! addresses are compared with AND".

/// Encode a port index as a one-hot vector.
#[inline(always)]
pub fn encode_onehot(index: u32) -> u32 {
    debug_assert!(index < 32);
    1u32 << index
}

/// True iff exactly one bit is set.
#[inline(always)]
pub fn is_onehot(x: u32) -> bool {
    x != 0 && (x & (x - 1)) == 0
}

/// Decode a one-hot vector to its port index; `None` if not one-hot.
#[inline(always)]
pub fn decode_onehot(x: u32) -> Option<u32> {
    if is_onehot(x) {
        Some(x.trailing_zeros())
    } else {
        None
    }
}

/// The paper's isolation check: `sent & allowed == 0` means the master
/// asked for a slave outside its allowed set (invalid request).
#[inline(always)]
pub fn isolation_permits(sent_onehot: u32, allowed_mask: u32) -> bool {
    sent_onehot & allowed_mask != 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for i in 0..32 {
            assert_eq!(decode_onehot(encode_onehot(i)), Some(i));
        }
    }

    #[test]
    fn rejects_non_onehot() {
        assert_eq!(decode_onehot(0), None);
        assert_eq!(decode_onehot(0b11), None);
        assert_eq!(decode_onehot(0b1010), None);
        assert!(!is_onehot(0));
        assert!(!is_onehot(5));
    }

    #[test]
    fn paper_example_slave1_is_0b0010() {
        assert_eq!(encode_onehot(1), 0b0010);
    }

    #[test]
    fn isolation_and_compare() {
        // Master allowed slaves {1,3} = 0b1010.
        let allowed = 0b1010;
        assert!(isolation_permits(encode_onehot(1), allowed));
        assert!(isolation_permits(encode_onehot(3), allowed));
        assert!(!isolation_permits(encode_onehot(0), allowed));
        assert!(!isolation_permits(encode_onehot(2), allowed));
    }
}
