//! Bit-level helpers mirroring the combinational primitives the VHDL
//! implementation uses (parity trees, popcounts).

/// Population count of a 32-bit word.
///
/// The FPGA implements this as a LUT tree; we delegate to the CPU popcnt
/// but keep the named wrapper so call sites read like the RTL.
#[inline(always)]
pub fn popcount_u32(x: u32) -> u32 {
    x.count_ones()
}

/// Even parity of a 32-bit word (1 = odd number of set bits).
///
/// This is the parity-tree primitive of the Hamming encoder/decoder.
#[inline(always)]
pub fn parity_u32(x: u32) -> u32 {
    x.count_ones() & 1
}

/// Extract bit `i` (0-indexed) of `x`.
#[inline(always)]
pub fn bit(x: u32, i: u32) -> u32 {
    (x >> i) & 1
}

/// Set bit `i` of `x` to `v` (v must be 0 or 1).
#[inline(always)]
pub fn with_bit(x: u32, i: u32, v: u32) -> u32 {
    debug_assert!(v <= 1);
    (x & !(1 << i)) | (v << i)
}

/// Rotate a one-bit-set mask left by one within `width` bits, wrapping.
/// Used by the WB-to-AXI channel-select shift register (§IV.G).
#[inline(always)]
pub fn rotate_onehot_left(x: u32, width: u32) -> u32 {
    debug_assert!(width > 0 && width <= 32);
    let top = 1u32 << (width - 1);
    if x & top != 0 {
        1
    } else {
        x << 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parity_matches_naive() {
        for x in [0u32, 1, 3, 7, 0xFFFF_FFFF, 0x8000_0001, 12345] {
            let naive = (0..32).map(|i| (x >> i) & 1).sum::<u32>() & 1;
            assert_eq!(parity_u32(x), naive, "x={x:#x}");
        }
    }

    #[test]
    fn bit_ops_roundtrip() {
        let x = 0b1010_1100u32;
        assert_eq!(bit(x, 2), 1);
        assert_eq!(bit(x, 0), 0);
        assert_eq!(with_bit(x, 0, 1) & 1, 1);
        assert_eq!(with_bit(x, 2, 0), x & !(1 << 2));
    }

    #[test]
    fn onehot_rotation_wraps() {
        // 3-bit shift register as in the WB-to-AXI module.
        let mut s = 0b001u32;
        let seq: Vec<u32> = (0..6)
            .map(|_| {
                let cur = s;
                s = rotate_onehot_left(s, 3);
                cur
            })
            .collect();
        assert_eq!(seq, vec![0b001, 0b010, 0b100, 0b001, 0b010, 0b100]);
    }
}
