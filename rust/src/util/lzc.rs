//! Leading-zero counter — the primitive the paper's WRR arbiter is built
//! on (§IV.E.1, refs [31], [32]): "we propose Weighted Round Robin (WRR)
//! arbiter based on leading zero counters (LZC), which operates at higher
//! frequencies and has less area overhead compared to priority encoders".
//!
//! The arbiter rotates the request vector so the *next* candidate after
//! the last grantee sits at the MSB end, then picks the first set bit via
//! the LZC.  [`lzc_select`] packages exactly that selection step.

/// Leading-zero count of a 32-bit word, LZC(0) = 32.
///
/// Mirrors the recursive-doubling circuit of Oklobdzija [31]; delegated
/// to the CPU instruction but kept as the named arbiter primitive.
#[inline(always)]
pub fn leading_zeros_u32(x: u32) -> u32 {
    x.leading_zeros()
}

/// Round-robin selection via LZC, the core of the WRR arbiter.
///
/// Given a request bit-vector `requests` over `width` ports and the port
/// granted most recently (`last`, or `None` after reset), return the next
/// port to grant: the first requester strictly after `last` in cyclic
/// order, or `None` when nothing is requested.
pub fn lzc_select(requests: u32, width: u32, last: Option<u32>) -> Option<u32> {
    debug_assert!(width > 0 && width <= 32);
    let mask = if width == 32 { u32::MAX } else { (1 << width) - 1 };
    let req = requests & mask;
    if req == 0 {
        return None;
    }
    // Rotate so that position (last+1) maps to bit 0, emulating the
    // barrel-shift in front of the LZC tree.  `start == 0` must not
    // shift by `width`: at a full 32-bit vector that is `u32 << 32`,
    // an overflow panic in debug builds.
    let start = last.map(|l| (l + 1) % width).unwrap_or(0);
    let rotated = if start == 0 {
        req
    } else {
        ((req >> start) | (req << (width - start))) & mask
    };
    // First set bit from the LSB end of the rotated vector = 31 - LZC of
    // the bit-reversed vector; equivalent to trailing_zeros here.
    let first = rotated.trailing_zeros();
    Some((start + first) % width)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lzc_of_zero_is_width() {
        assert_eq!(leading_zeros_u32(0), 32);
        assert_eq!(leading_zeros_u32(1), 31);
        assert_eq!(leading_zeros_u32(0x8000_0000), 0);
    }

    #[test]
    fn selects_none_when_idle() {
        assert_eq!(lzc_select(0, 4, None), None);
        assert_eq!(lzc_select(0, 4, Some(2)), None);
    }

    #[test]
    fn selects_first_requester_after_reset() {
        assert_eq!(lzc_select(0b0100, 4, None), Some(2));
        assert_eq!(lzc_select(0b0001, 4, None), Some(0));
    }

    #[test]
    fn round_robin_rotation() {
        // All four request; grants must rotate 0,1,2,3,0,...
        let mut last = None;
        let mut order = Vec::new();
        for _ in 0..8 {
            let g = lzc_select(0b1111, 4, last).unwrap();
            order.push(g);
            last = Some(g);
        }
        assert_eq!(order, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn skips_idle_ports() {
        // Ports 1 and 3 request; starting after 1 we must pick 3 then 1.
        assert_eq!(lzc_select(0b1010, 4, Some(1)), Some(3));
        assert_eq!(lzc_select(0b1010, 4, Some(3)), Some(1));
    }

    #[test]
    fn single_requester_always_wins() {
        for last in [None, Some(0), Some(1), Some(2), Some(3)] {
            assert_eq!(lzc_select(0b0100, 4, last), Some(2));
        }
    }

    #[test]
    fn ignores_bits_beyond_width() {
        assert_eq!(lzc_select(0xFFF0, 4, None), None);
    }

    #[test]
    fn full_width_vector_never_overflows_the_rotate() {
        // width = 32 with start = 0 (reset, or last = 31) used to shift
        // a u32 by 32 — a debug-build overflow panic.
        assert_eq!(lzc_select(u32::MAX, 32, None), Some(0));
        assert_eq!(lzc_select(u32::MAX, 32, Some(31)), Some(0));
        assert_eq!(lzc_select(u32::MAX, 32, Some(0)), Some(1));
        assert_eq!(lzc_select(1 << 31, 32, Some(31)), Some(31));
    }
}
