//! Crate-wide error type.

use thiserror::Error;

/// Unified error for the elastic-fpga coordinator.
#[derive(Debug, Error)]
pub enum ElasticError {
    /// PJRT / XLA runtime failures (artifact load, compile, execute).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Artifact missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Resource manager could not satisfy an allocation.
    #[error("allocation error: {0}")]
    Allocation(String),

    /// A WISHBONE transaction failed (invalid destination, timeout, ...).
    #[error("wishbone error: {0:?}")]
    Wishbone(crate::wishbone::WbError),

    /// Simulation invariant violated (a bug in the model, not the workload).
    #[error("simulation invariant violated: {0}")]
    Sim(String),

    /// Server/request-path failures.
    #[error("server error: {0}")]
    Server(String),

    /// Payload verification against the golden model failed.
    #[error("verification error: {0}")]
    Verify(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for ElasticError {
    fn from(e: xla::Error) -> Self {
        ElasticError::Xla(e.to_string())
    }
}

impl From<crate::wishbone::WbError> for ElasticError {
    fn from(e: crate::wishbone::WbError) -> Self {
        ElasticError::Wishbone(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, ElasticError>;
