//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is unavailable in
//! this offline environment (DESIGN.md §7).

use std::fmt;

/// Unified error for the elastic-fpga coordinator.
#[derive(Debug)]
pub enum ElasticError {
    /// Runtime failures (artifact load, compile, execute).
    Xla(String),

    /// Artifact missing or malformed.
    Artifact(String),

    /// Configuration file / CLI errors.
    Config(String),

    /// Resource manager could not satisfy an allocation.
    Allocation(String),

    /// A region / port / app ID falls outside the **configured**
    /// register-file layout (`crate::regfile::RegfileLayout`, banked to
    /// the crossbar width).  Such a port cannot be programmed for
    /// isolation, destinations or bandwidth, so the register file and
    /// the manager refuse it instead of panicking or silently running
    /// with power-on defaults.  Since the banked layout v2, every port
    /// of a shell is programmable — this error only fires for addresses
    /// past the shell's own width (e.g. region 17 on a 16-port board).
    RegfileWindow(String),

    /// A WISHBONE transaction failed (invalid destination, timeout, ...).
    Wishbone(crate::wishbone::WbError),

    /// Simulation invariant violated (a bug in the model, not the workload).
    Sim(String),

    /// Server/request-path failures.
    Server(String),

    /// Payload verification against the golden model failed.
    Verify(String),

    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for ElasticError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ElasticError::Xla(m) => write!(f, "xla runtime error: {m}"),
            ElasticError::Artifact(m) => write!(f, "artifact error: {m}"),
            ElasticError::Config(m) => write!(f, "config error: {m}"),
            ElasticError::Allocation(m) => write!(f, "allocation error: {m}"),
            ElasticError::RegfileWindow(m) => {
                write!(f, "register-file window error: {m}")
            }
            ElasticError::Wishbone(e) => write!(f, "wishbone error: {e:?}"),
            ElasticError::Sim(m) => {
                write!(f, "simulation invariant violated: {m}")
            }
            ElasticError::Server(m) => write!(f, "server error: {m}"),
            ElasticError::Verify(m) => write!(f, "verification error: {m}"),
            ElasticError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for ElasticError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ElasticError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ElasticError {
    fn from(e: std::io::Error) -> Self {
        ElasticError::Io(e)
    }
}

impl From<crate::wishbone::WbError> for ElasticError {
    fn from(e: crate::wishbone::WbError) -> Self {
        ElasticError::Wishbone(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, ElasticError>;
