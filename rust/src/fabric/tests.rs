//! Fabric integration tests: the full shell (bridges + crossbar +
//! modules + regfile + ICAP) composed, with §IV.G's bridge-latency
//! claims pinned exactly.

use super::*;
use crate::hamming;
use crate::modules::{ModuleKind, ModuleState};
use crate::util::SplitMix64;
use crate::xdma::RequestPolicy;

fn fabric() -> Fabric {
    Fabric::new(SystemConfig::paper_defaults())
}

/// Program the regfile for a chain of FPGA stages at the given ports for
/// `app`: port0 -> ports[0] -> ports[1] -> ... -> port0.
fn program_chain(f: &mut Fabric, app: u32, ports: &[usize]) {
    let first = ports.first().copied().unwrap_or(0);
    f.regfile.set_app_destination(app as usize, 1 << first).unwrap();
    f.regfile.set_allowed_slaves(0, 1 << first).unwrap();
    for (i, &p) in ports.iter().enumerate() {
        let next = ports.get(i + 1).copied().unwrap_or(0);
        f.regfile.set_pr_destination(p, 1 << next).unwrap();
        f.regfile.set_allowed_slaves(p, 1 << next).unwrap();
    }
}

fn install_chain(f: &mut Fabric, app: u32, kinds: &[ModuleKind]) -> Vec<usize> {
    let ports: Vec<usize> = (1..=kinds.len()).collect();
    program_chain(f, app, &ports);
    for (&p, &k) in ports.iter().zip(kinds) {
        f.install_static_module(p, k, app);
    }
    ports
}

fn rand_words(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = SplitMix64::new(seed);
    let mut v = vec![0u32; n];
    rng.fill_u32(&mut v);
    v
}

fn stream_app(f: &mut Fabric, app: u32, data: &[u32]) {
    // Per-app channel affinity (same policy as the manager): intra-app
    // burst order is only guaranteed within one H2C channel.
    let channel = app as usize % crate::xdma::H2C_CHANNELS;
    for chunk in data.chunks(8) {
        f.h2c_push(channel, H2cBurst { app_id: app, words: chunk.to_vec() })
            .expect("affinity channel in range");
    }
}

#[test]
fn single_module_roundtrip_multiplier() {
    let mut f = fabric();
    install_chain(&mut f, 0, &[ModuleKind::Multiplier]);
    let data = rand_words(64, 1);
    stream_app(&mut f, 0, &data);
    f.run_until_idle(100_000).unwrap();
    assert_eq!(
        f.app_output(0),
        hamming::multiply_buf(&data, hamming::MULT_CONSTANT).as_slice()
    );
}

#[test]
fn three_stage_pipeline_matches_golden() {
    // The Fig-5 dataflow: bridge -> multiplier -> encoder -> decoder ->
    // bridge, all on the fabric.
    let mut f = fabric();
    install_chain(&mut f, 0, &ModuleKind::pipeline());
    let data = rand_words(256, 2);
    stream_app(&mut f, 0, &data);
    f.run_until_idle(1_000_000).unwrap();
    assert_eq!(
        f.app_output(0),
        hamming::pipeline_buf(&data, hamming::MULT_CONSTANT).as_slice()
    );
    assert_eq!(app_error(&f, 0), None);
}

#[test]
fn full_16kb_buffer_through_pipeline() {
    // The paper's exact use case: 16 KB (4096 words).
    let mut f = fabric();
    install_chain(&mut f, 0, &ModuleKind::pipeline());
    let data = rand_words(4096, 3);
    stream_app(&mut f, 0, &data);
    let cycles = f.run_until_idle(10_000_000).unwrap();
    assert_eq!(
        f.app_output(0),
        hamming::pipeline_buf(&data, hamming::MULT_CONSTANT).as_slice()
    );
    // Plausibility: a 4096-word store-and-forward stream should take
    // O(100k) cycles, far under a cycle per bit.
    assert!(cycles < 400_000, "pipeline took {cycles} cycles");
}

#[test]
fn bridge_half_full_delivers_user_data_in_15_cc() {
    // §IV.G: "the latency to deliver user data from FIFO to a computation
    // module is reduced to 15 clock cycles".
    let mut f = fabric();
    install_chain(&mut f, 0, &[ModuleKind::Multiplier]);
    f.h2c_push(0, H2cBurst { app_id: 0, words: (1..=8).collect() }).unwrap();
    let mut left_ready_at = None;
    for _ in 0..100 {
        let c = f.now() + 1;
        f.tick(c);
        let m = f.module_at(1).unwrap();
        if m.state != ModuleState::Ready && left_ready_at.is_none() {
            left_ready_at = Some(c);
        }
        if f.idle() {
            break;
        }
    }
    assert_eq!(left_ready_at, Some(15), "half-full policy must hit 15 cc");
}

#[test]
fn bridge_full_policy_delivers_user_data_in_19_cc() {
    // §IV.G: "...compared to 19 clock cycles for the case where AXI side
    // buffer becomes full for a master to send request."
    let mut f = fabric();
    install_chain(&mut f, 0, &[ModuleKind::Multiplier]);
    f.axi2wb.policy = RequestPolicy::Full;
    f.h2c_push(0, H2cBurst { app_id: 0, words: (1..=8).collect() }).unwrap();
    let mut left_ready_at = None;
    for _ in 0..100 {
        let c = f.now() + 1;
        f.tick(c);
        let m = f.module_at(1).unwrap();
        if m.state != ModuleState::Ready && left_ready_at.is_none() {
            left_ready_at = Some(c);
        }
        if f.idle() {
            break;
        }
    }
    assert_eq!(left_ready_at, Some(19), "full policy must hit 19 cc");
}

#[test]
fn icap_reconfiguration_installs_module_and_releases_reset() {
    let mut f = fabric();
    program_chain(&mut f, 0, &[1]);
    // Small bitstream so the test is fast.
    f.reconfigure_with(crate::icap::ReconfigRequest {
        region: 1,
        kind: ModuleKind::Multiplier,
        app_id: 0,
        bitstream_words: 128,
        fail_after: None,
    })
    .unwrap();
    assert!(f.regfile.port_reset(1).unwrap(), "reset asserted during PR");
    assert!(f.module_at(1).is_none());
    // Run past the programming time (128 words * 2 cc).
    for _ in 0..300 {
        let c = f.now() + 1;
        f.tick(c);
    }
    assert!(f.module_at(1).is_some(), "module installed");
    assert!(!f.regfile.port_reset(1).unwrap(), "reset released");
    assert_eq!(f.regfile.icap_status(), crate::regfile::IcapStatus::Done);
    assert_eq!(f.reconfig_log().len(), 1);
    assert!(f.reconfig_log()[0].ok);
    // And it processes data.
    let data = rand_words(16, 4);
    stream_app(&mut f, 0, &data);
    f.run_until_idle(10_000).unwrap();
    assert_eq!(
        f.app_output(0),
        hamming::multiply_buf(&data, hamming::MULT_CONSTANT).as_slice()
    );
}

#[test]
fn failed_bitstream_leaves_region_empty_with_error_status() {
    let mut f = fabric();
    f.reconfigure_with(crate::icap::ReconfigRequest {
        region: 2,
        kind: ModuleKind::HammingEncoder,
        app_id: 1,
        bitstream_words: 100,
        fail_after: Some(10),
    })
    .unwrap();
    for _ in 0..100 {
        let c = f.now() + 1;
        f.tick(c);
    }
    assert!(f.module_at(2).is_none());
    assert_eq!(f.regfile.icap_status(), crate::regfile::IcapStatus::Error);
    assert!(f.regfile.port_reset(2).unwrap(), "failed region stays isolated");
}

#[test]
fn icap_serializes_concurrent_reconfigurations() {
    let mut f = fabric();
    f.reconfigure_with(crate::icap::ReconfigRequest {
        region: 1,
        kind: ModuleKind::Multiplier,
        app_id: 0,
        bitstream_words: 1000,
        fail_after: None,
    })
    .unwrap();
    let second = f.reconfigure(2, ModuleKind::HammingEncoder, 0);
    assert!(second.is_err(), "second PR while ICAP busy must fail");
}

#[test]
fn destination_update_redirects_mid_stream_output() {
    // Elasticity's key regfile mechanism (§IV.A): "updates the other
    // module's destination addresses so that they communicate with the
    // newly available module".  Here: multiplier first sends to the host
    // (port 0); after reprogramming its destination register it sends to
    // the encoder at port 2.
    let mut f = fabric();
    // multiplier at 1 -> port 0 initially.
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.regfile.set_allowed_slaves(0, 0b0010).unwrap();
    f.regfile.set_pr_destination(1, 0b0001).unwrap();
    f.regfile.set_allowed_slaves(1, 0b0101).unwrap(); // may reach 0 or 2
    f.install_static_module(1, ModuleKind::Multiplier, 0);
    let batch1 = rand_words(8, 5);
    stream_app(&mut f, 0, &batch1);
    f.run_until_idle(10_000).unwrap();
    assert_eq!(
        f.take_app_output(0),
        hamming::multiply_buf(&batch1, hamming::MULT_CONSTANT)
    );
    // Now the encoder "becomes available": install at port 2 and repoint
    // the multiplier's destination register.
    f.regfile.set_pr_destination(2, 0b0001).unwrap();
    f.regfile.set_allowed_slaves(2, 0b0001).unwrap();
    f.install_static_module(2, ModuleKind::HammingEncoder, 0);
    f.regfile.set_pr_destination(1, 0b0100).unwrap();
    let batch2 = rand_words(8, 6);
    stream_app(&mut f, 0, &batch2);
    f.run_until_idle(10_000).unwrap();
    let want: Vec<u32> = batch2
        .iter()
        .map(|&w| hamming::encode_word(hamming::multiply_word(w, hamming::MULT_CONSTANT)))
        .collect();
    assert_eq!(f.app_output(0), want.as_slice());
}

#[test]
fn two_apps_share_the_fabric_in_isolation() {
    // App 0 owns the multiplier at port 1; app 1 owns the encoder at
    // port 2.  Both stream concurrently; outputs must not mix.
    let mut f = fabric();
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.regfile.set_app_destination(1, 0b0100).unwrap();
    f.regfile.set_allowed_slaves(0, 0b0110).unwrap();
    f.regfile.set_pr_destination(1, 0b0001).unwrap();
    f.regfile.set_allowed_slaves(1, 0b0001).unwrap();
    f.regfile.set_pr_destination(2, 0b0001).unwrap();
    f.regfile.set_allowed_slaves(2, 0b0001).unwrap();
    f.install_static_module(1, ModuleKind::Multiplier, 0);
    f.install_static_module(2, ModuleKind::HammingEncoder, 1);
    let a = rand_words(64, 7);
    let b = rand_words(64, 8);
    // Two apps on their affinity channels; the bridge interleaves them.
    for (ca, cb) in a.chunks(8).zip(b.chunks(8)) {
        f.h2c_push(0, H2cBurst { app_id: 0, words: ca.to_vec() }).unwrap();
        f.h2c_push(1, H2cBurst { app_id: 1, words: cb.to_vec() }).unwrap();
    }
    f.run_until_idle(1_000_000).unwrap();
    assert_eq!(
        f.app_output(0),
        hamming::multiply_buf(&a, hamming::MULT_CONSTANT).as_slice()
    );
    assert_eq!(f.app_output(1), hamming::encode_buf(&b).as_slice());
    assert_eq!(app_error(&f, 0), None);
    assert_eq!(app_error(&f, 1), None);
}

#[test]
fn module_sending_to_disallowed_port_records_pr_error() {
    // Isolation violation from a *module* (not the bridge): the regfile
    // must capture the PR region's error status (Table III reg 17).
    let mut f = fabric();
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.regfile.set_allowed_slaves(0, 0b0010).unwrap();
    f.regfile.set_pr_destination(1, 0b0100).unwrap(); // points at port 2...
    f.regfile.set_allowed_slaves(1, 0b0001).unwrap(); // ...but only port 0 allowed
    f.install_static_module(1, ModuleKind::Multiplier, 0);
    stream_app(&mut f, 0, &rand_words(8, 9));
    // Run; module's send must fail with InvalidDestination.
    for _ in 0..200 {
        let c = f.now() + 1;
        f.tick(c);
    }
    assert_eq!(
        f.regfile.pr_error(1).unwrap(),
        Some(crate::wishbone::WbError::InvalidDestination)
    );
    assert_eq!(f.app_output(0), &[] as &[u32], "nothing reached the host");
}

#[test]
fn flush_c2h_emits_partial_tails() {
    // 4-word stream: the port-0 reassembly buffer holds a partial burst
    // until flushed.
    let mut f = fabric();
    install_chain(&mut f, 0, &[ModuleKind::Multiplier]);
    // 4-word burst (short): module batch is 8 words, so pad the module
    // batch by sending 8 words but expect... actually send exactly 8 so
    // the module fires, then check c2h assembled the full burst without
    // needing a flush, and that flush on an empty accumulator is a no-op.
    let data = rand_words(8, 10);
    stream_app(&mut f, 0, &data);
    f.run_until_idle(10_000).unwrap();
    let before = f.app_output(0).len();
    f.flush_c2h();
    assert_eq!(f.app_output(0).len(), before, "flush is a no-op when aligned");
    assert_eq!(before, 8);
}

#[test]
fn c2h_channels_rotate_round_robin() {
    let mut f = fabric();
    install_chain(&mut f, 0, &[ModuleKind::Multiplier]);
    let data = rand_words(24, 11); // 3 bursts -> one per C2H channel
    stream_app(&mut f, 0, &data);
    f.run_until_idle(100_000).unwrap();
    for ch in 0..3 {
        let got = f.xdma.c2h_drain(ch).unwrap();
        assert_eq!(got.len(), 8, "channel {ch} got {}", got.len());
    }
}

#[test]
fn fabric_starts_isolated_until_programmed() {
    // Power-on: the bridge may not reach any slave; a submitted burst
    // must fail with InvalidDestination and record an app error.
    let mut f = fabric();
    f.install_static_module(1, ModuleKind::Multiplier, 0);
    // NOTE: no allowed_slaves programming for port 0.
    f.regfile.set_app_destination(0, 0b0010).unwrap();
    f.h2c_push(0, H2cBurst { app_id: 0, words: vec![1; 8] }).unwrap();
    for _ in 0..100 {
        let c = f.now() + 1;
        f.tick(c);
    }
    assert_eq!(
        app_error(&f, 0),
        Some(crate::wishbone::WbError::InvalidDestination)
    );
    assert_eq!(f.app_output(0), &[] as &[u32]);
}
