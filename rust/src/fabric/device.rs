//! FPGA device model: the Kintex UltraScale XCKU115 on the KCU1500
//! board (§V.A), and the PR-region partitioning the manager allocates
//! from.
//!
//! Resource totals are the public device table values the paper's
//! utilization percentages are computed against (e.g. Table I reports
//! the WB crossbar's 475 LUTs as 0.07% — 475 / 663,360 ≈ 0.0716%).

/// Resource inventory of one device or region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resources {
    pub luts: u64,
    pub ffs: u64,
    pub brams: u64,
}

impl Resources {
    /// Component-wise subtraction, saturating at zero.
    pub fn saturating_sub(self, other: Resources) -> Resources {
        Resources {
            luts: self.luts.saturating_sub(other.luts),
            ffs: self.ffs.saturating_sub(other.ffs),
            brams: self.brams.saturating_sub(other.brams),
        }
    }

    /// Does `self` fit within `capacity`?
    pub fn fits_in(self, capacity: Resources) -> bool {
        self.luts <= capacity.luts && self.ffs <= capacity.ffs && self.brams <= capacity.brams
    }
}

/// XCKU115 device totals (Kintex UltraScale, KCU1500 board).
pub const XCKU115: Resources = Resources {
    luts: 663_360,
    ffs: 1_326_720,
    brams: 2_160,
};

/// One partially reconfigurable region's static footprint.
#[derive(Debug, Clone, PartialEq)]
pub struct PrRegionSpec {
    /// 1-indexed region number = crossbar port.
    pub region: usize,
    /// Resources fenced into this region.
    pub capacity: Resources,
}

/// The device model: totals plus the PR floorplan.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    /// Device totals.
    pub total: Resources,
    /// PR regions (the paper argues for many *small* regions).
    pub regions: Vec<PrRegionSpec>,
}

impl DeviceModel {
    /// The paper's prototype floorplan: three small regions on an
    /// XCKU115, each comfortably larger than the biggest prototype
    /// module (WB Hamming decoder: 432 LUTs / 646 FFs, Table I).
    pub fn kcu1500_prototype() -> Self {
        let region_cap = Resources { luts: 2_000, ffs: 4_000, brams: 4 };
        DeviceModel {
            total: XCKU115,
            regions: (1..=3)
                .map(|region| PrRegionSpec { region, capacity: region_cap })
                .collect(),
        }
    }

    /// A floorplan with `n` uniform regions (scaling studies / Fig 6).
    pub fn uniform(n: usize, capacity: Resources) -> Self {
        DeviceModel {
            total: XCKU115,
            regions: (1..=n).map(|region| PrRegionSpec { region, capacity }).collect(),
        }
    }

    /// Percentage of device LUTs a count represents (Table I's % column).
    pub fn lut_pct(&self, luts: u64) -> f64 {
        100.0 * luts as f64 / self.total.luts as f64
    }

    /// Percentage of device FFs.
    pub fn ff_pct(&self, ffs: u64) -> f64 {
        100.0 * ffs as f64 / self.total.ffs as f64
    }

    /// Percentage of device BRAMs.
    pub fn bram_pct(&self, brams: f64) -> f64 {
        100.0 * brams / self.total.brams as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xcku115_percentages_match_table1() {
        let d = DeviceModel::kcu1500_prototype();
        // Table I: WB crossbar 475 LUTs = 0.07%, 60 FFs = 0.004%.
        assert!((d.lut_pct(475) - 0.07).abs() < 0.005, "{}", d.lut_pct(475));
        assert!((d.ff_pct(60) - 0.004).abs() < 0.001, "{}", d.ff_pct(60));
        // XDMA: 33441 LUTs = 5.04%.
        assert!((d.lut_pct(33_441) - 5.04).abs() < 0.01);
        // 62 BRAMs = 2.87%.
        assert!((d.bram_pct(62.0) - 2.87).abs() < 0.01);
    }

    #[test]
    fn prototype_regions_fit_the_modules() {
        let d = DeviceModel::kcu1500_prototype();
        assert_eq!(d.regions.len(), 3);
        // Largest prototype module: WB Hamming decoder (432 LUT, 646 FF).
        let decoder = Resources { luts: 432, ffs: 646, brams: 0 };
        for r in &d.regions {
            assert!(decoder.fits_in(r.capacity), "region {}", r.region);
        }
    }

    #[test]
    fn resource_arithmetic() {
        let a = Resources { luts: 100, ffs: 50, brams: 2 };
        let b = Resources { luts: 30, ffs: 60, brams: 1 };
        let c = a.saturating_sub(b);
        assert_eq!(c, Resources { luts: 70, ffs: 0, brams: 1 });
        assert!(!a.fits_in(b));
        assert!(b.fits_in(Resources { luts: 30, ffs: 60, brams: 1 }));
    }
}
