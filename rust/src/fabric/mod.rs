//! The FPGA shell: everything Figure 3 wires together, composed and
//! clocked as one synchronous design.
//!
//! * Crossbar port 0: AXI-to-WB bridge (master) + WB-to-AXI bridge
//!   (slave), fed by the XDMA H2C/C2H channels.
//! * Crossbar ports 1..N-1: PR regions, each hosting at most one
//!   computation module (instantiated by ICAP completion).
//! * Register file: programmed by the manager over the AXI-Lite bypass;
//!   re-synced into the crossbar/modules whenever its write generation
//!   advances.
//! * ICAP: serializes partial reconfigurations; the fabric asserts the
//!   target port's reset for the duration (§IV.C).
//!
//! The device model also carries the XCKU115 resource inventory used by
//! the area model and the manager's feasibility checks.

mod device;

pub use device::{DeviceModel, PrRegionSpec, XCKU115};

use std::collections::HashMap;

use crate::config::SystemConfig;
use crate::crossbar::Crossbar;
use crate::icap::{Icap, ReconfigDone, ReconfigRequest};
use crate::modules::{ComputationModule, ModuleKind};
use crate::regfile::RegisterFile;
use crate::sim::{EventDriven, Tick};
use crate::telemetry::{wb_error_name, TraceEvent, Tracer};
use crate::wishbone::WbError;
use crate::xdma::{AxiToWb, H2cBurst, WbToAxi, Xdma, BRIDGE_BUFFER_WORDS};
use crate::{ElasticError, Result};

/// The composed shell.
pub struct Fabric {
    cfg: SystemConfig,
    /// The crossbar switch (paper's core contribution).
    pub xbar: Crossbar,
    /// Table III register file.
    pub regfile: RegisterFile,
    /// PR-region module slots, indexed by crossbar port (slot 0 unused —
    /// port 0 is the bridge).
    pub modules: Vec<Option<ComputationModule>>,
    /// AXI-to-WB bridge (port 0 master half).
    pub axi2wb: AxiToWb,
    /// WB-to-AXI bridge (port 0 slave half).
    pub wb2axi: WbToAxi,
    /// XDMA channel fabric.
    pub xdma: Xdma,
    /// ICAP + CDC FIFO.
    pub icap: Icap,
    /// Per-app ordered output words (host-driver reassembly view; the
    /// same words also land in the C2H channel FIFOs).
    output_log: HashMap<u32, Vec<u32>>,
    /// Reassembly buffers: completed bursts at port 0's slave, per source
    /// port, grouped to `BRIDGE_BUFFER_WORDS` before C2H forwarding.
    rx_accum: Vec<Vec<u32>>,
    /// Reusable drain scratch (§Perf: avoids a Vec allocation per port
    /// per cycle in the hot tick loop).
    rx_scratch: Vec<(u32, usize)>,
    /// ICAP completions observed this run (manager reads these).
    reconfig_log: Vec<ReconfigDone>,
    /// Last regfile generation synced into the crossbar.
    synced_gen: u64,
    /// Last ICAP status mirrored into the regfile.
    mirrored_icap: crate::regfile::IcapStatus,
    /// Cycles actually executed through [`Tick::tick`] (perf
    /// observability — `benches/fabric_serving.rs` reports executed vs
    /// skipped; excluded from oracle-equivalence comparisons by design).
    pub executed_cycles: u64,
    /// Cycles accounted arithmetically by the fast-path
    /// ([`EventDriven::fast_forward`]) instead of executed.
    pub skipped_cycles: u64,
    /// Cycle-stamped telemetry sink (DESIGN.md §14).  Off by default:
    /// every emission site is a single discriminant branch.  Enable via
    /// [`Fabric::set_tracing`], which also turns on crossbar grant
    /// recording so arbitration grants surface as
    /// [`TraceEvent::GrantIssued`].
    pub telemetry: Tracer,
    cycle: u64,
}

impl Fabric {
    /// Build the shell from a configuration.  The register file is
    /// banked to the crossbar width, so every port is programmable.
    pub fn new(cfg: SystemConfig) -> Self {
        let n = cfg.fabric.num_ports;
        assert!(
            cfg.fabric.num_pr_regions == n - 1,
            "prototype wiring: one PR region per non-bridge port"
        );
        let mut xbar = Crossbar::new(n, cfg.crossbar.clone());
        let regfile = RegisterFile::with_ports(n);
        // Power-on: crossbar mirrors the (zeroed) regfile — fully isolated.
        for p in 0..n {
            xbar.set_allowed_slaves(p, 0);
        }
        Self {
            xbar,
            regfile,
            modules: (0..n).map(|_| None).collect(),
            axi2wb: AxiToWb::new(),
            wb2axi: WbToAxi::new(),
            xdma: Xdma::new(),
            icap: Icap::new(64),
            output_log: HashMap::new(),
            rx_accum: vec![Vec::new(); n],
            rx_scratch: Vec::with_capacity(64),
            reconfig_log: Vec::new(),
            synced_gen: 0,
            mirrored_icap: crate::regfile::IcapStatus::Idle,
            executed_cycles: 0,
            skipped_cycles: 0,
            telemetry: Tracer::Off,
            cfg,
            cycle: 0,
        }
    }

    /// Install a telemetry sink.  An enabled sink also switches on
    /// crossbar grant recording (drained into the sink every tick);
    /// installing [`Tracer::Off`] switches it back off.
    pub fn set_tracing(&mut self, tracer: Tracer) {
        self.xbar.set_record_grants(tracer.enabled());
        self.telemetry = tracer;
    }

    /// System configuration.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Current fabric cycle.
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Begin partial reconfiguration of `region` (1-indexed port number)
    /// with `kind` for `app_id`.  Asserts the port reset for the duration
    /// (§IV.C).  Fails if the ICAP is busy.
    pub fn reconfigure(
        &mut self,
        region: usize,
        kind: ModuleKind,
        app_id: u32,
    ) -> Result<()> {
        if region == 0 || region >= self.xbar.ports() {
            return Err(ElasticError::Allocation(format!(
                "region {region} out of range"
            )));
        }
        let words = (self.cfg.manager.bitstream_bytes / 4) as u64;
        self.reconfigure_with(ReconfigRequest {
            region,
            kind,
            app_id,
            bitstream_words: words.max(1),
            fail_after: None,
        })
    }

    /// Reconfigure with an explicit descriptor (failure injection etc.).
    pub fn reconfigure_with(&mut self, req: ReconfigRequest) -> Result<()> {
        let region = req.region;
        let app_id = req.app_id;
        let words = req.bitstream_words;
        if !self.icap.start(req) {
            return Err(ElasticError::Allocation(
                "ICAP busy: reconfigurations are serialized".into(),
            ));
        }
        let cycle = self.cycle;
        self.telemetry.emit_with(|| TraceEvent::IcapStart {
            cycle,
            app: app_id,
            region,
            words,
        });
        // Old module (if any) is torn out; port isolated during PR.
        self.modules[region] = None;
        self.regfile
            .set_port_reset(region, true)
            .expect("validated region within layout");
        Ok(())
    }

    /// Remove a module and free its region immediately (no ICAP traffic;
    /// clearing a region does not require programming a bitstream).
    pub fn clear_region(&mut self, region: usize) {
        self.modules[region] = None;
        self.regfile
            .set_port_reset(region, true)
            .expect("region within layout");
    }

    /// Install a module *statically*, without ICAP programming.  This is
    /// the paper's own prototype path (§V.B: the ICAP module "has not
    /// been implemented in the current prototype [...] the features of
    /// the proposed 32-bit WB Crossbar interconnect are tested using
    /// statically allocated modules").
    pub fn install_static_module(
        &mut self,
        region: usize,
        kind: ModuleKind,
        app_id: u32,
    ) {
        assert!(region > 0 && region < self.xbar.ports(), "bad region {region}");
        let mut m = ComputationModule::from_spec(kind, region, app_id);
        m.dest_onehot = self
            .regfile
            .pr_destination(region)
            .expect("region within layout");
        self.modules[region] = Some(m);
        self.regfile
            .set_port_reset(region, false)
            .expect("region within layout");
    }

    /// Park a region's module for the configuration cache (DESIGN.md
    /// §16): the bitstream geometry stays resident but every piece of
    /// architectural state is scrubbed by constructing a *fresh* module
    /// owned by the host (app 0) with its port reset asserted.  A later
    /// cache hit rebinds it via [`Fabric::install_static_module`]; until
    /// then the port is isolated exactly like a cleared region, so no
    /// tenant state — FIFO words, counters, error latches — survives
    /// the handoff.
    pub fn park_region(&mut self, region: usize, kind: ModuleKind) {
        assert!(region > 0 && region < self.xbar.ports(), "bad region {region}");
        let m = ComputationModule::from_spec(kind, region, 0);
        self.modules[region] = Some(m);
        self.regfile
            .set_port_reset(region, true)
            .expect("region within layout");
    }

    /// Which module currently occupies `region`?
    pub fn module_at(&self, region: usize) -> Option<&ComputationModule> {
        self.modules.get(region).and_then(Option::as_ref)
    }

    /// Host driver: queue an app-tagged burst on an H2C channel.  An
    /// out-of-range channel is refused with a typed error.
    pub fn h2c_push(&mut self, channel: usize, burst: H2cBurst) -> Result<()> {
        self.xdma.h2c_push(channel, burst)
    }

    /// Install per-app H2C descriptor-scheduler weights on the bridge
    /// (DESIGN.md §15).  The manager lowers these from the compiled
    /// bandwidth plan in `apply_plan`, alongside the crossbar budgets,
    /// so end-to-end shares compose bridge-DRR × crossbar-WRR.
    pub fn set_h2c_weights(&mut self, weights: &[(u32, u32)]) {
        self.xdma.set_h2c_weights(weights);
    }

    /// Ordered output words collected for `app_id` so far.
    pub fn app_output(&self, app_id: u32) -> &[u32] {
        self.output_log.get(&app_id).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Take (and clear) an app's collected output.
    pub fn take_app_output(&mut self, app_id: u32) -> Vec<u32> {
        self.output_log.remove(&app_id).unwrap_or_default()
    }

    /// Reconfiguration completions observed so far.
    pub fn reconfig_log(&self) -> &[ReconfigDone] {
        &self.reconfig_log
    }

    /// Nothing in flight anywhere?
    pub fn idle(&self) -> bool {
        self.xbar.quiescent()
            && !self.axi2wb.busy()
            && !self.icap.busy()
            && self.xdma.h2c_pending() == 0
            && self
                .modules
                .iter()
                .flatten()
                .all(|m| m.state == crate::modules::ModuleState::Ready && m.input_fill() == 0)
            && self.rx_accum.iter().all(Vec::is_empty)
    }

    /// The one fast/oracle drive loop (DESIGN.md §12): execute cycles
    /// until `done(self)` holds after a tick or the clock reaches
    /// `end`; returns whether `done` held.  With `fast` on,
    /// deterministic busy stretches fast-forward through the
    /// busy-period horizon instead of single-stepping.  `done` must be
    /// invariant over skipped stretches — true for both current
    /// predicates ([`Fabric::idle`] and module installation, which only
    /// change at executed cycles) — so checking it only at executed
    /// cycles observes the same stop cycle the oracle does.  Every
    /// caller shares this loop so the skip contract lives in one place.
    pub(crate) fn drive_until(
        &mut self,
        end: u64,
        fast: bool,
        done: impl Fn(&Fabric) -> bool,
    ) -> bool {
        while self.cycle < end {
            if fast && !done(self) {
                let target = self
                    .next_interesting_cycle(self.cycle)
                    .saturating_sub(1)
                    .min(end.saturating_sub(1));
                if target > self.cycle {
                    self.fast_forward(target);
                }
            }
            let c = self.cycle + 1;
            self.tick(c);
            if done(self) {
                return true;
            }
        }
        false
    }

    fn run_until_idle_impl(&mut self, max: u64, fast: bool) -> Result<u64> {
        let start = self.cycle;
        let end = start.saturating_add(max);
        if self.drive_until(end, fast, Fabric::idle) {
            Ok(self.cycle - start)
        } else {
            Err(ElasticError::Sim(format!(
                "fabric did not quiesce within {max} cycles"
            )))
        }
    }

    /// Run until [`Fabric::idle`] or `max` cycles; returns cycles executed.
    /// This is the cycle-by-cycle **oracle** — every cycle ticks.
    pub fn run_until_idle(&mut self, max: u64) -> Result<u64> {
        self.run_until_idle_impl(max, false)
    }

    /// Horizon-skipping counterpart of [`Fabric::run_until_idle`]:
    /// **cycle-exact** with it (same end state, same cycles charged, same
    /// return) but only the interesting cycles execute — deterministic
    /// busy stretches (ICAP word-streaming, module compute countdowns)
    /// fast-forward arithmetically (DESIGN.md §12; equivalence pinned by
    /// `tests/fastpath_equivalence.rs`).
    pub fn run_until_idle_fast(&mut self, max: u64) -> Result<u64> {
        self.run_until_idle_impl(max, true)
    }

    // ------------------------------------------------------------------

    /// Mirror register-file configuration into the crossbar and modules.
    ///
    /// The register file is banked to the crossbar width
    /// ([`crate::regfile::RegfileLayout`]), so *every* port's isolation
    /// mask, reset bit, WRR package budgets and destination address are
    /// mirrored — no port is left on power-on defaults.
    fn sync_regfile(&mut self) {
        if self.regfile.generation() == self.synced_gen {
            return;
        }
        let n = self.xbar.ports();
        debug_assert_eq!(n, self.regfile.layout().num_ports());
        for p in 0..n {
            let allowed = self
                .regfile
                .allowed_slaves(p)
                .expect("port within layout");
            self.xbar.set_allowed_slaves(p, allowed);
            let was_reset =
                self.regfile.port_reset(p).expect("port within layout");
            self.xbar.set_port_reset(p, was_reset);
            for m in 0..n {
                let budget = self
                    .regfile
                    .allowed_packages(p, m)
                    .expect("port within layout");
                let effective = if budget == 0 {
                    self.cfg.crossbar.default_packages
                } else {
                    budget
                };
                self.xbar
                    .set_allowed_packages(p, m, effective)
                    .expect("in-layout master with a positive budget");
            }
        }
        // Destination addresses into the modules.
        for region in 1..n {
            if let Some(m) = self.modules[region].as_mut() {
                m.dest_onehot = self
                    .regfile
                    .pr_destination(region)
                    .expect("region within layout");
            }
        }
        self.synced_gen = self.regfile.generation();
    }

    fn mirror_icap_status(&mut self) {
        if self.icap.status != self.mirrored_icap {
            self.regfile.set_icap_status(self.icap.status);
            self.mirrored_icap = self.icap.status;
        }
    }

    fn handle_reconfig_done(&mut self, done: ReconfigDone) {
        self.telemetry.emit_with(|| TraceEvent::IcapDone {
            cycle: done.cycle,
            app: done.app_id,
            region: done.region,
            ok: done.ok,
        });
        if done.ok {
            let mut m = ComputationModule::from_spec(done.kind, done.region, done.app_id);
            m.dest_onehot = self
                .regfile
                .pr_destination(done.region)
                .expect("region within layout");
            self.modules[done.region] = Some(m);
            // Release the reset: the region rejoins the crossbar (§IV.C).
            self.regfile
                .set_port_reset(done.region, false)
                .expect("region within layout");
        }
        self.reconfig_log.push(done);
    }

    /// Move recorded crossbar grants into the telemetry sink.  Guarded
    /// so the disabled path is a branch plus an `is_empty` check.
    fn drain_grant_telemetry(&mut self) {
        if !self.telemetry.enabled() || self.xbar.grant_log().is_empty() {
            return;
        }
        for g in self.xbar.take_grant_log() {
            self.telemetry.emit(TraceEvent::GrantIssued {
                cycle: g.cycle,
                app: g.app_id,
                slave: g.slave,
                master: g.master,
                words: g.words,
            });
        }
    }

    fn route_events(&mut self) {
        for ev in self.xbar.take_events() {
            let app_covered =
                self.regfile.layout().covers_app(ev.app_id as usize);
            if let Err(err) = ev.result {
                let cycle = self.cycle;
                self.telemetry.emit_with(|| TraceEvent::ViolationMasked {
                    cycle,
                    app: ev.app_id,
                    port: ev.port,
                    err: wb_error_name(err),
                });
            }
            if ev.port == 0 {
                self.axi2wb.on_send_complete(ev.result);
                if app_covered {
                    let _ = self
                        .regfile
                        .set_app_error(ev.app_id as usize, ev.result.err());
                }
            } else if let Some(m) = self.modules[ev.port].as_mut() {
                m.on_send_complete(ev.result);
                let _ = self.regfile.set_pr_error(ev.port, ev.result.err());
                if app_covered && ev.result.is_err() {
                    let _ = self
                        .regfile
                        .set_app_error(ev.app_id as usize, ev.result.err());
                }
            }
        }
    }

    fn tick_modules(&mut self) {
        // Field-disjoint borrows: `self.modules`, `self.xbar`,
        // `self.rx_scratch`, `self.regfile`, and `self.telemetry` never
        // alias (§Perf: avoids moving the module struct in and out of
        // its slot every cycle).
        let modules = &mut self.modules;
        let xbar = &mut self.xbar;
        let scratch = &mut self.rx_scratch;
        let regfile = &mut self.regfile;
        let telemetry = &mut self.telemetry;
        let cycle = self.cycle;
        for p in 1..xbar.ports() {
            let Some(m) = modules[p].as_mut() else { continue };
            let cap = m.absorb_capacity();
            if cap > 0 && xbar.rx_len(p) > 0 {
                scratch.clear();
                xbar.drain_rx_into(p, cap, scratch);
                let absorbed = m.absorb_pairs(scratch);
                debug_assert_eq!(absorbed, scratch.len());
            }
            if let Some(job) = m.tick() {
                // Boundary validation (DESIGN.md §17): the shell does
                // not trust the hosted kernel's output registers.  A
                // batch with the wrong word count or an out-of-mask
                // word is dropped here — it never reaches the crossbar
                // — and the violation latches into the module's error
                // register, the PR error-status register, and the
                // owning app's error spill, exactly like a masked
                // wishbone violation.
                let mask = m.kind.spec().output_mask;
                let honest = job.words.len() == m.batch_words
                    && job.words.iter().all(|&w| w & !mask == 0);
                if honest {
                    xbar.push_job(p, job);
                } else {
                    let app_id = m.app_id;
                    m.on_send_complete(Err(WbError::ContractViolation));
                    let _ = regfile
                        .set_pr_error(p, Some(WbError::ContractViolation));
                    if regfile.layout().covers_app(app_id as usize) {
                        let _ = regfile.set_app_error(
                            app_id as usize,
                            Some(WbError::ContractViolation),
                        );
                    }
                    telemetry.emit_with(|| TraceEvent::ViolationMasked {
                        cycle,
                        app: app_id,
                        port: p,
                        err: wb_error_name(WbError::ContractViolation),
                    });
                }
            }
        }
    }

    fn tick_port0_slave(&mut self) {
        // Words arriving at port 0's slave side are results headed for
        // the host: group per source into bridge-sized bursts, then
        // forward to a C2H channel and the app output log.
        if self.xbar.rx_len(0) == 0 {
            return;
        }
        self.rx_scratch.clear();
        self.xbar.drain_rx_into(0, usize::MAX, &mut self.rx_scratch);
        for i in 0..self.rx_scratch.len() {
            let (w, src) = self.rx_scratch[i];
            self.rx_accum[src].push(w);
            if self.rx_accum[src].len() == BRIDGE_BUFFER_WORDS {
                let app = self.app_of_port(src);
                let burst = std::mem::take(&mut self.rx_accum[src]);
                self.wb2axi.forward(&mut self.xdma, app, &burst);
                self.output_log.entry(app).or_default().extend_from_slice(&burst);
            }
        }
    }

    /// Flush partially filled C2H reassembly buffers (stream tails).
    pub fn flush_c2h(&mut self) {
        for src in 0..self.rx_accum.len() {
            if !self.rx_accum[src].is_empty() {
                let app = self.app_of_port(src);
                let burst = std::mem::take(&mut self.rx_accum[src]);
                self.wb2axi.forward(&mut self.xdma, app, &burst);
                self.output_log.entry(app).or_default().extend_from_slice(&burst);
            }
        }
    }

    fn app_of_port(&self, port: usize) -> u32 {
        self.modules
            .get(port)
            .and_then(Option::as_ref)
            .map(|m| m.app_id)
            .unwrap_or(0)
    }

    fn tick_bridge(&mut self) {
        let regfile = &self.regfile;
        // An app ID with no destination register resolves to 0 (not
        // one-hot): the master interface rejects it as
        // InvalidDestination, exactly like an unprogrammed app.
        if let Some(job) = self.axi2wb.tick(&mut self.xdma, |app| {
            regfile.app_destination(app as usize).unwrap_or(0)
        }) {
            let cycle = self.cycle;
            let app = job.app_id;
            let words = job.words.len();
            let channel = self.axi2wb.last_channel;
            self.telemetry.emit_with(|| TraceEvent::H2cScheduled {
                cycle,
                app,
                channel,
                words,
            });
            self.xbar.push_job(0, job);
        }
    }
}

impl Tick for Fabric {
    fn tick(&mut self, cycle: u64) {
        self.cycle = cycle;
        self.executed_cycles += 1;
        self.sync_regfile();
        self.icap.tick(cycle);
        for done in self.icap.take_done() {
            self.handle_reconfig_done(done);
        }
        self.mirror_icap_status();
        self.sync_regfile(); // reconfig completion may have touched resets
        self.xbar.tick(cycle);
        self.drain_grant_telemetry();
        self.route_events();
        self.tick_modules();
        self.tick_port0_slave();
        self.tick_bridge();
    }
}

impl EventDriven for Fabric {
    fn stable(&self) -> bool {
        // `idle()` covers the datapath (crossbar masters, bridges, XDMA,
        // ICAP, module FSMs, reassembly buffers); on top of that require
        // the crossbar's arbiters to have settled and all pending
        // register-file/ICAP mirroring to have been absorbed, so a tick
        // would be a pure no-op.
        self.idle()
            && self.xbar.stable_point()
            && self.regfile.generation() == self.synced_gen
            && self.icap.status == self.mirrored_icap
    }

    fn fast_forward(&mut self, to_cycle: u64) {
        let delta = to_cycle.saturating_sub(self.cycle);
        if delta == 0 {
            return;
        }
        // Idle-cycle accounting plus the deterministic busy-period
        // arithmetic each component owns (DESIGN.md §12): the crossbar
        // accounts its cycle counter, the ICAP streams words in closed
        // form, modules advance their compute countdowns.  Everything
        // else is frozen over the skipped stretch — guaranteed by
        // `next_interesting_cycle` below.
        self.xbar.fast_forward(to_cycle);
        self.icap.fast_forward(to_cycle);
        for slot in self.modules.iter_mut() {
            if let Some(m) = slot.as_mut() {
                m.fast_forward(delta);
            }
        }
        self.skipped_cycles += delta;
        self.cycle = to_cycle;
    }

    /// Compose the busy-period horizon over every ticking component.
    ///
    /// The gate: any coupled-datapath activity — crossbar words or
    /// arbitration, words buffered at a draining slave port, pending
    /// register-file sync or ICAP mirroring, a filling bridge, an H2C
    /// backlog awaiting pickup — forces `now + 1` (every cycle
    /// interesting).  Past the gate, the only self-scheduled events left
    /// are pure countdowns, and the fabric's horizon is their minimum:
    /// module compute expiries, the ICAP's completion pop, bridge
    /// passivity.  A component with no self-scheduled event reports
    /// [`HORIZON_NONE`](crate::sim::HORIZON_NONE).
    fn next_interesting_cycle(&self, now: u64) -> u64 {
        if !self.xbar.stable_point()
            || self.regfile.generation() != self.synced_gen
            || self.icap.status != self.mirrored_icap
            || self.icap.done_pending()
            || self.xbar.rx_len(0) > 0
        {
            return now + 1;
        }
        let mut horizon = crate::sim::HORIZON_NONE;
        for p in 1..self.xbar.ports() {
            if let Some(m) = &self.modules[p] {
                if self.xbar.rx_len(p) > 0 && m.absorb_capacity() > 0 {
                    // The module drains its slave buffer next tick.
                    return now + 1;
                }
                horizon = horizon.min(m.next_interesting_cycle(now));
            }
        }
        horizon
            .min(self.icap.next_interesting_cycle(now))
            .min(self.axi2wb.next_interesting_cycle(&self.xdma, now))
    }
}

/// Errors the fabric surfaces per app after a run (regfile view).
pub fn app_error(fabric: &Fabric, app_id: u32) -> Option<WbError> {
    fabric.regfile.app_error(app_id as usize).ok().flatten()
}

#[cfg(test)]
mod tests;
