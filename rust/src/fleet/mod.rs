//! Multi-FPGA fleet scheduler: elastic serving at rack scale.
//!
//! The paper's manager grows and shrinks PR-region allocations on *one*
//! board; FOS and the multi-tenancy line of work (PAPERS.md) show that
//! the interesting elasticity questions appear at fleet scale — many
//! shells, dynamic workloads, placement pressure.  This layer builds on
//! [`crate::cluster`]: a [`Fleet`] owns N independent fabric nodes (one
//! [`crate::manager::ElasticManager`] each), routes incoming requests
//! with an **admission-control policy**, and migrates overflow work —
//! stage chains that would spill onto the server CPU of a constrained
//! board — to any board with enough free PR regions to host the whole
//! chain on fabric.
//!
//! # Virtual time and the event-driven fast-path
//!
//! The fleet runs a trace in *virtual fabric cycles*: each node is busy
//! until its backlog drains, and an arriving request starts at
//! `max(arrival, node.busy_until)`.  Idle gaps between arrivals are
//! never ticked — that is the event-driven discipline of
//! [`crate::sim::Clock::run_scheduled`] applied at fleet granularity.
//!
//! Request *service time* comes from the cycle-accurate oracle: the
//! first time a request shape `(stage chain, payload words, FPGA
//! stages)` is seen, it executes on the node's fabric simulator
//! cycle-by-cycle (and is verified against the golden model).  Fabric
//! timing is data-independent — word values never influence handshakes
//! — so the measured cost is memoized and replayed for every later
//! request of the same shape.  With the fast-path off every request runs
//! on the oracle; `fast_path_equivalence` in this module's tests pins
//! that both modes produce identical schedules.

use std::collections::HashMap;

use crate::cluster::{Cluster, PlacementPolicy};
use crate::config::SystemConfig;
use crate::manager::AppRequest;
use crate::metrics::CycleRecorder;
use crate::modules::ModuleKind;
use crate::runtime::RuntimeHandle;
use crate::timing::CostBreakdown;
use crate::workload::TraceEvent;
use crate::Result;

/// Admission-control policy: which fabric serves an incoming request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// The fabric whose backlog drains earliest (ties: lowest index).
    LeastLoaded,
    /// Pin each application to the fabric that first served it (cache-
    /// and reconfiguration-friendly: the app's modules stay resident).
    StickyByApp,
    /// Admit on spare **bandwidth share**: prefer the fabric whose
    /// bandwidth plane has the largest unclaimed share
    /// ([`crate::manager::ElasticManager::spare_share`], derived from
    /// the register-file budget banks and the plan in force); ties
    /// broken least-loaded.
    BandwidthAware,
}

impl AdmissionPolicy {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "least" | "least-loaded" => Some(AdmissionPolicy::LeastLoaded),
            "sticky" | "sticky-by-app" => Some(AdmissionPolicy::StickyByApp),
            "bandwidth" | "bandwidth-aware" => Some(AdmissionPolicy::BandwidthAware),
            _ => None,
        }
    }
}

/// A request shape: everything that determines its fabric timing.
/// Payload *values* are excluded on purpose — the datapath's handshakes
/// are data-independent, which is what makes the memoization exact.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ShapeKey {
    stages: Vec<ModuleKind>,
    words: usize,
    fpga_stages: usize,
}

/// Convert a timing-model cost into fabric cycles of service time.
/// Reconfiguration is included: the board is occupied while the ICAP
/// programs, exactly as the server's lane clock charges
/// `fabric_cycles + reconfig_cycles` for the same concept.
pub fn service_cycles(cfg: &SystemConfig, cost: &CostBreakdown) -> u64 {
    ((cost.total_ms() + cost.reconfig_ms) * cfg.fabric.clock_mhz * 1000.0)
        .round() as u64
}

/// Scheduling outcome for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestOutcome {
    pub app_id: u32,
    /// Node that served the request.
    pub node: usize,
    /// Arrival, start-of-service, and completion, in fabric cycles.
    pub arrival_cycle: u64,
    pub start_cycle: u64,
    pub completion_cycle: u64,
    /// Modeled service time (PCIe + fabric + CPU suffix).
    pub service_cycles: u64,
    /// Stages hosted on fabric.
    pub fpga_stages: usize,
    /// Was the request moved off its policy-chosen node to a board that
    /// could host the whole chain on fabric?
    pub migrated: bool,
}

/// Aggregate result of a fleet run.
#[derive(Debug)]
pub struct FleetReport {
    /// Per-request outcomes, in arrival order.
    pub outcomes: Vec<RequestOutcome>,
    /// Requests completed (the fleet loses none; this equals the trace
    /// length on success and the asserting tests pin that).
    pub completed: u64,
    /// Virtual cycle at which the last node drained.
    pub makespan_cycles: u64,
    /// Queue-wait distribution (start - arrival).
    pub queue_wait: CycleRecorder,
    /// End-to-end latency distribution (completion - arrival).
    pub latency: CycleRecorder,
    /// Requests served per node.
    pub per_node_served: Vec<u64>,
    /// Requests migrated off their policy-chosen node.
    pub migrated: u64,
    /// Fast-path cache hits vs cycle-accurate oracle executions.
    pub fast_path_hits: u64,
    pub oracle_runs: u64,
}

impl FleetReport {
    /// Completed requests per virtual second.
    pub fn throughput_per_s(&self, cfg: &SystemConfig) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        let secs = cfg.cycles_to_ms(self.makespan_cycles) / 1e3;
        self.completed as f64 / secs
    }
}

/// The fleet scheduler.
pub struct Fleet {
    cluster: Cluster,
    policy: AdmissionPolicy,
    cfg: SystemConfig,
    /// Virtual cycle at which each node's backlog drains.
    busy_until: Vec<u64>,
    /// Sticky app -> node pins.
    pins: HashMap<u32, usize>,
    /// Move overflow chains to a board that fits them fully (on by
    /// default; the CPU-suffix fallback still applies when no board can).
    pub migrate_overflow: bool,
    fast_path: bool,
    shape_cache: HashMap<ShapeKey, u64>,
    migrated: u64,
    fast_path_hits: u64,
    oracle_runs: u64,
}

impl Fleet {
    /// Launch `n` fabric nodes under `policy`.  `fast_path` enables the
    /// shape-memoized event-driven mode *and* busy-period horizon
    /// skipping on every node's fabric drive (DESIGN.md §12), so the
    /// first-of-shape service-cost measurement rides the horizon too;
    /// with it off every request runs on the cycle-by-cycle oracle,
    /// every cycle ticked.
    pub fn launch(
        n: usize,
        cfg: &SystemConfig,
        runtime: Option<RuntimeHandle>,
        policy: AdmissionPolicy,
        fast_path: bool,
    ) -> Self {
        // The cluster's own per-request policy is irrelevant here (the
        // fleet always routes explicitly via execute_on), but
        // MostAvailable is the sane default for direct cluster use.
        let mut cluster =
            Cluster::launch(n, cfg, runtime, PlacementPolicy::MostAvailable);
        for i in 0..n {
            cluster.node_mut(i).manager_mut().fast_path = fast_path;
        }
        Self {
            busy_until: vec![0; n],
            pins: HashMap::new(),
            migrate_overflow: true,
            fast_path,
            shape_cache: HashMap::new(),
            migrated: 0,
            fast_path_hits: 0,
            oracle_runs: 0,
            cluster,
            policy,
            cfg: cfg.clone(),
        }
    }

    /// The underlying cluster (read-only).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Mutable cluster access (churn injection in tests/examples).
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    /// Fence `count` PR regions on `node` offline (churn injection).
    pub fn fence_node(&mut self, node: usize, count: usize) -> usize {
        self.cluster.node_mut(node).manager_mut().fence_regions(count)
    }

    /// The admission policy in force.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Pick the node for `req` (arriving at `arrival`, in fabric
    /// cycles) under the admission policy, then apply overflow
    /// migration.  Returns `(node, migrated)`.
    fn select_node(&mut self, req: &AppRequest, arrival: u64) -> (usize, bool) {
        let base = match self.policy {
            AdmissionPolicy::LeastLoaded => self.least_loaded(),
            AdmissionPolicy::StickyByApp => {
                if let Some(&pinned) = self.pins.get(&req.app_id) {
                    pinned
                } else {
                    let chosen = self.least_loaded();
                    self.pins.insert(req.app_id, chosen);
                    chosen
                }
            }
            AdmissionPolicy::BandwidthAware => self.most_spare_bandwidth(),
        };
        if !self.migrate_overflow {
            return (base, false);
        }
        let need = req.stages.len();
        if self.cluster.nodes()[base].available_regions() >= need {
            return (base, false);
        }
        // Overflow: the policy-chosen board would run part of the chain
        // on the server CPU.  Migrate to the board that can start this
        // request earliest among those hosting the whole chain on
        // fabric — but only if waiting for it is cheaper than the CPU
        // suffix the base board would pay.  Start times are relative to
        // the request's arrival, so a board idle at arrival costs zero
        // wait regardless of when its last backlog drained.
        let overflow_stages =
            need - self.cluster.nodes()[base].available_regions();
        let cpu_suffix_cycles = (overflow_stages as f64
            * self.cfg.timing.cpu_stage_ms
            * self.cfg.fabric.clock_mhz
            * 1000.0) as u64;
        let start = |i: usize| self.busy_until[i].max(arrival);
        let candidate = (0..self.cluster.node_count())
            .filter(|&i| self.cluster.nodes()[i].available_regions() >= need)
            .min_by_key(|&i| (start(i), i));
        match candidate {
            Some(i)
                if start(i) <= start(base).saturating_add(cpu_suffix_cycles) =>
            {
                (i, true)
            }
            _ => (base, false),
        }
    }

    fn least_loaded(&self) -> usize {
        (0..self.busy_until.len())
            .min_by_key(|&i| (self.busy_until[i], i))
            .expect("fleet has nodes")
    }

    fn most_spare_bandwidth(&self) -> usize {
        // Maximize the unclaimed bandwidth share (register-file view of
        // the plan in force); ties go to the least-loaded node.
        (0..self.cluster.node_count())
            .min_by_key(|&i| {
                let spare = self.cluster.nodes()[i].manager().spare_share();
                (std::cmp::Reverse(spare), self.busy_until[i], i)
            })
            .expect("fleet has nodes")
    }

    /// Execute one request on `node`, returning `(service_cycles,
    /// fpga_stages)`.  Fast-path: memoized by shape after one oracle run.
    fn execute_one(
        &mut self,
        node: usize,
        req: &AppRequest,
    ) -> Result<(u64, usize)> {
        let fpga_stages = req
            .stages
            .len()
            .min(self.cluster.nodes()[node].available_regions());
        let key = ShapeKey {
            stages: req.stages.clone(),
            words: req.data.len(),
            fpga_stages,
        };
        if self.fast_path {
            if let Some(&cycles) = self.shape_cache.get(&key) {
                self.fast_path_hits += 1;
                // Keep the cluster's per-node stats in step with the
                // oracle mode even though the fabric never runs.
                let n = self.cluster.node_mut(node);
                n.served += 1;
                n.fpga_stages_hosted += fpga_stages as u64;
                return Ok((cycles, fpga_stages));
            }
        }
        let report = self.cluster.execute_on(node, req)?;
        self.oracle_runs += 1;
        debug_assert!(report.verified, "oracle run failed golden verification");
        debug_assert_eq!(report.fpga_stages, fpga_stages);
        let cycles = service_cycles(&self.cfg, &report.cost);
        if self.fast_path {
            self.shape_cache.insert(key, cycles);
        }
        Ok((cycles, fpga_stages))
    }

    /// Run an arrival-ordered trace to completion.
    pub fn run_trace(&mut self, trace: &[TraceEvent]) -> Result<FleetReport> {
        let cycles_per_ms = self.cfg.fabric.clock_mhz * 1000.0;
        let mut outcomes = Vec::with_capacity(trace.len());
        let mut queue_wait = CycleRecorder::new();
        let mut latency = CycleRecorder::new();
        let mut per_node_served = vec![0u64; self.cluster.node_count()];
        for ev in trace {
            let arrival = (ev.arrival_ms * cycles_per_ms).round() as u64;
            let (node, migrated) = self.select_node(&ev.request, arrival);
            if migrated {
                self.migrated += 1;
            }
            let start = arrival.max(self.busy_until[node]);
            let (service, fpga_stages) = self.execute_one(node, &ev.request)?;
            let completion = start + service;
            self.busy_until[node] = completion;
            per_node_served[node] += 1;
            queue_wait.record(start - arrival);
            latency.record(completion - arrival);
            outcomes.push(RequestOutcome {
                app_id: ev.request.app_id,
                node,
                arrival_cycle: arrival,
                start_cycle: start,
                completion_cycle: completion,
                service_cycles: service,
                fpga_stages,
                migrated,
            });
        }
        Ok(FleetReport {
            completed: outcomes.len() as u64,
            makespan_cycles: self.busy_until.iter().copied().max().unwrap_or(0),
            outcomes,
            queue_wait,
            latency,
            per_node_served,
            migrated: self.migrated,
            fast_path_hits: self.fast_path_hits,
            oracle_runs: self.oracle_runs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_count, WorkloadSpec};

    fn cfg() -> SystemConfig {
        SystemConfig::paper_defaults()
    }

    fn small_trace(n: usize, seed: u64) -> Vec<TraceEvent> {
        generate_count(&WorkloadSpec::fleet_mix(), seed, n)
    }

    #[test]
    fn fast_path_equivalence_with_oracle() {
        // Same trace, same policy: the shape-memoized fast-path must
        // produce the identical schedule the all-oracle run produces.
        let trace = small_trace(120, 7);
        for policy in [
            AdmissionPolicy::LeastLoaded,
            AdmissionPolicy::StickyByApp,
            AdmissionPolicy::BandwidthAware,
        ] {
            let mut oracle = Fleet::launch(3, &cfg(), None, policy, false);
            let mut fast = Fleet::launch(3, &cfg(), None, policy, true);
            oracle.fence_node(0, 2);
            fast.fence_node(0, 2);
            let a = oracle.run_trace(&trace).unwrap();
            let b = fast.run_trace(&trace).unwrap();
            assert_eq!(a.outcomes, b.outcomes, "policy {policy:?}");
            assert_eq!(a.makespan_cycles, b.makespan_cycles);
            assert!(b.fast_path_hits > 0, "cache never hit");
            assert!(
                b.oracle_runs < a.oracle_runs,
                "fast path did not reduce oracle executions"
            );
        }
    }

    #[test]
    fn completes_every_request() {
        let trace = small_trace(200, 9);
        let mut fleet =
            Fleet::launch(4, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        assert_eq!(report.completed, 200);
        assert_eq!(report.outcomes.len(), 200);
        assert_eq!(report.per_node_served.iter().sum::<u64>(), 200);
        // Causality on every outcome.
        for o in &report.outcomes {
            assert!(o.start_cycle >= o.arrival_cycle);
            assert_eq!(o.completion_cycle, o.start_cycle + o.service_cycles);
        }
    }

    #[test]
    fn least_loaded_uses_all_nodes() {
        let trace = small_trace(100, 3);
        let mut fleet =
            Fleet::launch(4, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(
            report.per_node_served.iter().all(|&s| s > 0),
            "idle node under least-loaded: {:?}",
            report.per_node_served
        );
    }

    #[test]
    fn sticky_policy_pins_apps_to_one_node() {
        let trace = small_trace(150, 5);
        let mut fleet =
            Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        fleet.migrate_overflow = false; // pure pinning
        let report = fleet.run_trace(&trace).unwrap();
        let mut seen: HashMap<u32, usize> = HashMap::new();
        for o in &report.outcomes {
            let node = *seen.entry(o.app_id).or_insert(o.node);
            assert_eq!(o.node, node, "app {} moved nodes", o.app_id);
        }
    }

    #[test]
    fn overflow_migrates_to_a_board_with_free_regions() {
        // Node 0 keeps 1 region; 3-stage chains pinned there by the
        // sticky policy must migrate to a full-capacity board.
        let trace = small_trace(80, 13);
        let mut fleet =
            Fleet::launch(2, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        fleet.fence_node(0, 2);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(report.migrated > 0, "no migrations despite fenced node");
        // Migration exists to keep whole chains on fabric: a migrated
        // request hosts its entire stage chain, and never on the board
        // that could not fit it.
        for (o, ev) in report.outcomes.iter().zip(&trace) {
            if o.migrated {
                assert_eq!(o.fpga_stages, ev.request.stages.len());
                assert_ne!(o.node, 0);
            }
        }
    }

    #[test]
    fn burst_arrivals_have_monotone_queue_waits_per_node() {
        // All requests arrive at once: each node's backlog serializes
        // them, so queue waits are non-decreasing per node.
        let mut trace = small_trace(60, 17);
        for ev in trace.iter_mut() {
            ev.arrival_ms = 0.0;
        }
        let mut fleet =
            Fleet::launch(2, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        let mut last = vec![0u64; 2];
        for o in &report.outcomes {
            let wait = o.start_cycle - o.arrival_cycle;
            assert!(wait >= last[o.node], "queue wait regressed on {}", o.node);
            last[o.node] = wait;
        }
    }

    #[test]
    fn bandwidth_aware_avoids_fenced_boards() {
        // Fencing regions shrinks a board's spare bandwidth in the
        // register-file view; the policy must shift load away from it.
        let trace = small_trace(90, 23);
        let mut fleet = Fleet::launch(
            3,
            &cfg(),
            None,
            AdmissionPolicy::BandwidthAware,
            true,
        );
        fleet.fence_node(0, 2);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(
            report.per_node_served[0] < report.per_node_served[1]
                && report.per_node_served[0] < report.per_node_served[2],
            "fenced board got the most load: {:?}",
            report.per_node_served
        );
    }
}
