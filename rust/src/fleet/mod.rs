//! Multi-FPGA fleet scheduler: elastic serving at rack scale.
//!
//! The paper's manager grows and shrinks PR-region allocations on *one*
//! board; FOS and the multi-tenancy line of work (PAPERS.md) show that
//! the interesting elasticity questions appear at fleet scale — many
//! shells, dynamic workloads, placement pressure.  This layer builds on
//! [`crate::cluster`]: a [`Fleet`] owns N independent fabric nodes (one
//! [`crate::manager::ElasticManager`] each), routes incoming requests
//! with an **admission-control policy**, and migrates overflow work —
//! stage chains that would spill onto the server CPU of a constrained
//! board — to any board with enough free PR regions to host the whole
//! chain on fabric.  Stage chains are [`ModuleKind`]s from the pluggable
//! kernel registry ([`crate::kernels`], DESIGN.md §17): shape keys,
//! resident-module affinity and the config cache treat a
//! `[kernels]`-declared kernel exactly like a seed one.
//!
//! # Virtual time and the event-driven fast-path
//!
//! The fleet runs a trace in *virtual fabric cycles*: each node is busy
//! until its backlog drains, and an arriving request starts at
//! `max(arrival, node.busy_until)`.  Idle gaps between arrivals are
//! never ticked — that is the event-driven discipline of
//! [`crate::sim::Clock::run_scheduled`] applied at fleet granularity.
//!
//! Request *service time* comes from the cycle-accurate oracle: the
//! first time a request shape `(stage chain, payload words, FPGA
//! stages)` is seen, it executes on the node's fabric simulator
//! cycle-by-cycle (and is verified against the golden model).  Fabric
//! timing is data-independent — word values never influence handshakes
//! — so the measured cost is memoized and replayed for every later
//! request of the same shape.  With the fast-path off every request runs
//! on the oracle; `fast_path_equivalence` in this module's tests pins
//! that both modes produce identical schedules.
//!
//! # Sharded execution (DESIGN.md §13)
//!
//! With `execution_threads > 1` the trace still *admits* sequentially —
//! `select_node`, the pins, `busy_until` and all counters evolve in
//! arrival order exactly as in the serial path — but the expensive part,
//! the cycle-accurate cost measurements, fans out across the boards on
//! scoped threads.  Each board's fabric is driven by at most one thread
//! at a time, and because service cost is a pure function of the request
//! shape, the merged cost cache (folded back in a deterministic order at
//! each quiesce point) reproduces the serial schedule byte for byte.

use std::collections::{HashMap, HashSet};

use crate::cluster::{BoardNode, Cluster, PlacementPolicy};
use crate::config::SystemConfig;
use crate::manager::AppRequest;
use crate::metrics::{CycleRecorder, CycleThroughput};
use crate::modules::ModuleKind;
use crate::runtime::RuntimeHandle;
use crate::telemetry::{MetricsRegistry, RequestSpan, TraceEvent as TelemetryEvent, Tracer};
use crate::timing::CostBreakdown;
use crate::workload::TraceEvent;
use crate::{ElasticError, Result};

/// Admission-control policy: which fabric serves an incoming request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// The fabric whose backlog drains earliest (ties: lowest index).
    LeastLoaded,
    /// Pin each application to the fabric that first served it (cache-
    /// and reconfiguration-friendly: the app's modules stay resident).
    StickyByApp,
    /// Admit on spare **bandwidth share**: prefer the fabric whose
    /// bandwidth plane has the largest unclaimed share
    /// ([`crate::manager::ElasticManager::spare_share`], derived from
    /// the register-file budget banks and the plan in force); ties
    /// broken least-loaded.
    BandwidthAware,
    /// Weighted admission over plan headroom (DESIGN.md §15): each
    /// board's backlog at arrival is scaled by the inverse of its spare
    /// bandwidth share, so a board whose plan is nearly fully promised
    /// must be proportionally *more* idle than an uncontracted one to
    /// win the request; ties broken least-loaded.
    PlanWeighted,
}

impl AdmissionPolicy {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "least" | "least-loaded" => Some(AdmissionPolicy::LeastLoaded),
            "sticky" | "sticky-by-app" => Some(AdmissionPolicy::StickyByApp),
            "bandwidth" | "bandwidth-aware" => Some(AdmissionPolicy::BandwidthAware),
            "weighted" | "plan-weighted" => Some(AdmissionPolicy::PlanWeighted),
            _ => None,
        }
    }
}

/// A request shape: everything that determines its fabric timing.
/// Payload *values* are excluded on purpose — the datapath's handshakes
/// are data-independent, which is what makes the memoization exact.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ShapeKey {
    stages: Vec<ModuleKind>,
    words: usize,
    fpga_stages: usize,
}

/// Convert a timing-model cost into fabric cycles of service time.
/// Reconfiguration is included: the board is occupied while the ICAP
/// programs, exactly as the server's lane clock charges
/// `fabric_cycles + reconfig_cycles` for the same concept.
pub fn service_cycles(cfg: &SystemConfig, cost: &CostBreakdown) -> u64 {
    ((cost.total_ms() + cost.reconfig_ms) * cfg.fabric.clock_mhz * 1000.0)
        .round() as u64
}

/// Apply the configuration-cache elision to a batch leader's cost
/// (DESIGN.md §16): `hits` of its `fpga_stages` regions rebind without
/// ICAP traffic, and per-stage reconfiguration is uniform (all
/// bitstreams are the same size), so the cost keeps exactly
/// `(fpga_stages - hits) / fpga_stages` of its reconfiguration term.
/// Returns the elided ICAP cycles (the service delta).  With zero hits
/// the cost is untouched — not even a float operation — which is what
/// keeps the cache-off schedule byte-identical.  Every caller (serial
/// commit, sharded commit, oracle replay) performs this exact float
/// sequence, so all paths agree bit for bit.
fn elide_reconfig(
    cfg: &SystemConfig,
    cost: &mut CostBreakdown,
    hits: usize,
    fpga_stages: usize,
) -> u64 {
    if hits == 0 || fpga_stages == 0 {
        return 0;
    }
    let cold = service_cycles(cfg, cost);
    cost.reconfig_ms =
        cost.reconfig_ms * ((fpga_stages - hits) as f64) / (fpga_stages as f64);
    cold - service_cycles(cfg, cost)
}

/// Scheduling outcome for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestOutcome {
    pub app_id: u32,
    /// Node that served the request.
    pub node: usize,
    /// Arrival, start-of-service, and completion, in fabric cycles.
    pub arrival_cycle: u64,
    pub start_cycle: u64,
    pub completion_cycle: u64,
    /// Modeled service time (PCIe + fabric + CPU suffix).
    pub service_cycles: u64,
    /// Stages hosted on fabric.
    pub fpga_stages: usize,
    /// Was the request moved off its policy-chosen node to a board that
    /// could host the whole chain on fabric?
    pub migrated: bool,
    /// Did the request ride another request's fabric stream as a batch
    /// follower (DESIGN.md §15)?  Followers skip the reconfiguration
    /// round — the leader already programmed the chain — so their
    /// service excludes `reconfig_ms`; everything else about the
    /// outcome is demuxed per request exactly as when unbatched.
    pub coalesced: bool,
    /// FPGA stages this request rebound from the node's configuration
    /// cache (DESIGN.md §16) — their ICAP restream was elided from the
    /// service cost.  Always 0 with the cache off and for batch
    /// followers (the leader's claims cover the whole stream).
    pub cache_hits: usize,
    /// Cycle-exact latency decomposition (DESIGN.md §14):
    /// `span.total_cycles() == service_cycles` and
    /// `span.end_to_end_cycles() == completion_cycle - arrival_cycle`,
    /// exactly, for every outcome.
    pub span: RequestSpan,
}

/// Aggregate result of a fleet run.
#[derive(Debug)]
pub struct FleetReport {
    /// Per-request outcomes, in arrival order.
    pub outcomes: Vec<RequestOutcome>,
    /// Requests completed (the fleet loses none; this equals the trace
    /// length on success and the asserting tests pin that).
    pub completed: u64,
    /// Virtual cycle at which the last node drained.
    pub makespan_cycles: u64,
    /// Queue-wait distribution (start - arrival).
    pub queue_wait: CycleRecorder,
    /// End-to-end latency distribution (completion - arrival).
    pub latency: CycleRecorder,
    /// Requests served per node.
    pub per_node_served: Vec<u64>,
    /// Requests migrated off their policy-chosen node.
    pub migrated: u64,
    /// Fast-path cache hits vs cycle-accurate oracle executions.
    pub fast_path_hits: u64,
    pub oracle_runs: u64,
    /// Same-app coalescing (DESIGN.md §15): batches of size ≥ 2 formed,
    /// and the number of follower requests that rode a leader's stream.
    pub batches_formed: u64,
    pub batched_requests: u64,
    /// Configuration cache (DESIGN.md §16): FPGA stages rebound from a
    /// node's resident set vs. programmed cold, and the total ICAP
    /// cycles those rebinds elided from service.  All zero with
    /// `config_cache_regions = 0`.
    pub config_cache_hits: u64,
    pub config_cache_misses: u64,
    pub icap_cycles_elided: u64,
    /// The trace's telemetry event stream (empty unless the fleet's
    /// [`Fleet::tracer`] is [`Tracer::Full`]).  Emitted only at the
    /// sequential admission/commit points, so it is byte-identical at
    /// every `execution_threads` count (`tests/fleet_threads.rs`).
    pub events: Vec<TelemetryEvent>,
}

impl FleetReport {
    /// Completed requests per virtual second.
    pub fn throughput_per_s(&self, cfg: &SystemConfig) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        let secs = cfg.cycles_to_ms(self.makespan_cycles) / 1e3;
        self.completed as f64 / secs
    }

    /// Build a per-app / per-node metrics registry from this report.
    /// Everything is derived from virtual-clock quantities, so the
    /// snapshot is deterministic across runs, hosts and thread counts.
    pub fn metrics(&self, cfg: &SystemConfig) -> MetricsRegistry {
        let mut reg = MetricsRegistry::new();
        reg.inc("fleet_requests_total", &[], self.completed);
        reg.inc("fleet_migrated_total", &[], self.migrated);
        reg.inc("fleet_fast_path_hits_total", &[], self.fast_path_hits);
        reg.inc("fleet_oracle_runs_total", &[], self.oracle_runs);
        reg.inc("fleet_batches_total", &[], self.batches_formed);
        reg.inc("fleet_batched_requests_total", &[], self.batched_requests);
        reg.inc("config_cache_hits", &[], self.config_cache_hits);
        reg.inc("config_cache_misses", &[], self.config_cache_misses);
        reg.inc("icap_cycles_elided", &[], self.icap_cycles_elided);
        reg.set_gauge("fleet_makespan_cycles", &[], self.makespan_cycles as f64);
        reg.set_gauge(
            "fleet_requests_per_vs",
            &[],
            self.throughput_per_s(cfg),
        );
        let mut tp = CycleThroughput::new();
        tp.record_items(self.completed, 0);
        tp.set_cycles(self.makespan_cycles);
        reg.set_gauge("fleet_requests_per_mcycle", &[], tp.items_per_mcycle());
        for (i, &served) in self.per_node_served.iter().enumerate() {
            let node = i.to_string();
            reg.inc("node_requests_total", &[("node", &node)], served);
        }
        for o in &self.outcomes {
            let app = o.app_id.to_string();
            let labels = [("app", app.as_str())];
            reg.inc("app_requests_total", &labels, 1);
            if o.migrated {
                reg.inc("app_migrated_total", &labels, 1);
            }
            reg.observe("app_service_cycles", &labels, o.service_cycles);
            reg.observe("app_queue_wait_cycles", &labels, o.span.queue_wait_cycles);
            reg.observe("app_bridge_cycles", &labels, o.span.bridge_cycles);
            reg.observe("app_icap_cycles", &labels, o.span.icap_cycles);
            reg.observe("app_fabric_cycles", &labels, o.span.fabric_cycles);
            reg.observe("app_cpu_cycles", &labels, o.span.cpu_cycles);
        }
        reg
    }
}

/// The fleet scheduler.
pub struct Fleet {
    cluster: Cluster,
    policy: AdmissionPolicy,
    cfg: SystemConfig,
    /// Virtual cycle at which each node's backlog drains.
    busy_until: Vec<u64>,
    /// Sticky app -> node pins.
    pins: HashMap<u32, usize>,
    /// Move overflow chains to a board that fits them fully (on by
    /// default; the CPU-suffix fallback still applies when no board can).
    pub migrate_overflow: bool,
    /// Fan oracle cost measurements out across up to this many scoped
    /// worker threads (`1`, the default, keeps the fully serial path).
    /// Admission stays sequential either way, so reports are
    /// byte-identical across thread counts (`tests/fleet_threads.rs`).
    pub execution_threads: usize,
    /// Telemetry sink (DESIGN.md §14).  Off by default; set to
    /// [`Tracer::full`] to collect the per-trace event stream surfaced
    /// in [`FleetReport::events`].  Events are emitted only at the
    /// sequential admission/commit points, never from worker threads,
    /// so the stream is byte-identical at every thread count.
    pub tracer: Tracer,
    /// Same-app coalescing window (DESIGN.md §15): the maximum number
    /// of requests one fabric stream carries.  `1` (the default)
    /// disables look-ahead entirely — the executors are byte-identical
    /// to the pre-batching scheduler.  A follower joins the leader's
    /// batch only if it is the *next* trace event, targets the same app
    /// and stage chain, and has already arrived by the leader's start
    /// instant, so batching never delays any request.
    pub batch_window: usize,
    /// Optional extra bound on the window: a follower must arrive
    /// within this many cycles of the leader's arrival (`0`, the
    /// default, bounds followers only by the leader's start instant).
    pub batch_cycles: u64,
    /// Fleet-level configuration-cache capacity (DESIGN.md §16): the
    /// maximum module configurations each node keeps resident after a
    /// request releases its regions, so the next leader needing the
    /// same [`ModuleKind`]s elides their ICAP restream.  `0` (the
    /// default) is off — every schedule is byte-identical to the
    /// pre-cache fleet.  The cache is modeled *virtually* at the
    /// sequential admission/commit points, exactly like the batch
    /// window's follower elision, so schedules stay byte-identical at
    /// every `execution_threads` count; the node managers themselves
    /// always run cache-off (forced in [`Fleet::launch`]) to keep the
    /// oracle and the sharded speculative harvest shape-pure.
    pub config_cache_regions: usize,
    /// Per-node virtual resident set: `(kind, lru_stamp)` entries,
    /// stamped from [`Self::cache_clock`] at commit points only.
    node_residents: Vec<Vec<(ModuleKind, u64)>>,
    /// Monotone virtual LRU clock for the fleet cache.
    cache_clock: u64,
    config_cache_hits: u64,
    config_cache_misses: u64,
    icap_cycles_elided: u64,
    fast_path: bool,
    shape_cache: HashMap<ShapeKey, CostBreakdown>,
    migrated: u64,
    fast_path_hits: u64,
    oracle_runs: u64,
    batches_formed: u64,
    batched_requests: u64,
}

impl Fleet {
    /// Launch `n` fabric nodes under `policy`.  `fast_path` enables the
    /// shape-memoized event-driven mode *and* busy-period horizon
    /// skipping on every node's fabric drive (DESIGN.md §12), so the
    /// first-of-shape service-cost measurement rides the horizon too;
    /// with it off every request runs on the cycle-by-cycle oracle,
    /// every cycle ticked.
    pub fn launch(
        n: usize,
        cfg: &SystemConfig,
        runtime: Option<RuntimeHandle>,
        policy: AdmissionPolicy,
        fast_path: bool,
    ) -> Self {
        // The cluster's own per-request policy is irrelevant here (the
        // fleet always routes explicitly via execute_on), but
        // MostAvailable is the sane default for direct cluster use.
        // Node managers always run with *their* configuration cache off
        // (the fleet models the cache virtually at commit points):
        // oracle runs and the sharded speculative harvest must stay
        // pure functions of the request shape, which resident state on
        // a shared fabric would break.
        let mut node_cfg = cfg.clone();
        node_cfg.manager.config_cache_regions = 0;
        let mut cluster =
            Cluster::launch(n, &node_cfg, runtime, PlacementPolicy::MostAvailable);
        for i in 0..n {
            cluster.node_mut(i).manager_mut().fast_path = fast_path;
        }
        Self {
            busy_until: vec![0; n],
            pins: HashMap::new(),
            migrate_overflow: true,
            execution_threads: 1,
            tracer: Tracer::Off,
            batch_window: 1,
            batch_cycles: 0,
            config_cache_regions: cfg.manager.config_cache_regions,
            node_residents: (0..n).map(|_| Vec::new()).collect(),
            cache_clock: 0,
            config_cache_hits: 0,
            config_cache_misses: 0,
            icap_cycles_elided: 0,
            fast_path,
            shape_cache: HashMap::new(),
            migrated: 0,
            fast_path_hits: 0,
            oracle_runs: 0,
            batches_formed: 0,
            batched_requests: 0,
            cluster,
            policy,
            cfg: cfg.clone(),
        }
    }

    /// Flip the timed-ICAP programming path on every node manager.
    /// Invalidates the shape-memoized cost cache: memoized breakdowns
    /// embed the reconfiguration term, which this switch changes.
    pub fn set_use_icap(&mut self, on: bool) {
        for i in 0..self.cluster.node_count() {
            self.cluster.node_mut(i).manager_mut().use_icap = on;
        }
        self.shape_cache.clear();
    }

    /// The underlying cluster (read-only).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Mutable cluster access (churn injection in tests/examples).
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    /// Fence `count` PR regions on `node` offline (churn injection).
    pub fn fence_node(&mut self, node: usize, count: usize) -> usize {
        self.cluster.node_mut(node).manager_mut().fence_regions(count)
    }

    /// The admission policy in force.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Pick the node for `req` (arriving at `arrival`, in fabric
    /// cycles) under the admission policy, then apply overflow
    /// migration.  Returns `(node, migrated_from)`: `migrated_from` is
    /// the policy-chosen node the request was moved off, if any.
    fn select_node(
        &mut self,
        req: &AppRequest,
        arrival: u64,
    ) -> (usize, Option<usize>) {
        let base = match self.policy {
            AdmissionPolicy::LeastLoaded => self.least_loaded(&req.stages),
            AdmissionPolicy::StickyByApp => {
                if let Some(&pinned) = self.pins.get(&req.app_id) {
                    pinned
                } else {
                    let chosen = self.least_loaded(&req.stages);
                    self.pins.insert(req.app_id, chosen);
                    chosen
                }
            }
            AdmissionPolicy::BandwidthAware => {
                self.most_spare_bandwidth(&req.stages)
            }
            AdmissionPolicy::PlanWeighted => {
                self.plan_weighted(arrival, &req.stages)
            }
        };
        if !self.migrate_overflow {
            return (base, None);
        }
        let need = req.stages.len();
        if self.cluster.nodes()[base].available_regions() >= need {
            return (base, None);
        }
        // Overflow: the policy-chosen board would run part of the chain
        // on the server CPU.  Migrate to the board that can start this
        // request earliest among those hosting the whole chain on
        // fabric — but only if waiting for it is cheaper than the CPU
        // suffix the base board would pay.  Start times are relative to
        // the request's arrival, so a board idle at arrival costs zero
        // wait regardless of when its last backlog drained.
        let overflow_stages =
            need - self.cluster.nodes()[base].available_regions();
        let cpu_suffix_cycles = (overflow_stages as f64
            * self.cfg.timing.cpu_stage_ms
            * self.cfg.fabric.clock_mhz
            * 1000.0) as u64;
        let start = |i: usize| self.busy_until[i].max(arrival);
        let candidate = (0..self.cluster.node_count())
            .filter(|&i| self.cluster.nodes()[i].available_regions() >= need)
            .min_by_key(|&i| {
                (
                    start(i),
                    std::cmp::Reverse(self.affinity_hits(i, &req.stages)),
                    i,
                )
            });
        match candidate {
            Some(i)
                if start(i) <= start(base).saturating_add(cpu_suffix_cycles) =>
            {
                (i, Some(base))
            }
            _ => (base, None),
        }
    }

    /// Configuration-affinity score for admission (DESIGN.md §16): how
    /// many of the chain's stages this node's virtual resident set
    /// covers, matching entries greedily in stage order.  Always 0 with
    /// the cache off, so every policy's ordering is then byte-identical
    /// to the pre-cache fleet.
    fn affinity_hits(&self, node: usize, stages: &[ModuleKind]) -> usize {
        if self.config_cache_regions == 0 {
            return 0;
        }
        let residents = &self.node_residents[node];
        let mut claimed = vec![false; residents.len()];
        let mut hits = 0usize;
        for &kind in stages {
            if let Some(i) = (0..residents.len())
                .find(|&i| !claimed[i] && residents[i].0 == kind)
            {
                claimed[i] = true;
                hits += 1;
            }
        }
        hits
    }

    fn least_loaded(&self, stages: &[ModuleKind]) -> usize {
        // Ties on the drain instant prefer configuration affinity —
        // the node whose resident set covers more of the chain.
        (0..self.busy_until.len())
            .min_by_key(|&i| {
                (
                    self.busy_until[i],
                    std::cmp::Reverse(self.affinity_hits(i, stages)),
                    i,
                )
            })
            .expect("fleet has nodes")
    }

    fn plan_weighted(&self, arrival: u64, stages: &[ModuleKind]) -> usize {
        // Backlog the request would wait behind, inflated by how little
        // of the board's bandwidth plane is still unpromised: a board
        // with spare share `s` (parts-per-SHARE_UNIT) weighs its
        // backlog by `SHARE_UNIT / max(s, 1)`.  Integer arithmetic in
        // u128 keeps the score exact and overflow-free.
        (0..self.cluster.node_count())
            .min_by_key(|&i| {
                let backlog =
                    self.busy_until[i].saturating_sub(arrival) as u128;
                let spare = self.cluster.nodes()[i]
                    .manager()
                    .spare_share()
                    .max(1) as u128;
                let score = backlog * crate::qos::SHARE_UNIT as u128 / spare;
                (
                    score,
                    self.busy_until[i],
                    std::cmp::Reverse(self.affinity_hits(i, stages)),
                    i,
                )
            })
            .expect("fleet has nodes")
    }

    fn most_spare_bandwidth(&self, stages: &[ModuleKind]) -> usize {
        // Maximize the unclaimed bandwidth share (register-file view of
        // the plan in force); ties go to the least-loaded node, then to
        // configuration affinity.
        (0..self.cluster.node_count())
            .min_by_key(|&i| {
                let spare = self.cluster.nodes()[i].manager().spare_share();
                (
                    std::cmp::Reverse(spare),
                    self.busy_until[i],
                    std::cmp::Reverse(self.affinity_hits(i, stages)),
                    i,
                )
            })
            .expect("fleet has nodes")
    }

    /// Advance one node's virtual configuration cache at a batch
    /// leader's commit point and return how many of its FPGA stages hit
    /// (DESIGN.md §16).  Runs only at the sequential commit points — in
    /// arrival order in both executors — so cache evolution, and every
    /// schedule derived from it, is byte-identical at every thread
    /// count.  A hit claims one unclaimed resident entry of the stage's
    /// kind and refreshes its LRU stamp; a miss inserts a fresh entry
    /// (the cold restream leaves the configuration resident).  The set
    /// is then LRU-trimmed to `min(config_cache_regions, free regions)`
    /// with a [`TelemetryEvent::CacheEvict`] per eviction.
    fn cache_commit(
        &mut self,
        node: usize,
        stages: &[ModuleKind],
        fpga_stages: usize,
        cycle: u64,
    ) -> usize {
        if self.config_cache_regions == 0 || fpga_stages == 0 {
            return 0;
        }
        let cap = self
            .config_cache_regions
            .min(self.cluster.nodes()[node].available_regions());
        let mut hits = 0usize;
        {
            let residents = &mut self.node_residents[node];
            let mut claimed = vec![false; residents.len()];
            for &kind in stages.iter().take(fpga_stages) {
                self.cache_clock += 1;
                match (0..residents.len())
                    .find(|&i| !claimed[i] && residents[i].0 == kind)
                {
                    Some(i) => {
                        claimed[i] = true;
                        residents[i].1 = self.cache_clock;
                        hits += 1;
                    }
                    None => {
                        // Cold stage: after this commit its bitstream is
                        // resident too.  The fresh entry is claimed — two
                        // cold stages of one kind occupy two regions.
                        residents.push((kind, self.cache_clock));
                        claimed.push(true);
                    }
                }
            }
        }
        self.config_cache_hits += hits as u64;
        self.config_cache_misses += (fpga_stages - hits) as u64;
        while self.node_residents[node].len() > cap {
            let oldest = (0..self.node_residents[node].len())
                .min_by_key(|&i| (self.node_residents[node][i].1, i))
                .expect("nonempty resident set");
            let (kind, _) = self.node_residents[node].remove(oldest);
            if self.tracer.enabled() {
                self.tracer.emit(TelemetryEvent::CacheEvict {
                    cycle,
                    node,
                    region: oldest,
                    kind: kind.name(),
                });
            }
        }
        hits
    }

    /// Execute one request on `node`, returning its cost breakdown and
    /// `fpga_stages`.  Fast-path: memoized by shape after one oracle
    /// run.  The breakdown (not just its cycle total) is cached so
    /// committed outcomes carry an exact [`RequestSpan`] in both modes.
    fn execute_one(
        &mut self,
        node: usize,
        req: &AppRequest,
    ) -> Result<(CostBreakdown, usize)> {
        let fpga_stages = req
            .stages
            .len()
            .min(self.cluster.nodes()[node].available_regions());
        let key = ShapeKey {
            stages: req.stages.clone(),
            words: req.data.len(),
            fpga_stages,
        };
        if self.fast_path {
            if let Some(&cost) = self.shape_cache.get(&key) {
                self.fast_path_hits += 1;
                // Keep the cluster's per-node stats in step with the
                // oracle mode even though the fabric never runs.
                let n = self.cluster.node_mut(node);
                n.served += 1;
                n.fpga_stages_hosted += fpga_stages as u64;
                return Ok((cost, fpga_stages));
            }
        }
        let report = self.cluster.execute_on(node, req)?;
        self.oracle_runs += 1;
        debug_assert!(report.verified, "oracle run failed golden verification");
        debug_assert_eq!(report.fpga_stages, fpga_stages);
        if self.fast_path {
            self.shape_cache.insert(key, report.cost);
        }
        Ok((report.cost, fpga_stages))
    }

    /// Run an arrival-ordered trace to completion.
    ///
    /// The report's `migrated` / `fast_path_hits` / `oracle_runs` are
    /// **per-trace deltas**, consistent with the per-trace `outcomes` /
    /// `per_node_served` (the cumulative fleet totals used to leak into
    /// every report, so a second `run_trace` on the same fleet claimed
    /// the first trace's counts too).
    pub fn run_trace(&mut self, trace: &[TraceEvent]) -> Result<FleetReport> {
        let at_entry = (
            self.migrated,
            self.fast_path_hits,
            self.oracle_runs,
            self.batches_formed,
            self.batched_requests,
            self.config_cache_hits,
            self.config_cache_misses,
            self.icap_cycles_elided,
        );
        let mut report = if self.execution_threads > 1 {
            self.run_trace_sharded(trace)?
        } else {
            self.run_trace_serial(trace)?
        };
        report.migrated = self.migrated - at_entry.0;
        report.fast_path_hits = self.fast_path_hits - at_entry.1;
        report.oracle_runs = self.oracle_runs - at_entry.2;
        report.batches_formed = self.batches_formed - at_entry.3;
        report.batched_requests = self.batched_requests - at_entry.4;
        report.config_cache_hits = self.config_cache_hits - at_entry.5;
        report.config_cache_misses = self.config_cache_misses - at_entry.6;
        report.icap_cycles_elided = self.icap_cycles_elided - at_entry.7;
        // Per-trace event stream, like the counters above.
        report.events = self.tracer.take_events();
        Ok(report)
    }

    /// Emit the lifecycle events for one committed outcome.  Called
    /// only from the sequential admission/commit points, in arrival
    /// order — never from worker threads — so the serial and sharded
    /// executors produce identical streams.
    fn emit_request_events(
        &mut self,
        o: &RequestOutcome,
        migrated_from: Option<usize>,
    ) {
        if !self.tracer.enabled() {
            return;
        }
        let (app, node) = (o.app_id, o.node);
        self.tracer.emit(TelemetryEvent::RequestAdmitted {
            cycle: o.arrival_cycle,
            app,
            node,
        });
        if let Some(from) = migrated_from {
            self.tracer.emit(TelemetryEvent::Migration {
                cycle: o.arrival_cycle,
                app,
                from,
                to: node,
            });
        }
        if o.start_cycle > o.arrival_cycle {
            self.tracer.emit(TelemetryEvent::RequestQueued {
                cycle: o.arrival_cycle,
                app,
                node,
                wait_cycles: o.start_cycle - o.arrival_cycle,
            });
        }
        self.tracer.emit(TelemetryEvent::RequestDispatched {
            cycle: o.start_cycle,
            app,
            node,
        });
        self.tracer.emit(TelemetryEvent::RequestCompleted {
            cycle: o.completion_cycle,
            app,
            node,
            service_cycles: o.service_cycles,
        });
    }

    /// How many consecutive trace events starting at `cursor` ride one
    /// fabric stream under the batch-window contract (DESIGN.md §15):
    /// the leader plus every immediately-following request of the same
    /// app and stage chain that has already arrived by the leader's
    /// start instant (and, with `batch_cycles > 0`, within that many
    /// cycles of the leader's arrival).  Always ≥ 1; exactly 1 when
    /// `batch_window` is 1, so the legacy schedule is reproduced
    /// byte for byte.
    fn batch_len(
        &self,
        trace: &[TraceEvent],
        cursor: usize,
        leader_arrival: u64,
        leader_start: u64,
    ) -> usize {
        let cycles_per_ms = self.cfg.fabric.clock_mhz * 1000.0;
        let leader = &trace[cursor].request;
        let mut len = 1;
        while len < self.batch_window.max(1) && cursor + len < trace.len() {
            let ev = &trace[cursor + len];
            let arrival = (ev.arrival_ms * cycles_per_ms).round() as u64;
            let eligible = ev.request.app_id == leader.app_id
                && ev.request.stages == leader.stages
                && arrival <= leader_start
                && (self.batch_cycles == 0
                    || arrival.saturating_sub(leader_arrival)
                        <= self.batch_cycles);
            if !eligible {
                break;
            }
            len += 1;
        }
        len
    }

    /// The single-threaded executor: admit and measure in one pass.
    fn run_trace_serial(&mut self, trace: &[TraceEvent]) -> Result<FleetReport> {
        let cycles_per_ms = self.cfg.fabric.clock_mhz * 1000.0;
        let mut outcomes = Vec::with_capacity(trace.len());
        let mut queue_wait = CycleRecorder::new();
        let mut latency = CycleRecorder::new();
        let mut per_node_served = vec![0u64; self.cluster.node_count()];
        let mut cursor = 0usize;
        while cursor < trace.len() {
            let ev = &trace[cursor];
            let arrival = (ev.arrival_ms * cycles_per_ms).round() as u64;
            let (node, migrated_from) = self.select_node(&ev.request, arrival);
            let migrated = migrated_from.is_some();
            if migrated {
                self.migrated += 1;
            }
            let start = arrival.max(self.busy_until[node]);
            let size = self.batch_len(trace, cursor, arrival, start);
            if size >= 2 {
                self.batches_formed += 1;
                self.batched_requests += (size - 1) as u64;
                if self.tracer.enabled() {
                    self.tracer.emit(TelemetryEvent::BatchFormed {
                        cycle: start,
                        app: ev.request.app_id,
                        node,
                        size,
                    });
                }
            }
            // Batch members run back-to-back on the leader's stream;
            // followers skip reconfiguration (the leader programmed the
            // chain) and are demuxed into per-request outcomes.
            let mut member_start = start;
            for m in 0..size {
                let ev_m = &trace[cursor + m];
                let arrival_m = (ev_m.arrival_ms * cycles_per_ms).round() as u64;
                let (mut cost, fpga_stages) =
                    self.execute_one(node, &ev_m.request)?;
                let mut cache_hits = 0usize;
                if m > 0 {
                    cost.reconfig_ms = 0.0;
                } else {
                    cache_hits = self.cache_commit(
                        node,
                        &ev_m.request.stages,
                        fpga_stages,
                        start,
                    );
                    let cycles =
                        elide_reconfig(&self.cfg, &mut cost, cache_hits, fpga_stages);
                    if cache_hits > 0 {
                        self.icap_cycles_elided += cycles;
                        if self.tracer.enabled() {
                            self.tracer.emit(TelemetryEvent::IcapElided {
                                cycle: start,
                                app: ev_m.request.app_id,
                                node,
                                region: 0,
                                cycles,
                            });
                        }
                    }
                }
                let service = service_cycles(&self.cfg, &cost);
                let span = RequestSpan::decompose(
                    &self.cfg,
                    &cost,
                    member_start - arrival_m,
                );
                let completion = member_start + service;
                self.busy_until[node] = completion;
                per_node_served[node] += 1;
                queue_wait.record(member_start - arrival_m);
                latency.record(completion - arrival_m);
                let outcome = RequestOutcome {
                    app_id: ev_m.request.app_id,
                    node,
                    arrival_cycle: arrival_m,
                    start_cycle: member_start,
                    completion_cycle: completion,
                    service_cycles: service,
                    fpga_stages,
                    migrated: migrated && m == 0,
                    coalesced: m > 0,
                    cache_hits,
                    span,
                };
                self.emit_request_events(
                    &outcome,
                    if m == 0 { migrated_from } else { None },
                );
                outcomes.push(outcome);
                member_start = completion;
            }
            cursor += size;
        }
        Ok(FleetReport {
            completed: outcomes.len() as u64,
            makespan_cycles: self.busy_until.iter().copied().max().unwrap_or(0),
            outcomes,
            queue_wait,
            latency,
            per_node_served,
            migrated: self.migrated,
            fast_path_hits: self.fast_path_hits,
            oracle_runs: self.oracle_runs,
            batches_formed: self.batches_formed,
            batched_requests: self.batched_requests,
            config_cache_hits: self.config_cache_hits,
            config_cache_misses: self.config_cache_misses,
            icap_cycles_elided: self.icap_cycles_elided,
            events: Vec::new(),
        })
    }

    /// The sharded executor (DESIGN.md §13).  Admission runs
    /// sequentially at quiesce points; only the cycle-accurate cost
    /// measurements — the expensive part — fan out across the boards on
    /// scoped threads.  Fabric timing is data-independent, so a
    /// request's service cost is a pure function of its [`ShapeKey`]
    /// (pinned by `fast_path_equivalence_with_oracle`): measuring a
    /// shape on any board of the right free-region count, in any round,
    /// yields exactly the value the serial path measures in place.
    fn run_trace_sharded(&mut self, trace: &[TraceEvent]) -> Result<FleetReport> {
        let threads = self.execution_threads;
        let cycles_per_ms = self.cfg.fabric.clock_mhz * 1000.0;
        let n_nodes = self.cluster.node_count();
        let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(trace.len());
        let mut queue_wait = CycleRecorder::new();
        let mut latency = CycleRecorder::new();
        let mut per_node_served = vec![0u64; n_nodes];
        // Shape -> cost breakdown, local to this run.  Fast-path mode
        // seeds it from the persistent cache; oracle mode starts cold so
        // every shape is re-measured (and every request replayed)
        // cycle-by-cycle.
        let mut costs: HashMap<ShapeKey, CostBreakdown> = if self.fast_path {
            self.shape_cache.clone()
        } else {
            HashMap::new()
        };
        // Speculative measurements that failed, surfaced only if
        // admission actually reaches a request of that shape — the
        // serial path would fail at that exact request, and a shape that
        // never commits must not fail the run.
        let mut failed: HashMap<ShapeKey, ElasticError> = HashMap::new();
        let mut cursor = 0usize;
        loop {
            // Quiesce point: commit every event whose cost is known.
            // select_node runs here, sequentially and in arrival order,
            // so pins, busy_until, node stats and the counters evolve
            // exactly as in the serial path.
            let round_start = cursor;
            'commit: while cursor < trace.len() {
                let ev = &trace[cursor];
                let arrival = (ev.arrival_ms * cycles_per_ms).round() as u64;
                let (node, migrated_from) = self.select_node(&ev.request, arrival);
                let migrated = migrated_from.is_some();
                let fpga_stages = ev
                    .request
                    .stages
                    .len()
                    .min(self.cluster.nodes()[node].available_regions());
                let start = arrival.max(self.busy_until[node]);
                // Batch membership is a pure function of the trace and
                // the leader's start instant, so it matches the serial
                // path exactly; a batch commits only when every
                // member's cost is known, keeping the commit order (and
                // all bookkeeping) identical to serial.
                let size = self.batch_len(trace, cursor, arrival, start);
                let mut member_costs = Vec::with_capacity(size);
                for m in 0..size {
                    let req_m = &trace[cursor + m].request;
                    let key = ShapeKey {
                        stages: req_m.stages.clone(),
                        words: req_m.data.len(),
                        fpga_stages,
                    };
                    match costs.get(&key) {
                        Some(&c) => member_costs.push((key, c)),
                        None => {
                            if let Some(e) = failed.remove(&key) {
                                return Err(e);
                            }
                            // Measure this shape, then resume here.
                            break 'commit;
                        }
                    }
                }
                if migrated {
                    self.migrated += 1;
                }
                if size >= 2 {
                    self.batches_formed += 1;
                    self.batched_requests += (size - 1) as u64;
                    if self.tracer.enabled() {
                        self.tracer.emit(TelemetryEvent::BatchFormed {
                            cycle: start,
                            app: ev.request.app_id,
                            node,
                            size,
                        });
                    }
                }
                let mut member_start = start;
                for (m, (key, raw)) in member_costs.into_iter().enumerate() {
                    if self.fast_path {
                        // Commit-time bookkeeping mirrors the serial
                        // path: the first committed use of a shape is
                        // the oracle run that filled the cache; every
                        // later one is a hit.  Speculative measurements
                        // count for nothing.
                        if self.shape_cache.contains_key(&key) {
                            self.fast_path_hits += 1;
                        } else {
                            self.shape_cache.insert(key, raw);
                            self.oracle_runs += 1;
                        }
                    } else {
                        self.oracle_runs += 1;
                    }
                    let mut cost = raw;
                    let ev_m = &trace[cursor + m];
                    let mut cache_hits = 0usize;
                    if m > 0 {
                        cost.reconfig_ms = 0.0;
                    } else {
                        // Identical commit-point cache evolution and
                        // float sequence as the serial executor — the
                        // byte-identity across thread counts hinges on
                        // this mirroring exactly.
                        cache_hits = self.cache_commit(
                            node,
                            &ev_m.request.stages,
                            fpga_stages,
                            start,
                        );
                        let cycles = elide_reconfig(
                            &self.cfg,
                            &mut cost,
                            cache_hits,
                            fpga_stages,
                        );
                        if cache_hits > 0 {
                            self.icap_cycles_elided += cycles;
                            if self.tracer.enabled() {
                                self.tracer.emit(TelemetryEvent::IcapElided {
                                    cycle: start,
                                    app: ev_m.request.app_id,
                                    node,
                                    region: 0,
                                    cycles,
                                });
                            }
                        }
                    }
                    let arrival_m =
                        (ev_m.arrival_ms * cycles_per_ms).round() as u64;
                    let service = service_cycles(&self.cfg, &cost);
                    let span = RequestSpan::decompose(
                        &self.cfg,
                        &cost,
                        member_start - arrival_m,
                    );
                    let completion = member_start + service;
                    self.busy_until[node] = completion;
                    {
                        let n = self.cluster.node_mut(node);
                        n.served += 1;
                        n.fpga_stages_hosted += fpga_stages as u64;
                    }
                    per_node_served[node] += 1;
                    queue_wait.record(member_start - arrival_m);
                    latency.record(completion - arrival_m);
                    let outcome = RequestOutcome {
                        app_id: ev_m.request.app_id,
                        node,
                        arrival_cycle: arrival_m,
                        start_cycle: member_start,
                        completion_cycle: completion,
                        service_cycles: service,
                        fpga_stages,
                        migrated: migrated && m == 0,
                        coalesced: m > 0,
                        cache_hits,
                        span,
                    };
                    self.emit_request_events(
                        &outcome,
                        if m == 0 { migrated_from } else { None },
                    );
                    outcomes.push(outcome);
                    member_start = completion;
                }
                cursor += size;
            }

            // Oracle fidelity: with the fast-path off, every committed
            // request still executes cycle-by-cycle on its admitted node
            // — per-node arrival order, nodes in parallel — and must
            // measure exactly the cost admission charged.
            if !self.fast_path && cursor > round_start {
                let mut per_node: Vec<Vec<FabricJob<'_>>> =
                    (0..n_nodes).map(|_| Vec::new()).collect();
                for (i, o) in outcomes.iter().enumerate().skip(round_start) {
                    per_node[o.node].push(FabricJob {
                        tag: i,
                        req: &trace[i].request,
                        fpga_stages: o.fpga_stages,
                    });
                }
                let results =
                    execute_on_nodes(self.cluster.nodes_mut(), per_node, threads);
                for (tag, r) in results {
                    let mut measured = r?;
                    // A standalone replay pays the reconfiguration a
                    // batch follower skipped — and the full restream a
                    // cache hit elided (node managers run cache-off, so
                    // replays are always cold); compare like with like
                    // via the identical float sequence the commit used.
                    if outcomes[tag].coalesced {
                        measured.reconfig_ms = 0.0;
                    } else {
                        elide_reconfig(
                            &self.cfg,
                            &mut measured,
                            outcomes[tag].cache_hits,
                            outcomes[tag].fpga_stages,
                        );
                    }
                    debug_assert_eq!(
                        service_cycles(&self.cfg, &measured),
                        outcomes[tag].service_cycles,
                        "oracle replay diverged from admission-time cost"
                    );
                }
            }

            if cursor >= trace.len() {
                break;
            }

            // Harvest: every unmeasured shape the remaining trace could
            // need, under every node-capacity class (which node admits a
            // request is unknown until its turn, but fpga_stages depends
            // on the node only through its free-region count).
            // First-appearance order keeps the merge deterministic.
            let avails: Vec<usize> = self
                .cluster
                .nodes()
                .iter()
                .map(BoardNode::available_regions)
                .collect();
            let mut classes = avails.clone();
            classes.sort_unstable();
            classes.dedup();
            let mut seen: HashSet<ShapeKey> = HashSet::new();
            let mut work: Vec<(ShapeKey, &AppRequest)> = Vec::new();
            for ev in &trace[cursor..] {
                for &avail in &classes {
                    let fpga_stages = ev.request.stages.len().min(avail);
                    let key = ShapeKey {
                        stages: ev.request.stages.clone(),
                        words: ev.request.data.len(),
                        fpga_stages,
                    };
                    if costs.contains_key(&key)
                        || failed.contains_key(&key)
                        || !seen.insert(key.clone())
                    {
                        continue;
                    }
                    work.push((key, &ev.request));
                }
            }
            assert!(
                !work.is_empty(),
                "sharded fleet stalled: blocked shape neither measured nor failed"
            );
            // Spread shapes over the boards able to measure them (a
            // board measures a shape exactly when its free-region count
            // maps the chain onto the shape's fpga_stages); round-robin
            // by shape index keeps the assignment deterministic.
            let mut per_node: Vec<Vec<FabricJob<'_>>> =
                (0..n_nodes).map(|_| Vec::new()).collect();
            for (widx, (key, req)) in work.iter().enumerate() {
                let eligible: Vec<usize> = (0..n_nodes)
                    .filter(|&i| {
                        key.stages.len().min(avails[i]) == key.fpga_stages
                    })
                    .collect();
                let node = eligible[widx % eligible.len()];
                per_node[node].push(FabricJob {
                    tag: widx,
                    req: *req,
                    fpga_stages: key.fpga_stages,
                });
            }
            let results =
                execute_on_nodes(self.cluster.nodes_mut(), per_node, threads);
            // Quiesce merge, in harvest order.
            for (tag, r) in results {
                let key = work[tag].0.clone();
                match r {
                    Ok(c) => {
                        costs.insert(key, c);
                    }
                    Err(e) => {
                        failed.insert(key, e);
                    }
                }
            }
        }
        Ok(FleetReport {
            completed: outcomes.len() as u64,
            makespan_cycles: self.busy_until.iter().copied().max().unwrap_or(0),
            outcomes,
            queue_wait,
            latency,
            per_node_served,
            // Overwritten with per-trace deltas by run_trace.
            migrated: self.migrated,
            fast_path_hits: self.fast_path_hits,
            oracle_runs: self.oracle_runs,
            batches_formed: self.batches_formed,
            batched_requests: self.batched_requests,
            config_cache_hits: self.config_cache_hits,
            config_cache_misses: self.config_cache_misses,
            icap_cycles_elided: self.icap_cycles_elided,
            events: Vec::new(),
        })
    }
}

/// One unit of parallel fabric work: execute `req` on a board and return
/// its measured service cost, tagged for a deterministic merge.
struct FabricJob<'a> {
    tag: usize,
    req: &'a AppRequest,
    fpga_stages: usize,
}

/// Execute per-node job lists on at most `threads` scoped OS threads.
/// Nodes are dealt round-robin across the threads, so each thread owns a
/// disjoint set of `&mut BoardNode` — no board is ever driven from two
/// threads, and within a board jobs run in the order given.  Results
/// come back sorted by tag, making the caller's merge independent of
/// thread interleaving.
fn execute_on_nodes(
    nodes: &mut [BoardNode],
    mut per_node: Vec<Vec<FabricJob<'_>>>,
    threads: usize,
) -> Vec<(usize, Result<CostBreakdown>)> {
    debug_assert_eq!(per_node.len(), nodes.len());
    let node_jobs: Vec<_> = nodes
        .iter_mut()
        .zip(per_node.drain(..))
        .filter(|(_, jobs)| !jobs.is_empty())
        .collect();
    let lanes = threads.min(node_jobs.len()).max(1);
    let mut groups: Vec<Vec<_>> = (0..lanes).map(|_| Vec::new()).collect();
    for (i, nj) in node_jobs.into_iter().enumerate() {
        groups[i % lanes].push(nj);
    }
    let mut out: Vec<(usize, Result<CostBreakdown>)> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|group| {
                s.spawn(move || {
                    let mut res = Vec::new();
                    for (node, jobs) in group {
                        for job in jobs {
                            let r = node.manager_mut().execute(job.req).map(
                                |rep| {
                                    debug_assert!(
                                        rep.verified,
                                        "oracle run failed golden verification"
                                    );
                                    debug_assert_eq!(
                                        rep.fpga_stages,
                                        job.fpga_stages
                                    );
                                    rep.cost
                                },
                            );
                            res.push((job.tag, r));
                        }
                    }
                    res
                })
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("fleet execution thread panicked"));
        }
    });
    out.sort_unstable_by_key(|&(tag, _)| tag);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_count, WorkloadSpec};

    fn cfg() -> SystemConfig {
        SystemConfig::paper_defaults()
    }

    fn small_trace(n: usize, seed: u64) -> Vec<TraceEvent> {
        generate_count(&WorkloadSpec::fleet_mix(), seed, n)
    }

    /// Each base event duplicated `dup` times at the same arrival
    /// instant: consecutive same-app, same-chain requests that the
    /// batch window is allowed to coalesce.
    fn bursty_trace(n: usize, dup: usize, seed: u64) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for ev in small_trace(n, seed) {
            for _ in 0..dup {
                out.push(ev.clone());
            }
        }
        out
    }

    #[test]
    fn fast_path_equivalence_with_oracle() {
        // Same trace, same policy: the shape-memoized fast-path must
        // produce the identical schedule the all-oracle run produces.
        let trace = small_trace(120, 7);
        for policy in [
            AdmissionPolicy::LeastLoaded,
            AdmissionPolicy::StickyByApp,
            AdmissionPolicy::BandwidthAware,
        ] {
            let mut oracle = Fleet::launch(3, &cfg(), None, policy, false);
            let mut fast = Fleet::launch(3, &cfg(), None, policy, true);
            oracle.fence_node(0, 2);
            fast.fence_node(0, 2);
            let a = oracle.run_trace(&trace).unwrap();
            let b = fast.run_trace(&trace).unwrap();
            assert_eq!(a.outcomes, b.outcomes, "policy {policy:?}");
            assert_eq!(a.makespan_cycles, b.makespan_cycles);
            assert!(b.fast_path_hits > 0, "cache never hit");
            assert!(
                b.oracle_runs < a.oracle_runs,
                "fast path did not reduce oracle executions"
            );
        }
    }

    #[test]
    fn sharded_execution_matches_serial_byte_for_byte() {
        // Same trace, same policy, both path modes: the sharded executor
        // must reproduce the serial schedule, recorder sample streams,
        // per-node stats and per-trace counters exactly, at every thread
        // count (the heavier cross-policy suite lives in
        // tests/fleet_threads.rs).
        let trace = small_trace(140, 29);
        for fast in [true, false] {
            let mut serial =
                Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, fast);
            serial.fence_node(0, 2);
            let want = serial.run_trace(&trace).unwrap();
            for threads in [2usize, 4, 8] {
                let mut sharded = Fleet::launch(
                    3,
                    &cfg(),
                    None,
                    AdmissionPolicy::StickyByApp,
                    fast,
                );
                sharded.fence_node(0, 2);
                sharded.execution_threads = threads;
                let got = sharded.run_trace(&trace).unwrap();
                assert_eq!(
                    want.outcomes, got.outcomes,
                    "fast={fast} threads={threads}"
                );
                assert_eq!(want.queue_wait.samples(), got.queue_wait.samples());
                assert_eq!(want.latency.samples(), got.latency.samples());
                assert_eq!(want.per_node_served, got.per_node_served);
                assert_eq!(want.migrated, got.migrated);
                assert_eq!(want.fast_path_hits, got.fast_path_hits);
                assert_eq!(want.oracle_runs, got.oracle_runs);
                assert_eq!(want.makespan_cycles, got.makespan_cycles);
            }
        }
    }

    #[test]
    fn batch_window_one_reproduces_the_legacy_schedule() {
        // Bursty same-app duplicates would coalesce at W > 1; at W = 1
        // (whatever batch_cycles says) the executor must reproduce the
        // unbatched schedule byte for byte, with zero batches formed.
        let trace = bursty_trace(40, 3, 11);
        let mut base =
            Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        base.tracer = Tracer::full();
        let want = base.run_trace(&trace).unwrap();
        let mut w1 =
            Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        w1.batch_window = 1;
        w1.batch_cycles = 10_000;
        w1.tracer = Tracer::full();
        let got = w1.run_trace(&trace).unwrap();
        assert_eq!(want.outcomes, got.outcomes);
        assert_eq!(want.events, got.events);
        assert_eq!(got.batches_formed, 0);
        assert_eq!(got.batched_requests, 0);
        assert!(got.outcomes.iter().all(|o| !o.coalesced));
    }

    #[test]
    fn batching_coalesces_followers_and_never_delays_a_request() {
        // Sticky pins with migration off make the unbatched follower
        // land on the leader's node anyway, so coalescing — which only
        // removes the follower's reconfiguration round — must finish
        // every request no later, request by request.
        let trace = bursty_trace(40, 3, 11);
        let run = |window: usize| {
            let mut fleet = Fleet::launch(
                3,
                &cfg(),
                None,
                AdmissionPolicy::StickyByApp,
                true,
            );
            fleet.migrate_overflow = false;
            fleet.batch_window = window;
            fleet.run_trace(&trace).unwrap()
        };
        let plain = run(1);
        let batched = run(3);
        assert!(batched.batches_formed > 0, "no batches formed");
        assert_eq!(
            batched.batched_requests,
            batched.outcomes.iter().filter(|o| o.coalesced).count() as u64
        );
        assert_eq!(plain.completed, batched.completed);
        for (p, b) in plain.outcomes.iter().zip(&batched.outcomes) {
            assert_eq!(p.app_id, b.app_id);
            assert!(
                b.completion_cycle <= p.completion_cycle,
                "batching delayed app {} ({} > {})",
                b.app_id,
                b.completion_cycle,
                p.completion_cycle
            );
            // Demux exactness: every outcome — follower or not —
            // carries a span that sums to its service and end-to-end
            // latency (DESIGN.md §14 invariants survive batching).
            assert_eq!(b.span.total_cycles(), b.service_cycles);
            assert_eq!(
                b.span.end_to_end_cycles(),
                b.completion_cycle - b.arrival_cycle
            );
            if b.coalesced {
                assert_eq!(b.span.icap_cycles, 0, "follower paid reconfig");
            }
        }
        assert!(batched.makespan_cycles <= plain.makespan_cycles);
    }

    #[test]
    fn batched_sharded_execution_matches_serial_at_every_thread_count() {
        // The batch demux property (ISSUE 8): with a window W ≥ 1 the
        // sharded executor must reproduce the serial batched schedule —
        // outcomes, spans, events, batch counters — at every thread
        // count, in both path modes.
        let trace = bursty_trace(30, 3, 29);
        for fast in [true, false] {
            let mut serial =
                Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, fast);
            serial.batch_window = 4;
            serial.tracer = Tracer::full();
            let want = serial.run_trace(&trace).unwrap();
            assert!(want.batches_formed > 0, "fast={fast}: no batches");
            for threads in [2usize, 4, 8] {
                let mut sharded = Fleet::launch(
                    3,
                    &cfg(),
                    None,
                    AdmissionPolicy::StickyByApp,
                    fast,
                );
                sharded.batch_window = 4;
                sharded.execution_threads = threads;
                sharded.tracer = Tracer::full();
                let got = sharded.run_trace(&trace).unwrap();
                assert_eq!(
                    want.outcomes, got.outcomes,
                    "fast={fast} threads={threads}"
                );
                assert_eq!(want.events, got.events);
                assert_eq!(want.queue_wait.samples(), got.queue_wait.samples());
                assert_eq!(want.latency.samples(), got.latency.samples());
                assert_eq!(want.per_node_served, got.per_node_served);
                assert_eq!(want.batches_formed, got.batches_formed);
                assert_eq!(want.batched_requests, got.batched_requests);
                assert_eq!(want.fast_path_hits, got.fast_path_hits);
                assert_eq!(want.oracle_runs, got.oracle_runs);
                assert_eq!(want.makespan_cycles, got.makespan_cycles);
            }
        }
    }

    #[test]
    fn plan_weighted_admission_shifts_load_toward_headroom() {
        // Fence two of node 0's regions: its spare share — headroom ×
        // free-region fraction — drops to a third of the others'.  Under
        // a standing burst the weighted policy inflates its backlog 3×,
        // so it must end up serving the least.
        let mut trace = small_trace(90, 23);
        for ev in trace.iter_mut() {
            ev.arrival_ms = 0.0;
        }
        let mut fleet = Fleet::launch(
            3,
            &cfg(),
            None,
            AdmissionPolicy::PlanWeighted,
            true,
        );
        fleet.fence_node(0, 2);
        let report = fleet.run_trace(&trace).unwrap();
        assert_eq!(report.completed, 90);
        assert!(
            report.per_node_served[0] < report.per_node_served[1]
                && report.per_node_served[0] < report.per_node_served[2],
            "low-headroom board won the load: {:?}",
            report.per_node_served
        );
    }

    #[test]
    fn completes_every_request() {
        let trace = small_trace(200, 9);
        let mut fleet =
            Fleet::launch(4, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        assert_eq!(report.completed, 200);
        assert_eq!(report.outcomes.len(), 200);
        assert_eq!(report.per_node_served.iter().sum::<u64>(), 200);
        // Causality on every outcome.
        for o in &report.outcomes {
            assert!(o.start_cycle >= o.arrival_cycle);
            assert_eq!(o.completion_cycle, o.start_cycle + o.service_cycles);
        }
    }

    #[test]
    fn least_loaded_uses_all_nodes() {
        let trace = small_trace(100, 3);
        let mut fleet =
            Fleet::launch(4, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(
            report.per_node_served.iter().all(|&s| s > 0),
            "idle node under least-loaded: {:?}",
            report.per_node_served
        );
    }

    #[test]
    fn sticky_policy_pins_apps_to_one_node() {
        let trace = small_trace(150, 5);
        let mut fleet =
            Fleet::launch(3, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        fleet.migrate_overflow = false; // pure pinning
        let report = fleet.run_trace(&trace).unwrap();
        let mut seen: HashMap<u32, usize> = HashMap::new();
        for o in &report.outcomes {
            let node = *seen.entry(o.app_id).or_insert(o.node);
            assert_eq!(o.node, node, "app {} moved nodes", o.app_id);
        }
    }

    #[test]
    fn overflow_migrates_to_a_board_with_free_regions() {
        // Node 0 keeps 1 region; 3-stage chains pinned there by the
        // sticky policy must migrate to a full-capacity board.
        let trace = small_trace(80, 13);
        let mut fleet =
            Fleet::launch(2, &cfg(), None, AdmissionPolicy::StickyByApp, true);
        fleet.fence_node(0, 2);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(report.migrated > 0, "no migrations despite fenced node");
        // Migration exists to keep whole chains on fabric: a migrated
        // request hosts its entire stage chain, and never on the board
        // that could not fit it.
        for (o, ev) in report.outcomes.iter().zip(&trace) {
            if o.migrated {
                assert_eq!(o.fpga_stages, ev.request.stages.len());
                assert_ne!(o.node, 0);
            }
        }
    }

    #[test]
    fn burst_arrivals_have_monotone_queue_waits_per_node() {
        // All requests arrive at once: each node's backlog serializes
        // them, so queue waits are non-decreasing per node.
        let mut trace = small_trace(60, 17);
        for ev in trace.iter_mut() {
            ev.arrival_ms = 0.0;
        }
        let mut fleet =
            Fleet::launch(2, &cfg(), None, AdmissionPolicy::LeastLoaded, true);
        let report = fleet.run_trace(&trace).unwrap();
        let mut last = vec![0u64; 2];
        for o in &report.outcomes {
            let wait = o.start_cycle - o.arrival_cycle;
            assert!(wait >= last[o.node], "queue wait regressed on {}", o.node);
            last[o.node] = wait;
        }
    }

    #[test]
    fn config_cache_elides_icap_restreams_on_repeated_shapes() {
        // Repeated same-app shapes with the timed ICAP on: every leader
        // after the first finds its kinds resident, so the warm fleet
        // elides their restreams and finishes strictly earlier.
        let trace = bursty_trace(20, 3, 31);
        let run = |cache: usize| {
            let mut c = cfg();
            c.manager.config_cache_regions = cache;
            let mut fleet = Fleet::launch(
                2,
                &c,
                None,
                AdmissionPolicy::StickyByApp,
                true,
            );
            fleet.set_use_icap(true);
            fleet.run_trace(&trace).unwrap()
        };
        let cold = run(0);
        let warm = run(3);
        assert_eq!(cold.completed, warm.completed);
        // Off = no cache activity at all.
        assert_eq!(cold.config_cache_hits, 0);
        assert_eq!(cold.config_cache_misses, 0);
        assert_eq!(cold.icap_cycles_elided, 0);
        assert!(cold.outcomes.iter().all(|o| o.cache_hits == 0));
        // On = rebinds happen and they elide real ICAP cycles.
        assert!(warm.config_cache_hits > 0, "no cache hits on repeats");
        assert!(warm.icap_cycles_elided > 0, "hits elided nothing");
        let service_sum = |r: &FleetReport| {
            r.outcomes.iter().map(|o| o.service_cycles).sum::<u64>()
        };
        assert!(service_sum(&warm) < service_sum(&cold));
        assert!(warm.makespan_cycles < cold.makespan_cycles);
    }

    #[test]
    fn config_cache_matches_oracle_byte_for_byte() {
        // With the cache on, the shape-memoized fast path and the
        // all-oracle run must still produce the identical schedule:
        // elision is applied at the same sequential commit points with
        // the same float sequence in both modes.
        let trace = bursty_trace(15, 2, 47);
        let mut c = cfg();
        c.manager.config_cache_regions = 2;
        // Keep the cycle-by-cycle oracle affordable: a small bitstream
        // still exercises the timed ICAP and a nonzero elision.
        c.manager.bitstream_bytes = 4096;
        let mut oracle =
            Fleet::launch(2, &c, None, AdmissionPolicy::LeastLoaded, false);
        oracle.set_use_icap(true);
        let mut fast =
            Fleet::launch(2, &c, None, AdmissionPolicy::LeastLoaded, true);
        fast.set_use_icap(true);
        let a = oracle.run_trace(&trace).unwrap();
        let b = fast.run_trace(&trace).unwrap();
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert!(b.config_cache_hits > 0, "cache never hit");
        assert_eq!(a.config_cache_hits, b.config_cache_hits);
        assert_eq!(a.icap_cycles_elided, b.icap_cycles_elided);
    }

    #[test]
    fn config_cache_capacity_trims_lru_and_emits_evictions() {
        // Capacity 1 with multi-stage chains: every leader's commit
        // inserts more kinds than fit, so the LRU trim must evict —
        // deterministically, with CacheEvict events — while immediate
        // same-shape repeats can still hit the surviving entry.
        let trace = bursty_trace(20, 2, 31);
        let mut c = cfg();
        c.manager.config_cache_regions = 1;
        let mut fleet =
            Fleet::launch(2, &c, None, AdmissionPolicy::StickyByApp, true);
        fleet.set_use_icap(true);
        fleet.tracer = Tracer::full();
        let report = fleet.run_trace(&trace).unwrap();
        assert!(report.config_cache_misses > 0);
        let evictions = report
            .events
            .iter()
            .filter(|e| matches!(e, TelemetryEvent::CacheEvict { .. }))
            .count();
        assert!(evictions > 0, "capacity 1 never evicted");
        // The resident set respects the knob on every node.
        for residents in &fleet.node_residents {
            assert!(residents.len() <= 1, "cap exceeded: {residents:?}");
        }
    }

    #[test]
    fn bandwidth_aware_avoids_fenced_boards() {
        // Fencing regions shrinks a board's spare bandwidth in the
        // register-file view; the policy must shift load away from it.
        let trace = small_trace(90, 23);
        let mut fleet = Fleet::launch(
            3,
            &cfg(),
            None,
            AdmissionPolicy::BandwidthAware,
            true,
        );
        fleet.fence_node(0, 2);
        let report = fleet.run_trace(&trace).unwrap();
        assert!(
            report.per_node_served[0] < report.per_node_served[1]
                && report.per_node_served[0] < report.per_node_served[2],
            "fenced board got the most load: {:?}",
            report.per_node_served
        );
    }
}
