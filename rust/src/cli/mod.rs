//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §7).
//!
//! ```text
//! elastic-fpga <subcommand> [--flag value ...]
//!
//! Subcommands:
//!   quickstart           run one 16 KB pipeline request end to end
//!   serve                start the serving loop on a synthetic workload
//!   fleet                run the multi-FPGA fleet simulator
//!   autoscale            run the closed-loop autoscaler vs the static baseline
//!   fig5                 reproduce Fig 5 (elasticity execution times)
//!   fig6                 reproduce Fig 6 (worst-case latency scaling)
//!   table1               reproduce Table I (area usage)
//!   table2               reproduce Table II (prior-art comparison)
//!   bandwidth            reproduce §V.D (dynamic bandwidth allocation)
//!   overhead             reproduce §V.E (communication overhead)
//! ```

use std::collections::BTreeMap;

use crate::{ElasticError, Result};

/// A parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub struct Cli {
    /// The subcommand.
    pub command: String,
    /// `--key value` pairs (flags without a value get `"true"`).
    pub flags: BTreeMap<String, String>,
}

impl Cli {
    /// Parse `argv[1..]`.
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut it = args.iter().peekable();
        let command = it
            .next()
            .cloned()
            .ok_or_else(|| ElasticError::Config(USAGE.trim().into()))?;
        if command.starts_with('-') {
            return Err(ElasticError::Config(format!(
                "expected a subcommand, got '{command}'\n{USAGE}"
            )));
        }
        let mut flags = BTreeMap::new();
        while let Some(arg) = it.next() {
            let key = arg.strip_prefix("--").ok_or_else(|| {
                ElasticError::Config(format!("expected --flag, got '{arg}'"))
            })?;
            if key.is_empty() {
                return Err(ElasticError::Config("empty flag name".into()));
            }
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    it.next().cloned().unwrap()
                }
                _ => "true".to_string(),
            };
            flags.insert(key.to_string(), value);
        }
        Ok(Cli { command, flags })
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// usize flag with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ElasticError::Config(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    /// f64 flag with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ElasticError::Config(format!("--{key} expects a number, got '{v}'"))
            }),
        }
    }

    /// bool flag (present or `--key true/false`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key).map(String::as_str) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(ElasticError::Config(format!(
                "--{key} expects true/false, got '{v}'"
            ))),
        }
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
usage: elastic-fpga <subcommand> [--flag value ...]

subcommands:
  quickstart   run one 16 KB pipeline request end to end (uses artifacts/)
  serve        run the serving loop on a synthetic workload
  fleet        run the multi-FPGA fleet simulator (event-driven fast-path)
  autoscale    closed-loop PR-region autoscaler vs static baseline (diurnal+churn)
  fig5         reproduce Fig 5 (elasticity execution times)
  fig6         reproduce Fig 6 (worst-case latency vs #PR regions)
  table1       reproduce Table I (area usage of all components)
  table2       reproduce Table II (comparison with prior art)
  bandwidth    reproduce §V.D (dynamic bandwidth allocation)
  overhead     reproduce §V.E (communication overhead cycle counts)

common flags:
  --artifacts DIR    artifact directory (default: artifacts)
  --config FILE      TOML config overlay
  --kernels FILE     TOML file of extra [kernels.<name>] declarations,
                     installed into the kernel registry on top of the
                     config overlay's tables; duplicate names are
                     refused (DESIGN.md §17)
  --plan SPEC        per-app bandwidth shares, app=ppu pairs out of 1000
                     (e.g. `--plan 0=750,1=250`; overrides [qos.shares];
                     refused by `autoscale`, which derives shares from
                     footprints)
  --requests N       request count for `serve`/`fleet`/`autoscale`
                     (default: 64/10000/20000)
  --no-pjrt          skip PJRT; use the golden model for CPU stages
  --batch-window N   same-app coalescing window per lane/stream for
                     `serve`/`fleet` (1..=64; 1 = off, the default;
                     DESIGN.md §15)
  --config-cache N   resident-module configuration cache capacity per
                     board for `serve`/`fleet`: released regions park
                     their module for ICAP-free rebinding, LRU-trimmed
                     to N (0 = off, the default; DESIGN.md §16)
  --metrics-out F    write a schema-versioned JSON metrics snapshot
                     (`serve`/`fleet`, DESIGN.md §14)

fleet flags:
  --fabrics N        simulated boards (default: 8)
  --policy P         least | sticky | bandwidth | weighted (default: least)
  --batch-cycles N   batch followers must arrive within N virtual cycles
                     of their leader (0 = bounded only by the leader's
                     start instant, the default)
  --seed N           workload seed (default: 1)
  --oracle           disable the fast-path; run every request cycle-by-cycle
  --threads N        shard oracle runs across N scoped threads; results are
                     byte-identical to --threads 1 (default: 1)
  --trace            capture the cycle-stamped telemetry event stream
  --trace-out F      write the event stream as JSON (implies --trace)

autoscale flags:
  --fabrics N        simulated boards (default: 5)
  --tenants N        diurnal tenant streams, up to the port count (default: 4)
  --policy P         depth | slo | predictive (default: depth)
  --period S         diurnal period in seconds (default: 20)
  --seed N           workload + churn seed (default: 1)
  --churn B          inject board outages + region fencing (default: true)
  --config FILE      board shape overlay (e.g. configs/scale16.toml for
                     16-port boards; default: the autoscale profile)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = Cli::parse(&argv(&["fig5", "--requests", "10", "--no-pjrt"])).unwrap();
        assert_eq!(c.command, "fig5");
        assert_eq!(c.usize_or("requests", 0).unwrap(), 10);
        assert!(c.bool_or("no-pjrt", false).unwrap());
        assert_eq!(c.str_or("artifacts", "artifacts"), "artifacts");
    }

    #[test]
    fn rejects_missing_subcommand() {
        assert!(Cli::parse(&argv(&[])).is_err());
        assert!(Cli::parse(&argv(&["--flag"])).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let c = Cli::parse(&argv(&["serve", "--requests", "abc"])).unwrap();
        assert!(c.usize_or("requests", 1).is_err());
        let c = Cli::parse(&argv(&["serve", "--no-pjrt", "maybe"])).unwrap();
        assert!(c.bool_or("no-pjrt", false).is_err());
        let c = Cli::parse(&argv(&["autoscale", "--period", "x"])).unwrap();
        assert!(c.f64_or("period", 1.0).is_err());
    }

    #[test]
    fn parses_f64_flags() {
        let c = Cli::parse(&argv(&["autoscale", "--period", "12.5"])).unwrap();
        assert_eq!(c.f64_or("period", 1.0).unwrap(), 12.5);
        assert_eq!(c.f64_or("missing", 20.0).unwrap(), 20.0);
    }

    #[test]
    fn flag_without_value_is_true() {
        let c = Cli::parse(&argv(&["serve", "--verbose", "--requests", "3"])).unwrap();
        assert_eq!(c.str_or("verbose", ""), "true");
        assert_eq!(c.usize_or("requests", 0).unwrap(), 3);
    }
}
