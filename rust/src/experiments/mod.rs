//! Experiment drivers: one function per table/figure in the paper's
//! evaluation (§V), shared by the CLI (`elastic-fpga fig5 ...`) and the
//! bench harness (`cargo bench`).  Each returns structured rows so
//! benches can assert the claims and EXPERIMENTS.md can quote them.

use crate::area;
use crate::baselines::noc;
use crate::baselines::sharedbus::SharedBus;
use crate::config::SystemConfig;
use crate::crossbar::Crossbar;
use crate::fabric::DeviceModel;
use crate::manager::{AppRequest, ElasticManager};
use crate::modules::ModuleKind;
use crate::runtime::RuntimeHandle;
use crate::sim::{Clock, Tick};
use crate::util::onehot::encode_onehot;
use crate::util::SplitMix64;
use crate::wishbone::Job;
use crate::Result;

// ---------------------------------------------------------------------
// Fig 5 — resource elasticity execution time
// ---------------------------------------------------------------------

/// One Fig-5 bar.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    /// Case number (1..=3): how many stages run on the FPGA.
    pub case: usize,
    /// Mean execution time over `reps` runs (ms, timing model).
    pub mean_ms: f64,
    /// Mean PCIe / fabric / CPU split.
    pub pcie_ms: f64,
    pub fabric_ms: f64,
    pub cpu_ms: f64,
}

/// Reproduce Fig 5: 16 KB through multiplier -> encoder -> decoder with
/// 1, 2, 3 PR regions available; `reps` repetitions each (paper: 10).
pub fn fig5(
    cfg: &SystemConfig,
    runtime: Option<RuntimeHandle>,
    words: usize,
    reps: usize,
) -> Result<Vec<Fig5Row>> {
    let mut rows = Vec::new();
    for case in 1..=3usize {
        let mut total = 0.0;
        let mut pcie = 0.0;
        let mut fabric = 0.0;
        let mut cpu = 0.0;
        for rep in 0..reps {
            let mut mgr = ElasticManager::new(cfg.clone(), runtime.clone());
            mgr.fence_regions(3 - case);
            let mut rng = SplitMix64::new((case * 1000 + rep) as u64);
            let mut data = vec![0u32; words];
            rng.fill_u32(&mut data);
            let rep = mgr.execute(&AppRequest::pipeline(0, data))?;
            debug_assert!(rep.verified);
            total += rep.cost.total_ms();
            pcie += rep.cost.pcie_ms;
            fabric += rep.cost.fabric_ms;
            cpu += rep.cost.cpu_ms;
        }
        let n = reps as f64;
        rows.push(Fig5Row {
            case,
            mean_ms: total / n,
            pcie_ms: pcie / n,
            fabric_ms: fabric / n,
            cpu_ms: cpu / n,
        });
    }
    Ok(rows)
}

/// Render Fig 5 rows like the paper's bar chart data.
pub fn fig5_render(rows: &[Fig5Row]) -> String {
    let mut s = String::from(
        "Fig 5 — Execution time vs available PR regions (16 KB, mult->enc->dec)\n\
         | case | FPGA stages | exec time (ms) | pcie | fabric | cpu |\n\
         |------|-------------|----------------|------|--------|-----|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "|   {}  |      {}      | {:>14.2} | {:>4.2} | {:>6.3} | {:>4.2} |\n",
            r.case, r.case, r.mean_ms, r.pcie_ms, r.fabric_ms, r.cpu_ms
        ));
    }
    s.push_str("paper: case1 = 16.9 ms, case3 = 10.87 ms\n");
    s
}

// ---------------------------------------------------------------------
// §V.D — dynamic bandwidth allocation
// ---------------------------------------------------------------------

/// One §V.D row: a case at a package budget.
#[derive(Debug, Clone)]
pub struct BandwidthRow {
    /// Accelerators configured on the FPGA (1..=3).
    pub accelerators: usize,
    /// Packages per grant (16 or 128).
    pub packages: u32,
    /// Fabric cycles to stream the payload through the chain.
    pub fabric_cycles: u64,
}

/// Stream `words` through a chain of `accs` modules with the given WRR
/// package budget, large module batches so the budget (not the batch)
/// chops the bursts — §V.D's mechanism.
pub fn bandwidth_case(accs: usize, packages: u32, words: usize) -> Result<BandwidthRow> {
    use crate::xdma::H2cBurst;
    let mut cfg = SystemConfig::paper_defaults();
    // Big slave buffers so only the WRR budget limits burst length.
    cfg.crossbar.slave_buffer_words = 512;
    let mut fabric = crate::fabric::Fabric::new(cfg);
    let kinds = ModuleKind::pipeline();
    let ports: Vec<usize> = (1..=accs).collect();
    // Program the chain + budgets.
    fabric.regfile.set_app_destination(0, 1 << ports[0])?;
    fabric.regfile.set_allowed_slaves(0, 1 << ports[0])?;
    for (i, &p) in ports.iter().enumerate() {
        let next = ports.get(i + 1).copied().unwrap_or(0);
        fabric.regfile.set_pr_destination(p, 1 << next)?;
        fabric.regfile.set_allowed_slaves(p, 1 << next)?;
    }
    for slave in 0..4usize {
        for master in 0..4usize {
            fabric
                .regfile
                .set_allowed_packages(slave, master, packages.min(255))?;
        }
    }
    for (&p, &k) in ports.iter().zip(kinds.iter()) {
        fabric.install_static_module(p, k, 0);
        // Large input registers: stream in 512-word batches.  This is a
        // deliberate per-instance override of the spec geometry; the
        // fabric's output contract check follows the instance, so the
        // oversized batches stay honest (kernels/mod.rs).
        fabric.modules[p].as_mut().unwrap().batch_words = 512;
    }
    // Stream the payload in 512-word host bursts.
    for (i, chunk) in (0..words).collect::<Vec<_>>().chunks(512).enumerate() {
        let mut rng = SplitMix64::new(i as u64);
        let mut burst = vec![0u32; chunk.len()];
        rng.fill_u32(&mut burst);
        fabric.h2c_push(0, H2cBurst { app_id: 0, words: burst })?;
    }
    let cycles = fabric.run_until_idle(1_000_000_000)?;
    fabric.flush_c2h();
    Ok(BandwidthRow { accelerators: accs, packages, fabric_cycles: cycles })
}

/// Full §V.D sweep: cases 1..=3 at 16 and 128 packages.
pub fn bandwidth_sweep(words: usize) -> Result<Vec<BandwidthRow>> {
    let mut rows = Vec::new();
    for accs in 1..=3 {
        for packages in [16u32, 128] {
            rows.push(bandwidth_case(accs, packages, words)?);
        }
    }
    Ok(rows)
}

/// Improvement (%) going 16 -> 128 packages, per accelerator count.
pub fn bandwidth_improvements(rows: &[BandwidthRow]) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    for accs in 1..=3 {
        let c16 = rows
            .iter()
            .find(|r| r.accelerators == accs && r.packages == 16)
            .map(|r| r.fabric_cycles as f64)
            .unwrap_or(f64::NAN);
        let c128 = rows
            .iter()
            .find(|r| r.accelerators == accs && r.packages == 128)
            .map(|r| r.fabric_cycles as f64)
            .unwrap_or(f64::NAN);
        out.push((accs, (c16 - c128) / c16 * 100.0));
    }
    out
}

/// Render the §V.D table.
pub fn bandwidth_render(rows: &[BandwidthRow]) -> String {
    let mut s = String::from(
        "§V.D — Dynamic bandwidth allocation (16 vs 128 packages/grant)\n\
         | accelerators | packages | fabric cycles |\n\
         |--------------|----------|---------------|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "|      {}       |   {:>4}   | {:>13} |\n",
            r.accelerators, r.packages, r.fabric_cycles
        ));
    }
    for (accs, imp) in bandwidth_improvements(rows) {
        s.push_str(&format!(
            "improvement with {accs} accelerator(s): {imp:.2}%\n"
        ));
    }
    s.push_str("paper: 5.24% (1 acc) -> 6% (3 accs), end-to-end\n");
    s
}

// ---------------------------------------------------------------------
// §V.E — communication overhead
// ---------------------------------------------------------------------

/// §V.E cycle counts, measured from the crossbar simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct OverheadResult {
    pub best_time_to_grant: u64,
    pub best_completion_8: u64,
    pub worst_time_to_grant: u64,
    pub worst_completion_8: u64,
}

/// Measure best- and worst-case time-to-grant / completion on the 4x4
/// crossbar with 8-word packages.
pub fn comm_overhead(cfg: &SystemConfig) -> OverheadResult {
    // Best case: one master, idle slave.
    let mut xb = Crossbar::new(4, cfg.crossbar.clone());
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    xb.push_job(0, Job::new(encode_onehot(1), vec![0; 8], 0));
    let best = run_collect(&mut xb, 1_000);
    // Worst case: 3 masters target the fourth simultaneously.
    let mut xb = Crossbar::new(4, cfg.crossbar.clone());
    for m in 0..4 {
        xb.set_allowed_slaves(m, 0b1111);
    }
    for m in 0..3 {
        xb.push_job(m, Job::new(encode_onehot(3), vec![0; 8], 0));
    }
    let worst = run_collect(&mut xb, 1_000);
    OverheadResult {
        best_time_to_grant: best.iter().map(|e| e.time_to_grant()).min().unwrap(),
        best_completion_8: best.iter().map(|e| e.completion_latency()).min().unwrap(),
        worst_time_to_grant: worst.iter().map(|e| e.time_to_grant()).max().unwrap(),
        worst_completion_8: worst.iter().map(|e| e.completion_latency()).max().unwrap(),
    }
}

fn run_collect(xb: &mut Crossbar, max: u64) -> Vec<crate::crossbar::XbarEvent> {
    let mut clk = Clock::new();
    let mut events = Vec::new();
    for _ in 0..max {
        let c = clk.advance();
        xb.tick(c);
        for s in 0..xb.ports() {
            xb.drain_rx(s, usize::MAX);
        }
        events.extend(xb.take_events());
        if xb.quiescent() {
            break;
        }
    }
    events
}

/// Render §V.E.
pub fn overhead_render(r: &OverheadResult) -> String {
    format!(
        "§V.E — Communication overhead (8 packages)\n\
         best-case time-to-grant:      {:>3} cc   (paper: 4)\n\
         best-case completion:         {:>3} cc   (paper: 13)\n\
         worst-case time-to-grant:     {:>3} cc   (paper: 28)\n\
         worst-case completion:        {:>3} cc   (paper: 37)\n",
        r.best_time_to_grant,
        r.best_completion_8,
        r.worst_time_to_grant,
        r.worst_completion_8
    )
}

// ---------------------------------------------------------------------
// Fig 6 — worst-case latency vs number of PR regions
// ---------------------------------------------------------------------

/// One Fig-6 point.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig6Row {
    /// Crossbar ports (PR regions + bridge port).
    pub ports: usize,
    /// Worst-case time-to-grant (all N-1 masters -> one slave, 8 words).
    pub worst_time_to_grant: u64,
    /// Worst-case completion.
    pub worst_completion: u64,
    /// Analytic: 12(N-2) + 4.
    pub analytic_ttg: u64,
}

/// Sweep port counts; every master sends 8 words to the last port.
pub fn fig6(cfg: &SystemConfig, port_counts: &[usize]) -> Vec<Fig6Row> {
    let mut rows = Vec::new();
    for &n in port_counts {
        let mut xb = Crossbar::new(n, cfg.crossbar.clone());
        let all = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
        for m in 0..n {
            xb.set_allowed_slaves(m, all);
        }
        for m in 0..n - 1 {
            xb.push_job(m, Job::new(encode_onehot(n as u32 - 1), vec![0; 8], 0));
        }
        let events = run_collect(&mut xb, 100_000);
        rows.push(Fig6Row {
            ports: n,
            worst_time_to_grant: events.iter().map(|e| e.time_to_grant()).max().unwrap(),
            worst_completion: events
                .iter()
                .map(|e| e.completion_latency())
                .max()
                .unwrap(),
            analytic_ttg: 12 * (n as u64 - 2) + 4,
        });
    }
    rows
}

/// Render Fig 6.
pub fn fig6_render(rows: &[Fig6Row]) -> String {
    let mut s = String::from(
        "Fig 6 — Number of PRs vs worst-case latency (8 data words each)\n\
         | ports | worst time-to-grant | worst completion | analytic 12(N-2)+4 |\n\
         |-------|---------------------|------------------|--------------------|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {:>5} | {:>19} | {:>16} | {:>18} |\n",
            r.ports, r.worst_time_to_grant, r.worst_completion, r.analytic_ttg
        ));
    }
    s.push_str("paper: linear growth in the number of PR regions\n");
    s
}

// ---------------------------------------------------------------------
// Table I / Table II
// ---------------------------------------------------------------------

/// Render Table I from the area model.
pub fn table1_render() -> String {
    let device = DeviceModel::kcu1500_prototype();
    format!(
        "Table I — Area usage of all components (XCKU115)\n{}",
        area::table1_report(&device)
    )
}

/// Table II rows plus the measured latency comparison.
pub fn table2_render(cfg: &SystemConfig) -> String {
    let h = area::headline_claims();
    // Latency side: 8-word request on each interconnect.
    let xbar = comm_overhead(cfg).best_completion_8;
    let noc_cc = noc::uncontended_completion(2, 8);
    let mut bus = SharedBus::new();
    bus.request(0, 1, 8);
    let mut clk = Clock::new();
    clk.run_until(&mut bus, 100, |b| !b.busy()).unwrap();
    let bus_cc = bus.take_delivered()[0].completion_latency();

    let mut s = String::from(
        "Table II — Comparison with existing work\n\
         | design                                   | LUTs | FFs  | power (mW) | 8-word request (cc) |\n\
         |------------------------------------------|------|------|------------|---------------------|\n",
    );
    s.push_str(&format!(
        "| 4x4 WB crossbar (this work)              | {:>4} | {:>4} | {:>10} | {:>19} |\n",
        area::table2::WB_CROSSBAR_4X4.luts,
        area::table2::WB_CROSSBAR_4X4.ffs,
        1,
        xbar
    ));
    s.push_str(&format!(
        "| 2x2 NoC 3-port routers [16]              | {:>4} | {:>4} | {:>10} | {:>19} |\n",
        area::table2::NOC_2X2_3PORT.luts,
        area::table2::NOC_2X2_3PORT.ffs,
        80,
        noc_cc
    ));
    s.push_str(&format!(
        "| 4x4 WB crossbar interconnection system   | {:>4} | {:>4} | {:>10} | {:>19} |\n",
        area::table2::WB_SYSTEM_4X4.luts,
        area::table2::WB_SYSTEM_4X4.ffs,
        "-",
        xbar
    ));
    s.push_str(&format!(
        "| 4 communication infrastructures in [21]  | {:>4} | {:>4} | {:>10} | {:>19} |\n",
        area::table2::EWB_X4.luts,
        area::table2::EWB_X4.ffs,
        "-",
        bus_cc
    ));
    s.push_str(&format!(
        "\nheadlines: {:.0}% fewer LUTs and {:.0}% fewer FFs than the NoC \
         (paper: 61%/95%); {:.0}x less power (paper: 80x);\n\
         {:.1}% more LUTs / {:.1}% fewer FFs than 4x E-WB (paper: +48.6%/-46.4%);\n\
         request completion {} cc vs NoC {} cc = {:.0}% fewer cycles (paper: 69%).\n",
        h.lut_savings_vs_noc_pct,
        h.ff_savings_vs_noc_pct,
        h.power_ratio_vs_noc,
        h.lut_overhead_vs_ewb_pct,
        h.ff_savings_vs_ewb_pct,
        xbar,
        noc_cc,
        (noc_cc as f64 - xbar as f64) / xbar as f64 * 100.0,
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_matches_paper_exactly() {
        let r = comm_overhead(&SystemConfig::paper_defaults());
        assert_eq!(
            r,
            OverheadResult {
                best_time_to_grant: 4,
                best_completion_8: 13,
                worst_time_to_grant: 28,
                worst_completion_8: 37,
            }
        );
    }

    #[test]
    fn fig6_simulated_matches_analytic() {
        let rows = fig6(&SystemConfig::paper_defaults(), &[3, 4, 8, 16]);
        for r in &rows {
            assert_eq!(r.worst_time_to_grant, r.analytic_ttg, "n={}", r.ports);
        }
        // Linearity: constant slope of 12 per added port.
        let r4 = rows.iter().find(|r| r.ports == 4).unwrap();
        let r8 = rows.iter().find(|r| r.ports == 8).unwrap();
        assert_eq!(
            r8.worst_time_to_grant - r4.worst_time_to_grant,
            12 * 4,
            "slope must be 12 cc per port"
        );
    }

    #[test]
    fn bandwidth_direction_matches_paper() {
        // 128-package budgets must beat 16-package budgets, and the
        // improvement must grow with accelerator count.
        let rows = bandwidth_sweep(4096).unwrap();
        let imps = bandwidth_improvements(&rows);
        for (accs, imp) in &imps {
            assert!(*imp > 0.0, "accs={accs}: improvement {imp} not positive");
        }
        assert!(
            imps[2].1 > imps[0].1,
            "improvement must grow with accelerators: {imps:?}"
        );
    }

    #[test]
    fn fig5_rows_reproduce_shape() {
        let rows =
            fig5(&SystemConfig::paper_defaults(), None, 4096, 2).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows[0].mean_ms > rows[1].mean_ms);
        assert!(rows[1].mean_ms > rows[2].mean_ms);
    }

    #[test]
    fn renders_are_complete() {
        let cfg = SystemConfig::paper_defaults();
        assert!(table1_render().contains("WB Crossbar"));
        let t2 = table2_render(&cfg);
        assert!(t2.contains("475") && t2.contains("1220"));
        let oh = overhead_render(&comm_overhead(&cfg));
        assert!(oh.contains("4 cc") || oh.contains("  4 cc"));
    }
}
