//! Manifest-driven kernel registry (DESIGN.md §17): the pluggable
//! tenant-kernel runtime that replaces the closed `ModuleKind` enum.
//!
//! A kernel is a [`KernelSpec`] identity — stable [`KernelId`], display
//! name, artifact key, input geometry, per-word latency model, area
//! cost — plus a [`ModuleBehavior`] giving it a golden buffer
//! transform, a compute-countdown horizon, and the `fast_forward`
//! arithmetic the event-driven fast path relies on (DESIGN.md §12).
//! Three families are built in:
//!
//! * **Seed** — the paper's three prototype modules (constant
//!   multiplier, Hamming(31,26) encoder/decoder).  They occupy ids
//!   0..=2, are resolved through a static table (no lock, no
//!   allocation), and are byte-identical to the pre-registry enum at
//!   the default registry.
//! * **Table** — synthetic kernels declared in a `[kernels.<name>]`
//!   config table: a parameterized word transform (`mul`/`add`/`xor`/
//!   `rotl`/`and` + output mask) with configurable latency, geometry
//!   and area.  These open the kernel-zoo scenario space without any
//!   edit to `rust/src/modules/`.
//! * **Artifact** — AOT-artifact-backed kernels executing the
//!   interpreter kernel of an existing [`crate::runtime`] manifest
//!   entry; geometry and dtype are cross-checked against the
//!   [`ArtifactManifest`] before registration (Omniglot-style boundary
//!   validation), and on-server stages run through the PJRT path.
//!
//! Everything is validated at the boundary: hostile declarations
//! (reserved seed names, duplicate names, zero/absurd latency,
//! geometry lies vs the manifest) are refused with typed
//! [`ElasticError`]s; at run time the fabric length/mask-validates
//! every batch a module emits before it re-enters the shell
//! ([`KernelSpec::output_mask`]), containing a misbehaving kernel as a
//! `pr_error` latch instead of corrupted fabric state.

use std::fmt;
use std::sync::{OnceLock, RwLock};

use crate::hamming;
use crate::runtime::ArtifactManifest;
use crate::xdma::BRIDGE_BUFFER_WORDS;
use crate::{ElasticError, Result};

/// Number of built-in seed kernels (ids `0..SEED_KERNELS`).
pub const SEED_KERNELS: usize = 3;

/// Registry capacity guard: latency models beyond this are refused as
/// absurd (a single batch would stall a lane for ~a simulated second).
const MAX_LATENCY_BASE: u32 = 1 << 20;
/// Per-word latency cap (same rationale).
const MAX_LATENCY_PER_WORD: u32 = 1 << 12;

/// Stable identity of a registered kernel.
///
/// Seed kernels keep their historical `ModuleKind`-style names as
/// associated constants, so `ModuleKind::Multiplier` (via the
/// [`crate::modules::ModuleKind`] re-export) still works in both value
/// and pattern position.  Ids are dense: `0..SEED_KERNELS` are the
/// seeds, registration order numbers the rest.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(u16);

impl KernelId {
    /// Constant multiplier (wrapping u32 multiply) — seed kernel 0.
    #[allow(non_upper_case_globals)]
    pub const Multiplier: KernelId = KernelId(0);
    /// Hamming(31,26) encoder — seed kernel 1.
    #[allow(non_upper_case_globals)]
    pub const HammingEncoder: KernelId = KernelId(1);
    /// Hamming(31,26) decoder (single-error correction) — seed kernel 2.
    #[allow(non_upper_case_globals)]
    pub const HammingDecoder: KernelId = KernelId(2);

    /// Is this one of the three built-in seed kernels?
    pub fn is_seed(self) -> bool {
        (self.0 as usize) < SEED_KERNELS
    }

    /// The kernel's registered spec.  Seed ids resolve through a static
    /// table (no lock); registered ids take a read lock but never
    /// allocate — the hot-path contract of DESIGN.md §17.
    pub fn spec(self) -> &'static KernelSpec {
        if let Some(s) = seed_specs().get(self.0 as usize) {
            return s;
        }
        let reg = registry().read().unwrap();
        reg.get(self.0 as usize - SEED_KERNELS)
            .map(|r| r.spec)
            .expect("KernelId minted by the registry")
    }

    /// Stable display name.
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// The AOT artifact key associated with this kernel: the manifest
    /// key for seed and artifact-backed kernels (matching
    /// `python/compile/model.py::EXPORTS`), the kernel's own name for
    /// table-driven kernels (which have no AOT artifact).
    pub fn artifact(self) -> &'static str {
        let spec = self.spec();
        spec.artifact.unwrap_or(spec.name)
    }

    /// The manifest artifact this kernel's on-server stage may execute
    /// through the PJRT path, if any.  `None` for table-driven kernels:
    /// their CPU stages run the golden transform directly instead of
    /// erroring on an unknown manifest key.
    pub fn pjrt_artifact(self) -> Option<&'static str> {
        self.spec().artifact
    }

    /// The per-word combinational function (golden model).
    pub fn apply_word(self, w: u32) -> u32 {
        self.spec().behavior.apply_word(w)
    }

    /// Buffer-level golden transform.
    pub fn apply_buf(self, buf: &[u32]) -> Vec<u32> {
        self.spec().behavior.apply_buf(buf)
    }

    /// Compute-countdown cycles for one `batch_words` batch.
    pub fn compute_cycles(self, batch_words: usize) -> u32 {
        self.spec().behavior.compute_cycles(batch_words)
    }

    /// Fast-forward arithmetic over a running compute countdown
    /// (DESIGN.md §12: exact, never crossing the horizon).
    pub fn fast_forward_countdown(self, remaining: u32, skipped: u64) -> u32 {
        self.spec().behavior.fast_forward(remaining, skipped)
    }

    /// The Fig-5 pipeline order.
    pub fn pipeline() -> [KernelId; 3] {
        [KernelId::Multiplier, KernelId::HammingEncoder, KernelId::HammingDecoder]
    }
}

impl fmt::Debug for KernelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Print the registered name so logs stay readable; fall back to
        // the raw id for an id whose registry entry cannot be resolved
        // (only reachable from a poisoned-lock panic path).
        if let Some(s) = seed_specs().get(self.0 as usize) {
            return f.write_str(s.name);
        }
        match registry().try_read() {
            Ok(reg) => match reg.get(self.0 as usize - SEED_KERNELS) {
                Some(r) => f.write_str(r.spec.name),
                None => write!(f, "kernel#{}", self.0),
            },
            Err(_) => write!(f, "kernel#{}", self.0),
        }
    }
}

/// The behavior contract every kernel implements: its golden transform
/// plus the two pieces of arithmetic the event-driven fast path needs
/// to model it without ticking (DESIGN.md §12, §17).
pub trait ModuleBehavior: Send + Sync {
    /// Per-word combinational function.
    fn apply_word(&self, w: u32) -> u32;

    /// Buffer-level transform (1:1 by default; the shell's output
    /// contract checks length and mask on every emitted batch).
    fn apply_buf(&self, buf: &[u32]) -> Vec<u32> {
        buf.iter().map(|&w| self.apply_word(w)).collect()
    }

    /// Compute-countdown horizon: cycles the computation units run for
    /// one batch of `batch_words` words.  Must be ≥ 1 and constant per
    /// geometry — the fast path folds it into exact skip arithmetic.
    fn compute_cycles(&self, batch_words: usize) -> u32;

    /// Advance a running countdown over `skipped` fast-forwarded
    /// cycles.  Callers keep the skip strictly below the horizon.
    fn fast_forward(&self, remaining: u32, skipped: u64) -> u32 {
        debug_assert!(
            (remaining as u64) > skipped,
            "skip crossed the compute countdown"
        );
        remaining - skipped as u32
    }
}

/// A registered kernel's identity and resource model.
pub struct KernelSpec {
    /// Stable registry id.
    pub id: KernelId,
    /// Display name (unique across the registry).
    pub name: &'static str,
    /// Manifest artifact key for PJRT-eligible kernels; `None` for
    /// table-driven kernels.
    pub artifact: Option<&'static str>,
    /// Input geometry: words per module batch (the input-register
    /// depth a PR-region instance is built with).  Must divide the
    /// 8-word bridge burst so batches always fill.
    pub batch_words: usize,
    /// Latency model: fixed cycles per batch…
    pub latency_base: u32,
    /// …plus cycles per word in the batch.
    pub latency_per_word: u32,
    /// Every output word `w` must satisfy `w & mask == w`; the fabric
    /// refuses (and latches `pr_error` for) batches that violate it.
    pub output_mask: u32,
    /// Area cost: LUTs (Table I-anchored for the seeds).
    pub luts: u64,
    /// Area cost: flip-flops.
    pub ffs: u64,
    behavior: &'static dyn ModuleBehavior,
}

impl KernelSpec {
    /// Compute-countdown cycles for one batch of this spec's geometry.
    pub fn compute_latency(&self) -> u32 {
        self.behavior.compute_cycles(self.batch_words)
    }
}

impl fmt::Debug for KernelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KernelSpec")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("artifact", &self.artifact)
            .field("batch_words", &self.batch_words)
            .field("latency_base", &self.latency_base)
            .field("latency_per_word", &self.latency_per_word)
            .field("output_mask", &self.output_mask)
            .field("luts", &self.luts)
            .field("ffs", &self.ffs)
            .finish()
    }
}

// ---------------------------------------------------------------------
// Seed family.

struct MultiplierBehavior;
struct EncoderBehavior;
struct DecoderBehavior;

impl ModuleBehavior for MultiplierBehavior {
    fn apply_word(&self, w: u32) -> u32 {
        hamming::multiply_word(w, hamming::MULT_CONSTANT)
    }
    fn compute_cycles(&self, _batch_words: usize) -> u32 {
        1 // parallel computation units -> 1 cc (§IV.H)
    }
}

impl ModuleBehavior for EncoderBehavior {
    fn apply_word(&self, w: u32) -> u32 {
        hamming::encode_word(w)
    }
    fn compute_cycles(&self, _batch_words: usize) -> u32 {
        1
    }
}

impl ModuleBehavior for DecoderBehavior {
    fn apply_word(&self, w: u32) -> u32 {
        hamming::decode_word(w).0
    }
    fn compute_cycles(&self, _batch_words: usize) -> u32 {
        1
    }
}

static MULTIPLIER_BEHAVIOR: MultiplierBehavior = MultiplierBehavior;
static ENCODER_BEHAVIOR: EncoderBehavior = EncoderBehavior;
static DECODER_BEHAVIOR: DecoderBehavior = DecoderBehavior;

/// The three seed specs.  Area is anchored on Table I's measured rows
/// ([`crate::area::table1`]); masks are the true output invariants of
/// the golden model, so the boundary check never fires for the seeds.
fn seed_specs() -> &'static [KernelSpec; SEED_KERNELS] {
    static SPECS: OnceLock<[KernelSpec; SEED_KERNELS]> = OnceLock::new();
    SPECS.get_or_init(|| {
        [
            KernelSpec {
                id: KernelId::Multiplier,
                name: "multiplier",
                artifact: Some("multiplier"),
                batch_words: BRIDGE_BUFFER_WORDS,
                latency_base: 1,
                latency_per_word: 0,
                output_mask: u32::MAX,
                luts: crate::area::table1::WB_MULTIPLIER.luts,
                ffs: crate::area::table1::WB_MULTIPLIER.ffs,
                behavior: &MULTIPLIER_BEHAVIOR,
            },
            KernelSpec {
                id: KernelId::HammingEncoder,
                name: "hamming_enc",
                artifact: Some("hamming_enc"),
                batch_words: BRIDGE_BUFFER_WORDS,
                latency_base: 1,
                latency_per_word: 0,
                output_mask: hamming::CODE_MASK,
                luts: crate::area::table1::WB_HAMMING_ENCODER.luts,
                ffs: crate::area::table1::WB_HAMMING_ENCODER.ffs,
                behavior: &ENCODER_BEHAVIOR,
            },
            KernelSpec {
                id: KernelId::HammingDecoder,
                name: "hamming_dec",
                artifact: Some("hamming_dec"),
                batch_words: BRIDGE_BUFFER_WORDS,
                latency_base: 1,
                latency_per_word: 0,
                output_mask: hamming::DATA_MASK,
                luts: crate::area::table1::HAMMING_DECODER.luts,
                ffs: crate::area::table1::HAMMING_DECODER.ffs,
                behavior: &DECODER_BEHAVIOR,
            },
        ]
    })
}

// ---------------------------------------------------------------------
// Table family.

/// The parameterized word transform of a table-driven kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TableOp {
    Mul,
    Add,
    Xor,
    Rotl,
    And,
}

impl TableOp {
    fn parse(s: &str) -> Option<TableOp> {
        match s {
            "mul" => Some(TableOp::Mul),
            "add" => Some(TableOp::Add),
            "xor" => Some(TableOp::Xor),
            "rotl" => Some(TableOp::Rotl),
            "and" => Some(TableOp::And),
            _ => None,
        }
    }
}

struct TableBehavior {
    op: TableOp,
    operand: u32,
    mask: u32,
    latency_base: u32,
    latency_per_word: u32,
}

impl ModuleBehavior for TableBehavior {
    fn apply_word(&self, w: u32) -> u32 {
        let x = match self.op {
            TableOp::Mul => w.wrapping_mul(self.operand),
            TableOp::Add => w.wrapping_add(self.operand),
            TableOp::Xor => w ^ self.operand,
            TableOp::Rotl => w.rotate_left(self.operand % 32),
            TableOp::And => w & self.operand,
        };
        x & self.mask
    }
    fn compute_cycles(&self, batch_words: usize) -> u32 {
        self.latency_base + self.latency_per_word * batch_words as u32
    }
}

// ---------------------------------------------------------------------
// Artifact family.

struct ArtifactBehavior {
    kernel: crate::runtime::StageFn,
    latency_base: u32,
    latency_per_word: u32,
}

impl ModuleBehavior for ArtifactBehavior {
    fn apply_word(&self, w: u32) -> u32 {
        (self.kernel)(&[w])[0]
    }
    fn apply_buf(&self, buf: &[u32]) -> Vec<u32> {
        (self.kernel)(buf)
    }
    fn compute_cycles(&self, batch_words: usize) -> u32 {
        self.latency_base + self.latency_per_word * batch_words as u32
    }
}

// ---------------------------------------------------------------------
// Declarations (the `[kernels.<name>]` schema) and registration.

/// A parsed kernel declaration — the owned, validated form of one
/// `[kernels.<name>]` config table (or a `--kernels` file entry).
/// Exactly one family marker must be set: `op` (table-driven) or
/// `artifact` (AOT-artifact-backed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelDecl {
    /// Unique kernel name (the subtable key).
    pub name: String,
    /// Table family: the word transform (`mul`/`add`/`xor`/`rotl`/`and`).
    pub op: Option<String>,
    /// Table family: the transform's constant operand.
    pub operand: u32,
    /// Output mask (`output & mask == output` contract); defaults to
    /// all ones.
    pub mask: u32,
    /// Artifact family: the manifest key to execute.
    pub artifact: Option<String>,
    /// Artifact family: declared input geometry, cross-checked against
    /// the manifest entry (a mismatch is refused as a geometry lie).
    pub input_words: Option<usize>,
    /// Module batch size in words (must divide the 8-word burst).
    pub batch_words: usize,
    /// Latency model: fixed cycles per batch (≥ 1).
    pub latency_base: u32,
    /// Latency model: cycles per word.
    pub latency_per_word: u32,
    /// Area model: LUTs.
    pub luts: u64,
    /// Area model: flip-flops.
    pub ffs: u64,
}

impl Default for KernelDecl {
    fn default() -> Self {
        Self {
            name: String::new(),
            op: None,
            operand: 1,
            mask: u32::MAX,
            artifact: None,
            input_words: None,
            batch_words: BRIDGE_BUFFER_WORDS,
            latency_base: 1,
            latency_per_word: 0,
            luts: 64,
            ffs: 64,
        }
    }
}

struct Registered {
    spec: &'static KernelSpec,
    decl: KernelDecl,
}

fn registry() -> &'static RwLock<Vec<Registered>> {
    static REG: OnceLock<RwLock<Vec<Registered>>> = OnceLock::new();
    REG.get_or_init(|| RwLock::new(Vec::new()))
}

/// Seed kernel names — reserved; a declaration may not shadow them.
fn seed_by_name(name: &str) -> Option<KernelId> {
    match name {
        "multiplier" => Some(KernelId::Multiplier),
        "hamming_enc" => Some(KernelId::HammingEncoder),
        "hamming_dec" => Some(KernelId::HammingDecoder),
        _ => None,
    }
}

/// Resolve a kernel name to its id: seeds first, then the registry.
pub fn lookup(name: &str) -> Option<KernelId> {
    if let Some(id) = seed_by_name(name) {
        return Some(id);
    }
    let reg = registry().read().unwrap();
    reg.iter().find(|r| r.spec.name == name).map(|r| r.spec.id)
}

/// Resolve a kernel name or refuse with a typed error naming the
/// known kernels (no panic, no silent default).
pub fn resolve(name: &str) -> Result<KernelId> {
    lookup(name).ok_or_else(|| {
        let reg = registry().read().unwrap();
        let mut known: Vec<&str> =
            seed_specs().iter().map(|s| s.name).collect();
        known.extend(reg.iter().map(|r| r.spec.name));
        ElasticError::Config(format!(
            "unknown kernel '{name}' (known: {})",
            known.join(", ")
        ))
    })
}

/// Names of every registered kernel, seeds first then registration
/// order (the order `[kernels]` tables install in: sorted, because the
/// TOML doc is a BTreeMap).
pub fn names() -> Vec<&'static str> {
    let mut out: Vec<&'static str> =
        seed_specs().iter().map(|s| s.name).collect();
    let reg = registry().read().unwrap();
    out.extend(reg.iter().map(|r| r.spec.name));
    out
}

fn validate(decl: &KernelDecl, manifest: Option<&ArtifactManifest>) -> Result<()> {
    let name = &decl.name;
    if name.is_empty() {
        return Err(ElasticError::Config("kernel name must be non-empty".into()));
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-')
    {
        return Err(ElasticError::Config(format!(
            "kernel name '{name}' must be lowercase [a-z0-9_-]"
        )));
    }
    if seed_by_name(name).is_some() {
        return Err(ElasticError::Config(format!(
            "kernel name '{name}' is reserved for a built-in seed kernel"
        )));
    }
    match (&decl.op, &decl.artifact) {
        (Some(_), Some(_)) => {
            return Err(ElasticError::Config(format!(
                "kernel '{name}': declare either op or artifact, not both"
            )));
        }
        (None, None) => {
            return Err(ElasticError::Config(format!(
                "kernel '{name}': missing family — declare op (table-driven) \
                 or artifact (AOT-backed)"
            )));
        }
        _ => {}
    }
    if let Some(op) = &decl.op {
        if TableOp::parse(op).is_none() {
            return Err(ElasticError::Config(format!(
                "kernel '{name}': unknown op '{op}' \
                 (known: mul, add, xor, rotl, and)"
            )));
        }
        if decl.mask == 0 {
            return Err(ElasticError::Config(format!(
                "kernel '{name}': output mask must be non-zero"
            )));
        }
    }
    if decl.latency_base == 0 || decl.latency_base > MAX_LATENCY_BASE {
        return Err(ElasticError::Config(format!(
            "kernel '{name}': latency_base {} outside 1..={MAX_LATENCY_BASE}",
            decl.latency_base
        )));
    }
    if decl.latency_per_word > MAX_LATENCY_PER_WORD {
        return Err(ElasticError::Config(format!(
            "kernel '{name}': latency_per_word {} above {MAX_LATENCY_PER_WORD}",
            decl.latency_per_word
        )));
    }
    if decl.batch_words == 0
        || decl.batch_words > BRIDGE_BUFFER_WORDS
        || BRIDGE_BUFFER_WORDS % decl.batch_words != 0
    {
        return Err(ElasticError::Config(format!(
            "kernel '{name}': batch_words {} must divide the \
             {BRIDGE_BUFFER_WORDS}-word bridge burst",
            decl.batch_words
        )));
    }
    if let Some(artifact) = &decl.artifact {
        let manifest = manifest.ok_or_else(|| {
            ElasticError::Artifact(format!(
                "kernel '{name}': artifact-backed declaration needs an \
                 artifact manifest (is the artifact directory configured?)"
            ))
        })?;
        let entry = manifest.get(artifact).ok_or_else(|| {
            ElasticError::Artifact(format!(
                "kernel '{name}': artifact '{artifact}' not in the manifest"
            ))
        })?;
        if entry.dtype != "u32" {
            return Err(ElasticError::Artifact(format!(
                "kernel '{name}': artifact '{artifact}' dtype '{}' is not u32",
                entry.dtype
            )));
        }
        if let Some(declared) = decl.input_words {
            if declared != entry.input_words {
                return Err(ElasticError::Artifact(format!(
                    "kernel '{name}': declared input_words {declared} \
                     contradicts the manifest ({} for '{artifact}')",
                    entry.input_words
                )));
            }
        }
        if crate::runtime::interpreter_kernel(artifact).is_none() {
            return Err(ElasticError::Artifact(format!(
                "kernel '{name}': no interpreter kernel for artifact \
                 '{artifact}' — the offline runtime cannot execute it"
            )));
        }
    }
    Ok(())
}

fn build_behavior(decl: &KernelDecl) -> &'static dyn ModuleBehavior {
    if let Some(op) = &decl.op {
        Box::leak(Box::new(TableBehavior {
            op: TableOp::parse(op).expect("validated op"),
            operand: decl.operand,
            mask: decl.mask,
            latency_base: decl.latency_base,
            latency_per_word: decl.latency_per_word,
        }))
    } else {
        let artifact = decl.artifact.as_deref().expect("validated family");
        Box::leak(Box::new(ArtifactBehavior {
            kernel: crate::runtime::interpreter_kernel(artifact)
                .expect("validated artifact"),
            latency_base: decl.latency_base,
            latency_per_word: decl.latency_per_word,
        }))
    }
}

fn register_locked(
    reg: &mut Vec<Registered>,
    decl: KernelDecl,
    behavior: &'static dyn ModuleBehavior,
    output_mask: u32,
) -> Result<KernelId> {
    if let Some(existing) = reg.iter().find(|r| r.spec.name == decl.name) {
        // Idempotent on byte-identical redefinition (parallel tests and
        // repeated example/bench setup); conflicting redefinition is a
        // typed refusal — never a silent shadow.
        if existing.decl == decl {
            return Ok(existing.spec.id);
        }
        return Err(ElasticError::Config(format!(
            "duplicate kernel name '{}' with a conflicting definition",
            decl.name
        )));
    }
    let idx = reg.len() + SEED_KERNELS;
    if idx > u16::MAX as usize {
        return Err(ElasticError::Config("kernel registry full".into()));
    }
    let id = KernelId(idx as u16);
    let name: &'static str = Box::leak(decl.name.clone().into_boxed_str());
    let artifact: Option<&'static str> = decl
        .artifact
        .clone()
        .map(|a| &*Box::leak(a.into_boxed_str()));
    let spec: &'static KernelSpec = Box::leak(Box::new(KernelSpec {
        id,
        name,
        artifact,
        batch_words: decl.batch_words,
        latency_base: decl.latency_base,
        latency_per_word: decl.latency_per_word,
        output_mask,
        luts: decl.luts,
        ffs: decl.ffs,
        behavior,
    }));
    reg.push(Registered { spec, decl });
    Ok(id)
}

/// Validate and register one kernel declaration.  Artifact-backed
/// declarations need the manifest for the geometry/dtype cross-check.
/// Registering the same name with a byte-identical declaration returns
/// the existing id; a conflicting redefinition, a reserved seed name,
/// or an invalid spec is refused with a typed error.
pub fn register(
    decl: KernelDecl,
    manifest: Option<&ArtifactManifest>,
) -> Result<KernelId> {
    validate(&decl, manifest)?;
    let behavior = build_behavior(&decl);
    let output_mask = if decl.op.is_some() { decl.mask } else { u32::MAX };
    let mut reg = registry().write().unwrap();
    register_locked(&mut reg, decl, behavior, output_mask)
}

/// Register every declaration of a parsed `[kernels]` config section
/// (or `--kernels` file), refusing duplicates *within the batch* even
/// when the definitions agree — one source must not declare a kernel
/// twice.  Returns the ids in declaration order.
pub fn install_declared(
    decls: &[KernelDecl],
    manifest: Option<&ArtifactManifest>,
) -> Result<Vec<KernelId>> {
    for (i, d) in decls.iter().enumerate() {
        if decls[..i].iter().any(|e| e.name == d.name) {
            return Err(ElasticError::Config(format!(
                "duplicate kernel name '{}' in one declaration set",
                d.name
            )));
        }
    }
    decls
        .iter()
        .map(|d| register(d.clone(), manifest))
        .collect()
}

// ---------------------------------------------------------------------
// Hostile-spec hook (boundary property tests only).

/// Test-only registration of deliberately misbehaving kernels,
/// bypassing validation so `tests/kernel_boundary.rs` can prove the
/// shell contains them.  Hidden from docs; never reachable from config.
#[doc(hidden)]
pub mod hostile {
    use super::*;

    /// How the hostile kernel violates the output contract.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum HostileMode {
        /// Emits one word fewer than the batch (wrong output length).
        ShortOutput,
        /// Emits one word more than the batch (wrong output length).
        LongOutput,
        /// Emits all-ones words while declaring a 26-bit output mask.
        OutOfMask,
    }

    struct HostileBehavior {
        mode: HostileMode,
    }

    impl ModuleBehavior for HostileBehavior {
        fn apply_word(&self, w: u32) -> u32 {
            w
        }
        fn apply_buf(&self, buf: &[u32]) -> Vec<u32> {
            match self.mode {
                HostileMode::ShortOutput => {
                    buf[..buf.len().saturating_sub(1)].to_vec()
                }
                HostileMode::LongOutput => {
                    let mut v = buf.to_vec();
                    v.push(0);
                    v
                }
                HostileMode::OutOfMask => vec![u32::MAX; buf.len()],
            }
        }
        fn compute_cycles(&self, _batch_words: usize) -> u32 {
            1
        }
    }

    /// Register a hostile kernel under `name` (idempotent per name+mode).
    pub fn register(name: &str, mode: HostileMode) -> KernelId {
        let decl = KernelDecl {
            name: name.to_string(),
            op: Some(format!("hostile:{mode:?}")),
            ..KernelDecl::default()
        };
        let behavior: &'static dyn ModuleBehavior =
            Box::leak(Box::new(HostileBehavior { mode }));
        let mask = match mode {
            HostileMode::OutOfMask => hamming::DATA_MASK,
            _ => u32::MAX,
        };
        let mut reg = super::registry().write().unwrap();
        super::register_locked(&mut reg, decl, behavior, mask)
            .expect("hostile registration is name-unique per test")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hamming::{DATA_MASK, MULT_CONSTANT};

    #[test]
    fn seed_specs_are_byte_identical_to_the_legacy_enum() {
        assert_eq!(KernelId::Multiplier.name(), "multiplier");
        assert_eq!(KernelId::HammingEncoder.name(), "hamming_enc");
        assert_eq!(KernelId::HammingDecoder.name(), "hamming_dec");
        assert_eq!(KernelId::Multiplier.artifact(), "multiplier");
        assert_eq!(KernelId::Multiplier.pjrt_artifact(), Some("multiplier"));
        let x = 0xDEAD_BEEF;
        assert_eq!(
            KernelId::Multiplier.apply_word(x),
            x.wrapping_mul(MULT_CONSTANT)
        );
        let enc = KernelId::HammingEncoder.apply_word(x);
        assert_eq!(KernelId::HammingDecoder.apply_word(enc), x & DATA_MASK);
        for id in KernelId::pipeline() {
            let spec = id.spec();
            assert_eq!(spec.batch_words, BRIDGE_BUFFER_WORDS);
            assert_eq!(spec.compute_latency(), 1, "seed latency is 1 cc");
            assert!(spec.luts > 0 && spec.ffs > 0, "Table I anchor");
        }
    }

    #[test]
    fn seed_masks_are_true_invariants() {
        for w in [0u32, 1, 0xFFFF_FFFF, 0x1234_5678, DATA_MASK] {
            for id in KernelId::pipeline() {
                let out = id.apply_word(w);
                let mask = id.spec().output_mask;
                assert_eq!(out & mask, out, "{id:?} violates its own mask");
            }
        }
    }

    #[test]
    fn table_kernel_semantics_and_latency() {
        let id = register(
            KernelDecl {
                name: "t-xor7".into(),
                op: Some("xor".into()),
                operand: 7,
                mask: 0xFFFF,
                latency_base: 3,
                latency_per_word: 2,
                batch_words: 4,
                ..KernelDecl::default()
            },
            None,
        )
        .unwrap();
        assert!(!id.is_seed());
        assert_eq!(id.name(), "t-xor7");
        assert_eq!(id.pjrt_artifact(), None, "table kernels skip PJRT");
        assert_eq!(id.apply_word(0x0001_0203), (0x0001_0203 ^ 7) & 0xFFFF);
        assert_eq!(id.spec().compute_latency(), 3 + 2 * 4);
        assert_eq!(id.fast_forward_countdown(10, 4), 6);
        // Idempotent re-registration, conflicting redefinition refused.
        let again = register(
            KernelDecl {
                name: "t-xor7".into(),
                op: Some("xor".into()),
                operand: 7,
                mask: 0xFFFF,
                latency_base: 3,
                latency_per_word: 2,
                batch_words: 4,
                ..KernelDecl::default()
            },
            None,
        )
        .unwrap();
        assert_eq!(again, id);
        let conflict = register(
            KernelDecl {
                name: "t-xor7".into(),
                op: Some("xor".into()),
                operand: 8,
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(conflict, Err(ElasticError::Config(_))));
    }

    #[test]
    fn hostile_declarations_are_refused_typed() {
        let reserved = register(
            KernelDecl {
                name: "multiplier".into(),
                op: Some("mul".into()),
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(reserved, Err(ElasticError::Config(_))));

        let zero_latency = register(
            KernelDecl {
                name: "t-zero".into(),
                op: Some("mul".into()),
                latency_base: 0,
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(zero_latency, Err(ElasticError::Config(_))));

        let absurd = register(
            KernelDecl {
                name: "t-absurd".into(),
                op: Some("mul".into()),
                latency_base: u32::MAX,
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(absurd, Err(ElasticError::Config(_))));

        let bad_batch = register(
            KernelDecl {
                name: "t-batch3".into(),
                op: Some("mul".into()),
                batch_words: 3,
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(bad_batch, Err(ElasticError::Config(_))));

        let bad_op = register(
            KernelDecl {
                name: "t-badop".into(),
                op: Some("div".into()),
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(bad_op, Err(ElasticError::Config(_))));

        let no_family = register(
            KernelDecl { name: "t-nofam".into(), ..KernelDecl::default() },
            None,
        );
        assert!(matches!(no_family, Err(ElasticError::Config(_))));
    }

    #[test]
    fn artifact_kernel_validates_against_the_manifest() {
        let manifest = ArtifactManifest::parse(
            r#"{"multiplier": {"file": "multiplier.hlo.txt",
                 "input_words": 4096, "dtype": "u32", "sha256": ""}}"#,
        )
        .unwrap();
        // Geometry lie: declared input_words contradicts the manifest.
        let lie = register(
            KernelDecl {
                name: "a-mult-lie".into(),
                artifact: Some("multiplier".into()),
                input_words: Some(1024),
                ..KernelDecl::default()
            },
            Some(&manifest),
        );
        assert!(matches!(lie, Err(ElasticError::Artifact(_))));
        // Unknown artifact.
        let unknown = register(
            KernelDecl {
                name: "a-ghost".into(),
                artifact: Some("ghost".into()),
                ..KernelDecl::default()
            },
            Some(&manifest),
        );
        assert!(matches!(unknown, Err(ElasticError::Artifact(_))));
        // No manifest at all.
        let missing = register(
            KernelDecl {
                name: "a-nomanifest".into(),
                artifact: Some("multiplier".into()),
                ..KernelDecl::default()
            },
            None,
        );
        assert!(matches!(missing, Err(ElasticError::Artifact(_))));
        // Honest declaration: executes the interpreter kernel.
        let ok = register(
            KernelDecl {
                name: "a-mult".into(),
                artifact: Some("multiplier".into()),
                input_words: Some(4096),
                latency_base: 2,
                ..KernelDecl::default()
            },
            Some(&manifest),
        )
        .unwrap();
        assert_eq!(ok.pjrt_artifact(), Some("multiplier"));
        let x = [5u32, 6, 7];
        assert_eq!(
            ok.apply_buf(&x),
            x.iter().map(|&w| w.wrapping_mul(MULT_CONSTANT)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn resolve_refuses_unknown_names() {
        assert_eq!(resolve("multiplier").unwrap(), KernelId::Multiplier);
        let err = resolve("no-such-kernel");
        assert!(matches!(err, Err(ElasticError::Config(_))));
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("no-such-kernel"), "{msg}");
        assert!(msg.contains("multiplier"), "names the known set: {msg}");
    }

    #[test]
    fn install_declared_refuses_in_batch_duplicates() {
        let d = KernelDecl {
            name: "t-dup".into(),
            op: Some("add".into()),
            ..KernelDecl::default()
        };
        let err = install_declared(&[d.clone(), d], None);
        assert!(matches!(err, Err(ElasticError::Config(_))));
    }

    #[test]
    fn debug_prints_kernel_names() {
        assert_eq!(format!("{:?}", KernelId::Multiplier), "multiplier");
        let id = register(
            KernelDecl {
                name: "t-debug".into(),
                op: Some("and".into()),
                operand: 0xFF,
                ..KernelDecl::default()
            },
            None,
        )
        .unwrap();
        assert_eq!(format!("{id:?}"), "t-debug");
    }
}
