//! WISHBONE bus interfaces (§II.B, §IV.F).
//!
//! The paper's modules talk WISHBONE B4: master initiates read/write
//! requests, slave acks or stalls; a built-in handshake removes the need
//! for extra transmission-safety logic.  This module holds the two
//! interface FSMs exactly as §IV.F describes them:
//!
//! * [`MasterIf`] — latches the module's request, provides the one-hot
//!   destination to the crossbar, runs watchdog timers for grant and ack,
//!   streams one data word per cycle once granted, stalls when the slave
//!   de-asserts ack, and registers the final error/success status.
//! * [`SlaveIf`] — enables its registers for incoming data while they hold
//!   no unread data, acks each word, stalls when full, and resumes when the
//!   computation module signals it has read the buffer.
//!
//! Cycle semantics are pinned by the §V.E walkthrough; the crossbar
//! ([`crate::crossbar`]) sequences these FSMs so that best-case
//! time-to-grant is exactly 4 cc and an 8-package request completes in
//! exactly 13 cc (tests in `crossbar`).

use std::collections::VecDeque;

/// WISHBONE transaction error codes, as stored in the register file
/// (§IV.D: "error codes marking communication failure due to either wrong
/// destination address or timeout due to unresponsive destination").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WbError {
    /// The master sent a destination address outside its allowed set, or
    /// a malformed (non-one-hot) address (§IV.E.2).
    InvalidDestination,
    /// Watchdog expiry while waiting for a grant (§IV.F.1).
    GrantTimeout,
    /// Watchdog expiry while waiting for a stalled slave's ack (§IV.F.1).
    AckTimeout,
    /// The targeted port is held in reset (§IV.C: during partial
    /// reconfiguration the port must not participate).
    PortInReset,
    /// The hosted kernel emitted a batch violating its registered
    /// output contract — wrong word count or an out-of-mask word
    /// (DESIGN.md §17 boundary validation).  The shell drops the batch
    /// and latches this code instead of routing corrupt data.
    ContractViolation,
}

impl WbError {
    /// Register-file encoding (Table III error-status registers).
    pub fn code(self) -> u32 {
        match self {
            WbError::InvalidDestination => 0x1,
            WbError::GrantTimeout => 0x2,
            WbError::AckTimeout => 0x3,
            WbError::PortInReset => 0x4,
            WbError::ContractViolation => 0x5,
        }
    }

    /// Decode a register-file error code.
    pub fn from_code(code: u32) -> Option<Self> {
        match code {
            0x1 => Some(WbError::InvalidDestination),
            0x2 => Some(WbError::GrantTimeout),
            0x3 => Some(WbError::AckTimeout),
            0x4 => Some(WbError::PortInReset),
            0x5 => Some(WbError::ContractViolation),
            _ => None,
        }
    }
}

/// A transfer job handed to a master interface by its computation module
/// (or bridge): send `words` to the slave named by `dest_onehot`.
#[derive(Debug, Clone)]
pub struct Job {
    /// One-hot destination slave address (§IV.E.2).
    pub dest_onehot: u32,
    /// Payload words, streamed one per cycle once granted.
    pub words: Vec<u32>,
    /// Application ID tag (the paper tags user data with an app ID; we
    /// carry it as sideband metadata — DESIGN.md notes the deviation).
    pub app_id: u32,
    /// Request originates *inside* the master interface (the AXI-WB
    /// bridge case, §IV.G): skips the module→interface latch cycle, so
    /// the best-case grant arrives "after 3 clock cycles" instead of 4.
    pub pre_latched: bool,
}

impl Job {
    /// Convenience constructor for module-originated jobs.
    pub fn new(dest_onehot: u32, words: Vec<u32>, app_id: u32) -> Self {
        Self { dest_onehot, words, app_id, pre_latched: false }
    }

    /// Constructor for bridge-originated jobs (no latch cycle).
    pub fn pre_latched(dest_onehot: u32, words: Vec<u32>, app_id: u32) -> Self {
        Self { dest_onehot, words, app_id, pre_latched: true }
    }
}

/// Master-interface FSM state.  State names describe what has *completed*
/// as of the end of the last tick (see crossbar cycle walkthrough).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MasterState {
    /// No job in flight.
    Idle,
    /// cc consumed: module request latched by the master interface
    /// ("2 ccs for the module's request to reach the master interface and
    /// for it to initiate a request" — this is the first of the two).
    Latched,
    /// Waiting for the slave port's arbiter to grant (request issued,
    /// isolation check passed).
    WaitGrant,
    /// The target slave is busy serving another master; the interface has
    /// withdrawn its request and waits for the bus to free.
    WaitFree,
    /// Granted: streaming one word per cycle.
    Sending,
    /// Slave stalled (buffer full); transmission paused.
    Stalled,
    /// Final cycle: error/success status being registered.
    Status,
}

/// Per-master bookkeeping the crossbar sequences.
#[derive(Debug)]
pub struct MasterIf {
    /// Current FSM state.
    pub state: MasterState,
    /// Job queue from the module (front = in flight).
    pub queue: VecDeque<Job>,
    /// Words of the in-flight job already delivered.
    pub sent: usize,
    /// Words delivered in the current grant (for WRR package chopping).
    pub sent_in_grant: u32,
    /// Cycle at which the in-flight job was latched (for time-to-grant).
    pub request_cycle: u64,
    /// Cycle of the first grant for the in-flight job (0 = not yet).
    pub first_grant_cycle: u64,
    /// Watchdog counter (grant or ack wait).
    pub waited: u64,
    /// Isolation mask: one-hot OR of slaves this master may address
    /// (Table III "Allowed Addresses of Port N Master").
    pub allowed_slaves: u32,
    /// Held in reset by the register file (§IV.C).
    pub in_reset: bool,
    /// Outcome to register during the Status cycle.
    pub pending_status: Option<Result<(), WbError>>,
}

impl MasterIf {
    /// New idle interface with the given isolation mask.
    pub fn new(allowed_slaves: u32) -> Self {
        Self {
            state: MasterState::Idle,
            queue: VecDeque::new(),
            sent: 0,
            sent_in_grant: 0,
            request_cycle: 0,
            first_grant_cycle: 0,
            waited: 0,
            allowed_slaves,
            in_reset: false,
            pending_status: None,
        }
    }

    /// The in-flight job, if any.
    pub fn job(&self) -> Option<&Job> {
        self.queue.front()
    }

    /// Words remaining in the in-flight job.
    pub fn remaining(&self) -> usize {
        self.job().map(|j| j.words.len() - self.sent).unwrap_or(0)
    }

    /// Enqueue a new transfer job.
    pub fn push_job(&mut self, job: Job) {
        self.queue.push_back(job);
    }

    /// Apply a reset pulse: abort everything (§IV.C isolation during PR).
    pub fn reset(&mut self) {
        self.state = MasterState::Idle;
        self.queue.clear();
        self.sent = 0;
        self.sent_in_grant = 0;
        self.waited = 0;
        self.pending_status = None;
    }
}

/// Slave-interface FSM: an N-word receive buffer with stall semantics.
#[derive(Debug)]
pub struct SlaveIf {
    /// Received words awaiting the module's read, with source port tags.
    pub rx: VecDeque<(u32, usize)>,
    /// Register capacity in words (paper prototype: 8).
    pub capacity: usize,
    /// Held in reset by the register file.
    pub in_reset: bool,
    /// Total words accepted (stats).
    pub words_accepted: u64,
    /// Cycles in which a master was stalled on this slave (stats).
    pub stall_cycles: u64,
}

impl SlaveIf {
    /// New empty interface with `capacity`-word registers.
    pub fn new(capacity: usize) -> Self {
        Self {
            rx: VecDeque::with_capacity(capacity),
            capacity,
            in_reset: false,
            words_accepted: 0,
            stall_cycles: 0,
        }
    }

    /// Can a new word be registered this cycle?  (§IV.F.2: registers are
    /// enabled "provided those registers currently do not contain any
    /// unread data" — modelled at word granularity by the buffer.)
    pub fn can_accept(&self) -> bool {
        !self.in_reset && self.rx.len() < self.capacity
    }

    /// Register one incoming word from `src`.  Caller must have checked
    /// [`SlaveIf::can_accept`].
    pub fn accept(&mut self, word: u32, src: usize) {
        debug_assert!(self.can_accept());
        self.rx.push_back((word, src));
        self.words_accepted += 1;
    }

    /// The module reads up to `max` words ("the module triggers the slave
    /// interface once it has read the data").
    pub fn drain(&mut self, max: usize) -> Vec<(u32, usize)> {
        let take = max.min(self.rx.len());
        self.rx.drain(..take).collect()
    }

    /// Apply a reset pulse.
    pub fn reset(&mut self) {
        self.rx.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_roundtrip() {
        for e in [
            WbError::InvalidDestination,
            WbError::GrantTimeout,
            WbError::AckTimeout,
            WbError::PortInReset,
            WbError::ContractViolation,
        ] {
            assert_eq!(WbError::from_code(e.code()), Some(e));
        }
        assert_eq!(WbError::from_code(0), None);
        assert_eq!(WbError::from_code(99), None);
    }

    #[test]
    fn slave_if_stalls_at_capacity() {
        let mut s = SlaveIf::new(2);
        assert!(s.can_accept());
        s.accept(1, 0);
        s.accept(2, 0);
        assert!(!s.can_accept());
        let read = s.drain(1);
        assert_eq!(read, vec![(1, 0)]);
        assert!(s.can_accept());
    }

    #[test]
    fn slave_if_reset_clears_buffer() {
        let mut s = SlaveIf::new(4);
        s.accept(7, 1);
        s.reset();
        assert!(s.rx.is_empty());
        assert_eq!(s.words_accepted, 1, "stats survive reset");
    }

    #[test]
    fn master_if_reset_aborts_queue() {
        let mut m = MasterIf::new(0b1111);
        m.push_job(Job::new(0b0010, vec![1, 2, 3], 0));
        m.state = MasterState::Sending;
        m.sent = 1;
        m.reset();
        assert_eq!(m.state, MasterState::Idle);
        assert!(m.queue.is_empty());
        assert_eq!(m.remaining(), 0);
    }
}
