//! Multi-tenant serving loop: the deployment shape the paper's cloud
//! story implies (apps submit acceleration requests; the manager
//! allocates PR regions elastically; overflow compute runs on the
//! server) — generalized to a **fabric-count-generic** scheduler so one
//! server can front a whole board fleet.
//!
//! Architecture (std::thread + mpsc — tokio is unavailable offline, see
//! DESIGN.md §7):
//!
//! ```text
//!   clients --submit--> [bounded queue] --> admission thread
//!                                            | policy picks a lane from
//!                                            | shared per-lane counters
//!                mpsc per lane               v
//!          [lane executor 0] [lane executor 1] ... one thread per fabric;
//!                  \              |              FPGA prefix + per-lane
//!                   \             |              autoscale tick run here
//!                    v            v
//!                      [worker pool] -- on-server suffix stages
//!                            |
//!                            v
//!                      response channels
//! ```
//!
//! Each fabric lane is an independent synchronous design (as in
//! hardware), so each gets its own executor thread: the FPGA prefix of
//! lane 0 no longer blocks admission to lane 1.  The admission thread
//! only routes — the policy ([`AdmissionPolicy`], shared with the
//! [`crate::fleet`] trace simulator) reads shared per-lane counters
//! ([`LaneStatus`]: admitted/completed depth, published spare bandwidth)
//! plus its own deterministic forwarded counts, so sticky pinning stays
//! run-to-run deterministic (pinned by `tests/fleet_server.rs`).
//! CPU-suffix work still fans out to a shared worker pool — pipeline
//! parallelism across requests on top of lane parallelism across
//! fabrics.  The bounded queue provides backpressure: `submit` blocks
//! when `queue_depth` requests are in flight.
//!
//! Every lane's fabric drive rides the busy-period horizon fast-path
//! (`ElasticManager.fast_path`, on by default — DESIGN.md §12): FPGA
//! prefixes and the lane autoscaler's ICAP reconfigurations execute
//! only their interesting cycles while staying cycle-exact with the
//! oracle, so wall-clock serving throughput scales with *work*, not
//! with modeled ICAP latency.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::SystemConfig;
use crate::fleet::AdmissionPolicy;
use crate::manager::{golden_chain, AppReport, AppRequest, ElasticManager, StagePlacement};
use crate::modules::ModuleKind;
use crate::runtime::RuntimeHandle;
use crate::sim::ControlCadence;
use crate::telemetry::{
    FlightDump, MetricsRegistry, RequestSpan, TraceEvent, Tracer, DEFAULT_FLIGHT_CAPACITY,
};
use crate::timing::{evaluate, ExecutionTimeline};
use crate::{ElasticError, Result};

/// Fleet shape of a serving instance.
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Number of independent fabrics the scheduler drives.
    pub fabrics: usize,
    /// Admission policy routing requests to fabrics.
    pub policy: AdmissionPolicy,
    /// Optional lane-level autoscaling tick interleaved with serving.
    pub autoscale: Option<LaneAutoscale>,
}

impl FleetOptions {
    /// The single-board shape of the original prototype.
    pub fn single() -> Self {
        Self {
            fabrics: 1,
            policy: AdmissionPolicy::LeastLoaded,
            autoscale: None,
        }
    }
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self::single()
    }
}

/// On-line lane elasticity — the serving-loop counterpart of the
/// trace-driven [`crate::autoscale::Engine`].  Each lane executor runs
/// its own control tick against its own demand (that lane's
/// admitted-minus-completed depth, [`LaneStatus`]), so one hot lane no
/// longer drags every cold lane through lockstep grow/shrink.  Ticks
/// fire on two cadences: every `every` admissions *to that lane*, and —
/// through a [`crate::sim::ControlCadence`] horizon on the lane's
/// virtual clock — every `every_cycles` fabric cycles, so a pending
/// control tick bounds the lane's jump instead of dragging it back to
/// cycle-stepping (DESIGN.md §13).  A shrink tick reserves one region
/// per app with work in flight on the lane (the cheap per-app
/// reservation floor), on top of `min_regions`.
#[derive(Debug, Clone, Copy)]
pub struct LaneAutoscale {
    /// Admissions to a lane between its control ticks (0 disables).
    pub every: usize,
    /// Fabric cycles of a lane's virtual clock between its control
    /// ticks (0 disables the cycle cadence).
    pub every_cycles: u64,
    /// Unfence one region when the lane's depth exceeds this.
    pub grow_above: usize,
    /// Fence one region when the lane's depth is at or below this
    /// (hysteresis: keep `grow_above > shrink_below`).
    pub shrink_below: usize,
    /// Regions each lane always keeps available.
    pub min_regions: usize,
}

impl Default for LaneAutoscale {
    fn default() -> Self {
        Self {
            every: 8,
            every_cycles: 0,
            grow_above: 8,
            shrink_below: 1,
            min_regions: 1,
        }
    }
}

/// Counters for the server's lane autoscaler.
#[derive(Debug, Default)]
pub struct ScaleStats {
    grows: AtomicU64,
    shrinks: AtomicU64,
}

impl ScaleStats {
    /// Control ticks that unfenced at least one region.
    pub fn grows(&self) -> u64 {
        self.grows.load(Ordering::Relaxed)
    }

    /// Control ticks that fenced at least one region.
    pub fn shrinks(&self) -> u64 {
        self.shrinks.load(Ordering::Relaxed)
    }
}

/// A finished request as the client sees it.
#[derive(Debug)]
pub struct Response {
    pub report: Result<AppReport>,
    /// Wall-clock service time (queue + fabric sim + on-server stages).
    pub wall: std::time::Duration,
    /// Fabric lane that served the request.
    pub fabric: usize,
    /// The lane's cumulative virtual clock (total fabric cycles it has
    /// ever consumed) when its executor picked the request up —
    /// deterministic, unlike `wall`.  It never drains, so it is a
    /// backlog *proxy* for ordering requests admitted to the same lane,
    /// not a latency: use the fleet simulator's `start - arrival` queue
    /// wait for that.
    pub queue_wait_cycles: u64,
}

enum WorkerMsg {
    CpuSuffix {
        req: AppRequest,
        partial: Vec<u32>,
        remaining: Vec<ModuleKind>,
        tl: ExecutionTimeline,
        fpga_stages: usize,
        placement: Vec<StagePlacement>,
        submitted: Instant,
        fabric: usize,
        queue_wait_cycles: u64,
        lane: Arc<LaneStatus>,
        respond: Sender<Response>,
    },
    Stop,
}

struct Submission {
    req: AppRequest,
    respond: Sender<Response>,
    submitted: Instant,
}

/// Simple counting semaphore (no external deps).
struct Semaphore {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Self { count: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    fn release(&self) {
        *self.count.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// The serving engine.
pub struct ElasticServer {
    submit_tx: Option<Sender<Submission>>,
    scheduler: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
    scale_stats: Arc<ScaleStats>,
    statuses: Vec<Arc<LaneStatus>>,
    flight_dumps: Arc<Mutex<Vec<FlightDump>>>,
}

/// Legacy name for the single-fabric shape.
pub type Server = ElasticServer;

impl ElasticServer {
    /// Start a single-fabric server (the original prototype shape).
    /// `runtime` is shared by all workers.
    pub fn start(cfg: SystemConfig, runtime: Option<RuntimeHandle>) -> Self {
        Self::start_fleet(cfg, FleetOptions::single(), runtime)
    }

    /// Start the scheduler + worker threads over `opts.fabrics`
    /// independent fabric lanes.
    pub fn start_fleet(
        cfg: SystemConfig,
        opts: FleetOptions,
        runtime: Option<RuntimeHandle>,
    ) -> Self {
        let (submit_tx, submit_rx) = channel::<Submission>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let slots = Arc::new(Semaphore::new(cfg.server.queue_depth));
        let in_flight = Arc::new(AtomicUsize::new(0));

        let worker_count = cfg.server.workers.max(1);
        let mut workers = Vec::new();
        for w in 0..worker_count {
            let rx = Arc::clone(&work_rx);
            let rt = runtime.clone();
            let cfg_w = cfg.clone();
            let slots_w = Arc::clone(&slots);
            let in_flight_w = Arc::clone(&in_flight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("efpga-worker-{w}"))
                    .spawn(move || {
                        worker_loop(rx, rt, cfg_w, slots_w, in_flight_w)
                    })
                    .expect("spawn worker"),
            );
        }

        let sched_cfg = cfg.clone();
        let sched_rt = runtime;
        let slots_s = Arc::clone(&slots);
        let in_flight_s = Arc::clone(&in_flight);
        let scale_stats = Arc::new(ScaleStats::default());
        let scale_stats_s = Arc::clone(&scale_stats);
        let statuses: Vec<Arc<LaneStatus>> = (0..opts.fabrics.max(1))
            .map(|_| Arc::new(LaneStatus::default()))
            .collect();
        let statuses_s = statuses.clone();
        let flight_dumps: Arc<Mutex<Vec<FlightDump>>> =
            Arc::new(Mutex::new(Vec::new()));
        let flight_dumps_s = Arc::clone(&flight_dumps);
        let scheduler = std::thread::Builder::new()
            .name("efpga-scheduler".into())
            .spawn(move || {
                scheduler_loop(
                    submit_rx,
                    work_tx,
                    sched_cfg,
                    opts,
                    sched_rt,
                    worker_count,
                    slots_s,
                    in_flight_s,
                    scale_stats_s,
                    statuses_s,
                    flight_dumps_s,
                )
            })
            .expect("spawn scheduler");

        Self {
            submit_tx: Some(submit_tx),
            scheduler: Some(scheduler),
            workers,
            slots,
            in_flight,
            scale_stats,
            statuses,
            flight_dumps,
        }
    }

    /// Submit a request; blocks while the queue is full (backpressure).
    /// Returns the channel the response will arrive on.
    pub fn submit(&self, req: AppRequest) -> Result<Receiver<Response>> {
        self.slots.acquire();
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.submit_tx
            .as_ref()
            .expect("server running")
            .send(Submission { req, respond: tx, submitted: Instant::now() })
            .map_err(|_| ElasticError::Server("scheduler gone".into()))?;
        Ok(rx)
    }

    /// Requests currently queued or executing.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Lane-autoscaler counters (all zero when autoscale is off).
    pub fn scale_stats(&self) -> &ScaleStats {
        &self.scale_stats
    }

    /// Shared per-lane counters, one [`LaneStatus`] per fabric lane.
    pub fn lane_statuses(&self) -> &[Arc<LaneStatus>] {
        &self.statuses
    }

    /// Point-in-time metrics snapshot (DESIGN.md §14): per-lane
    /// admitted/completed counters, depth/clock/spare-share gauges from
    /// the shared [`LaneStatus`] blocks, plus the autoscaler's
    /// grow/shrink totals.  Safe to call while the server is serving —
    /// the counters are the same atomics the admission policies read.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.set_gauge("server_in_flight", &[], self.in_flight() as f64);
        m.inc("server_scale_grows_total", &[], self.scale_stats.grows());
        m.inc("server_scale_shrinks_total", &[], self.scale_stats.shrinks());
        m.set_gauge(
            "server_flight_dumps",
            &[],
            self.flight_dumps.lock().unwrap().len() as f64,
        );
        for (i, lane) in self.statuses.iter().enumerate() {
            let l = i.to_string();
            let labels: [(&str, &str); 1] = [("lane", l.as_str())];
            m.inc(
                "lane_admitted_total",
                &labels,
                lane.admitted.load(Ordering::SeqCst),
            );
            m.inc(
                "lane_completed_total",
                &labels,
                lane.completed.load(Ordering::SeqCst),
            );
            m.set_gauge("lane_depth", &labels, lane.depth() as f64);
            m.set_gauge(
                "lane_clock_cycles",
                &labels,
                lane.clock.load(Ordering::SeqCst) as f64,
            );
            m.set_gauge(
                "lane_spare_share",
                &labels,
                lane.spare_share.load(Ordering::SeqCst) as f64,
            );
            m.inc("lane_batches_total", &labels, lane.batches());
            m.inc("lane_coalesced_total", &labels, lane.coalesced());
            m.set_gauge(
                "lane_resident_modules",
                &labels,
                lane.resident_modules().len() as f64,
            );
        }
        m
    }

    /// Flight-recorder dumps the lane executors collected on request
    /// errors (each carries the lane's last-N-events window).
    pub fn flight_dumps(&self) -> Vec<FlightDump> {
        self.flight_dumps.lock().unwrap().clone()
    }

    /// Stop accepting requests, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.submit_tx.take()); // scheduler's recv errors -> drains
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ElasticServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Shared per-lane state: written by the admission thread, the lane's
/// executor and the worker pool; read by the placement policies and the
/// lane's autoscale control tick.  This is the *per-lane* demand signal
/// — admitted-minus-completed depth and the apps with work in flight on
/// this lane — replacing the old global in-flight gauge that made one
/// hot lane drag every cold lane through the same grow/shrink decision.
#[derive(Debug, Default)]
pub struct LaneStatus {
    /// Requests the admission thread has routed to this lane.
    admitted: AtomicU64,
    /// Requests whose responses have been delivered.
    completed: AtomicU64,
    /// The lane executor's published virtual clock (cumulative fabric
    /// cycles consumed on this lane).
    clock: AtomicU64,
    /// The lane manager's published spare crossbar share (refreshed at
    /// startup and after each control tick).
    spare_share: AtomicU64,
    /// App id -> outstanding requests on this lane; the shrink tick's
    /// per-app reservation floor counts this map's keys.
    apps: Mutex<HashMap<u32, usize>>,
    /// Coalescing counters (DESIGN.md §15): batches of size ≥ 2 the
    /// lane executor formed, and the follower submissions that rode
    /// a leader's stream (skipping admission-cadence work and the
    /// per-request placement plan).
    batches: AtomicU64,
    coalesced: AtomicU64,
    /// Resident configuration-cache snapshot (DESIGN.md §16): the lane
    /// manager's parked `(region, module-kind-name)` pairs, refreshed
    /// by the lane executor after each batch.  Empty while the
    /// configuration cache is disabled.
    residents: Mutex<Vec<(usize, &'static str)>>,
}

impl LaneStatus {
    /// Requests admitted to this lane whose responses have not been
    /// delivered yet.
    pub fn depth(&self) -> usize {
        let admitted = self.admitted.load(Ordering::SeqCst);
        let completed = self.completed.load(Ordering::SeqCst);
        admitted.saturating_sub(completed) as usize
    }

    /// Distinct apps with work in flight on this lane.
    pub fn active_apps(&self) -> usize {
        self.apps.lock().unwrap().len()
    }

    /// Batches of size ≥ 2 this lane's executor has formed.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::SeqCst)
    }

    /// Submissions served as batch followers on this lane.
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::SeqCst)
    }

    /// The lane manager's parked configuration-cache entries as
    /// `(region, module-kind-name)` pairs — a point-in-time snapshot
    /// published by the lane executor (empty while the cache is off).
    pub fn resident_modules(&self) -> Vec<(usize, &'static str)> {
        self.residents.lock().unwrap().clone()
    }

    fn note_app(&self, app_id: u32) {
        *self.apps.lock().unwrap().entry(app_id).or_insert(0) += 1;
    }

    fn clear_app(&self, app_id: u32) {
        let mut apps = self.apps.lock().unwrap();
        if let Some(n) = apps.get_mut(&app_id) {
            *n -= 1;
            if *n == 0 {
                apps.remove(&app_id);
            }
        }
    }
}

/// Terminal bookkeeping for one request.  Every response path — worker
/// completion, lane-executor error, dead-channel recovery — must run
/// this exactly once, after `respond.send`: lane completion counter,
/// per-app in-flight map, global in-flight gauge, queue slot.
fn finish_request(
    lane: &LaneStatus,
    app_id: u32,
    in_flight: &AtomicUsize,
    slots: &Semaphore,
) {
    lane.completed.fetch_add(1, Ordering::SeqCst);
    lane.clear_app(app_id);
    in_flight.fetch_sub(1, Ordering::SeqCst);
    slots.release();
}

fn select_lane(
    statuses: &[Arc<LaneStatus>],
    forwarded: &[u64],
    pins: &mut HashMap<u32, usize>,
    policy: AdmissionPolicy,
    req: &AppRequest,
) -> usize {
    let least_loaded = || {
        (0..statuses.len())
            .min_by_key(|&i| (statuses[i].depth(), forwarded[i], i))
            .expect("server has lanes")
    };
    match policy {
        AdmissionPolicy::LeastLoaded => least_loaded(),
        AdmissionPolicy::StickyByApp => {
            if let Some(&pinned) = pins.get(&req.app_id) {
                pinned
            } else {
                // First placement keys on the admission thread's own
                // deterministic forwarded counts, not on racy depths:
                // sticky pinning must be run-to-run reproducible
                // (pinned by tests/fleet_server.rs).
                let chosen = (0..statuses.len())
                    .min_by_key(|&i| (forwarded[i], i))
                    .expect("server has lanes");
                pins.insert(req.app_id, chosen);
                chosen
            }
        }
        AdmissionPolicy::BandwidthAware => (0..statuses.len())
            .min_by_key(|&i| {
                let spare = statuses[i].spare_share.load(Ordering::SeqCst);
                (std::cmp::Reverse(spare), statuses[i].depth(), forwarded[i], i)
            })
            .expect("server has lanes"),
        AdmissionPolicy::PlanWeighted => (0..statuses.len())
            .min_by_key(|&i| {
                // Mirror `fleet::Fleet::plan_weighted`: the lane's
                // backlog (depth, the on-line analogue of the trace
                // simulator's busy-until horizon) inflated by the
                // inverse of its published spare bandwidth share.
                // Integer u128 arithmetic keeps the score exact.
                let depth = statuses[i].depth();
                let spare =
                    statuses[i].spare_share.load(Ordering::SeqCst).max(1) as u128;
                let score = depth as u128 * crate::qos::SHARE_UNIT as u128 / spare;
                (score, depth, forwarded[i], i)
            })
            .expect("server has lanes"),
    }
}

/// The admission thread: routes each submission to a lane executor and
/// never touches a fabric itself, so the FPGA prefix of lane 0 cannot
/// block admission to lane 1.
#[allow(clippy::too_many_arguments)]
fn scheduler_loop(
    submit_rx: Receiver<Submission>,
    work_tx: Sender<WorkerMsg>,
    cfg: SystemConfig,
    opts: FleetOptions,
    runtime: Option<RuntimeHandle>,
    worker_count: usize,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
    scale_stats: Arc<ScaleStats>,
    statuses: Vec<Arc<LaneStatus>>,
    flight_dumps: Arc<Mutex<Vec<FlightDump>>>,
) {
    let fabrics = statuses.len();
    let mut lane_txs = Vec::new();
    let mut lane_handles = Vec::new();
    for lane_idx in 0..fabrics {
        let (tx, rx) = channel::<Submission>();
        lane_txs.push(tx);
        let cfg_l = cfg.clone();
        let rt = runtime.clone();
        let status = Arc::clone(&statuses[lane_idx]);
        let work = work_tx.clone();
        let slots_l = Arc::clone(&slots);
        let in_flight_l = Arc::clone(&in_flight);
        let stats = Arc::clone(&scale_stats);
        let dumps = Arc::clone(&flight_dumps);
        let autoscale = opts.autoscale;
        lane_handles.push(
            std::thread::Builder::new()
                .name(format!("efpga-lane-{lane_idx}"))
                .spawn(move || {
                    lane_loop(
                        rx,
                        work,
                        cfg_l,
                        rt,
                        autoscale,
                        lane_idx,
                        status,
                        slots_l,
                        in_flight_l,
                        stats,
                        dumps,
                    )
                })
                .expect("spawn lane executor"),
        );
    }

    let mut pins: HashMap<u32, usize> = HashMap::new();
    let mut forwarded = vec![0u64; fabrics];
    while let Ok(sub) = submit_rx.recv() {
        let lane =
            select_lane(&statuses, &forwarded, &mut pins, opts.policy, &sub.req);
        forwarded[lane] += 1;
        let status = &statuses[lane];
        status.admitted.fetch_add(1, Ordering::SeqCst);
        status.note_app(sub.req.app_id);
        if let Err(send_err) = lane_txs[lane].send(sub) {
            // Lane executor died: fail the request without leaking its
            // queue slot or its lane bookkeeping.
            let sub = send_err.0;
            let app_id = sub.req.app_id;
            let _ = sub.respond.send(Response {
                report: Err(ElasticError::Server("lane executor gone".into())),
                wall: sub.submitted.elapsed(),
                fabric: lane,
                queue_wait_cycles: status.clock.load(Ordering::SeqCst),
            });
            finish_request(status, app_id, &in_flight, &slots);
        }
    }
    // Drain: close the lane queues, wait for every executor to flush
    // its backlog into the shared worker FIFO, then stop each worker
    // with exactly one Stop — FIFO order guarantees all lane work
    // precedes the stops.
    drop(lane_txs);
    for h in lane_handles {
        let _ = h.join();
    }
    for _ in 0..worker_count {
        let _ = work_tx.send(WorkerMsg::Stop);
    }
}

/// One fabric lane's executor: owns the lane's [`ElasticManager`] and
/// virtual clock, serves FPGA prefixes in admission order, fans CPU
/// suffixes out to the shared worker pool, and runs this lane's
/// autoscale control ticks against this lane's own demand.
///
/// Each lane's fabric runs a flight-recorder tracer (always on — a
/// bounded ring, DESIGN.md §14): lifecycle and scale events stamped
/// from the lane's cumulative virtual clock interleave with the
/// fabric's own ICAP/grant events.  When a request errors, the lane
/// dumps its window (plus any spill dumps the manager took) into the
/// server-wide `flight_dumps` sink.
#[allow(clippy::too_many_arguments)]
fn lane_loop(
    rx: Receiver<Submission>,
    work_tx: Sender<WorkerMsg>,
    cfg: SystemConfig,
    runtime: Option<RuntimeHandle>,
    autoscale: Option<LaneAutoscale>,
    lane_idx: usize,
    status: Arc<LaneStatus>,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
    stats: Arc<ScaleStats>,
    dumps: Arc<Mutex<Vec<FlightDump>>>,
) {
    let batch_window = cfg.server.batch_window.max(1);
    let mut manager = ElasticManager::new(cfg, runtime);
    manager.fabric_mut().set_tracing(Tracer::flight(DEFAULT_FLIGHT_CAPACITY));
    let mut clock: u64 = 0;
    let mut cadence = ControlCadence::new(autoscale.map_or(0, |s| s.every_cycles));
    let mut admissions: usize = 0;
    status.spare_share.store(manager.spare_share() as u64, Ordering::SeqCst);
    // Submissions pulled off the lane channel but not yet served; the
    // coalescer's look-ahead window (DESIGN.md §15).
    let mut pending: VecDeque<Submission> = VecDeque::new();
    loop {
        let leader = match pending.pop_front() {
            Some(s) => s,
            None => match rx.recv() {
                Ok(s) => s,
                Err(_) => break,
            },
        };
        // Make everything already queued on this lane visible to the
        // coalescer; never blocks once a leader is in hand.
        while let Ok(next) = rx.try_recv() {
            pending.push_back(next);
        }
        // Batch: the contiguous prefix of pending submissions for the
        // leader's app and stage chain, up to the window.  A batch is
        // one admission event for control purposes — followers skip
        // the cadence tick and the placement plan — but every member
        // keeps its own response, events and terminal bookkeeping.
        let mut batch = vec![leader];
        while batch.len() < batch_window {
            match pending.front() {
                Some(n)
                    if n.req.app_id == batch[0].req.app_id
                        && n.req.stages == batch[0].req.stages =>
                {
                    let follower = pending.pop_front().expect("front just checked");
                    batch.push(follower);
                }
                _ => break,
            }
        }
        if batch.len() >= 2 {
            status.batches.fetch_add(1, Ordering::SeqCst);
            status
                .coalesced
                .fetch_add(batch.len() as u64 - 1, Ordering::SeqCst);
            let (app, size) = (batch[0].req.app_id, batch.len());
            manager.fabric_mut().telemetry.emit_with(|| {
                TraceEvent::BatchFormed { cycle: clock, app, node: lane_idx, size }
            });
        }
        let mut placement: Option<Vec<StagePlacement>> = None;
        for (member, sub) in batch.into_iter().enumerate() {
            let app = sub.req.app_id;
            manager.fabric_mut().telemetry.emit_with(|| TraceEvent::RequestAdmitted {
                cycle: clock,
                app,
                node: lane_idx,
            });
            if member == 0 {
                admissions += 1;
                if let Some(scale) = autoscale {
                    let mut tick = scale.every > 0 && admissions % scale.every == 0;
                    // The cycle cadence is an EventDriven horizon on the
                    // lane's virtual clock: between boundaries it
                    // contributes `next_interesting_cycle`, so a pending
                    // control tick bounds the fast-path's jump instead of
                    // dragging the lane back to cycle-stepping (DESIGN.md
                    // §13).  Crossing several boundaries in one long prefix
                    // still costs one tick here — `due` consumes them all.
                    while cadence.due(clock) {
                        tick = true;
                    }
                    if tick {
                        autoscale_tick(&mut manager, &scale, &status, &stats, clock, lane_idx);
                        status
                            .spare_share
                            .store(manager.spare_share() as u64, Ordering::SeqCst);
                    }
                }
                placement = Some(manager.plan(&sub.req.stages));
            }
            let queue_wait_cycles = clock;
            let placement = placement.as_ref().expect("leader planned").clone();
            manager.fabric_mut().telemetry.emit_with(|| TraceEvent::RequestDispatched {
                cycle: clock,
                app,
                node: lane_idx,
            });
            // Run the FPGA prefix synchronously on this lane's fabric; hand
            // the CPU suffix to the worker pool.
            match run_fpga_prefix(&mut manager, &sub.req, &placement) {
                Ok((partial, tl, fpga_stages)) => {
                    let service = tl.fabric_cycles + tl.reconfig_cycles;
                    clock += service;
                    status.clock.store(clock, Ordering::SeqCst);
                    manager.fabric_mut().telemetry.emit_with(|| {
                        TraceEvent::RequestCompleted {
                            cycle: clock,
                            app,
                            node: lane_idx,
                            service_cycles: service,
                        }
                    });
                    let remaining: Vec<ModuleKind> = placement
                        .iter()
                        .filter(|p| !p.is_fpga())
                        .map(StagePlacement::kind)
                        .collect();
                    let msg = WorkerMsg::CpuSuffix {
                        req: sub.req,
                        partial,
                        remaining,
                        tl,
                        fpga_stages,
                        placement,
                        submitted: sub.submitted,
                        fabric: lane_idx,
                        queue_wait_cycles,
                        lane: Arc::clone(&status),
                        respond: sub.respond,
                    };
                    if let Err(send_err) = work_tx.send(msg) {
                        // Worker pool gone: fail the request here rather
                        // than leak its queue slot.
                        if let WorkerMsg::CpuSuffix { req, submitted, respond, lane, .. } =
                            send_err.0
                        {
                            let _ = respond.send(Response {
                                report: Err(ElasticError::Server(
                                    "worker pool gone".into(),
                                )),
                                wall: submitted.elapsed(),
                                fabric: lane_idx,
                                queue_wait_cycles,
                            });
                            finish_request(&lane, req.app_id, &in_flight, &slots);
                        }
                    }
                }
                Err(e) => {
                    // Dump this lane's flight window (the manager already
                    // dumped at the spill site for app errors) and publish
                    // everything collected to the server-wide sink.
                    let fab = manager.fabric_mut();
                    fab.telemetry.dump(&format!("lane {lane_idx}: app {app} failed: {e}"));
                    dumps.lock().unwrap().extend(fab.telemetry.take_dumps());
                    let _ = sub.respond.send(Response {
                        report: Err(e),
                        wall: sub.submitted.elapsed(),
                        fabric: lane_idx,
                        queue_wait_cycles,
                    });
                    finish_request(&status, app, &in_flight, &slots);
                }
            }
        }
        // Publish the lane's resident configuration-cache map so the
        // admission side (and metrics snapshots) can see which module
        // kinds are parked on which regions (DESIGN.md §16).
        *status.residents.lock().unwrap() = manager
            .resident_regions()
            .into_iter()
            .map(|(r, k)| (r, k.name()))
            .collect();
    }
}

/// One per-lane control tick: grow (unfence a region) when this lane's
/// depth is deep, shrink (fence one) when it has drained — never below
/// `min_regions`, and never below one region per app with work in
/// flight on the lane (the per-app reservation floor).  Footprint
/// changes emit [`TraceEvent::ScaleUp`]/[`TraceEvent::ScaleDown`]
/// stamped with the lane's virtual `clock`.
fn autoscale_tick(
    manager: &mut ElasticManager,
    scale: &LaneAutoscale,
    status: &LaneStatus,
    stats: &ScaleStats,
    clock: u64,
    lane_idx: usize,
) {
    let depth = status.depth();
    if depth > scale.grow_above {
        if manager.unfence_regions(1) > 0 {
            stats.grows.fetch_add(1, Ordering::Relaxed);
            manager.fabric_mut().telemetry.emit_with(|| TraceEvent::ScaleUp {
                cycle: clock,
                node: lane_idx,
                regions: 1,
            });
        }
    } else if depth <= scale.shrink_below {
        let reserved = scale.min_regions.max(status.active_apps());
        if manager.available_regions() > reserved && manager.fence_regions(1) > 0 {
            stats.shrinks.fetch_add(1, Ordering::Relaxed);
            manager.fabric_mut().telemetry.emit_with(|| TraceEvent::ScaleDown {
                cycle: clock,
                node: lane_idx,
                regions: 1,
            });
        }
    }
}

/// Execute the FPGA part of a request on one lane's fabric.
fn run_fpga_prefix(
    manager: &mut ElasticManager,
    req: &AppRequest,
    placement: &[StagePlacement],
) -> Result<(Vec<u32>, ExecutionTimeline, usize)> {
    use crate::xdma::BRIDGE_BUFFER_WORDS;
    if req.data.len() % BRIDGE_BUFFER_WORDS != 0 {
        return Err(ElasticError::Server(format!(
            "payload length {} not burst-aligned",
            req.data.len()
        )));
    }
    let mut tl = ExecutionTimeline::new();
    let fpga_kinds: Vec<(ModuleKind, usize)> = placement
        .iter()
        .filter_map(|p| match *p {
            StagePlacement::Fpga { kind, region } => Some((kind, region)),
            _ => None,
        })
        .collect();
    if fpga_kinds.is_empty() {
        return Ok((req.data.clone(), tl, 0));
    }
    // Install + program through the manager's placement path, but only
    // the prefix; then stream.
    let sub_placement: Vec<StagePlacement> = placement.to_vec();
    // Reuse manager's full path: execute_placed would also run CPU
    // stages; we want the split, so drive the fabric directly.
    let report = manager.execute_placed(
        &AppRequest {
            app_id: req.app_id,
            data: req.data.clone(),
            stages: fpga_kinds.iter().map(|&(k, _)| k).collect(),
        },
        &sub_placement[..fpga_kinds.len()],
    )?;
    tl.h2c_transfers = report.timeline.h2c_transfers.clone();
    tl.c2h_transfers = report.timeline.c2h_transfers.clone();
    tl.fabric_cycles = report.timeline.fabric_cycles;
    tl.reconfig_cycles = report.timeline.reconfig_cycles;
    Ok((report.output, tl, fpga_kinds.len()))
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    runtime: Option<RuntimeHandle>,
    cfg: SystemConfig,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(msg) = msg else { return };
        match msg {
            WorkerMsg::Stop => return,
            WorkerMsg::CpuSuffix {
                req,
                mut partial,
                remaining,
                mut tl,
                fpga_stages,
                placement,
                submitted,
                fabric,
                queue_wait_cycles,
                lane,
                respond,
            } => {
                let app_id = req.app_id;
                let mut failed: Option<ElasticError> = None;
                for kind in &remaining {
                    let t0 = Instant::now();
                    let out = run_stage(&runtime, *kind, &partial);
                    match out {
                        Ok(o) => {
                            partial = o;
                            tl.cpu_stage(
                                kind.name(),
                                Some(t0.elapsed().as_secs_f64() * 1e3),
                            );
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                let report = match failed {
                    Some(e) => Err(e),
                    None => {
                        let expected = golden_chain(&req.stages, &req.data);
                        let verified = partial == expected;
                        if cfg.manager.verify_results && !verified {
                            Err(ElasticError::Verify(format!(
                                "app {}: output mismatch",
                                req.app_id
                            )))
                        } else {
                            let cost = evaluate(&cfg, &tl);
                            Ok(AppReport {
                                app_id: req.app_id,
                                output: partial,
                                placement,
                                fpga_stages,
                                cost,
                                span: RequestSpan::decompose(&cfg, &cost, 0),
                                timeline: tl,
                                verified,
                            })
                        }
                    }
                };
                let _ = respond.send(Response {
                    report,
                    wall: submitted.elapsed(),
                    fabric,
                    queue_wait_cycles,
                });
                finish_request(&lane, app_id, &in_flight, &slots);
            }
        }
    }
}

fn run_stage(
    runtime: &Option<RuntimeHandle>,
    kind: ModuleKind,
    data: &[u32],
) -> Result<Vec<u32>> {
    // Table-driven kernels have no AOT artifact (`pjrt_artifact()` is
    // None): they run their registered behavior directly instead of
    // erroring on an unknown manifest key.
    if let (Some(rt), Some(artifact)) = (runtime, kind.pjrt_artifact()) {
        if let Some(out) = rt.run(artifact, data.to_vec())? {
            return Ok(out);
        }
    }
    Ok(kind.apply_buf(data))
}

/// Blocking convenience: submit and wait.
pub fn call(server: &ElasticServer, req: AppRequest) -> Result<AppReport> {
    let rx = server.submit(req)?;
    let resp = rx
        .recv()
        .map_err(|_| ElasticError::Server("response channel closed".into()))?;
    resp.report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manager::golden_pipeline;
    use crate::util::SplitMix64;

    fn data(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = SplitMix64::new(seed);
        let mut v = vec![0u32; n];
        rng.fill_u32(&mut v);
        v
    }

    #[test]
    fn serves_one_request() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let d = data(64, 1);
        let rep = call(&server, AppRequest::pipeline(0, d.clone())).unwrap();
        assert!(rep.verified);
        assert_eq!(rep.output, golden_pipeline(&d));
        server.shutdown();
    }

    #[test]
    fn serves_many_requests_in_order_of_submission() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..16u64 {
            let d = data(64, 100 + i);
            inputs.push(d.clone());
            rxs.push(server.submit(AppRequest::pipeline((i % 4) as u32, d)).unwrap());
        }
        for (rx, d) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().unwrap();
            let rep = resp.report.unwrap();
            assert!(rep.verified);
            assert_eq!(&rep.output, &golden_pipeline(d));
        }
        server.shutdown();
    }

    #[test]
    fn coalesces_same_app_submissions_into_batches() {
        // One lane, one app, a rapid stream of identical chains: while
        // the executor serves a leader the rest pile up on the lane
        // queue, so batches must form — and every member still gets
        // its own verified, demuxed response.
        let mut cfg = SystemConfig::paper_defaults();
        cfg.server.batch_window = 8;
        let server = ElasticServer::start_fleet(
            cfg,
            FleetOptions {
                fabrics: 1,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: None,
            },
            None,
        );
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..64u64 {
            let d = data(64, 500 + i);
            inputs.push(d.clone());
            rxs.push(server.submit(AppRequest::pipeline(0, d)).unwrap());
        }
        for (rx, d) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.fabric, 0);
            let rep = resp.report.unwrap();
            assert!(rep.verified);
            assert_eq!(&rep.output, &golden_pipeline(d));
        }
        let lane = &server.lane_statuses()[0];
        assert!(
            lane.coalesced() > 0,
            "64 rapid same-app submissions never coalesced"
        );
        assert!(lane.coalesced() >= lane.batches());
        server.shutdown();
    }

    #[test]
    fn rejects_unaligned_payload_via_response() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let rx = server.submit(AppRequest::pipeline(0, vec![1; 7])).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.report.is_err());
        server.shutdown();
    }

    #[test]
    fn backpressure_bounds_in_flight() {
        let mut cfg = SystemConfig::paper_defaults();
        cfg.server.queue_depth = 4;
        let server = Server::start(cfg, None);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            rxs.push(server.submit(AppRequest::pipeline(0, data(64, i))).unwrap());
            assert!(server.in_flight() <= 4, "queue depth exceeded");
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().report.is_ok());
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        drop(server); // must not hang or panic
    }

    #[test]
    fn fleet_server_spreads_lanes_and_reports_them() {
        let server = ElasticServer::start_fleet(
            SystemConfig::paper_defaults(),
            FleetOptions {
                fabrics: 2,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: None,
            },
            None,
        );
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..12u64 {
            let d = data(64, 300 + i);
            inputs.push(d.clone());
            rxs.push(server.submit(AppRequest::pipeline((i % 4) as u32, d)).unwrap());
        }
        let mut lanes_seen = [0usize; 2];
        for (rx, d) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().unwrap();
            assert!(resp.fabric < 2);
            lanes_seen[resp.fabric] += 1;
            let rep = resp.report.unwrap();
            assert!(rep.verified);
            assert_eq!(&rep.output, &golden_pipeline(d));
        }
        assert!(
            lanes_seen[0] > 0 && lanes_seen[1] > 0,
            "least-loaded never used a lane: {lanes_seen:?}"
        );
        server.shutdown();
    }

    #[test]
    fn lane_autoscale_ticks_scale_the_fabric_footprint() {
        // Phase A: sequential calls keep the queue at depth 1, so every
        // tick is a shrink until lanes hit the 1-region floor — later
        // requests run a 1-stage FPGA prefix + CPU suffix, still
        // verified.  Phase B: a burst drives the depth past grow_above,
        // so ticks unfence the regions back.
        let server = ElasticServer::start_fleet(
            SystemConfig::paper_defaults(),
            FleetOptions {
                fabrics: 1,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: Some(LaneAutoscale {
                    every: 1,
                    every_cycles: 0,
                    grow_above: 8,
                    // Depth reads 1 (or briefly 2) between sequential
                    // calls; 2 keeps the shrink phase race-free.
                    shrink_below: 2,
                    min_regions: 1,
                }),
            },
            None,
        );
        for i in 0..6u64 {
            let rep = call(&server, AppRequest::pipeline(0, data(64, i))).unwrap();
            assert!(rep.verified);
        }
        assert!(server.scale_stats().shrinks() > 0, "idle lanes never shrank");

        let mut rxs = Vec::new();
        for i in 0..24u64 {
            rxs.push(
                server
                    .submit(AppRequest::pipeline((i % 4) as u32, data(64, 100 + i)))
                    .unwrap(),
            );
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.report.unwrap().verified);
        }
        assert!(server.scale_stats().grows() > 0, "burst never grew lanes");
        server.shutdown();
    }

    #[test]
    fn cycle_cadence_ticks_shrink_on_virtual_clock() {
        // Admission cadence off (`every: 0`); ticks fire only when the
        // lane's virtual clock crosses an `every_cycles` boundary.  One
        // 3-stage 64-word prefix consumes far more than 128 fabric
        // cycles (ICAP programming alone dwarfs it), so every call
        // after the first crosses at least one boundary — and each
        // sequential call sees depth 1 <= shrink_below, shrinking the
        // lane toward the floor.
        let server = ElasticServer::start_fleet(
            SystemConfig::paper_defaults(),
            FleetOptions {
                fabrics: 1,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: Some(LaneAutoscale {
                    every: 0,
                    every_cycles: 128,
                    grow_above: 8,
                    shrink_below: 2,
                    min_regions: 1,
                }),
            },
            None,
        );
        for i in 0..6u64 {
            let rep = call(&server, AppRequest::pipeline(0, data(64, 900 + i))).unwrap();
            assert!(rep.verified);
        }
        assert!(
            server.scale_stats().shrinks() > 0,
            "virtual-clock cadence never ticked"
        );
        assert_eq!(server.scale_stats().grows(), 0, "no burst, no grows");
        server.shutdown();
    }

    #[test]
    fn autoscale_tick_scales_per_lane_demand() {
        // The demand signal is per lane: a deep lane grows while a
        // drained lane shrinks in the same control round — impossible
        // with the old global in-flight gauge, which fed every lane the
        // same depth.
        let cfg = SystemConfig::paper_defaults();
        let mut hot = ElasticManager::new(cfg.clone(), None);
        let mut cold = ElasticManager::new(cfg, None);
        hot.fence_regions(2);
        let scale = LaneAutoscale {
            every: 1,
            every_cycles: 0,
            grow_above: 2,
            shrink_below: 1,
            min_regions: 1,
        };
        let stats = ScaleStats::default();
        let hot_status = LaneStatus::default();
        hot_status.admitted.store(10, Ordering::SeqCst);
        let cold_status = LaneStatus::default();
        cold_status.admitted.store(4, Ordering::SeqCst);
        cold_status.completed.store(4, Ordering::SeqCst);
        let hot_avail = hot.available_regions();
        let cold_avail = cold.available_regions();
        autoscale_tick(&mut hot, &scale, &hot_status, &stats, 0, 0);
        autoscale_tick(&mut cold, &scale, &cold_status, &stats, 0, 1);
        assert_eq!(hot.available_regions(), hot_avail + 1, "deep lane grew");
        assert_eq!(cold.available_regions(), cold_avail - 1, "drained lane shrank");
        assert_eq!(stats.grows(), 1);
        assert_eq!(stats.shrinks(), 1);
    }

    #[test]
    fn shrink_respects_per_app_reservations() {
        let mut m = ElasticManager::new(SystemConfig::paper_defaults(), None);
        let scale = LaneAutoscale {
            every: 1,
            every_cycles: 0,
            grow_above: 8,
            shrink_below: 4,
            min_regions: 1,
        };
        let stats = ScaleStats::default();
        let status = LaneStatus::default();
        // Three distinct apps in flight reserve all three regions.
        for app in 0..3u32 {
            status.note_app(app);
        }
        status.admitted.store(3, Ordering::SeqCst);
        autoscale_tick(&mut m, &scale, &status, &stats, 0, 0);
        assert_eq!(stats.shrinks(), 0, "3 apps reserve all 3 regions");
        // One app drains; one region becomes reclaimable.
        status.clear_app(2);
        status.completed.store(1, Ordering::SeqCst);
        autoscale_tick(&mut m, &scale, &status, &stats, 0, 0);
        assert_eq!(stats.shrinks(), 1, "floor follows active apps down");
        assert_eq!(m.available_regions(), 2);
    }

    #[test]
    fn lane_publishes_resident_cache_map() {
        // With the configuration cache on, a served chain parks its
        // modules instead of clearing them, and the lane executor
        // publishes the `(region, kind)` map through LaneStatus.
        let mut cfg = SystemConfig::paper_defaults();
        cfg.manager.config_cache_regions = 3;
        let server = Server::start(cfg, None);
        let d = data(64, 7);
        let rep = call(&server, AppRequest::pipeline(0, d.clone())).unwrap();
        assert!(rep.verified);
        let lane = Arc::clone(&server.lane_statuses()[0]);
        // Join the lane executor so its final resident snapshot (taken
        // at the end of the batch iteration) is published.
        server.shutdown();
        let residents = lane.resident_modules();
        assert!(
            !residents.is_empty(),
            "cache enabled: served chain must leave parked modules"
        );
        for (region, _kind) in &residents {
            assert!(
                (1..=3).contains(region),
                "resident region {region} out of range"
            );
        }
    }

    #[test]
    fn cache_off_publishes_empty_resident_map() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let rep = call(&server, AppRequest::pipeline(0, data(64, 9))).unwrap();
        assert!(rep.verified);
        let lane = Arc::clone(&server.lane_statuses()[0]);
        server.shutdown();
        assert!(lane.resident_modules().is_empty(), "legacy mode parks nothing");
    }
}
