//! Multi-tenant serving loop: the deployment shape the paper's cloud
//! story implies (apps submit acceleration requests; the manager
//! allocates PR regions elastically; overflow compute runs on the
//! server) — generalized to a **fabric-count-generic** scheduler so one
//! server can front a whole board fleet.
//!
//! Architecture (std::thread + mpsc — tokio is unavailable offline, see
//! DESIGN.md §7):
//!
//! ```text
//!   clients --submit--> [bounded queue] --> scheduler thread
//!                                            | admission policy picks a
//!                                            | fabric lane; FPGA prefix
//!                                            | runs on that lane's
//!                                            v cycle simulator
//!                                      [worker pool] -- on-server
//!                                            |            suffix stages
//!                                            v
//!                                       response channels
//! ```
//!
//! The scheduler owns every fabric (each a single synchronous design, as
//! in hardware) and tracks a per-lane virtual clock of fabric cycles
//! consumed; the admission policy ([`AdmissionPolicy`], shared with the
//! [`crate::fleet`] trace simulator) routes each request to a lane.
//! CPU-suffix work is fanned out to workers so a fabric can start the
//! next request while earlier requests finish on the host — pipeline
//! parallelism across requests.  The bounded queue provides
//! backpressure: `submit` blocks when `queue_depth` requests are in
//! flight.
//!
//! Every lane's fabric drive rides the busy-period horizon fast-path
//! (`ElasticManager.fast_path`, on by default — DESIGN.md §12): FPGA
//! prefixes and the lane autoscaler's ICAP reconfigurations execute
//! only their interesting cycles while staying cycle-exact with the
//! oracle, so wall-clock serving throughput scales with *work*, not
//! with modeled ICAP latency.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::SystemConfig;
use crate::fleet::AdmissionPolicy;
use crate::manager::{golden_chain, AppReport, AppRequest, ElasticManager, StagePlacement};
use crate::modules::ModuleKind;
use crate::runtime::RuntimeHandle;
use crate::timing::{evaluate, ExecutionTimeline};
use crate::{ElasticError, Result};

/// Fleet shape of a serving instance.
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Number of independent fabrics the scheduler drives.
    pub fabrics: usize,
    /// Admission policy routing requests to fabrics.
    pub policy: AdmissionPolicy,
    /// Optional lane-level autoscaling tick interleaved with serving.
    pub autoscale: Option<LaneAutoscale>,
}

impl FleetOptions {
    /// The single-board shape of the original prototype.
    pub fn single() -> Self {
        Self {
            fabrics: 1,
            policy: AdmissionPolicy::LeastLoaded,
            autoscale: None,
        }
    }
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self::single()
    }
}

/// On-line lane elasticity: every `every` admissions the scheduler runs
/// a control tick — the serving-loop counterpart of the trace-driven
/// [`crate::autoscale::Engine`].  The demand signal is the server's
/// bounded-queue depth; actuation fences/unfences PR regions on every
/// lane, so subsequent placements shift between fabric and the server
/// CPU (per-app region *reservations* live in the autoscale engine; the
/// threaded server scales the fabric footprint as a whole).
#[derive(Debug, Clone, Copy)]
pub struct LaneAutoscale {
    /// Admissions between control ticks (0 disables).
    pub every: usize,
    /// Unfence one region per lane when in-flight depth exceeds this.
    pub grow_above: usize,
    /// Fence one region per lane when in-flight depth is at or below
    /// this (hysteresis: keep `grow_above > shrink_below`).
    pub shrink_below: usize,
    /// Regions each lane always keeps available.
    pub min_regions: usize,
}

impl Default for LaneAutoscale {
    fn default() -> Self {
        Self { every: 8, grow_above: 8, shrink_below: 1, min_regions: 1 }
    }
}

/// Counters for the server's lane autoscaler.
#[derive(Debug, Default)]
pub struct ScaleStats {
    grows: AtomicU64,
    shrinks: AtomicU64,
}

impl ScaleStats {
    /// Control ticks that unfenced at least one region.
    pub fn grows(&self) -> u64 {
        self.grows.load(Ordering::Relaxed)
    }

    /// Control ticks that fenced at least one region.
    pub fn shrinks(&self) -> u64 {
        self.shrinks.load(Ordering::Relaxed)
    }
}

/// A finished request as the client sees it.
#[derive(Debug)]
pub struct Response {
    pub report: Result<AppReport>,
    /// Wall-clock service time (queue + fabric sim + on-server stages).
    pub wall: std::time::Duration,
    /// Fabric lane that served the request.
    pub fabric: usize,
    /// The lane's cumulative virtual clock (total fabric cycles it has
    /// ever consumed) at admission — deterministic, unlike `wall`.  It
    /// never drains, so it is a backlog *proxy* for ordering requests
    /// admitted to the same lane, not a latency: use the fleet
    /// simulator's `start - arrival` queue wait for that.
    pub queue_wait_cycles: u64,
}

enum WorkerMsg {
    CpuSuffix {
        req: AppRequest,
        partial: Vec<u32>,
        remaining: Vec<ModuleKind>,
        tl: ExecutionTimeline,
        fpga_stages: usize,
        placement: Vec<StagePlacement>,
        submitted: Instant,
        fabric: usize,
        queue_wait_cycles: u64,
        respond: Sender<Response>,
    },
    Stop,
}

struct Submission {
    req: AppRequest,
    respond: Sender<Response>,
    submitted: Instant,
}

/// Simple counting semaphore (no external deps).
struct Semaphore {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Self { count: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    fn release(&self) {
        *self.count.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// The serving engine.
pub struct ElasticServer {
    submit_tx: Option<Sender<Submission>>,
    scheduler: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
    scale_stats: Arc<ScaleStats>,
}

/// Legacy name for the single-fabric shape.
pub type Server = ElasticServer;

impl ElasticServer {
    /// Start a single-fabric server (the original prototype shape).
    /// `runtime` is shared by all workers.
    pub fn start(cfg: SystemConfig, runtime: Option<RuntimeHandle>) -> Self {
        Self::start_fleet(cfg, FleetOptions::single(), runtime)
    }

    /// Start the scheduler + worker threads over `opts.fabrics`
    /// independent fabric lanes.
    pub fn start_fleet(
        cfg: SystemConfig,
        opts: FleetOptions,
        runtime: Option<RuntimeHandle>,
    ) -> Self {
        let (submit_tx, submit_rx) = channel::<Submission>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let slots = Arc::new(Semaphore::new(cfg.server.queue_depth));
        let in_flight = Arc::new(AtomicUsize::new(0));

        let mut workers = Vec::new();
        for w in 0..cfg.server.workers.max(1) {
            let rx = Arc::clone(&work_rx);
            let rt = runtime.clone();
            let cfg_w = cfg.clone();
            let slots_w = Arc::clone(&slots);
            let in_flight_w = Arc::clone(&in_flight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("efpga-worker-{w}"))
                    .spawn(move || {
                        worker_loop(rx, rt, cfg_w, slots_w, in_flight_w)
                    })
                    .expect("spawn worker"),
            );
        }

        let sched_cfg = cfg.clone();
        let sched_rt = runtime;
        let slots_s = Arc::clone(&slots);
        let in_flight_s = Arc::clone(&in_flight);
        let scale_stats = Arc::new(ScaleStats::default());
        let scale_stats_s = Arc::clone(&scale_stats);
        let scheduler = std::thread::Builder::new()
            .name("efpga-scheduler".into())
            .spawn(move || {
                scheduler_loop(
                    submit_rx,
                    work_tx,
                    sched_cfg,
                    opts,
                    sched_rt,
                    slots_s,
                    in_flight_s,
                    scale_stats_s,
                )
            })
            .expect("spawn scheduler");

        Self {
            submit_tx: Some(submit_tx),
            scheduler: Some(scheduler),
            workers,
            slots,
            in_flight,
            scale_stats,
        }
    }

    /// Submit a request; blocks while the queue is full (backpressure).
    /// Returns the channel the response will arrive on.
    pub fn submit(&self, req: AppRequest) -> Result<Receiver<Response>> {
        self.slots.acquire();
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.submit_tx
            .as_ref()
            .expect("server running")
            .send(Submission { req, respond: tx, submitted: Instant::now() })
            .map_err(|_| ElasticError::Server("scheduler gone".into()))?;
        Ok(rx)
    }

    /// Requests currently queued or executing.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Lane-autoscaler counters (all zero when autoscale is off).
    pub fn scale_stats(&self) -> &ScaleStats {
        &self.scale_stats
    }

    /// Stop accepting requests, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.submit_tx.take()); // scheduler's recv errors -> drains
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ElasticServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One fabric lane owned by the scheduler.
struct Lane {
    manager: ElasticManager,
    /// Cumulative fabric cycles consumed on this lane (virtual clock;
    /// the admission policy's load signal).
    clock: u64,
}

fn select_lane(
    lanes: &[Lane],
    pins: &mut HashMap<u32, usize>,
    policy: AdmissionPolicy,
    req: &AppRequest,
) -> usize {
    let least_loaded = |lanes: &[Lane]| {
        (0..lanes.len())
            .min_by_key(|&i| (lanes[i].clock, i))
            .expect("server has lanes")
    };
    match policy {
        AdmissionPolicy::LeastLoaded => least_loaded(lanes),
        AdmissionPolicy::StickyByApp => {
            if let Some(&pinned) = pins.get(&req.app_id) {
                pinned
            } else {
                let chosen = least_loaded(lanes);
                pins.insert(req.app_id, chosen);
                chosen
            }
        }
        AdmissionPolicy::BandwidthAware => (0..lanes.len())
            .min_by_key(|&i| {
                let spare = lanes[i].manager.spare_share();
                (std::cmp::Reverse(spare), lanes[i].clock, i)
            })
            .expect("server has lanes"),
    }
}

#[allow(clippy::too_many_arguments)]
fn scheduler_loop(
    submit_rx: Receiver<Submission>,
    work_tx: Sender<WorkerMsg>,
    cfg: SystemConfig,
    opts: FleetOptions,
    runtime: Option<RuntimeHandle>,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
    scale_stats: Arc<ScaleStats>,
) {
    let mut lanes: Vec<Lane> = (0..opts.fabrics.max(1))
        .map(|_| Lane {
            manager: ElasticManager::new(cfg.clone(), runtime.clone()),
            clock: 0,
        })
        .collect();
    let mut pins: HashMap<u32, usize> = HashMap::new();
    let mut admitted: usize = 0;
    while let Ok(sub) = submit_rx.recv() {
        admitted += 1;
        // Control-loop tick interleaved with serving: scale every lane's
        // fabric footprint against the queue's demand signal.
        if let Some(scale) = opts.autoscale {
            if scale.every > 0 && admitted % scale.every == 0 {
                autoscale_tick(&mut lanes, &scale, &in_flight, &scale_stats);
            }
        }
        let lane_idx = select_lane(&lanes, &mut pins, opts.policy, &sub.req);
        let queue_wait_cycles = lanes[lane_idx].clock;
        let lane = &mut lanes[lane_idx];
        let placement = lane.manager.plan(&sub.req.stages);
        // Run the FPGA prefix synchronously on the lane's fabric; hand
        // the CPU suffix to the worker pool.
        match run_fpga_prefix(&mut lane.manager, &sub.req, &placement) {
            Ok((partial, tl, fpga_stages)) => {
                lane.clock += tl.fabric_cycles + tl.reconfig_cycles;
                let remaining: Vec<ModuleKind> = placement
                    .iter()
                    .filter(|p| !p.is_fpga())
                    .map(StagePlacement::kind)
                    .collect();
                let msg = WorkerMsg::CpuSuffix {
                    req: sub.req,
                    partial,
                    remaining,
                    tl,
                    fpga_stages,
                    placement,
                    submitted: sub.submitted,
                    fabric: lane_idx,
                    queue_wait_cycles,
                    respond: sub.respond,
                };
                if work_tx.send(msg).is_err() {
                    break;
                }
            }
            Err(e) => {
                let _ = sub.respond.send(Response {
                    report: Err(e),
                    wall: sub.submitted.elapsed(),
                    fabric: lane_idx,
                    queue_wait_cycles,
                });
                in_flight.fetch_sub(1, Ordering::SeqCst);
                slots.release();
            }
        }
    }
    // Drain: tell workers to stop once the queue is empty.
    for _ in 0..64 {
        let _ = work_tx.send(WorkerMsg::Stop);
    }
}

/// One lane-autoscale control tick: grow (unfence a region per lane)
/// when the queue is deep, shrink (fence one per lane, keeping
/// `min_regions`) when it is drained.
fn autoscale_tick(
    lanes: &mut [Lane],
    scale: &LaneAutoscale,
    in_flight: &AtomicUsize,
    stats: &ScaleStats,
) {
    let depth = in_flight.load(Ordering::SeqCst);
    if depth > scale.grow_above {
        let mut grew = false;
        for lane in lanes.iter_mut() {
            if lane.manager.unfence_regions(1) > 0 {
                grew = true;
            }
        }
        if grew {
            stats.grows.fetch_add(1, Ordering::Relaxed);
        }
    } else if depth <= scale.shrink_below {
        let mut shrank = false;
        for lane in lanes.iter_mut() {
            if lane.manager.available_regions() > scale.min_regions
                && lane.manager.fence_regions(1) > 0
            {
                shrank = true;
            }
        }
        if shrank {
            stats.shrinks.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Execute the FPGA part of a request on one lane's fabric.
fn run_fpga_prefix(
    manager: &mut ElasticManager,
    req: &AppRequest,
    placement: &[StagePlacement],
) -> Result<(Vec<u32>, ExecutionTimeline, usize)> {
    use crate::xdma::BRIDGE_BUFFER_WORDS;
    if req.data.len() % BRIDGE_BUFFER_WORDS != 0 {
        return Err(ElasticError::Server(format!(
            "payload length {} not burst-aligned",
            req.data.len()
        )));
    }
    let mut tl = ExecutionTimeline::new();
    let fpga_kinds: Vec<(ModuleKind, usize)> = placement
        .iter()
        .filter_map(|p| match *p {
            StagePlacement::Fpga { kind, region } => Some((kind, region)),
            _ => None,
        })
        .collect();
    if fpga_kinds.is_empty() {
        return Ok((req.data.clone(), tl, 0));
    }
    // Install + program through the manager's placement path, but only
    // the prefix; then stream.
    let sub_placement: Vec<StagePlacement> = placement.to_vec();
    // Reuse manager's full path: execute_placed would also run CPU
    // stages; we want the split, so drive the fabric directly.
    let report = manager.execute_placed(
        &AppRequest {
            app_id: req.app_id,
            data: req.data.clone(),
            stages: fpga_kinds.iter().map(|&(k, _)| k).collect(),
        },
        &sub_placement[..fpga_kinds.len()],
    )?;
    tl.h2c_transfers = report.timeline.h2c_transfers.clone();
    tl.c2h_transfers = report.timeline.c2h_transfers.clone();
    tl.fabric_cycles = report.timeline.fabric_cycles;
    tl.reconfig_cycles = report.timeline.reconfig_cycles;
    Ok((report.output, tl, fpga_kinds.len()))
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    runtime: Option<RuntimeHandle>,
    cfg: SystemConfig,
    slots: Arc<Semaphore>,
    in_flight: Arc<AtomicUsize>,
) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(msg) = msg else { return };
        match msg {
            WorkerMsg::Stop => return,
            WorkerMsg::CpuSuffix {
                req,
                mut partial,
                remaining,
                mut tl,
                fpga_stages,
                placement,
                submitted,
                fabric,
                queue_wait_cycles,
                respond,
            } => {
                let mut failed: Option<ElasticError> = None;
                for kind in &remaining {
                    let t0 = Instant::now();
                    let out = run_stage(&runtime, *kind, &partial);
                    match out {
                        Ok(o) => {
                            partial = o;
                            tl.cpu_stage(
                                kind.name(),
                                Some(t0.elapsed().as_secs_f64() * 1e3),
                            );
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                let report = match failed {
                    Some(e) => Err(e),
                    None => {
                        let expected = golden_chain(&req.stages, &req.data);
                        let verified = partial == expected;
                        if cfg.manager.verify_results && !verified {
                            Err(ElasticError::Verify(format!(
                                "app {}: output mismatch",
                                req.app_id
                            )))
                        } else {
                            Ok(AppReport {
                                app_id: req.app_id,
                                output: partial,
                                placement,
                                fpga_stages,
                                cost: evaluate(&cfg, &tl),
                                timeline: tl,
                                verified,
                            })
                        }
                    }
                };
                let _ = respond.send(Response {
                    report,
                    wall: submitted.elapsed(),
                    fabric,
                    queue_wait_cycles,
                });
                in_flight.fetch_sub(1, Ordering::SeqCst);
                slots.release();
            }
        }
    }
}

fn run_stage(
    runtime: &Option<RuntimeHandle>,
    kind: ModuleKind,
    data: &[u32],
) -> Result<Vec<u32>> {
    if let Some(rt) = runtime {
        if let Some(out) = rt.run(kind.artifact(), data.to_vec())? {
            return Ok(out);
        }
    }
    Ok(kind.apply_buf(data))
}

/// Blocking convenience: submit and wait.
pub fn call(server: &ElasticServer, req: AppRequest) -> Result<AppReport> {
    let rx = server.submit(req)?;
    let resp = rx
        .recv()
        .map_err(|_| ElasticError::Server("response channel closed".into()))?;
    resp.report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manager::golden_pipeline;
    use crate::util::SplitMix64;

    fn data(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = SplitMix64::new(seed);
        let mut v = vec![0u32; n];
        rng.fill_u32(&mut v);
        v
    }

    #[test]
    fn serves_one_request() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let d = data(64, 1);
        let rep = call(&server, AppRequest::pipeline(0, d.clone())).unwrap();
        assert!(rep.verified);
        assert_eq!(rep.output, golden_pipeline(&d));
        server.shutdown();
    }

    #[test]
    fn serves_many_requests_in_order_of_submission() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..16u64 {
            let d = data(64, 100 + i);
            inputs.push(d.clone());
            rxs.push(server.submit(AppRequest::pipeline((i % 4) as u32, d)).unwrap());
        }
        for (rx, d) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().unwrap();
            let rep = resp.report.unwrap();
            assert!(rep.verified);
            assert_eq!(&rep.output, &golden_pipeline(d));
        }
        server.shutdown();
    }

    #[test]
    fn rejects_unaligned_payload_via_response() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        let rx = server.submit(AppRequest::pipeline(0, vec![1; 7])).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.report.is_err());
        server.shutdown();
    }

    #[test]
    fn backpressure_bounds_in_flight() {
        let mut cfg = SystemConfig::paper_defaults();
        cfg.server.queue_depth = 4;
        let server = Server::start(cfg, None);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            rxs.push(server.submit(AppRequest::pipeline(0, data(64, i))).unwrap());
            assert!(server.in_flight() <= 4, "queue depth exceeded");
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().report.is_ok());
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let server = Server::start(SystemConfig::paper_defaults(), None);
        drop(server); // must not hang or panic
    }

    #[test]
    fn fleet_server_spreads_lanes_and_reports_them() {
        let server = ElasticServer::start_fleet(
            SystemConfig::paper_defaults(),
            FleetOptions {
                fabrics: 2,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: None,
            },
            None,
        );
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..12u64 {
            let d = data(64, 300 + i);
            inputs.push(d.clone());
            rxs.push(server.submit(AppRequest::pipeline((i % 4) as u32, d)).unwrap());
        }
        let mut lanes_seen = [0usize; 2];
        for (rx, d) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().unwrap();
            assert!(resp.fabric < 2);
            lanes_seen[resp.fabric] += 1;
            let rep = resp.report.unwrap();
            assert!(rep.verified);
            assert_eq!(&rep.output, &golden_pipeline(d));
        }
        assert!(
            lanes_seen[0] > 0 && lanes_seen[1] > 0,
            "least-loaded never used a lane: {lanes_seen:?}"
        );
        server.shutdown();
    }

    #[test]
    fn lane_autoscale_ticks_scale_the_fabric_footprint() {
        // Phase A: sequential calls keep the queue at depth 1, so every
        // tick is a shrink until lanes hit the 1-region floor — later
        // requests run a 1-stage FPGA prefix + CPU suffix, still
        // verified.  Phase B: a burst drives the depth past grow_above,
        // so ticks unfence the regions back.
        let server = ElasticServer::start_fleet(
            SystemConfig::paper_defaults(),
            FleetOptions {
                fabrics: 1,
                policy: AdmissionPolicy::LeastLoaded,
                autoscale: Some(LaneAutoscale {
                    every: 1,
                    grow_above: 8,
                    // Depth reads 1 (or briefly 2) between sequential
                    // calls; 2 keeps the shrink phase race-free.
                    shrink_below: 2,
                    min_regions: 1,
                }),
            },
            None,
        );
        for i in 0..6u64 {
            let rep = call(&server, AppRequest::pipeline(0, data(64, i))).unwrap();
            assert!(rep.verified);
        }
        assert!(server.scale_stats().shrinks() > 0, "idle lanes never shrank");

        let mut rxs = Vec::new();
        for i in 0..24u64 {
            rxs.push(
                server
                    .submit(AppRequest::pipeline((i % 4) as u32, data(64, 100 + i)))
                    .unwrap(),
            );
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.report.unwrap().verified);
        }
        assert!(server.scale_stats().grows() > 0, "burst never grew lanes");
        server.shutdown();
    }
}
