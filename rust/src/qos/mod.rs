//! The per-app bandwidth plane: §IV.E.1's package budgets lifted to
//! application granularity.
//!
//! The paper guarantees that "the allocated bandwidth for the PR region
//! is ensured by the weighted round-robin arbiter in the slave port of
//! the crossbar" — a *per-master* knob.  An application, however, owns a
//! *set* of masters (one per PR region of its chain), so its bandwidth
//! share used to be an emergent accident of whichever ports the chain
//! happened to occupy.  FOS and the multi-tenancy line of work
//! (PAPERS.md) treat tenant-level guarantees as the unit the operator
//! reasons about; this module makes bandwidth that kind of contract.
//!
//! * [`BandwidthPlan`] — the declarative contract: per-app shares in
//!   parts-per-[`SHARE_UNIT`], with the unclaimed remainder forming the
//!   **best-effort pool**.
//! * [`BandwidthPlan::compile`] — the deterministic lowering to the
//!   hardware knobs that exist: per-master WRR package budgets over the
//!   full banked register-file width (2..=32 ports) plus an app-aware
//!   arbiter rotation order.  See DESIGN.md §11 for the lowering rules.
//! * [`PlanProgram`] — the compiled image the manager writes through
//!   [`crate::regfile::RegisterFile::write_master_budgets`] and
//!   [`crate::crossbar::Crossbar::set_rotation_order`].
//!
//! The compiler is a pure function of `(plan, port ownership, knobs)`,
//! so the control plane can recompile on every allocation transition
//! (the autoscaler does) and two boards with the same ownership map
//! always carry byte-identical budget banks.

use crate::{ElasticError, Result};

/// Shares are expressed in parts-per-unit of this denominator (per
/// mille: 1000 = the whole bandwidth plane).
pub const SHARE_UNIT: u32 = 1000;

/// A declarative per-app bandwidth contract.
///
/// Apps with an explicit share receive a guaranteed fraction of the WRR
/// rotation quantum, proportional among themselves; every other app
/// rides the **best-effort pool** (the unclaimed remainder) at the
/// crossbar's default package budget.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BandwidthPlan {
    /// `(app_id, share_ppu)`, kept sorted by app ID, shares all > 0.
    shares: Vec<(u32, u32)>,
}

impl BandwidthPlan {
    /// The empty (pure best-effort) plan: every master keeps the
    /// crossbar's default package budget — byte-identical to the
    /// pre-plan programming model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a plan from explicit `(app_id, share_ppu)` pairs.
    pub fn with_shares(shares: &[(u32, u32)]) -> Result<Self> {
        let mut plan = Self::new();
        for &(app, ppu) in shares {
            plan.set_share(app, ppu)?;
        }
        Ok(plan)
    }

    /// Set (or, with `ppu == 0`, remove) `app`'s guaranteed share.
    /// Fails when the explicit shares would exceed [`SHARE_UNIT`].
    pub fn set_share(&mut self, app: u32, ppu: u32) -> Result<()> {
        // Reject before summing: an arbitrary u32 from the CLI must not
        // overflow the overcommit arithmetic below (stored shares each
        // honor this bound, so `others + ppu` stays well within u32).
        if ppu > SHARE_UNIT {
            return Err(ElasticError::Config(format!(
                "app {app} share {ppu} exceeds {SHARE_UNIT}"
            )));
        }
        let others: u32 = self
            .shares
            .iter()
            .filter(|&&(a, _)| a != app)
            .map(|&(_, s)| s)
            .sum();
        if others + ppu > SHARE_UNIT {
            return Err(ElasticError::Config(format!(
                "bandwidth plan overcommitted: app {app} share {ppu} + \
                 {others} already promised exceeds {SHARE_UNIT}"
            )));
        }
        self.shares.retain(|&(a, _)| a != app);
        if ppu > 0 {
            self.shares.push((app, ppu));
            self.shares.sort_unstable_by_key(|&(a, _)| a);
        }
        Ok(())
    }

    /// `app`'s explicit share, if it has one.
    pub fn share_of(&self, app: u32) -> Option<u32> {
        self.shares
            .iter()
            .find(|&&(a, _)| a == app)
            .map(|&(_, s)| s)
    }

    /// The explicit `(app_id, share_ppu)` pairs, ascending by app ID.
    pub fn shares(&self) -> &[(u32, u32)] {
        &self.shares
    }

    /// The unclaimed remainder: the best-effort pool, in
    /// parts-per-[`SHARE_UNIT`].
    pub fn best_effort_share(&self) -> u32 {
        SHARE_UNIT - self.shares.iter().map(|&(_, s)| s).sum::<u32>()
    }

    /// No explicit shares — everything is best-effort.
    pub fn is_empty(&self) -> bool {
        self.shares.is_empty()
    }

    /// Parse the CLI spelling: comma-separated `app=ppu` pairs, e.g.
    /// `--plan 0=750,1=250`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::new();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (app, ppu) = part.trim().split_once('=').ok_or_else(|| {
                ElasticError::Config(format!(
                    "plan entry '{part}' is not app=share (e.g. 0=750)"
                ))
            })?;
            let app: u32 = app.trim().parse().map_err(|_| {
                ElasticError::Config(format!("plan app ID '{app}' not a number"))
            })?;
            let ppu: u32 = ppu.trim().parse().map_err(|_| {
                ElasticError::Config(format!("plan share '{ppu}' not a number"))
            })?;
            if plan.share_of(app).is_some() {
                return Err(ElasticError::Config(format!(
                    "plan names app {app} twice"
                )));
            }
            plan.set_share(app, ppu)?;
        }
        Ok(plan)
    }

    /// Lower the plan to the knobs the shell actually has, for a board
    /// whose master ports are owned per `port_app` (`port_app[p]` is the
    /// app whose chain occupies port `p`'s master; `None` for the bridge
    /// port 0 and for free regions).
    ///
    /// Deterministic lowering rules (DESIGN.md §11):
    ///
    /// 1. An app with explicit share `s` and `k ≥ 1` resident masters
    ///    gets `B = max(k, round(T·s / SHARE_UNIT))` packages per full
    ///    WRR rotation (`T = rotation_packages`), distributed over its
    ///    masters by largest remainder in ascending port order — so
    ///    per-app totals are proportional to shares and every master
    ///    keeps a positive budget.
    /// 2. Best-effort masters (owned by an app without a share) and
    ///    unowned masters keep `default_packages` — the pre-plan image.
    /// 3. The bridge master (port 0) multiplexes every app's inbound
    ///    traffic: it gets `T` whenever the plan has explicit shares
    ///    (one grant can deliver any app's full quantum), otherwise the
    ///    default.
    /// 4. Rotation order: bridge first, then explicit-share apps in
    ///    ascending app ID (each app's masters ascending and therefore
    ///    **adjacent** — a multi-region app's share is contiguous even
    ///    past 4 masters), then best-effort masters, then free ports.
    pub fn compile(
        &self,
        port_app: &[Option<u32>],
        rotation_packages: u32,
        default_packages: u32,
    ) -> Result<PlanProgram> {
        let n = port_app.len();
        if !(2..=32).contains(&n) {
            return Err(ElasticError::Config(format!(
                "bandwidth plan targets {n} ports, expected 2..=32"
            )));
        }
        if !(1..=255).contains(&rotation_packages) {
            return Err(ElasticError::Config(format!(
                "rotation quantum {rotation_packages} does not fit the \
                 8-bit package field (1..=255)"
            )));
        }
        if !(1..=255).contains(&default_packages) {
            return Err(ElasticError::Config(format!(
                "default package budget {default_packages} must be 1..=255"
            )));
        }

        let mut budgets = vec![default_packages; n];
        budgets[0] = if self.is_empty() {
            default_packages
        } else {
            rotation_packages
        };

        // Masters of each explicit-share app, ascending port order.
        let mut app_packages: Vec<(u32, u32)> = Vec::new();
        for &(app, ppu) in &self.shares {
            let masters: Vec<usize> = (1..n)
                .filter(|&p| port_app[p] == Some(app))
                .collect();
            if masters.is_empty() {
                continue; // share reserved, app not resident here
            }
            let k = masters.len() as u32;
            let quantum = (rotation_packages as u64 * ppu as u64
                + SHARE_UNIT as u64 / 2)
                / SHARE_UNIT as u64;
            let total = (quantum as u32).max(k).min(255 * k);
            let base = total / k;
            let extra = (total % k) as usize;
            for (i, &p) in masters.iter().enumerate() {
                budgets[p] = base + u32::from(i < extra);
            }
            app_packages.push((app, total));
        }

        // Rotation: bridge, contracted apps (masters adjacent), then
        // best-effort owned ports, then free ports.
        let mut rotation = Vec::with_capacity(n);
        rotation.push(0);
        for &(app, _) in &self.shares {
            rotation.extend((1..n).filter(|&p| port_app[p] == Some(app)));
        }
        for p in 1..n {
            let owned_contracted = port_app[p]
                .map(|a| self.share_of(a).is_some())
                .unwrap_or(false);
            if port_app[p].is_some() && !owned_contracted {
                rotation.push(p);
            }
        }
        rotation.extend((1..n).filter(|&p| port_app[p].is_none()));
        debug_assert_eq!(rotation.len(), n);

        Ok(PlanProgram { budgets, rotation, app_packages })
    }
}

/// A plan lowered for one concrete board: what the manager writes into
/// the register file and the crossbar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanProgram {
    /// Per-master package budget (index = crossbar port), each 1..=255,
    /// written uniformly into every slave's budget bank.
    pub budgets: Vec<u32>,
    /// App-aware WRR rotation order: a permutation of `0..N` with every
    /// contracted app's masters adjacent.
    pub rotation: Vec<usize>,
    /// Per contracted resident app: total packages per full rotation.
    /// Doubles as the weight vector for the bridge's per-app H2C
    /// descriptor scheduler ([`crate::xdma::Xdma::set_h2c_weights`],
    /// DESIGN.md §15) so host-side and fabric-side arbitration enforce
    /// the same ratios.
    pub app_packages: Vec<(u32, u32)>,
}

impl PlanProgram {
    /// The effective share (parts-per-[`SHARE_UNIT`]) `app` achieves
    /// per rotation quantum `rotation_packages`.
    pub fn effective_share(&self, app: u32, rotation_packages: u32) -> u32 {
        self.app_packages
            .iter()
            .find(|&&(a, _)| a == app)
            .map(|&(_, pk)| {
                (pk as u64 * SHARE_UNIT as u64 / rotation_packages.max(1) as u64)
                    as u32
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_compiles_to_the_default_image() {
        let plan = BandwidthPlan::new();
        let port_app = vec![None, Some(0), Some(1), None];
        let prog = plan.compile(&port_app, 64, 8).unwrap();
        assert_eq!(prog.budgets, vec![8, 8, 8, 8]);
        assert_eq!(prog.rotation, vec![0, 1, 2, 3]);
        assert!(prog.app_packages.is_empty());
    }

    #[test]
    fn shares_lower_proportionally_with_largest_remainder() {
        let plan = BandwidthPlan::with_shares(&[(0, 750), (1, 250)]).unwrap();
        // App 0 on ports 1..=3, app 1 on port 4 (16-port board).
        let mut port_app = vec![None; 16];
        for p in 1..=3 {
            port_app[p] = Some(0);
        }
        port_app[4] = Some(1);
        let prog = plan.compile(&port_app, 64, 8).unwrap();
        // T=64: app 0 gets 48 over 3 masters (16 each), app 1 gets 16.
        assert_eq!(&prog.budgets[1..=4], &[16, 16, 16, 16]);
        assert_eq!(prog.app_packages, vec![(0, 48), (1, 16)]);
        assert_eq!(prog.effective_share(0, 64), 750);
        assert_eq!(prog.effective_share(1, 64), 250);
        // Bridge carries any app's full quantum; free ports stay default.
        assert_eq!(prog.budgets[0], 64);
        assert_eq!(prog.budgets[5], 8);
        // Contracted masters adjacent, right after the bridge.
        assert_eq!(&prog.rotation[..5], &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn uneven_split_spreads_the_remainder_deterministically() {
        let plan = BandwidthPlan::with_shares(&[(7, 500)]).unwrap();
        let mut port_app = vec![None; 8];
        for p in [2usize, 5, 6] {
            port_app[p] = Some(7);
        }
        let prog = plan.compile(&port_app, 100, 8).unwrap();
        // 50 packages over 3 masters: 17, 17, 16 in ascending port order.
        assert_eq!(prog.budgets[2], 17);
        assert_eq!(prog.budgets[5], 17);
        assert_eq!(prog.budgets[6], 16);
        assert_eq!(prog.rotation[1..4], [2, 5, 6]);
    }

    #[test]
    fn tiny_share_keeps_every_master_granted() {
        let plan = BandwidthPlan::with_shares(&[(0, 10)]).unwrap();
        let mut port_app = vec![None; 8];
        for p in 1..=5 {
            port_app[p] = Some(0);
        }
        let prog = plan.compile(&port_app, 16, 8).unwrap();
        // round(16 * 10/1000) = 0 < 5 masters: floor at 1 package each.
        for p in 1..=5 {
            assert_eq!(prog.budgets[p], 1, "port {p}");
        }
    }

    #[test]
    fn rotation_groups_best_effort_after_contracted() {
        let plan = BandwidthPlan::with_shares(&[(2, 400)]).unwrap();
        let port_app =
            vec![None, Some(9), Some(2), None, Some(2), Some(9), None, None];
        let prog = plan.compile(&port_app, 64, 8).unwrap();
        assert_eq!(prog.rotation, vec![0, 2, 4, 1, 5, 3, 6, 7]);
    }

    #[test]
    fn overcommit_and_malformed_specs_are_refused() {
        assert!(BandwidthPlan::with_shares(&[(0, 600), (1, 500)]).is_err());
        let mut plan = BandwidthPlan::with_shares(&[(0, 600)]).unwrap();
        assert!(plan.set_share(1, 500).is_err());
        plan.set_share(0, 100).unwrap(); // re-set shrinks, never doubles
        assert_eq!(plan.share_of(0), Some(100));
        plan.set_share(0, 0).unwrap();
        assert!(plan.is_empty());
        assert!(BandwidthPlan::parse("0:700").is_err());
        assert!(BandwidthPlan::parse("x=1").is_err());
        assert!(BandwidthPlan::parse("0=700,0=100").is_err());
        // A huge CLI share must fail cleanly, never overflow the
        // overcommit sum (debug) or wrap past it (release).
        assert!(BandwidthPlan::parse("0=500,1=4294967295").is_err());
        let mut big = BandwidthPlan::new();
        assert!(big.set_share(0, SHARE_UNIT + 1).is_err());
        let p = BandwidthPlan::parse("0=700, 3=100").unwrap();
        assert_eq!(p.share_of(0), Some(700));
        assert_eq!(p.share_of(3), Some(100));
        assert_eq!(p.best_effort_share(), 200);
    }

    #[test]
    fn compile_validates_its_knobs() {
        let plan = BandwidthPlan::new();
        assert!(plan.compile(&[None; 1], 64, 8).is_err());
        assert!(plan.compile(&[None; 33], 64, 8).is_err());
        assert!(plan.compile(&[None; 4], 0, 8).is_err());
        assert!(plan.compile(&[None; 4], 256, 8).is_err());
        assert!(plan.compile(&[None; 4], 64, 0).is_err());
    }

    #[test]
    fn compile_is_deterministic_at_any_width() {
        for n in 2..=32usize {
            let plan =
                BandwidthPlan::with_shares(&[(0, 500), (1, 300)]).unwrap();
            let mut port_app = vec![None; n];
            for p in 1..n {
                port_app[p] = Some((p % 3) as u32);
            }
            let a = plan.compile(&port_app, 64, 8).unwrap();
            let b = plan.compile(&port_app, 64, 8).unwrap();
            assert_eq!(a, b, "width {n}");
            assert_eq!(a.budgets.len(), n);
            assert!(a.budgets.iter().all(|&b| (1..=255).contains(&b)));
            let mut sorted = a.rotation.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "width {n}");
        }
    }
}
