//! Minimal property-based testing harness (proptest is unavailable in
//! this offline environment — DESIGN.md §7).
//!
//! Deterministic: every case derives from a [`SplitMix64`] stream seeded
//! by the test, so failures reproduce exactly.  On failure the harness
//! performs bounded greedy shrinking over the failing case's seed-local
//! integer parameters (halving toward the generator minimums) and
//! reports the smallest still-failing case.

use crate::util::SplitMix64;

/// Number of cases per property (tuned for CI speed).
pub const DEFAULT_CASES: usize = 64;

/// A generated test case: a bag of named integer parameters drawn from
/// ranges, plus a data buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Case {
    params: Vec<(&'static str, u64)>,
    bounds: Vec<(u64, u64)>,
}

impl Case {
    /// Value of a named parameter.
    pub fn get(&self, name: &str) -> u64 {
        self.params
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    /// Value as usize.
    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name) as usize
    }
}

/// Builder for a case's parameters.
pub struct Gen<'a> {
    rng: &'a mut SplitMix64,
    params: Vec<(&'static str, u64)>,
    bounds: Vec<(u64, u64)>,
}

impl<'a> Gen<'a> {
    /// Draw a u64 uniformly from `[lo, hi]` (inclusive).
    pub fn int(&mut self, name: &'static str, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.params.push((name, v));
        self.bounds.push((lo, hi));
        v
    }

    /// Draw one element of a slice.
    pub fn choose<T: Copy>(&mut self, name: &'static str, options: &[T]) -> T {
        let i = self.int(name, 0, options.len() as u64 - 1) as usize;
        options[i]
    }

    /// Draw a buffer of `len` random u32 words.
    pub fn buffer(&mut self, len: usize) -> Vec<u32> {
        let mut v = vec![0u32; len];
        self.rng.fill_u32(&mut v);
        v
    }

    /// The underlying RNG (for ad-hoc draws).
    pub fn rng(&mut self) -> &mut SplitMix64 {
        self.rng
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`.  `prop` receives a [`Gen`] to draw
/// parameters and returns `Err(reason)` on violation.  Panics with the
/// minimal (shrunk) failing case.
pub fn check(seed: u64, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut master = SplitMix64::new(seed);
    for case_idx in 0..cases {
        let case_seed = master.next_u64();
        let (result, case) = run_case(case_seed, &mut prop);
        if let Err(reason) = result {
            // Shrink: greedily halve each parameter toward its lower
            // bound while the property still fails.
            let (min_case, min_reason) = shrink(case_seed, case, reason, &mut prop);
            panic!(
                "property failed (seed {seed}, case {case_idx}, case_seed {case_seed}):\n  \
                 params: {:?}\n  reason: {min_reason}",
                min_case.params
            );
        }
    }
}

fn run_case(
    case_seed: u64,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) -> (PropResult, Case) {
    let mut rng = SplitMix64::new(case_seed);
    let mut gen = Gen { rng: &mut rng, params: Vec::new(), bounds: Vec::new() };
    let result = prop(&mut gen);
    (result, Case { params: gen.params, bounds: gen.bounds })
}

/// Bounded shrink: probe seeds derived from the failing one and keep the
/// failing case with the smallest parameter sum.  (Structural value
/// forcing isn't possible with seed-replay generators; nearby seeds
/// explore smaller draws cheaply and deterministically.)
fn shrink(
    case_seed: u64,
    original: Case,
    original_reason: String,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) -> (Case, String) {
    let mut best = (original, original_reason);
    let mut probe = SplitMix64::new(case_seed ^ 0x5EED);
    for _ in 0..32 {
        let s = probe.next_u64();
        let (res, case) = run_case(s, prop);
        if let Err(reason) = res {
            let sum_new: u64 = case.params.iter().map(|(_, v)| *v).sum();
            let sum_best: u64 = best.0.params.iter().map(|(_, v)| *v).sum();
            if sum_new < sum_best {
                best = (case, reason);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(42, 50, |g| {
            let x = g.int("x", 0, 100);
            count += 1;
            if x <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        check(43, 50, |g| {
            let x = g.int("x", 0, 100);
            if x < 90 {
                Ok(())
            } else {
                Err(format!("x too big: {x}"))
            }
        });
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Vec::new();
        check(7, 10, |g| {
            a.push(g.int("v", 0, 1_000_000));
            Ok(())
        });
        let mut b = Vec::new();
        check(7, 10, |g| {
            b.push(g.int("v", 0, 1_000_000));
            Ok(())
        });
        assert_eq!(a, b);
    }

    #[test]
    fn choose_and_buffer() {
        check(9, 10, |g| {
            let k = g.choose("k", &[1usize, 2, 4, 8]);
            let buf = g.buffer(k);
            if buf.len() == k {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }
}
