//! The register file (§IV.D) — Table III reproduced exactly at 4 ports,
//! generalized to any crossbar width by the banked layout v2.
//!
//! Twenty 32-bit registers provide configuration to the crossbar and PR
//! regions and collect status from ICAP, the computation modules and the
//! AXI-WB bridge.  Table III (the 4-port instantiation):
//!
//! | N  | Address | Contents                                          |
//! |----|---------|---------------------------------------------------|
//! | 0  | 0x00    | FPGA device ID                                    |
//! | 1  | 0x04    | PR region 1 destination address                   |
//! | 2  | 0x08    | PR region 2 destination address                   |
//! | 3  | 0x0C    | PR region 3 destination address                   |
//! | 4  | 0x10    | Reset PR regions and ports [3:0]                  |
//! | 5  | 0x14    | Allowed addresses of port 0 master                |
//! | 6  | 0x18    | Allowed addresses of port 1 master                |
//! | 7  | 0x1C    | Allowed addresses of port 2 master                |
//! | 8  | 0x20    | Allowed addresses of port 3 master                |
//! | 9  | 0x24    | Package numbers allowed in port 0 for ports [3:0] |
//! | 10 | 0x28    | Package numbers allowed in port 1 for ports [3:0] |
//! | 11 | 0x2C    | Package numbers allowed in port 2 for ports [3:0] |
//! | 12 | 0x30    | Package numbers allowed in port 3 for ports [3:0] |
//! | 13 | 0x34    | Application ID 0 destination address              |
//! | 14 | 0x38    | Application ID 1 destination address              |
//! | 15 | 0x3C    | Application ID 2 destination address              |
//! | 16 | 0x40    | Application ID 3 destination address              |
//! | 17 | 0x44    | PR region [3:1] last transaction error status     |
//! | 18 | 0x48    | App. ID [3:0] last transaction error status       |
//! | 19 | 0x4C    | ICAP status                                       |
//!
//! Package-number registers hold four 8-bit fields (master 0 in bits
//! [7:0] ... master 3 in bits [31:24]); a field value of 0 means "use the
//! default budget" so an unprogrammed register file stays functional.
//! Error-status registers hold 8-bit error codes per region / app ID.
//!
//! # The banked layout v2
//!
//! A [`RegfileLayout`] computes every bank's base address from the port
//! count, so a [`RegisterFile`] built with [`RegisterFile::with_ports`]
//! programs destinations, isolation masks, WRR package budgets, app
//! destinations and error status for **any** crossbar width — budget
//! and error fields beyond 4 spill into ⌈N/4⌉-register banks with the
//! same 8-bit packing.  The 4-port instantiation is byte-for-byte
//! identical to Table III (golden test below), and the Table III byte
//! addresses keep working on wider layouts through the v1 compatibility
//! window ([`RegisterFile::v1_read_addr`] /
//! [`RegisterFile::v1_write_addr`]).
//!
//! Typed accessors return `Err(`[`crate::ElasticError::RegfileWindow`]`)`
//! for ports/regions/apps outside the *configured* layout instead of
//! panicking, so a stray AXI-Lite-style host access can never crash the
//! shell model; the manager surfaces the same typed error when asked to
//! place work it cannot program.

mod layout;

pub use layout::{RegfileLayout, FIELDS_PER_REG};

use crate::wishbone::WbError;
use crate::{ElasticError, Result};

/// Number of registers in the Table III (4-port) instantiation.
pub const NUM_REGS: usize = 20;

/// Crossbar ports Table III programs: bridge port 0 + PR regions 1..=3.
pub const MAX_PORTS: usize = 4;

/// PR regions (= non-bridge ports) addressable by Table III.
pub const MAX_PR_REGIONS: usize = MAX_PORTS - 1;

/// Symbolic Table III register indices (the 4-port instantiation; wider
/// layouts derive their map from [`RegfileLayout`]).
pub mod regs {
    pub const DEVICE_ID: usize = 0;
    pub const PR1_DEST: usize = 1;
    pub const PR2_DEST: usize = 2;
    pub const PR3_DEST: usize = 3;
    pub const RESET: usize = 4;
    pub const ALLOWED_PORT0: usize = 5;
    pub const ALLOWED_PORT1: usize = 6;
    pub const ALLOWED_PORT2: usize = 7;
    pub const ALLOWED_PORT3: usize = 8;
    pub const PACKAGES_PORT0: usize = 9;
    pub const PACKAGES_PORT1: usize = 10;
    pub const PACKAGES_PORT2: usize = 11;
    pub const PACKAGES_PORT3: usize = 12;
    pub const APP0_DEST: usize = 13;
    pub const APP1_DEST: usize = 14;
    pub const APP2_DEST: usize = 15;
    pub const APP3_DEST: usize = 16;
    pub const PR_ERROR_STATUS: usize = 17;
    pub const APP_ERROR_STATUS: usize = 18;
    pub const ICAP_STATUS: usize = 19;
}

/// The KCU1500 prototype's device-ID register value (arbitrary constant
/// the host reads to confirm the shell is alive).
pub const DEVICE_ID_VALUE: u32 = 0x4B43_5531; // "KCU1"

/// ICAP status codes stored in the ICAP status register.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IcapStatus {
    Idle,
    Busy,
    Done,
    Error,
}

impl IcapStatus {
    /// Register encoding.
    pub fn code(self) -> u32 {
        match self {
            IcapStatus::Idle => 0,
            IcapStatus::Busy => 1,
            IcapStatus::Done => 2,
            IcapStatus::Error => 3,
        }
    }

    /// Decode.
    pub fn from_code(code: u32) -> Option<Self> {
        match code {
            0 => Some(IcapStatus::Idle),
            1 => Some(IcapStatus::Busy),
            2 => Some(IcapStatus::Done),
            3 => Some(IcapStatus::Error),
            _ => None,
        }
    }
}

/// The register file.  Addressed by byte address over the AXI-Lite bypass
/// (§IV.B) or by index from the fabric side.
#[derive(Debug, Clone)]
pub struct RegisterFile {
    layout: RegfileLayout,
    regs: Vec<u32>,
    /// Write-generation counter so the fabric can cheaply detect
    /// configuration changes and re-derive crossbar state.
    generation: u64,
}

impl Default for RegisterFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegisterFile {
    /// Power-on Table III file (4 ports): device ID set, all else zero.
    pub fn new() -> Self {
        Self::with_layout(RegfileLayout::table3())
    }

    /// Power-on file for an `num_ports`-wide crossbar.
    pub fn with_ports(num_ports: usize) -> Self {
        Self::with_layout(RegfileLayout::new(num_ports))
    }

    /// Power-on file under an explicit layout.
    pub fn with_layout(layout: RegfileLayout) -> Self {
        let mut regs = vec![0u32; layout.num_regs()];
        regs[layout.device_id_reg()] = DEVICE_ID_VALUE;
        Self { layout, regs, generation: 0 }
    }

    /// The layout this file is banked under.
    pub fn layout(&self) -> &RegfileLayout {
        &self.layout
    }

    /// Total registers (Table III: 20).
    pub fn num_regs(&self) -> usize {
        self.regs.len()
    }

    /// Read by register index.  Panics on out-of-range indices — index
    /// arithmetic comes from the layout, so a violation is a model bug;
    /// host-facing paths go through [`read_addr`](Self::read_addr).
    pub fn read(&self, index: usize) -> u32 {
        assert!(index < self.regs.len(), "register index {index} out of range");
        self.regs[index]
    }

    /// Write by register index (same contract as [`read`](Self::read)).
    pub fn write(&mut self, index: usize, value: u32) {
        assert!(index < self.regs.len(), "register index {index} out of range");
        self.regs[index] = value;
        self.generation += 1;
    }

    /// Read by byte address (AXI-Lite view; this layout's addressing).
    pub fn read_addr(&self, addr: u32) -> Option<u32> {
        let idx = (addr / 4) as usize;
        if addr % 4 == 0 && idx < self.regs.len() {
            Some(self.regs[idx])
        } else {
            None
        }
    }

    /// Write by byte address (AXI-Lite view).  Out-of-range or unaligned
    /// addresses are refused, never panicking the shell.
    pub fn write_addr(&mut self, addr: u32, value: u32) -> bool {
        let idx = (addr / 4) as usize;
        if addr % 4 == 0 && idx < self.regs.len() {
            self.write(idx, value);
            true
        } else {
            false
        }
    }

    /// Byte-granular AXI-Lite read (the host model's narrow-access
    /// path): any byte of any register in this layout's byte map
    /// (`addr = 4·reg + lane`).  `None` past the configured layout.
    pub fn read_byte(&self, addr: u32) -> Option<u8> {
        let idx = (addr / 4) as usize;
        if idx >= self.regs.len() {
            return None;
        }
        Some((self.regs[idx] >> (8 * (addr % 4))) as u8)
    }

    /// Byte-granular AXI-Lite write: read-modify-write of the
    /// containing 32-bit register, so a single-byte store into a packed
    /// bank (e.g. one master's 8-bit WRR budget field) replaces exactly
    /// that field and leaves its register neighbours untouched.
    /// Out-of-layout addresses are refused, never panicking; refusals
    /// do not bump the write generation.
    ///
    /// Precedence: on managed boards the bandwidth plan is the
    /// authoritative writer of the budget banks — a byte patch to a
    /// budget field takes effect immediately (generation-bumped) but
    /// only lasts until the next allocation event whose compiled plan
    /// differs from the last one applied
    /// ([`crate::manager::ElasticManager::apply_plan`] rewrites the
    /// banks then).  Patches to non-budget registers are not subject
    /// to plan rewrites.
    pub fn write_byte(&mut self, addr: u32, value: u8) -> bool {
        let idx = (addr / 4) as usize;
        if idx >= self.regs.len() {
            return false;
        }
        let shift = 8 * (addr % 4);
        let mut v = self.regs[idx];
        v &= !(0xFFu32 << shift);
        v |= (value as u32) << shift;
        self.write(idx, v);
        true
    }

    /// Read by **Table III** byte address, translated through the v1
    /// compatibility window — host software written against the 4-port
    /// map keeps working on any layout width.
    pub fn v1_read_addr(&self, addr: u32) -> Option<u32> {
        if addr % 4 != 0 {
            return None;
        }
        let v2 = self.layout.v1_compat_index((addr / 4) as usize)?;
        Some(self.regs[v2])
    }

    /// Write by **Table III** byte address through the v1 window.
    pub fn v1_write_addr(&mut self, addr: u32, value: u32) -> bool {
        if addr % 4 != 0 {
            return false;
        }
        match self.layout.v1_compat_index((addr / 4) as usize) {
            Some(v2) => {
                self.write(v2, value);
                true
            }
            None => false,
        }
    }

    /// Configuration-write generation counter.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    fn window_err(&self, what: &str, i: usize) -> ElasticError {
        ElasticError::RegfileWindow(format!(
            "{what} {i} is outside the configured {}-port register-file \
             layout",
            self.layout.num_ports()
        ))
    }

    fn check_region(&self, region: usize) -> Result<()> {
        if self.layout.covers_region(region) {
            Ok(())
        } else {
            Err(self.window_err("PR region", region))
        }
    }

    fn check_port(&self, port: usize) -> Result<()> {
        if self.layout.covers_port(port) {
            Ok(())
        } else {
            Err(self.window_err("port", port))
        }
    }

    fn check_app(&self, app_id: usize) -> Result<()> {
        if self.layout.covers_app(app_id) {
            Ok(())
        } else {
            Err(self.window_err("app ID", app_id))
        }
    }

    // ------------------------------------------------------------------
    // typed views (the fabric side)
    // ------------------------------------------------------------------

    /// PR region `r` (1-indexed) destination address (one-hot).
    pub fn pr_destination(&self, region: usize) -> Result<u32> {
        self.check_region(region)?;
        Ok(self.regs[self.layout.pr_dest_reg(region)])
    }

    /// Program PR region `r`'s destination (one-hot slave address).
    pub fn set_pr_destination(
        &mut self,
        region: usize,
        dest_onehot: u32,
    ) -> Result<()> {
        self.check_region(region)?;
        self.write(self.layout.pr_dest_reg(region), dest_onehot);
        Ok(())
    }

    /// Reset bit for port `p`.
    pub fn port_reset(&self, port: usize) -> Result<bool> {
        self.check_port(port)?;
        Ok(self.regs[self.layout.reset_reg()] >> port & 1 == 1)
    }

    /// Set/clear port `p`'s reset bit.
    pub fn set_port_reset(&mut self, port: usize, on: bool) -> Result<()> {
        self.check_port(port)?;
        let idx = self.layout.reset_reg();
        let mut v = self.regs[idx];
        if on {
            v |= 1 << port;
        } else {
            v &= !(1 << port);
        }
        self.write(idx, v);
        Ok(())
    }

    /// Allowed-slaves isolation mask for port `p`'s master.
    pub fn allowed_slaves(&self, port: usize) -> Result<u32> {
        self.check_port(port)?;
        Ok(self.regs[self.layout.allowed_reg(port)])
    }

    /// Program port `p`'s isolation mask.
    pub fn set_allowed_slaves(&mut self, port: usize, mask: u32) -> Result<()> {
        self.check_port(port)?;
        self.write(self.layout.allowed_reg(port), mask);
        Ok(())
    }

    /// Package budget for `master` at `slave` (8-bit fields; 0 =
    /// unprogrammed, caller substitutes the default).
    pub fn allowed_packages(&self, slave: usize, master: usize) -> Result<u32> {
        self.check_port(slave)?;
        self.check_port(master)?;
        let idx = self.layout.packages_reg(slave, master);
        Ok(self.regs[idx] >> RegfileLayout::packages_shift(master) & 0xFF)
    }

    /// Program the package budget for `master` at `slave` (1..=255).
    pub fn set_allowed_packages(
        &mut self,
        slave: usize,
        master: usize,
        packages: u32,
    ) -> Result<()> {
        self.check_port(slave)?;
        self.check_port(master)?;
        if packages > 0xFF {
            return Err(ElasticError::Config(format!(
                "package budget {packages} does not fit the 8-bit field"
            )));
        }
        let idx = self.layout.packages_reg(slave, master);
        let shift = RegfileLayout::packages_shift(master);
        let mut v = self.regs[idx];
        v &= !(0xFF << shift);
        v |= packages << shift;
        self.write(idx, v);
        Ok(())
    }

    /// Program a compiled bandwidth plan ([`crate::qos::PlanProgram`])
    /// into the banked package-budget registers: `budgets[m]` becomes
    /// master `m`'s per-grant budget at **every** slave port (bandwidth
    /// is a property of the master plane).  `budgets` must cover the
    /// whole layout width with values 1..=255.
    pub fn write_master_budgets(&mut self, budgets: &[u32]) -> Result<()> {
        if budgets.len() != self.layout.num_ports() {
            return Err(ElasticError::Config(format!(
                "plan programs {} masters, layout has {} ports",
                budgets.len(),
                self.layout.num_ports()
            )));
        }
        for (m, &b) in budgets.iter().enumerate() {
            if b == 0 {
                return Err(ElasticError::Config(format!(
                    "plan assigns master {m} a zero package budget"
                )));
            }
        }
        for s in 0..self.layout.num_ports() {
            for (m, &b) in budgets.iter().enumerate() {
                self.set_allowed_packages(s, m, b)?;
            }
        }
        Ok(())
    }

    /// The per-master budget image the last plan write left behind,
    /// read back from the slave-0 budget bank (plan writes are uniform
    /// across slaves; 0 means "unprogrammed, default applies").
    pub fn master_budgets(&self) -> Vec<u32> {
        (0..self.layout.num_ports())
            .map(|m| {
                self.allowed_packages(0, m)
                    .expect("master within own layout")
            })
            .collect()
    }

    /// Application `id`'s destination address.
    pub fn app_destination(&self, app_id: usize) -> Result<u32> {
        self.check_app(app_id)?;
        Ok(self.regs[self.layout.app_dest_reg(app_id)])
    }

    /// Program application `id`'s destination.
    pub fn set_app_destination(
        &mut self,
        app_id: usize,
        dest_onehot: u32,
    ) -> Result<()> {
        self.check_app(app_id)?;
        self.write(self.layout.app_dest_reg(app_id), dest_onehot);
        Ok(())
    }

    /// Last transaction error for PR region `r` (8-bit code, 0 = OK).
    pub fn pr_error(&self, region: usize) -> Result<Option<WbError>> {
        self.check_region(region)?;
        let idx = self.layout.pr_error_reg(region);
        Ok(WbError::from_code(
            self.regs[idx] >> RegfileLayout::pr_error_shift(region) & 0xFF,
        ))
    }

    /// Update one 8-bit status field.  Unchanged bytes are not
    /// re-written: the write generation drives the fabric's full-width
    /// crossbar remirror, so a success reported on every transfer must
    /// not look like a configuration change.
    fn set_status_byte(&mut self, idx: usize, shift: u32, code: u32) {
        let mut v = self.regs[idx];
        v &= !(0xFF << shift);
        v |= code << shift;
        if v != self.regs[idx] {
            self.write(idx, v);
        }
    }

    /// Record PR region `r`'s last transaction status.
    pub fn set_pr_error(
        &mut self,
        region: usize,
        err: Option<WbError>,
    ) -> Result<()> {
        self.check_region(region)?;
        self.set_status_byte(
            self.layout.pr_error_reg(region),
            RegfileLayout::pr_error_shift(region),
            err.map(WbError::code).unwrap_or(0),
        );
        Ok(())
    }

    /// Last transaction error for application `id`.
    pub fn app_error(&self, app_id: usize) -> Result<Option<WbError>> {
        self.check_app(app_id)?;
        let idx = self.layout.app_error_reg(app_id);
        Ok(WbError::from_code(
            self.regs[idx] >> RegfileLayout::app_error_shift(app_id) & 0xFF,
        ))
    }

    /// Record application `id`'s last transaction status.
    pub fn set_app_error(
        &mut self,
        app_id: usize,
        err: Option<WbError>,
    ) -> Result<()> {
        self.check_app(app_id)?;
        self.set_status_byte(
            self.layout.app_error_reg(app_id),
            RegfileLayout::app_error_shift(app_id),
            err.map(WbError::code).unwrap_or(0),
        );
        Ok(())
    }

    /// ICAP status.
    pub fn icap_status(&self) -> IcapStatus {
        IcapStatus::from_code(self.regs[self.layout.icap_reg()])
            .unwrap_or(IcapStatus::Error)
    }

    /// Record ICAP status.
    pub fn set_icap_status(&mut self, st: IcapStatus) {
        self.write(self.layout.icap_reg(), st.code());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_on_state() {
        let rf = RegisterFile::new();
        assert_eq!(rf.read(regs::DEVICE_ID), DEVICE_ID_VALUE);
        for i in 1..NUM_REGS {
            assert_eq!(rf.read(i), 0, "reg {i} must reset to 0");
        }
        assert_eq!(rf.icap_status(), IcapStatus::Idle);
    }

    #[test]
    fn table3_byte_addressing() {
        let mut rf = RegisterFile::new();
        assert_eq!(rf.read_addr(0x0), Some(DEVICE_ID_VALUE));
        assert!(rf.write_addr(0x14, 0b1110));
        assert_eq!(rf.allowed_slaves(0).unwrap(), 0b1110);
        assert!(rf.write_addr(0x4C, 2));
        assert_eq!(rf.icap_status(), IcapStatus::Done);
        // Address 0x50 is out of range; 0x2 is unaligned.
        assert_eq!(rf.read_addr(0x50), None);
        assert_eq!(rf.read_addr(0x2), None);
        assert!(!rf.write_addr(0x50, 1));
    }

    /// Golden Table III byte image: a fixed programming sequence through
    /// the typed v2 accessors must land in exactly the Table III bytes.
    /// Pins the 4-port instantiation of the banked layout byte-for-byte.
    #[test]
    fn golden_table3_byte_image() {
        let mut rf = RegisterFile::new();
        rf.set_pr_destination(1, 0b0100).unwrap(); // mult -> enc
        rf.set_pr_destination(2, 0b1000).unwrap(); // enc -> dec
        rf.set_pr_destination(3, 0b0001).unwrap(); // dec -> bridge
        rf.set_port_reset(2, true).unwrap();
        rf.set_allowed_slaves(0, 0b0010).unwrap();
        rf.set_allowed_slaves(1, 0b0100).unwrap();
        rf.set_allowed_slaves(2, 0b1000).unwrap();
        rf.set_allowed_slaves(3, 0b0001).unwrap();
        rf.set_allowed_packages(1, 0, 16).unwrap();
        rf.set_allowed_packages(2, 1, 32).unwrap();
        rf.set_allowed_packages(3, 2, 64).unwrap();
        rf.set_allowed_packages(0, 3, 128).unwrap();
        rf.set_app_destination(0, 0b0010).unwrap();
        rf.set_app_destination(3, 0b1000).unwrap();
        rf.set_pr_error(2, Some(WbError::GrantTimeout)).unwrap();
        rf.set_app_error(1, Some(WbError::InvalidDestination)).unwrap();
        rf.set_icap_status(IcapStatus::Busy);
        let golden: [u32; NUM_REGS] = [
            DEVICE_ID_VALUE, // 0x00 device ID
            0b0100,          // 0x04 PR1 dest
            0b1000,          // 0x08 PR2 dest
            0b0001,          // 0x0C PR3 dest
            0b0100,          // 0x10 reset, bit 2
            0b0010,          // 0x14 allowed port 0
            0b0100,          // 0x18 allowed port 1
            0b1000,          // 0x1C allowed port 2
            0b0001,          // 0x20 allowed port 3
            128 << 24,       // 0x24 packages port 0, master 3
            16,              // 0x28 packages port 1, master 0
            32 << 8,         // 0x2C packages port 2, master 1
            64 << 16,        // 0x30 packages port 3, master 2
            0b0010,          // 0x34 app 0 dest
            0,               // 0x38 app 1 dest
            0,               // 0x3C app 2 dest
            0b1000,          // 0x40 app 3 dest
            0x2 << 8,        // 0x44 PR error, region 2 = GrantTimeout
            0x1 << 8,        // 0x48 app error, app 1 = InvalidDestination
            1,               // 0x4C ICAP = Busy
        ];
        for (i, &want) in golden.iter().enumerate() {
            assert_eq!(
                rf.read_addr(4 * i as u32),
                Some(want),
                "Table III register {i} (byte 0x{:02X})",
                4 * i
            );
            // The v1 compat path is the identity at 4 ports.
            assert_eq!(rf.v1_read_addr(4 * i as u32), Some(want));
        }
    }

    #[test]
    fn wide_layout_programs_every_region_and_spills_fields() {
        let mut rf = RegisterFile::with_ports(16);
        assert_eq!(rf.num_regs(), 122);
        for r in 1..16 {
            rf.set_pr_destination(r, 1 << ((r + 1) % 16)).unwrap();
            rf.set_allowed_slaves(r, 1 << ((r + 1) % 16)).unwrap();
        }
        for m in 0..16 {
            rf.set_allowed_packages(5, m, (m as u32 + 1) * 10).unwrap();
        }
        for m in 0..16 {
            assert_eq!(
                rf.allowed_packages(5, m).unwrap(),
                ((m as u32 + 1) * 10) & 0xFF
            );
        }
        // Fields spill into the bank's later registers, 4 per register.
        let l = *rf.layout();
        assert_eq!(rf.read(l.packages_reg(5, 0)), 40 << 24 | 30 << 16 | 20 << 8 | 10);
        assert_eq!(rf.read(l.packages_reg(5, 15)) >> 24, 160 & 0xFF);
        // Errors for regions beyond Table III land in the spill regs.
        rf.set_pr_error(13, Some(WbError::AckTimeout)).unwrap();
        assert_eq!(rf.pr_error(13).unwrap(), Some(WbError::AckTimeout));
        assert_eq!(rf.pr_error(12).unwrap(), None);
        rf.set_app_error(9, Some(WbError::PortInReset)).unwrap();
        assert_eq!(rf.app_error(9).unwrap(), Some(WbError::PortInReset));
    }

    #[test]
    fn v1_window_reaches_translated_registers_on_wide_layouts() {
        let mut rf = RegisterFile::with_ports(16);
        // Table III 0x14 = allowed port 0; lives at reg 17 here.
        assert!(rf.v1_write_addr(0x14, 0b10));
        assert_eq!(rf.allowed_slaves(0).unwrap(), 0b10);
        assert_eq!(rf.read(17), 0b10);
        assert_eq!(rf.v1_read_addr(0x14), Some(0b10));
        // Table III 0x4C = ICAP status; lives at reg 121 here.
        assert!(rf.v1_write_addr(0x4C, 2));
        assert_eq!(rf.icap_status(), IcapStatus::Done);
        // Out-of-window and unaligned v1 addresses are refused.
        assert!(!rf.v1_write_addr(0x50, 1));
        assert_eq!(rf.v1_read_addr(0x52), None);
    }

    #[test]
    fn out_of_window_accesses_error_instead_of_panicking() {
        let mut rf = RegisterFile::new();
        assert!(matches!(
            rf.set_allowed_slaves(4, 0b1),
            Err(ElasticError::RegfileWindow(_))
        ));
        assert!(matches!(
            rf.pr_destination(4),
            Err(ElasticError::RegfileWindow(_))
        ));
        assert!(matches!(
            rf.set_pr_destination(0, 1),
            Err(ElasticError::RegfileWindow(_)),
        ));
        assert!(matches!(
            rf.app_error(4),
            Err(ElasticError::RegfileWindow(_))
        ));
        assert!(matches!(
            rf.set_allowed_packages(1, 9, 8),
            Err(ElasticError::RegfileWindow(_))
        ));
        assert!(matches!(
            rf.set_allowed_packages(1, 1, 300),
            Err(ElasticError::Config(_))
        ));
        let g = rf.generation();
        assert_eq!(g, 0, "refused writes must not bump the generation");
    }

    #[test]
    fn byte_shim_rmw_preserves_packed_neighbours() {
        // Table III reg 10 (byte base 0x28) packs four budget fields;
        // a single-byte host store must replace exactly one field.
        let mut rf = RegisterFile::new();
        rf.set_allowed_packages(1, 0, 16).unwrap();
        rf.set_allowed_packages(1, 3, 128).unwrap();
        assert!(rf.write_byte(0x28 + 1, 77), "master 1's field, byte lane 1");
        assert_eq!(rf.allowed_packages(1, 0).unwrap(), 16, "lane 0 untouched");
        assert_eq!(rf.allowed_packages(1, 1).unwrap(), 77);
        assert_eq!(rf.allowed_packages(1, 3).unwrap(), 128, "lane 3 untouched");
        assert_eq!(rf.read_byte(0x28 + 1), Some(77));
        assert_eq!(rf.read_byte(0x28 + 3), Some(128));
        // Device-ID bytes read little-endian lane by lane.
        assert_eq!(rf.read_byte(0x0), Some((DEVICE_ID_VALUE & 0xFF) as u8));
        assert_eq!(rf.read_byte(0x3), Some((DEVICE_ID_VALUE >> 24) as u8));
        // Past the layout: refused, no generation bump, no panic.
        let g = rf.generation();
        assert_eq!(rf.read_byte(4 * NUM_REGS as u32), None);
        assert!(!rf.write_byte(4 * NUM_REGS as u32, 1));
        assert_eq!(rf.generation(), g);
    }

    #[test]
    fn byte_shim_reaches_spill_banks_on_wide_layouts() {
        // Master 13's budget at slave 2 on a 16-port board lives in a
        // spill register Table III never had (reg 44, lane 1).
        let mut rf = RegisterFile::with_ports(16);
        let l = *rf.layout();
        let reg = l.packages_reg(2, 13);
        let lane = RegfileLayout::packages_shift(13) / 8;
        assert!(rf.write_byte(4 * reg as u32 + lane, 42));
        assert_eq!(rf.allowed_packages(2, 13).unwrap(), 42);
        assert_eq!(rf.read_byte(4 * reg as u32 + lane), Some(42));
    }

    #[test]
    fn master_budget_plane_round_trips() {
        let mut rf = RegisterFile::with_ports(8);
        let budgets: Vec<u32> = (1..=8).collect();
        rf.write_master_budgets(&budgets).unwrap();
        assert_eq!(rf.master_budgets(), budgets);
        // Uniform across every slave bank.
        for s in 0..8 {
            for m in 0..8 {
                assert_eq!(
                    rf.allowed_packages(s, m).unwrap(),
                    budgets[m],
                    "slave {s} master {m}"
                );
            }
        }
        // Wrong width and zero budgets are typed refusals.
        assert!(rf.write_master_budgets(&[1; 4]).is_err());
        assert!(rf.write_master_budgets(&[1, 1, 1, 0, 1, 1, 1, 1]).is_err());
    }

    #[test]
    fn reset_bits_are_independent() {
        let mut rf = RegisterFile::new();
        rf.set_port_reset(2, true).unwrap();
        assert!(rf.port_reset(2).unwrap());
        assert!(!rf.port_reset(0).unwrap());
        rf.set_port_reset(0, true).unwrap();
        rf.set_port_reset(2, false).unwrap();
        assert!(rf.port_reset(0).unwrap());
        assert!(!rf.port_reset(2).unwrap());
        assert_eq!(rf.read(regs::RESET), 0b0001);
    }

    #[test]
    fn package_fields_pack_four_masters() {
        let mut rf = RegisterFile::new();
        rf.set_allowed_packages(1, 0, 16).unwrap();
        rf.set_allowed_packages(1, 3, 128).unwrap();
        assert_eq!(rf.allowed_packages(1, 0).unwrap(), 16);
        assert_eq!(rf.allowed_packages(1, 3).unwrap(), 128);
        assert_eq!(rf.allowed_packages(1, 1).unwrap(), 0, "unprogrammed field");
        assert_eq!(rf.read(regs::PACKAGES_PORT1), 128 << 24 | 16);
    }

    #[test]
    fn pr_destinations() {
        let mut rf = RegisterFile::new();
        rf.set_pr_destination(1, 0b0100).unwrap();
        rf.set_pr_destination(3, 0b0001).unwrap();
        assert_eq!(rf.pr_destination(1).unwrap(), 0b0100);
        assert_eq!(rf.pr_destination(3).unwrap(), 0b0001);
        assert_eq!(rf.read_addr(0x4), Some(0b0100));
        assert_eq!(rf.read_addr(0xC), Some(0b0001));
    }

    #[test]
    fn error_status_fields() {
        let mut rf = RegisterFile::new();
        assert_eq!(rf.pr_error(1).unwrap(), None);
        rf.set_pr_error(2, Some(WbError::GrantTimeout)).unwrap();
        assert_eq!(rf.pr_error(2).unwrap(), Some(WbError::GrantTimeout));
        assert_eq!(rf.pr_error(1).unwrap(), None);
        rf.set_pr_error(2, None).unwrap();
        assert_eq!(rf.pr_error(2).unwrap(), None);

        rf.set_app_error(3, Some(WbError::InvalidDestination)).unwrap();
        assert_eq!(rf.app_error(3).unwrap(), Some(WbError::InvalidDestination));
        rf.set_app_error(3, None).unwrap();
        assert_eq!(rf.app_error(3).unwrap(), None);
    }

    #[test]
    fn generation_tracks_writes() {
        let mut rf = RegisterFile::new();
        let g0 = rf.generation();
        rf.set_allowed_slaves(0, 0b1111).unwrap();
        assert!(rf.generation() > g0);
        let g1 = rf.generation();
        let _ = rf.read(regs::ALLOWED_PORT0);
        assert_eq!(rf.generation(), g1, "reads don't bump generation");
    }

    #[test]
    fn unchanged_error_status_does_not_bump_generation() {
        // A success reported on every completed transfer writes 0 over
        // 0; it must not look like a configuration change (the fabric
        // remirrors the whole crossbar on every generation bump).
        let mut rf = RegisterFile::new();
        let g0 = rf.generation();
        rf.set_pr_error(1, None).unwrap();
        rf.set_app_error(0, None).unwrap();
        assert_eq!(rf.generation(), g0, "0-over-0 status bumped generation");
        rf.set_pr_error(1, Some(WbError::GrantTimeout)).unwrap();
        let g1 = rf.generation();
        assert!(g1 > g0, "real status change must be visible");
        rf.set_pr_error(1, Some(WbError::GrantTimeout)).unwrap();
        assert_eq!(rf.generation(), g1, "same-code rewrite bumped generation");
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        RegisterFile::new().read(NUM_REGS);
    }

    #[test]
    fn layout_window_bounds() {
        let rf = RegisterFile::new();
        let l = rf.layout();
        assert!(l.covers_port(0));
        assert!(l.covers_port(3));
        assert!(!l.covers_port(4));
        assert!(!l.covers_region(0), "port 0 is the bridge");
        assert!(l.covers_region(1));
        assert!(l.covers_region(MAX_PR_REGIONS));
        assert!(!l.covers_region(MAX_PR_REGIONS + 1));
        let wide = RegisterFile::with_ports(16);
        assert!(wide.layout().covers_region(15));
        assert!(!wide.layout().covers_region(16));
        assert!(wide.layout().covers_app(15));
    }
}
