//! The register file (§IV.D, Table III — reproduced exactly).
//!
//! Twenty 32-bit registers provide configuration to the crossbar and PR
//! regions and collect status from ICAP, the computation modules and the
//! AXI-WB bridge:
//!
//! | N  | Address | Contents                                          |
//! |----|---------|---------------------------------------------------|
//! | 0  | 0x00    | FPGA device ID                                    |
//! | 1  | 0x04    | PR region 1 destination address                   |
//! | 2  | 0x08    | PR region 2 destination address                   |
//! | 3  | 0x0C    | PR region 3 destination address                   |
//! | 4  | 0x10    | Reset PR regions and ports [3:0]                  |
//! | 5  | 0x14    | Allowed addresses of port 0 master                |
//! | 6  | 0x18    | Allowed addresses of port 1 master                |
//! | 7  | 0x1C    | Allowed addresses of port 2 master                |
//! | 8  | 0x20    | Allowed addresses of port 3 master                |
//! | 9  | 0x24    | Package numbers allowed in port 0 for ports [3:0] |
//! | 10 | 0x28    | Package numbers allowed in port 1 for ports [3:0] |
//! | 11 | 0x2C    | Package numbers allowed in port 2 for ports [3:0] |
//! | 12 | 0x30    | Package numbers allowed in port 3 for ports [3:0] |
//! | 13 | 0x34    | Application ID 0 destination address              |
//! | 14 | 0x38    | Application ID 1 destination address              |
//! | 15 | 0x3C    | Application ID 2 destination address              |
//! | 16 | 0x40    | Application ID 3 destination address              |
//! | 17 | 0x44    | PR region [3:1] last transaction error status     |
//! | 18 | 0x48    | App. ID [3:0] last transaction error status       |
//! | 19 | 0x4C    | ICAP status                                       |
//!
//! Package-number registers hold four 8-bit fields (master 0 in bits
//! [7:0] ... master 3 in bits [31:24]); a field value of 0 means "use the
//! default budget" so an unprogrammed register file stays functional.
//! Error-status registers hold 8-bit error codes per region / app ID.
//!
//! # The 4-port window
//!
//! Table III is hard-wired to a 4-port crossbar: destination, isolation,
//! bandwidth and error registers exist for the bridge port plus PR
//! regions 1..=[`MAX_PR_REGIONS`], and for app IDs 0..=3 — there simply
//! are no registers for a 5th port.  Configurations with more crossbar
//! ports can still *simulate* (the crossbar itself is size-generic, see
//! the Fig 6 sweep), but the manager refuses to place work on regions it
//! cannot program, returning [`crate::ElasticError::RegfileWindow`]
//! instead of silently running those ports with power-on defaults.
//! A scalable register-file layout is an open ROADMAP item.

use crate::wishbone::WbError;

/// Number of registers (Table III).
pub const NUM_REGS: usize = 20;

/// Crossbar ports Table III can program: bridge port 0 + PR regions 1..=3.
pub const MAX_PORTS: usize = 4;

/// PR regions (= non-bridge ports) addressable by Table III.
pub const MAX_PR_REGIONS: usize = MAX_PORTS - 1;

/// Symbolic register indices.
pub mod regs {
    pub const DEVICE_ID: usize = 0;
    pub const PR1_DEST: usize = 1;
    pub const PR2_DEST: usize = 2;
    pub const PR3_DEST: usize = 3;
    pub const RESET: usize = 4;
    pub const ALLOWED_PORT0: usize = 5;
    pub const ALLOWED_PORT1: usize = 6;
    pub const ALLOWED_PORT2: usize = 7;
    pub const ALLOWED_PORT3: usize = 8;
    pub const PACKAGES_PORT0: usize = 9;
    pub const PACKAGES_PORT1: usize = 10;
    pub const PACKAGES_PORT2: usize = 11;
    pub const PACKAGES_PORT3: usize = 12;
    pub const APP0_DEST: usize = 13;
    pub const APP1_DEST: usize = 14;
    pub const APP2_DEST: usize = 15;
    pub const APP3_DEST: usize = 16;
    pub const PR_ERROR_STATUS: usize = 17;
    pub const APP_ERROR_STATUS: usize = 18;
    pub const ICAP_STATUS: usize = 19;
}

/// The KCU1500 prototype's device-ID register value (arbitrary constant
/// the host reads to confirm the shell is alive).
pub const DEVICE_ID_VALUE: u32 = 0x4B43_5531; // "KCU1"

/// ICAP status codes stored in register 19.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IcapStatus {
    Idle,
    Busy,
    Done,
    Error,
}

impl IcapStatus {
    /// Register encoding.
    pub fn code(self) -> u32 {
        match self {
            IcapStatus::Idle => 0,
            IcapStatus::Busy => 1,
            IcapStatus::Done => 2,
            IcapStatus::Error => 3,
        }
    }

    /// Decode.
    pub fn from_code(code: u32) -> Option<Self> {
        match code {
            0 => Some(IcapStatus::Idle),
            1 => Some(IcapStatus::Busy),
            2 => Some(IcapStatus::Done),
            3 => Some(IcapStatus::Error),
            _ => None,
        }
    }
}

/// The register file.  Addressed by byte address over the AXI-Lite bypass
/// (§IV.B) or by index from the fabric side.
#[derive(Debug, Clone)]
pub struct RegisterFile {
    regs: [u32; NUM_REGS],
    /// Write-generation counter so the fabric can cheaply detect
    /// configuration changes and re-derive crossbar state.
    generation: u64,
}

impl Default for RegisterFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegisterFile {
    /// Does Table III provide programming registers for crossbar `port`?
    pub fn covers_port(port: usize) -> bool {
        port < MAX_PORTS
    }

    /// Does Table III provide programming registers for PR `region`
    /// (1-indexed, region = crossbar port)?
    pub fn covers_region(region: usize) -> bool {
        (1..=MAX_PR_REGIONS).contains(&region)
    }

    /// Power-on state: device ID set, everything else zero.
    pub fn new() -> Self {
        let mut regs = [0u32; NUM_REGS];
        regs[regs::DEVICE_ID] = DEVICE_ID_VALUE;
        Self { regs, generation: 0 }
    }

    /// Read by register index.
    pub fn read(&self, index: usize) -> u32 {
        assert!(index < NUM_REGS, "register index {index} out of range");
        self.regs[index]
    }

    /// Write by register index.
    pub fn write(&mut self, index: usize, value: u32) {
        assert!(index < NUM_REGS, "register index {index} out of range");
        self.regs[index] = value;
        self.generation += 1;
    }

    /// Read by byte address (AXI-Lite view, Table III addressing).
    pub fn read_addr(&self, addr: u32) -> Option<u32> {
        let idx = (addr / 4) as usize;
        if addr % 4 == 0 && idx < NUM_REGS {
            Some(self.regs[idx])
        } else {
            None
        }
    }

    /// Write by byte address (AXI-Lite view).
    pub fn write_addr(&mut self, addr: u32, value: u32) -> bool {
        let idx = (addr / 4) as usize;
        if addr % 4 == 0 && idx < NUM_REGS {
            self.write(idx, value);
            true
        } else {
            false
        }
    }

    /// Configuration-write generation counter.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    // ------------------------------------------------------------------
    // typed views (the fabric side)
    // ------------------------------------------------------------------

    /// PR region `r` (1-indexed, 1..=3) destination address (one-hot).
    pub fn pr_destination(&self, region: usize) -> u32 {
        assert!((1..=3).contains(&region), "PR region {region} out of range");
        self.regs[regs::PR1_DEST + region - 1]
    }

    /// Program PR region `r`'s destination (one-hot slave address).
    pub fn set_pr_destination(&mut self, region: usize, dest_onehot: u32) {
        assert!((1..=3).contains(&region));
        self.write(regs::PR1_DEST + region - 1, dest_onehot);
    }

    /// Reset bit for port `p` (register 4, bits [3:0]).
    pub fn port_reset(&self, port: usize) -> bool {
        assert!(port < 4);
        self.regs[regs::RESET] >> port & 1 == 1
    }

    /// Set/clear port `p`'s reset bit.
    pub fn set_port_reset(&mut self, port: usize, on: bool) {
        assert!(port < 4);
        let mut v = self.regs[regs::RESET];
        if on {
            v |= 1 << port;
        } else {
            v &= !(1 << port);
        }
        self.write(regs::RESET, v);
    }

    /// Allowed-slaves isolation mask for port `p`'s master (regs 5-8).
    pub fn allowed_slaves(&self, port: usize) -> u32 {
        assert!(port < 4);
        self.regs[regs::ALLOWED_PORT0 + port]
    }

    /// Program port `p`'s isolation mask.
    pub fn set_allowed_slaves(&mut self, port: usize, mask: u32) {
        assert!(port < 4);
        self.write(regs::ALLOWED_PORT0 + port, mask);
    }

    /// Package budget for `master` at `slave` (regs 9-12, 8-bit fields;
    /// 0 = unprogrammed, caller substitutes the default).
    pub fn allowed_packages(&self, slave: usize, master: usize) -> u32 {
        assert!(slave < 4 && master < 4);
        self.regs[regs::PACKAGES_PORT0 + slave] >> (8 * master) & 0xFF
    }

    /// Program the package budget for `master` at `slave` (1..=255).
    pub fn set_allowed_packages(&mut self, slave: usize, master: usize, packages: u32) {
        assert!(slave < 4 && master < 4);
        assert!(packages <= 0xFF, "package field is 8 bits");
        let idx = regs::PACKAGES_PORT0 + slave;
        let mut v = self.regs[idx];
        v &= !(0xFF << (8 * master));
        v |= packages << (8 * master);
        self.write(idx, v);
    }

    /// Application `id`'s destination address (regs 13-16).
    pub fn app_destination(&self, app_id: usize) -> u32 {
        assert!(app_id < 4);
        self.regs[regs::APP0_DEST + app_id]
    }

    /// Program application `id`'s destination.
    pub fn set_app_destination(&mut self, app_id: usize, dest_onehot: u32) {
        assert!(app_id < 4);
        self.write(regs::APP0_DEST + app_id, dest_onehot);
    }

    /// Last transaction error for PR region `r` (register 17; 8-bit code
    /// fields for regions [3:1], 0 = OK).
    pub fn pr_error(&self, region: usize) -> Option<WbError> {
        assert!((1..=3).contains(&region));
        WbError::from_code(self.regs[regs::PR_ERROR_STATUS] >> (8 * (region - 1)) & 0xFF)
    }

    /// Record PR region `r`'s last transaction status.
    pub fn set_pr_error(&mut self, region: usize, err: Option<WbError>) {
        assert!((1..=3).contains(&region));
        let idx = regs::PR_ERROR_STATUS;
        let mut v = self.regs[idx];
        v &= !(0xFF << (8 * (region - 1)));
        v |= err.map(WbError::code).unwrap_or(0) << (8 * (region - 1));
        self.write(idx, v);
    }

    /// Last transaction error for application `id` (register 18).
    pub fn app_error(&self, app_id: usize) -> Option<WbError> {
        assert!(app_id < 4);
        WbError::from_code(self.regs[regs::APP_ERROR_STATUS] >> (8 * app_id) & 0xFF)
    }

    /// Record application `id`'s last transaction status.
    pub fn set_app_error(&mut self, app_id: usize, err: Option<WbError>) {
        assert!(app_id < 4);
        let idx = regs::APP_ERROR_STATUS;
        let mut v = self.regs[idx];
        v &= !(0xFF << (8 * app_id));
        v |= err.map(WbError::code).unwrap_or(0) << (8 * app_id);
        self.write(idx, v);
    }

    /// ICAP status (register 19).
    pub fn icap_status(&self) -> IcapStatus {
        IcapStatus::from_code(self.regs[regs::ICAP_STATUS]).unwrap_or(IcapStatus::Error)
    }

    /// Record ICAP status.
    pub fn set_icap_status(&mut self, st: IcapStatus) {
        self.write(regs::ICAP_STATUS, st.code());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_on_state() {
        let rf = RegisterFile::new();
        assert_eq!(rf.read(regs::DEVICE_ID), DEVICE_ID_VALUE);
        for i in 1..NUM_REGS {
            assert_eq!(rf.read(i), 0, "reg {i} must reset to 0");
        }
        assert_eq!(rf.icap_status(), IcapStatus::Idle);
    }

    #[test]
    fn table3_byte_addressing() {
        let mut rf = RegisterFile::new();
        assert_eq!(rf.read_addr(0x0), Some(DEVICE_ID_VALUE));
        assert!(rf.write_addr(0x14, 0b1110));
        assert_eq!(rf.allowed_slaves(0), 0b1110);
        assert!(rf.write_addr(0x4C, 2));
        assert_eq!(rf.icap_status(), IcapStatus::Done);
        // Address 0x50 is out of range; 0x2 is unaligned.
        assert_eq!(rf.read_addr(0x50), None);
        assert_eq!(rf.read_addr(0x2), None);
        assert!(!rf.write_addr(0x50, 1));
    }

    #[test]
    fn reset_bits_are_independent() {
        let mut rf = RegisterFile::new();
        rf.set_port_reset(2, true);
        assert!(rf.port_reset(2));
        assert!(!rf.port_reset(0));
        rf.set_port_reset(0, true);
        rf.set_port_reset(2, false);
        assert!(rf.port_reset(0));
        assert!(!rf.port_reset(2));
        assert_eq!(rf.read(regs::RESET), 0b0001);
    }

    #[test]
    fn package_fields_pack_four_masters() {
        let mut rf = RegisterFile::new();
        rf.set_allowed_packages(1, 0, 16);
        rf.set_allowed_packages(1, 3, 128);
        assert_eq!(rf.allowed_packages(1, 0), 16);
        assert_eq!(rf.allowed_packages(1, 3), 128);
        assert_eq!(rf.allowed_packages(1, 1), 0, "unprogrammed field");
        assert_eq!(rf.read(regs::PACKAGES_PORT1), 128 << 24 | 16);
    }

    #[test]
    fn pr_destinations() {
        let mut rf = RegisterFile::new();
        rf.set_pr_destination(1, 0b0100);
        rf.set_pr_destination(3, 0b0001);
        assert_eq!(rf.pr_destination(1), 0b0100);
        assert_eq!(rf.pr_destination(3), 0b0001);
        assert_eq!(rf.read_addr(0x4), Some(0b0100));
        assert_eq!(rf.read_addr(0xC), Some(0b0001));
    }

    #[test]
    fn error_status_fields() {
        let mut rf = RegisterFile::new();
        assert_eq!(rf.pr_error(1), None);
        rf.set_pr_error(2, Some(WbError::GrantTimeout));
        assert_eq!(rf.pr_error(2), Some(WbError::GrantTimeout));
        assert_eq!(rf.pr_error(1), None);
        rf.set_pr_error(2, None);
        assert_eq!(rf.pr_error(2), None);

        rf.set_app_error(3, Some(WbError::InvalidDestination));
        assert_eq!(rf.app_error(3), Some(WbError::InvalidDestination));
        rf.set_app_error(3, None);
        assert_eq!(rf.app_error(3), None);
    }

    #[test]
    fn generation_tracks_writes() {
        let mut rf = RegisterFile::new();
        let g0 = rf.generation();
        rf.set_allowed_slaves(0, 0b1111);
        assert!(rf.generation() > g0);
        let g1 = rf.generation();
        let _ = rf.read(regs::ALLOWED_PORT0);
        assert_eq!(rf.generation(), g1, "reads don't bump generation");
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        RegisterFile::new().read(NUM_REGS);
    }

    #[test]
    fn table3_window_bounds() {
        assert!(RegisterFile::covers_port(0));
        assert!(RegisterFile::covers_port(3));
        assert!(!RegisterFile::covers_port(4));
        assert!(!RegisterFile::covers_region(0), "port 0 is the bridge");
        assert!(RegisterFile::covers_region(1));
        assert!(RegisterFile::covers_region(MAX_PR_REGIONS));
        assert!(!RegisterFile::covers_region(MAX_PR_REGIONS + 1));
    }
}
