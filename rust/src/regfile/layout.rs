//! The banked register-file layout (v2): Table III generalized to any
//! crossbar width.
//!
//! Table III hard-wires the register map to a 4-port crossbar.  The
//! banked layout keeps the *same bank order* and the same intra-register
//! field packing, but computes every bank's base address from the port
//! count `N`:
//!
//! | bank                | registers            | base (register index)  |
//! |---------------------|----------------------|------------------------|
//! | device ID           | 1                    | 0                      |
//! | PR destinations     | N-1 (regions 1..N-1) | 1                      |
//! | reset bits          | 1 (ports [N-1:0])    | N                      |
//! | allowed addresses   | N (one per master)   | N + 1                  |
//! | package budgets     | N·⌈N/4⌉              | 2N + 1                 |
//! | app destinations    | N (app IDs 0..N-1)   | 2N + 1 + N·⌈N/4⌉       |
//! | PR error status     | ⌈(N-1)/4⌉            | 3N + 1 + N·⌈N/4⌉       |
//! | app error status    | ⌈N/4⌉                | + ⌈(N-1)/4⌉            |
//! | ICAP status         | 1                    | last                   |
//!
//! Package-budget and error-status registers hold four 8-bit fields per
//! 32-bit register, exactly as in Table III; widths beyond 4 simply
//! spill into the next register of the bank (master `m`'s budget at
//! slave `s` lives in register `packages_base + s·⌈N/4⌉ + m/4`, bits
//! `[8(m%4)+7 : 8(m%4)]`).  The reset bank stays a single register:
//! the crossbar caps ports at 32, so the bits always fit.
//!
//! **The 4-port instantiation is byte-for-byte Table III** — every base
//! above evaluates to the Table III register number at `N = 4`, pinned
//! by the golden byte-image test in the parent module.

/// A banked register-file layout for an `N`-port crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegfileLayout {
    num_ports: usize,
}

/// 8-bit fields packed per 32-bit register (package budgets, error
/// status) — a Table III invariant the banked layout preserves.
pub const FIELDS_PER_REG: usize = 4;

impl RegfileLayout {
    /// Fewest ports a layout can describe (bridge + one PR region).
    pub const MIN_PORTS: usize = 2;
    /// Most ports a layout can describe (one-hot addresses and the
    /// reset register are 32 bits wide).
    pub const MAX_PORTS: usize = 32;

    /// Layout for an `num_ports`-wide crossbar (port 0 is the bridge,
    /// ports `1..num_ports` host PR regions).
    pub fn new(num_ports: usize) -> Self {
        assert!(
            (Self::MIN_PORTS..=Self::MAX_PORTS).contains(&num_ports),
            "layout needs {}..={} ports, got {num_ports}",
            Self::MIN_PORTS,
            Self::MAX_PORTS
        );
        Self { num_ports }
    }

    /// The paper's Table III instantiation (4 ports, 20 registers).
    pub fn table3() -> Self {
        Self::new(4)
    }

    /// Crossbar ports this layout programs.
    pub fn num_ports(&self) -> usize {
        self.num_ports
    }

    /// PR regions (= non-bridge ports) this layout programs.
    pub fn num_pr_regions(&self) -> usize {
        self.num_ports - 1
    }

    /// Application IDs with a destination register.
    pub fn num_app_ids(&self) -> usize {
        self.num_ports
    }

    /// Does the layout provide registers for crossbar `port`?
    pub fn covers_port(&self, port: usize) -> bool {
        port < self.num_ports
    }

    /// Does the layout provide registers for PR `region` (1-indexed,
    /// region = crossbar port; port 0 is the bridge)?
    pub fn covers_region(&self, region: usize) -> bool {
        (1..self.num_ports).contains(&region)
    }

    /// Does the layout provide a destination register for `app_id`?
    pub fn covers_app(&self, app_id: usize) -> bool {
        app_id < self.num_app_ids()
    }

    /// Budget registers per slave port: ⌈N/4⌉ (Table III: 1).
    pub fn budget_regs_per_slave(&self) -> usize {
        self.num_ports.div_ceil(FIELDS_PER_REG)
    }

    // ------------------------------------------------------------------
    // bank bases and per-field addressing
    // ------------------------------------------------------------------

    /// Register 0: the FPGA device ID.
    pub fn device_id_reg(&self) -> usize {
        0
    }

    /// Destination-address register of PR `region` (1..N-1).
    pub fn pr_dest_reg(&self, region: usize) -> usize {
        debug_assert!(self.covers_region(region));
        region
    }

    /// The reset register (bit `p` resets port `p`).
    pub fn reset_reg(&self) -> usize {
        self.num_ports
    }

    /// Allowed-addresses (isolation mask) register of `port`'s master.
    pub fn allowed_reg(&self, port: usize) -> usize {
        debug_assert!(self.covers_port(port));
        self.reset_reg() + 1 + port
    }

    /// First register of the package-budget bank.
    pub fn packages_base(&self) -> usize {
        self.reset_reg() + 1 + self.num_ports
    }

    /// Register holding `master`'s package budget at `slave`.
    pub fn packages_reg(&self, slave: usize, master: usize) -> usize {
        debug_assert!(self.covers_port(slave) && self.covers_port(master));
        self.packages_base()
            + slave * self.budget_regs_per_slave()
            + master / FIELDS_PER_REG
    }

    /// Bit shift of `master`'s 8-bit field within its budget register.
    pub fn packages_shift(master: usize) -> u32 {
        8 * (master % FIELDS_PER_REG) as u32
    }

    /// Destination-address register of application `app_id`.
    pub fn app_dest_reg(&self, app_id: usize) -> usize {
        debug_assert!(self.covers_app(app_id));
        self.packages_base()
            + self.num_ports * self.budget_regs_per_slave()
            + app_id
    }

    /// First register of the PR-region error-status bank.
    pub fn pr_error_base(&self) -> usize {
        self.app_dest_reg(0) + self.num_app_ids()
    }

    /// Error-status register of PR `region`.
    pub fn pr_error_reg(&self, region: usize) -> usize {
        debug_assert!(self.covers_region(region));
        self.pr_error_base() + (region - 1) / FIELDS_PER_REG
    }

    /// Bit shift of `region`'s 8-bit error field.
    pub fn pr_error_shift(region: usize) -> u32 {
        8 * ((region - 1) % FIELDS_PER_REG) as u32
    }

    /// Error-status register of application `app_id`.
    pub fn app_error_reg(&self, app_id: usize) -> usize {
        debug_assert!(self.covers_app(app_id));
        self.pr_error_base()
            + self.num_pr_regions().div_ceil(FIELDS_PER_REG)
            + app_id / FIELDS_PER_REG
    }

    /// Bit shift of `app_id`'s 8-bit error field.
    pub fn app_error_shift(app_id: usize) -> u32 {
        8 * (app_id % FIELDS_PER_REG) as u32
    }

    /// The ICAP status register (always the last register).
    pub fn icap_reg(&self) -> usize {
        self.app_error_reg(0)
            + self.num_app_ids().div_ceil(FIELDS_PER_REG)
    }

    /// Total registers in the layout (Table III: 20).
    pub fn num_regs(&self) -> usize {
        self.icap_reg() + 1
    }

    // ------------------------------------------------------------------
    // v1 (Table III) compatibility window
    // ------------------------------------------------------------------

    /// Translate a Table III register index (0..20) into this layout's
    /// register index, or `None` when the entry does not exist here
    /// (e.g. PR region 3 on a 3-port layout).
    ///
    /// Every Table III register maps onto a *whole* register of the
    /// banked layout with identical intra-register field packing, so
    /// host software written against Table III byte addresses keeps
    /// working unmodified on any width — the v1 compatibility window.
    pub fn v1_compat_index(&self, table3_index: usize) -> Option<usize> {
        use super::regs;
        Some(match table3_index {
            regs::DEVICE_ID => self.device_id_reg(),
            r @ regs::PR1_DEST..=regs::PR3_DEST => {
                let region = r - regs::PR1_DEST + 1;
                if !self.covers_region(region) {
                    return None;
                }
                self.pr_dest_reg(region)
            }
            regs::RESET => self.reset_reg(),
            r @ regs::ALLOWED_PORT0..=regs::ALLOWED_PORT3 => {
                let port = r - regs::ALLOWED_PORT0;
                if !self.covers_port(port) {
                    return None;
                }
                self.allowed_reg(port)
            }
            r @ regs::PACKAGES_PORT0..=regs::PACKAGES_PORT3 => {
                let slave = r - regs::PACKAGES_PORT0;
                if !self.covers_port(slave) {
                    return None;
                }
                // Table III's packages register holds masters 0..=3,
                // exactly the first budget register of the slave's bank.
                self.packages_reg(slave, 0)
            }
            r @ regs::APP0_DEST..=regs::APP3_DEST => {
                let app = r - regs::APP0_DEST;
                if !self.covers_app(app) {
                    return None;
                }
                self.app_dest_reg(app)
            }
            // Table III's error registers hold fields for regions 1..=3
            // and apps 0..=3 — the first register of each error bank.
            regs::PR_ERROR_STATUS => self.pr_error_reg(1),
            regs::APP_ERROR_STATUS => self.app_error_reg(0),
            regs::ICAP_STATUS => self.icap_reg(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_instantiation_reproduces_every_table3_index() {
        use crate::regfile::regs;
        let l = RegfileLayout::table3();
        assert_eq!(l.num_regs(), 20);
        assert_eq!(l.device_id_reg(), regs::DEVICE_ID);
        assert_eq!(l.pr_dest_reg(1), regs::PR1_DEST);
        assert_eq!(l.pr_dest_reg(2), regs::PR2_DEST);
        assert_eq!(l.pr_dest_reg(3), regs::PR3_DEST);
        assert_eq!(l.reset_reg(), regs::RESET);
        for p in 0..4 {
            assert_eq!(l.allowed_reg(p), regs::ALLOWED_PORT0 + p);
            assert_eq!(l.app_dest_reg(p), regs::APP0_DEST + p);
            for m in 0..4 {
                assert_eq!(l.packages_reg(p, m), regs::PACKAGES_PORT0 + p);
            }
        }
        assert_eq!(l.pr_error_reg(1), regs::PR_ERROR_STATUS);
        assert_eq!(l.pr_error_reg(3), regs::PR_ERROR_STATUS);
        assert_eq!(l.app_error_reg(0), regs::APP_ERROR_STATUS);
        assert_eq!(l.app_error_reg(3), regs::APP_ERROR_STATUS);
        assert_eq!(l.icap_reg(), regs::ICAP_STATUS);
        // The 4-port compat window is the identity.
        for i in 0..20 {
            assert_eq!(l.v1_compat_index(i), Some(i), "table3 reg {i}");
        }
        assert_eq!(l.v1_compat_index(20), None);
    }

    #[test]
    fn banks_are_contiguous_and_disjoint_at_any_width() {
        for n in RegfileLayout::MIN_PORTS..=RegfileLayout::MAX_PORTS {
            let l = RegfileLayout::new(n);
            // Walk the banks in order; every register index must be used
            // exactly once.
            let mut next = 0usize;
            let mut take = |idx: usize, what: &str| {
                assert_eq!(idx, next, "{what} not contiguous at n={n}");
                next += 1;
            };
            take(l.device_id_reg(), "device id");
            for r in 1..n {
                take(l.pr_dest_reg(r), "pr dest");
            }
            take(l.reset_reg(), "reset");
            for p in 0..n {
                take(l.allowed_reg(p), "allowed");
            }
            for s in 0..n {
                for chunk in 0..l.budget_regs_per_slave() {
                    take(l.packages_reg(s, chunk * FIELDS_PER_REG), "packages");
                }
            }
            for a in 0..n {
                take(l.app_dest_reg(a), "app dest");
            }
            for chunk in 0..(n - 1).div_ceil(FIELDS_PER_REG) {
                take(l.pr_error_reg(1 + chunk * FIELDS_PER_REG), "pr error");
            }
            for chunk in 0..n.div_ceil(FIELDS_PER_REG) {
                take(l.app_error_reg(chunk * FIELDS_PER_REG), "app error");
            }
            take(l.icap_reg(), "icap");
            assert_eq!(l.num_regs(), next, "register count at n={n}");
        }
    }

    #[test]
    fn sixteen_port_layout_addresses() {
        let l = RegfileLayout::new(16);
        assert_eq!(l.num_pr_regions(), 15);
        assert_eq!(l.budget_regs_per_slave(), 4);
        assert_eq!(l.reset_reg(), 16);
        assert_eq!(l.allowed_reg(0), 17);
        assert_eq!(l.packages_base(), 33);
        // Slave 2, master 13 → base + 2*4 + 3, field 13 % 4 = 1.
        assert_eq!(l.packages_reg(2, 13), 33 + 8 + 3);
        assert_eq!(RegfileLayout::packages_shift(13), 8);
        assert_eq!(l.app_dest_reg(0), 97);
        assert_eq!(l.pr_error_base(), 113);
        assert_eq!(l.pr_error_reg(15), 113 + 3);
        assert_eq!(l.app_error_reg(15), 117 + 3);
        assert_eq!(l.icap_reg(), 121);
        assert_eq!(l.num_regs(), 122);
    }

    #[test]
    fn compat_window_maps_onto_wide_layouts() {
        use crate::regfile::regs;
        let l = RegfileLayout::new(16);
        assert_eq!(l.v1_compat_index(regs::DEVICE_ID), Some(0));
        assert_eq!(l.v1_compat_index(regs::PR2_DEST), Some(2));
        assert_eq!(l.v1_compat_index(regs::RESET), Some(16));
        assert_eq!(l.v1_compat_index(regs::ALLOWED_PORT3), Some(20));
        assert_eq!(l.v1_compat_index(regs::PACKAGES_PORT1), Some(33 + 4));
        assert_eq!(l.v1_compat_index(regs::APP3_DEST), Some(100));
        assert_eq!(l.v1_compat_index(regs::PR_ERROR_STATUS), Some(113));
        assert_eq!(l.v1_compat_index(regs::APP_ERROR_STATUS), Some(117));
        assert_eq!(l.v1_compat_index(regs::ICAP_STATUS), Some(121));
        // A 3-port layout has no region-3 / port-3 entries.
        let s = RegfileLayout::new(3);
        assert_eq!(s.v1_compat_index(regs::PR3_DEST), None);
        assert_eq!(s.v1_compat_index(regs::ALLOWED_PORT3), None);
        assert_eq!(s.v1_compat_index(regs::APP3_DEST), None);
        assert_eq!(s.v1_compat_index(regs::PR2_DEST), Some(2));
    }

    #[test]
    #[should_panic]
    fn rejects_too_many_ports() {
        RegfileLayout::new(33);
    }
}
