//! Computation-module template (§IV.H) hosting any registered kernel
//! (§V.B seeds by default; see [`crate::kernels`] for the registry).
//!
//! The template comprises input and output registers, an error-status
//! register, computation units, and control logic: the module batches
//! incoming words from its WB slave interface into the input registers,
//! runs the computation units in parallel on the batch, then asks its WB
//! master interface to forward the results to its destination address
//! (programmed by the elastic manager through the register file).
//!
//! The computation units are whatever [`crate::kernels::ModuleBehavior`]
//! the hosted kernel registered: for the seed kernels that is the Rust
//! golden model ([`crate::hamming`]) whose *same math* ships as the
//! AOT-lowered JAX/Pallas artifact (executed via PJRT for on-server
//! stages and cross-verification); for table-driven kernels it is the
//! declared word transform.  The shell does not trust the behavior:
//! the fabric length/mask-validates every emitted batch against the
//! kernel's [`crate::kernels::KernelSpec`] before routing it.

use crate::sim::HORIZON_NONE;
use crate::wishbone::{Job, WbError};

/// Which kernel a PR region hosts.  Historically a closed enum of the
/// three prototype modules; now a stable registry id — the enum-style
/// variant names live on as associated constants, so existing
/// `ModuleKind::Multiplier` value *and* pattern uses keep compiling.
pub use crate::kernels::KernelId as ModuleKind;

/// Module FSM state (template control logic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleState {
    /// Input registers free; waiting to read a batch from the slave
    /// interface.
    Ready,
    /// Computation units running (`remaining` cycles left).
    Computing { remaining: u32 },
    /// Output handed to the master interface; waiting for the send to
    /// complete (status lands in the error register).
    SendWait,
}

/// One instantiated computation module, attached to a crossbar port.
#[derive(Debug)]
pub struct ComputationModule {
    /// Which kernel this hosts.
    pub kind: ModuleKind,
    /// Crossbar port the module's interfaces sit on.
    pub port: usize,
    /// Application that owns the hosting PR region.
    pub app_id: u32,
    /// One-hot destination address (Table III regs 1-3, programmed by the
    /// manager; re-programmed on migration).
    pub dest_onehot: u32,
    /// Batch size in words (input-register depth; prototype: 8).
    pub batch_words: usize,
    /// Computation-unit latency in cycles (parallel units -> 1 cc for
    /// the seeds; table kernels follow their declared latency model).
    pub compute_latency: u32,
    /// FSM state.
    pub state: ModuleState,
    /// Input registers.
    input: Vec<u32>,
    /// Words handed to the master interface for the in-flight send
    /// (output registers are moved into the Job — §Perf: no clone).
    pending_words: usize,
    /// Error-status register (§IV.H: "the status of the request is stored
    /// in the error register").
    pub error_status: Option<WbError>,
    /// Batches processed (stats).
    pub batches_done: u64,
    /// Words processed (stats).
    pub words_done: u64,
}

impl ComputationModule {
    /// Instantiate a module at `port` for `app_id` with the legacy
    /// template defaults (8-word batch, 1-cycle compute).
    pub fn new(kind: ModuleKind, port: usize, app_id: u32) -> Self {
        Self {
            kind,
            port,
            app_id,
            dest_onehot: 0,
            batch_words: 8,
            compute_latency: 1,
            state: ModuleState::Ready,
            input: Vec::with_capacity(8),
            pending_words: 0,
            error_status: None,
            batches_done: 0,
            words_done: 0,
        }
    }

    /// Instantiate a module with geometry and latency taken from the
    /// kernel's registered spec (the path the fabric installs through;
    /// byte-identical to [`ComputationModule::new`] + the fabric's
    /// historical `batch_words = BRIDGE_BUFFER_WORDS` fixup for the
    /// seed kernels).
    pub fn from_spec(kind: ModuleKind, port: usize, app_id: u32) -> Self {
        let spec = kind.spec();
        let mut m = Self::new(kind, port, app_id);
        m.batch_words = spec.batch_words;
        m.compute_latency = spec.compute_latency();
        m.input = Vec::with_capacity(spec.batch_words);
        m
    }

    /// Words currently latched in the input registers.
    pub fn input_fill(&self) -> usize {
        self.input.len()
    }

    /// Accept words drained from the slave interface.  Returns how many
    /// were absorbed (input registers hold one batch).
    pub fn absorb(&mut self, words: &[u32]) -> usize {
        if self.state != ModuleState::Ready {
            return 0;
        }
        let space = self.batch_words - self.input.len();
        let take = space.min(words.len());
        self.input.extend_from_slice(&words[..take]);
        take
    }

    /// Allocation-free variant over `(word, src)` pairs as drained from
    /// the crossbar (§Perf hot path).
    pub fn absorb_pairs(&mut self, pairs: &[(u32, usize)]) -> usize {
        if self.state != ModuleState::Ready {
            return 0;
        }
        let space = self.batch_words - self.input.len();
        let take = space.min(pairs.len());
        self.input.extend(pairs[..take].iter().map(|&(w, _)| w));
        take
    }

    /// Capacity left in the input registers this cycle.
    pub fn absorb_capacity(&self) -> usize {
        if self.state != ModuleState::Ready {
            0
        } else {
            self.batch_words - self.input.len()
        }
    }

    /// One clock of the control logic.  Returns a [`Job`] when the module
    /// requests its master interface (must be pushed to the crossbar by
    /// the fabric this cycle so the latch lands next cycle).
    pub fn tick(&mut self) -> Option<Job> {
        match self.state {
            ModuleState::Ready => {
                if self.input.len() == self.batch_words {
                    self.state = ModuleState::Computing {
                        remaining: self.compute_latency,
                    };
                }
                None
            }
            ModuleState::Computing { remaining } => {
                if remaining > 1 {
                    self.state = ModuleState::Computing { remaining: remaining - 1 };
                    return None;
                }
                // Computation units finish; output registers load and the
                // master interface is requested with the destination.
                let out = self.kind.apply_buf(&self.input);
                self.input.clear();
                self.pending_words = out.len();
                self.state = ModuleState::SendWait;
                Some(Job::new(self.dest_onehot, out, self.app_id))
            }
            ModuleState::SendWait => None,
        }
    }

    /// Busy-period horizon of the module FSM (DESIGN.md §12): the next
    /// cycle whose tick does anything beyond decrementing the compute
    /// countdown.  `Computing { remaining }` fires its master-interface
    /// request on the tick `remaining` cycles out; a full input batch
    /// transitions next tick; every other state is passive — it changes
    /// only on external stimulus (crossbar words or send completion).
    pub fn next_interesting_cycle(&self, now: u64) -> u64 {
        match self.state {
            ModuleState::Computing { remaining } => now + (remaining as u64).max(1),
            ModuleState::Ready if self.input.len() == self.batch_words => now + 1,
            _ => HORIZON_NONE,
        }
    }

    /// Account `cycles` skipped fast-path cycles: the compute countdown
    /// advances by the kernel's registered `fast_forward` arithmetic;
    /// every other state is a fixed point over the skipped stretch.
    /// Callers must keep the skip strictly below
    /// [`ComputationModule::next_interesting_cycle`].
    pub fn fast_forward(&mut self, cycles: u64) {
        if let ModuleState::Computing { remaining } = self.state {
            self.state = ModuleState::Computing {
                remaining: self.kind.fast_forward_countdown(remaining, cycles),
            };
        }
    }

    /// The fabric reports the outcome of the requested send.
    pub fn on_send_complete(&mut self, result: Result<(), WbError>) {
        debug_assert_eq!(self.state, ModuleState::SendWait);
        self.error_status = result.err();
        if result.is_ok() {
            self.batches_done += 1;
            self.words_done += self.pending_words as u64;
        }
        // §IV.H: "If the request is successful, the output registers are
        // reset.  If a slave interface has new data, it registers new
        // data; otherwise, it becomes idle."  On error we also return to
        // Ready — the manager observes the error register and decides.
        self.pending_words = 0;
        self.state = ModuleState::Ready;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hamming::{DATA_MASK, MULT_CONSTANT};

    #[test]
    fn kinds_map_to_artifacts_and_golden() {
        assert_eq!(ModuleKind::Multiplier.artifact(), "multiplier");
        assert_eq!(ModuleKind::HammingEncoder.artifact(), "hamming_enc");
        assert_eq!(ModuleKind::HammingDecoder.artifact(), "hamming_dec");
        let x = 0xDEAD_BEEF;
        assert_eq!(
            ModuleKind::Multiplier.apply_word(x),
            x.wrapping_mul(MULT_CONSTANT)
        );
        let enc = ModuleKind::HammingEncoder.apply_word(x);
        assert_eq!(ModuleKind::HammingDecoder.apply_word(enc), x & DATA_MASK);
    }

    #[test]
    fn pipeline_order_matches_fig5() {
        assert_eq!(
            ModuleKind::pipeline(),
            [
                ModuleKind::Multiplier,
                ModuleKind::HammingEncoder,
                ModuleKind::HammingDecoder
            ]
        );
    }

    #[test]
    fn from_spec_matches_legacy_seed_geometry() {
        for kind in ModuleKind::pipeline() {
            let legacy = ComputationModule::new(kind, 1, 0);
            let specd = ComputationModule::from_spec(kind, 1, 0);
            assert_eq!(specd.batch_words, legacy.batch_words);
            assert_eq!(specd.compute_latency, legacy.compute_latency);
            assert_eq!(specd.state, legacy.state);
        }
    }

    #[test]
    fn module_fsm_full_batch_cycle() {
        let mut m = ComputationModule::new(ModuleKind::Multiplier, 1, 0);
        m.dest_onehot = 0b0100;
        assert_eq!(m.absorb(&[1, 2, 3, 4, 5]), 5);
        assert!(m.tick().is_none(), "batch not full yet");
        assert_eq!(m.absorb(&[6, 7, 8, 9]), 3, "only batch space absorbed");
        // Batch full: Ready -> Computing this tick.
        assert!(m.tick().is_none());
        assert_eq!(m.state, ModuleState::Computing { remaining: 1 });
        // Compute done: job requested.
        let job = m.tick().expect("job after compute");
        assert_eq!(job.dest_onehot, 0b0100);
        assert_eq!(
            job.words,
            (1..=8u32).map(|w| w.wrapping_mul(MULT_CONSTANT)).collect::<Vec<_>>()
        );
        assert_eq!(m.state, ModuleState::SendWait);
        // No absorption while sending.
        assert_eq!(m.absorb(&[1]), 0);
        assert!(m.tick().is_none());
        m.on_send_complete(Ok(()));
        assert_eq!(m.state, ModuleState::Ready);
        assert_eq!(m.batches_done, 1);
        assert_eq!(m.words_done, 8);
        assert_eq!(m.error_status, None);
    }

    #[test]
    fn module_records_send_error() {
        let mut m = ComputationModule::new(ModuleKind::HammingEncoder, 2, 1);
        m.dest_onehot = 0b1000;
        m.absorb(&[0; 8]);
        m.tick();
        let _ = m.tick().unwrap();
        m.on_send_complete(Err(WbError::GrantTimeout));
        assert_eq!(m.error_status, Some(WbError::GrantTimeout));
        assert_eq!(m.batches_done, 0);
        assert_eq!(m.state, ModuleState::Ready, "module recovers");
    }

    #[test]
    fn multi_cycle_compute_latency() {
        let mut m = ComputationModule::new(ModuleKind::HammingDecoder, 3, 0);
        m.compute_latency = 3;
        m.dest_onehot = 0b0001;
        m.absorb(&[0; 8]);
        m.tick(); // Ready -> Computing{3}
        assert!(m.tick().is_none()); // 3 -> 2
        assert!(m.tick().is_none()); // 2 -> 1
        assert!(m.tick().is_some()); // fires
    }

    #[test]
    fn horizon_tracks_the_compute_countdown() {
        let mut m = ComputationModule::new(ModuleKind::Multiplier, 1, 0);
        m.compute_latency = 10;
        m.dest_onehot = 0b0001;
        // Passive states report no self-scheduled event.
        assert_eq!(m.next_interesting_cycle(5), HORIZON_NONE, "empty Ready");
        m.absorb(&[1, 2, 3]);
        assert_eq!(m.next_interesting_cycle(5), HORIZON_NONE, "partial batch");
        m.absorb(&[4, 5, 6, 7, 8]);
        assert_eq!(m.next_interesting_cycle(5), 6, "full batch fires next");
        m.tick(); // Ready -> Computing{10}
        assert_eq!(m.next_interesting_cycle(100), 110);
        // Fast-forward 9 of the 10 countdown cycles, then fire on the
        // horizon tick — exactly what 9 ticks would have produced.
        m.fast_forward(9);
        assert_eq!(m.state, ModuleState::Computing { remaining: 1 });
        assert_eq!(m.next_interesting_cycle(109), 110);
        assert!(m.tick().is_some(), "fires on the horizon cycle");
        assert_eq!(m.next_interesting_cycle(110), HORIZON_NONE, "SendWait passive");
        m.fast_forward(1000); // no-op in SendWait
        m.on_send_complete(Ok(()));
        assert_eq!(m.state, ModuleState::Ready);
    }
}
