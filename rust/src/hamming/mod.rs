//! Pure-Rust golden model of the three computation modules: constant
//! multiplier, Hamming(31,26) encoder, Hamming(31,26) decoder.
//!
//! This is the bit-exact mirror of `python/compile/kernels/hamming_spec.py`
//! (same positions, same masks — `test_mirrored_rust_constants` on the
//! Python side pins the literals).  The coordinator uses it to
//! cross-verify every PJRT result on the request path, and the
//! cycle-level module FSMs ([`crate::modules`]) use it as their
//! combinational payload function.
//!
//! Convention: codeword positions are 1-indexed 1..31; position `p` lives
//! in bit `p-1` of a `u32`, so codewords occupy bits [0,30].

/// Number of parity bits.
pub const NUM_PARITY: usize = 5;
/// Codeword length in bits.
pub const CODE_BITS: u32 = 31;
/// Payload width in bits.
pub const DATA_BITS: u32 = 26;
/// Mask of the 26 payload bits.
pub const DATA_MASK: u32 = 0x03FF_FFFF;
/// Mask of the 31 codeword bits.
pub const CODE_MASK: u32 = 0x7FFF_FFFF;

/// The multiplier module's constant (mirrors `model.MULT_CONSTANT`).
pub const MULT_CONSTANT: u32 = 0x9E37_79B1;

/// Parity masks: `PARITY_MASKS[i]` covers every codeword bit whose
/// 1-indexed position has bit `i` set.  Textbook Hamming(31,26) values.
pub const PARITY_MASKS: [u32; NUM_PARITY] =
    [0x5555_5555, 0x6666_6666, 0x7878_7878, 0x7F80_7F80, 0x7FFF_8000];

/// Data positions (1-indexed): every position in 1..=31 that is not a
/// power of two, in increasing order.  Payload bit `k` maps to position
/// `DATA_POSITIONS[k]`.
pub const DATA_POSITIONS: [u32; DATA_BITS as usize] = [
    3, 5, 6, 7, 9, 10, 11, 12, 13, 14, 15, 17, 18, 19, 20, 21, 22, 23, 24,
    25, 26, 27, 28, 29, 30, 31,
];

/// Constant-multiplier module: wrapping elementwise multiply.
#[inline(always)]
pub fn multiply_word(x: u32, k: u32) -> u32 {
    x.wrapping_mul(k)
}

/// Encode the low 26 bits of `d` into a 31-bit Hamming codeword.
pub fn encode_word(d: u32) -> u32 {
    let d = d & DATA_MASK;
    let mut cw = 0u32;
    for (k, &p) in DATA_POSITIONS.iter().enumerate() {
        cw |= ((d >> k) & 1) << (p - 1);
    }
    for (i, &mask) in PARITY_MASKS.iter().enumerate() {
        let par = (cw & mask).count_ones() & 1;
        cw |= par << ((1u32 << i) - 1);
    }
    cw
}

/// Decode a 31-bit codeword, correcting up to one flipped bit.
///
/// Returns `(payload, syndrome)`; syndrome 0 means no error detected,
/// otherwise it names the corrected (1-indexed) position.
pub fn decode_word(cw: u32) -> (u32, u32) {
    let mut cw = cw & CODE_MASK;
    let mut syn = 0u32;
    for (i, &mask) in PARITY_MASKS.iter().enumerate() {
        syn |= ((cw & mask).count_ones() & 1) << i;
    }
    if syn != 0 {
        cw ^= 1 << (syn - 1);
    }
    let mut d = 0u32;
    for (k, &p) in DATA_POSITIONS.iter().enumerate() {
        d |= ((cw >> (p - 1)) & 1) << k;
    }
    (d, syn)
}

/// Buffer-level multiplier (golden form of `artifacts/multiplier.hlo.txt`).
pub fn multiply_buf(x: &[u32], k: u32) -> Vec<u32> {
    x.iter().map(|&w| multiply_word(w, k)).collect()
}

/// Buffer-level encoder (golden form of `artifacts/hamming_enc.hlo.txt`).
pub fn encode_buf(x: &[u32]) -> Vec<u32> {
    x.iter().map(|&w| encode_word(w)).collect()
}

/// Buffer-level decoder (golden form of `artifacts/hamming_dec.hlo.txt`,
/// payload only).
pub fn decode_buf(x: &[u32]) -> Vec<u32> {
    x.iter().map(|&w| decode_word(w).0).collect()
}

/// Buffer-level decoder returning syndromes too (module error status).
pub fn decode_buf_syndromes(x: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let mut d = Vec::with_capacity(x.len());
    let mut s = Vec::with_capacity(x.len());
    for &w in x {
        let (dw, sw) = decode_word(w);
        d.push(dw);
        s.push(sw);
    }
    (d, s)
}

/// The full Fig-5 pipeline: `dec(enc(mult(x)))`.
///
/// Algebraically equal to `(x * K) & DATA_MASK` — the end-to-end contract
/// shared with `python/tests/test_model.py::test_pipeline_algebraic_identity`.
pub fn pipeline_buf(x: &[u32], k: u32) -> Vec<u32> {
    x.iter()
        .map(|&w| decode_word(encode_word(multiply_word(w, k))).0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_positions_are_the_non_powers_of_two() {
        let expect: Vec<u32> =
            (1u32..=31).filter(|p| !p.is_power_of_two()).collect();
        assert_eq!(DATA_POSITIONS.to_vec(), expect);
    }

    #[test]
    fn parity_masks_match_position_rule() {
        for i in 0..NUM_PARITY {
            let mut mask = 0u32;
            for p in 1..=CODE_BITS {
                if p & (1 << i) != 0 {
                    mask |= 1 << (p - 1);
                }
            }
            assert_eq!(PARITY_MASKS[i], mask, "mask {i}");
        }
    }

    #[test]
    fn zero_encodes_to_zero() {
        assert_eq!(encode_word(0), 0);
        assert_eq!(decode_word(0), (0, 0));
    }

    #[test]
    fn roundtrip_exhaustive_low_payloads() {
        for d in 0..4096u32 {
            let cw = encode_word(d);
            assert_eq!(cw & !CODE_MASK, 0, "fits 31 bits");
            assert_eq!(decode_word(cw), (d, 0));
        }
    }

    #[test]
    fn single_bit_error_always_corrected() {
        for d in [0u32, 1, DATA_MASK, 0x0155_5555, 0x02AA_AAAA, 1234567] {
            let cw = encode_word(d);
            for bit in 0..CODE_BITS {
                let (got, syn) = decode_word(cw ^ (1 << bit));
                assert_eq!(got, d, "d={d:#x} bit={bit}");
                assert_eq!(syn, bit + 1, "syndrome names the position");
            }
        }
    }

    #[test]
    fn high_data_bits_ignored_by_encoder() {
        assert_eq!(encode_word(0xFC00_0000), encode_word(0));
        assert_eq!(encode_word(0xFFFF_FFFF), encode_word(DATA_MASK));
    }

    #[test]
    fn bit31_ignored_by_decoder() {
        let cw = encode_word(0x00AB_CDEF);
        assert_eq!(decode_word(cw | 0x8000_0000), decode_word(cw));
    }

    #[test]
    fn pipeline_algebraic_identity() {
        let xs: Vec<u32> =
            (0u32..1000).map(|i| i.wrapping_mul(2_654_435_761)).collect();
        let got = pipeline_buf(&xs, MULT_CONSTANT);
        for (x, g) in xs.iter().zip(&got) {
            assert_eq!(*g, x.wrapping_mul(MULT_CONSTANT) & DATA_MASK);
        }
    }

    #[test]
    fn distinct_payloads_distinct_codewords() {
        use std::collections::HashSet;
        let set: HashSet<u32> = (0..8192).map(encode_word).collect();
        assert_eq!(set.len(), 8192);
    }
}
