//! Minimal JSON parser — enough for `artifacts/manifest.json` and
//! structured config values.  (serde/serde_json are unavailable in this
//! offline environment; see DESIGN.md §7.)
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP; numbers are parsed as f64 with an exact-integer accessor.

use std::collections::BTreeMap;

use crate::{ElasticError, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Exact non-negative integer value.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Object map access.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array access.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ElasticError {
        ElasticError::Config(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "multiplier": {"file": "multiplier.hlo.txt", "input_words": 4096,
                         "dtype": "u32", "sha256": "ab"},
          "pipeline": {"file": "pipeline.hlo.txt", "input_words": 4096,
                       "dtype": "u32", "sha256": "cd"}
        }"#;
        let v = Json::parse(doc).unwrap();
        let m = v.get("multiplier").unwrap();
        assert_eq!(m.get("file").unwrap().as_str(), Some("multiplier.hlo.txt"));
        assert_eq!(m.get("input_words").unwrap().as_usize(), Some(4096));
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        let a = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(a.as_arr().unwrap().len(), 3);
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
