//! Typed system configuration, loadable from a TOML-subset file
//! (`configs/*.toml`) with paper-calibrated defaults.
//!
//! Every constant that shapes an experiment lives here so benches can
//! sweep them and EXPERIMENTS.md can cite them.

pub mod json;
pub mod toml;

use std::path::Path;

use crate::Result;
use toml::TomlDoc;

/// Fabric-level parameters (the KCU1500 shell of §V.A/§V.B).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Crossbar port count (paper prototype: 4 — port 0 is the AXI
    /// bridge, ports 1..=3 host PR regions).  The register file is
    /// banked to this width ([`FabricConfig::regfile_layout`]), so any
    /// count in 2..=32 is fully programmable (`configs/scale16.toml`
    /// ships the 16-port scale-out shape).
    pub num_ports: usize,
    /// Fabric clock (MHz).  XDMA side of the shell runs at 250 MHz.
    pub clock_mhz: f64,
    /// ICAP clock (MHz), 125 MHz on the KCU1500.
    pub icap_clock_mhz: f64,
    /// Number of PR regions (= num_ports - 1 in the prototype).
    pub num_pr_regions: usize,
}

impl FabricConfig {
    /// The banked register-file layout this shell is programmed through
    /// (one bank set per crossbar port — see `regfile`).
    pub fn regfile_layout(&self) -> crate::regfile::RegfileLayout {
        crate::regfile::RegfileLayout::new(self.num_ports)
    }
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self {
            num_ports: 4,
            clock_mhz: 250.0,
            icap_clock_mhz: 125.0,
            num_pr_regions: 3,
        }
    }
}

/// Crossbar/WISHBONE parameters (§IV.E, §IV.F).
#[derive(Debug, Clone, PartialEq)]
pub struct CrossbarConfig {
    /// Watchdog: cycles a master waits for a grant before timing out.
    pub grant_timeout: u64,
    /// Watchdog: cycles a master waits for a slave ack before timing out.
    pub ack_timeout: u64,
    /// Default allowed packages per grant per master (regfile resettable;
    /// the paper's §V.E walkthrough uses 8).
    pub default_packages: u32,
    /// Slave-interface receive buffer depth in words.
    pub slave_buffer_words: usize,
}

impl Default for CrossbarConfig {
    fn default() -> Self {
        Self {
            grant_timeout: 1000,
            ack_timeout: 1000,
            default_packages: 8,
            slave_buffer_words: 8,
        }
    }
}

/// Testbed timing model for Fig 5 (see DESIGN.md §8 — calibration, not
/// measurement).
#[derive(Debug, Clone, PartialEq)]
pub struct TimingConfig {
    /// Effective PCIe Gen3 x8 streaming bandwidth (GB/s).
    pub pcie_gbps: f64,
    /// Fixed host-side cost per XDMA descriptor round (ms): driver,
    /// interrupt, completion.  Dominates small transfers.
    pub xdma_round_ms: f64,
    /// CPU time per on-server stage on the 16 KB buffer (ms).
    pub cpu_stage_ms: f64,
    /// Use measured PJRT wall time for on-server stages instead of
    /// `cpu_stage_ms` (reality mode; defaults off so Fig 5 matches the
    /// paper's testbed scale).
    pub measure_cpu_stages: bool,
}

impl Default for TimingConfig {
    fn default() -> Self {
        // Calibrated so the Fig-5 endpoints emerge from the model's
        // mechanism (DESIGN.md §8): case 3 = 2 descriptor rounds + fabric
        // ≈ 10.87 ms; case 1 adds two on-server stages ≈ 16.9 ms.
        Self {
            pcie_gbps: 7.9,
            xdma_round_ms: 5.36,
            cpu_stage_ms: 3.06,
            measure_cpu_stages: false,
        }
    }
}

/// Elastic-manager parameters (§IV.A).
#[derive(Debug, Clone, PartialEq)]
pub struct ManagerConfig {
    /// Bitstream size per PR region (bytes) — sets ICAP reconfig latency.
    pub bitstream_bytes: usize,
    /// Poll interval (in fabric cycles) for the migration check the paper
    /// describes ("checks again if there are any PR regions released").
    pub poll_cycles: u64,
    /// Verify every PJRT result against the Rust golden model.
    pub verify_results: bool,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        Self {
            bitstream_bytes: 2 * 1024 * 1024,
            poll_cycles: 1024,
            verify_results: true,
        }
    }
}

/// Server parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Worker threads executing on-server stages.
    pub workers: usize,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 64 }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SystemConfig {
    pub fabric: FabricConfig,
    pub crossbar: CrossbarConfig,
    pub timing: TimingConfig,
    pub manager: ManagerConfig,
    pub server: ServerConfig,
    /// Artifact directory (HLO text + manifest.json).
    pub artifact_dir: String,
}

impl SystemConfig {
    /// Paper-calibrated defaults (KCU1500 prototype).
    pub fn paper_defaults() -> Self {
        Self { artifact_dir: crate::DEFAULT_ARTIFACT_DIR.into(), ..Default::default() }
    }

    /// Load from a TOML-subset file, overlaying the defaults.
    pub fn load(path: &Path) -> Result<Self> {
        Ok(Self::from_doc(&TomlDoc::load(path)?))
    }

    /// Parse from text, overlaying the defaults.
    pub fn parse(text: &str) -> Result<Self> {
        Ok(Self::from_doc(&TomlDoc::parse(text)?))
    }

    fn from_doc(doc: &TomlDoc) -> Self {
        let d = Self::paper_defaults();
        Self {
            fabric: FabricConfig {
                num_ports: doc.usize_or("fabric.num_ports", d.fabric.num_ports),
                clock_mhz: doc.f64_or("fabric.clock_mhz", d.fabric.clock_mhz),
                icap_clock_mhz: doc
                    .f64_or("fabric.icap_clock_mhz", d.fabric.icap_clock_mhz),
                num_pr_regions: doc
                    .usize_or("fabric.num_pr_regions", d.fabric.num_pr_regions),
            },
            crossbar: CrossbarConfig {
                grant_timeout: doc
                    .usize_or("crossbar.grant_timeout", d.crossbar.grant_timeout as usize)
                    as u64,
                ack_timeout: doc
                    .usize_or("crossbar.ack_timeout", d.crossbar.ack_timeout as usize)
                    as u64,
                default_packages: doc.usize_or(
                    "crossbar.default_packages",
                    d.crossbar.default_packages as usize,
                ) as u32,
                slave_buffer_words: doc.usize_or(
                    "crossbar.slave_buffer_words",
                    d.crossbar.slave_buffer_words,
                ),
            },
            timing: TimingConfig {
                pcie_gbps: doc.f64_or("timing.pcie_gbps", d.timing.pcie_gbps),
                xdma_round_ms: doc
                    .f64_or("timing.xdma_round_ms", d.timing.xdma_round_ms),
                cpu_stage_ms: doc
                    .f64_or("timing.cpu_stage_ms", d.timing.cpu_stage_ms),
                measure_cpu_stages: doc.bool_or(
                    "timing.measure_cpu_stages",
                    d.timing.measure_cpu_stages,
                ),
            },
            manager: ManagerConfig {
                bitstream_bytes: doc.usize_or(
                    "manager.bitstream_bytes",
                    d.manager.bitstream_bytes,
                ),
                poll_cycles: doc
                    .usize_or("manager.poll_cycles", d.manager.poll_cycles as usize)
                    as u64,
                verify_results: doc
                    .bool_or("manager.verify_results", d.manager.verify_results),
            },
            server: ServerConfig {
                workers: doc.usize_or("server.workers", d.server.workers),
                queue_depth: doc
                    .usize_or("server.queue_depth", d.server.queue_depth),
            },
            artifact_dir: doc.str_or("artifact_dir", &d.artifact_dir),
        }
    }

    /// Fabric clock period in nanoseconds.
    pub fn clock_period_ns(&self) -> f64 {
        1000.0 / self.fabric.clock_mhz
    }

    /// Convert fabric cycles to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 * self.clock_period_ns() / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = SystemConfig::paper_defaults();
        assert_eq!(c.fabric.num_ports, 4);
        assert_eq!(c.fabric.num_pr_regions, 3);
        assert_eq!(c.fabric.regfile_layout().num_regs(), 20, "Table III");
        assert_eq!(c.fabric.clock_mhz, 250.0);
        assert_eq!(c.fabric.icap_clock_mhz, 125.0);
        assert_eq!(c.crossbar.default_packages, 8);
        assert_eq!(c.clock_period_ns(), 4.0);
    }

    #[test]
    fn overlay_from_text() {
        let c = SystemConfig::parse(
            "[fabric]\nnum_ports = 8\n[timing]\ncpu_stage_ms = 5.5\n",
        )
        .unwrap();
        assert_eq!(c.fabric.num_ports, 8);
        assert_eq!(c.timing.cpu_stage_ms, 5.5);
        // untouched values keep defaults
        assert_eq!(c.fabric.clock_mhz, 250.0);
    }

    #[test]
    fn cycles_to_ms_at_250mhz() {
        let c = SystemConfig::paper_defaults();
        assert!((c.cycles_to_ms(250_000) - 1.0).abs() < 1e-12);
    }
}
