//! Typed system configuration, loadable from a TOML-subset file
//! (`configs/*.toml`) with paper-calibrated defaults.
//!
//! Every constant that shapes an experiment lives here so benches can
//! sweep them and EXPERIMENTS.md can cite them.

pub mod json;
pub mod toml;

use std::path::Path;

use crate::kernels::KernelDecl;
use crate::Result;
use toml::TomlDoc;

/// Fabric-level parameters (the KCU1500 shell of §V.A/§V.B).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Crossbar port count (paper prototype: 4 — port 0 is the AXI
    /// bridge, ports 1..=3 host PR regions).  The register file is
    /// banked to this width ([`FabricConfig::regfile_layout`]), so any
    /// count in 2..=32 is fully programmable (`configs/scale16.toml`
    /// ships the 16-port scale-out shape).
    pub num_ports: usize,
    /// Fabric clock (MHz).  XDMA side of the shell runs at 250 MHz.
    pub clock_mhz: f64,
    /// ICAP clock (MHz), 125 MHz on the KCU1500.
    pub icap_clock_mhz: f64,
    /// Number of PR regions (= num_ports - 1 in the prototype).
    pub num_pr_regions: usize,
}

impl FabricConfig {
    /// The banked register-file layout this shell is programmed through
    /// (one bank set per crossbar port — see `regfile`).
    pub fn regfile_layout(&self) -> crate::regfile::RegfileLayout {
        crate::regfile::RegfileLayout::new(self.num_ports)
    }
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self {
            num_ports: 4,
            clock_mhz: 250.0,
            icap_clock_mhz: 125.0,
            num_pr_regions: 3,
        }
    }
}

/// Crossbar/WISHBONE parameters (§IV.E, §IV.F).
#[derive(Debug, Clone, PartialEq)]
pub struct CrossbarConfig {
    /// Watchdog: cycles a master waits for a grant before timing out.
    pub grant_timeout: u64,
    /// Watchdog: cycles a master waits for a slave ack before timing out.
    pub ack_timeout: u64,
    /// Default allowed packages per grant per master (regfile resettable;
    /// the paper's §V.E walkthrough uses 8).
    pub default_packages: u32,
    /// Slave-interface receive buffer depth in words.
    pub slave_buffer_words: usize,
}

impl Default for CrossbarConfig {
    fn default() -> Self {
        Self {
            grant_timeout: 1000,
            ack_timeout: 1000,
            default_packages: 8,
            slave_buffer_words: 8,
        }
    }
}

/// Testbed timing model for Fig 5 (see DESIGN.md §8 — calibration, not
/// measurement).
#[derive(Debug, Clone, PartialEq)]
pub struct TimingConfig {
    /// Effective PCIe Gen3 x8 streaming bandwidth (GB/s).
    pub pcie_gbps: f64,
    /// Fixed host-side cost per XDMA descriptor round (ms): driver,
    /// interrupt, completion.  Dominates small transfers.
    pub xdma_round_ms: f64,
    /// CPU time per on-server stage on the 16 KB buffer (ms).
    pub cpu_stage_ms: f64,
    /// Use measured PJRT wall time for on-server stages instead of
    /// `cpu_stage_ms` (reality mode; defaults off so Fig 5 matches the
    /// paper's testbed scale).
    pub measure_cpu_stages: bool,
}

impl Default for TimingConfig {
    fn default() -> Self {
        // Calibrated so the Fig-5 endpoints emerge from the model's
        // mechanism (DESIGN.md §8): case 3 = 2 descriptor rounds + fabric
        // ≈ 10.87 ms; case 1 adds two on-server stages ≈ 16.9 ms.
        Self {
            pcie_gbps: 7.9,
            xdma_round_ms: 5.36,
            cpu_stage_ms: 3.06,
            measure_cpu_stages: false,
        }
    }
}

/// Elastic-manager parameters (§IV.A).
#[derive(Debug, Clone, PartialEq)]
pub struct ManagerConfig {
    /// Bitstream size per PR region (bytes) — sets ICAP reconfig latency.
    pub bitstream_bytes: usize,
    /// Poll interval (in fabric cycles) for the migration check the paper
    /// describes ("checks again if there are any PR regions released").
    pub poll_cycles: u64,
    /// Verify every PJRT result against the Rust golden model.
    pub verify_results: bool,
    /// Configuration-cache capacity: maximum regions the manager keeps
    /// `Resident { kind }` after an app releases them, so a later
    /// request needing the same [`crate::modules::ModuleKind`] rebinds
    /// through the register file alone (zero ICAP cycles).  `0` (the
    /// default) disables the cache — regions free on release, exactly
    /// the legacy behavior.
    pub config_cache_regions: usize,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        Self {
            bitstream_bytes: 2 * 1024 * 1024,
            poll_cycles: 1024,
            verify_results: true,
            config_cache_regions: 0,
        }
    }
}

/// The per-app bandwidth plane (see [`crate::qos`] and DESIGN.md §11).
#[derive(Debug, Clone, PartialEq)]
pub struct QosConfig {
    /// WRR rotation quantum `T`: total packages a full rotation hands
    /// to the contracted share plane (1..=255; each app's per-rotation
    /// packages are `T · share / SHARE_UNIT`).
    pub rotation_packages: u32,
    /// Explicit `(app_id, share_ppu)` contracts; everything else rides
    /// the best-effort pool at the crossbar's default budget.
    pub shares: Vec<(u32, u32)>,
}

impl QosConfig {
    /// The configured plan as a validated [`crate::qos::BandwidthPlan`].
    pub fn plan(&self) -> crate::Result<crate::qos::BandwidthPlan> {
        crate::qos::BandwidthPlan::with_shares(&self.shares)
    }
}

impl Default for QosConfig {
    fn default() -> Self {
        // No contracts: the compiler emits the pre-plan default-budget
        // image, so an unconfigured [qos] table changes nothing.
        Self { rotation_packages: 64, shares: Vec::new() }
    }
}

/// Server parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Worker threads executing on-server stages.
    pub workers: usize,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Same-app coalescing window (DESIGN.md §15): maximum requests a
    /// lane executor or fleet stream serves per batch.  `1` (the
    /// default) disables coalescing — scheduling is byte-identical to
    /// the pre-batching server.  Valid range 1..=64.
    pub batch_window: usize,
    /// Optional fleet-side bound: a batch follower must arrive within
    /// this many fabric cycles of its leader (`0` = bounded only by
    /// the leader's start instant).
    pub batch_cycles: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 64, batch_window: 1, batch_cycles: 0 }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SystemConfig {
    pub fabric: FabricConfig,
    pub crossbar: CrossbarConfig,
    pub timing: TimingConfig,
    pub manager: ManagerConfig,
    pub server: ServerConfig,
    pub qos: QosConfig,
    /// Artifact directory (HLO text + manifest.json).
    pub artifact_dir: String,
    /// Kernel declarations from `[kernels.<name>]` tables (DESIGN.md
    /// §17), in sorted-name order.  Empty by default: the registry then
    /// holds only the three seed kernels and behavior is byte-identical
    /// to the pre-registry system.
    pub kernels: Vec<KernelDecl>,
}

impl SystemConfig {
    /// Paper-calibrated defaults (KCU1500 prototype).
    pub fn paper_defaults() -> Self {
        Self { artifact_dir: crate::DEFAULT_ARTIFACT_DIR.into(), ..Default::default() }
    }

    /// Load from a TOML-subset file, overlaying the defaults.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_doc(&TomlDoc::load(path)?)
    }

    /// Parse from text, overlaying the defaults.
    pub fn parse(text: &str) -> Result<Self> {
        Self::from_doc(&TomlDoc::parse(text)?)
    }

    /// Parse the `[qos.shares]` table: `appN = ppu` keys.
    fn qos_shares(doc: &TomlDoc) -> Result<Vec<(u32, u32)>> {
        let mut shares = Vec::new();
        for key in doc.keys_under("qos.shares") {
            let name = key.trim_start_matches("qos.shares.");
            let app: u32 = name
                .strip_prefix("app")
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| {
                    crate::ElasticError::Config(format!(
                        "[qos.shares] key '{name}' is not appN (e.g. app0)"
                    ))
                })?;
            let ppu = doc.get(key).and_then(|v| v.as_usize()).ok_or_else(
                || {
                    crate::ElasticError::Config(format!(
                        "[qos.shares] {name} must be an integer share"
                    ))
                },
            )?;
            // Range-check before narrowing: a 64-bit value must not
            // wrap into a plausible share.
            if ppu > crate::qos::SHARE_UNIT as usize {
                return Err(crate::ElasticError::Config(format!(
                    "[qos.shares] {name} = {ppu} exceeds {}",
                    crate::qos::SHARE_UNIT
                )));
            }
            shares.push((app, ppu as u32));
        }
        Ok(shares)
    }

    /// Parse the `[kernels.<name>]` tables into declarations (DESIGN.md
    /// §17).  A bare `[kernels]` header with no kernel subtables, an
    /// empty `[kernels.<name>]` table, and unknown fields are all typed
    /// refusals — a declaration either means something or fails loudly.
    /// Semantic validation (reserved names, family rules, latency and
    /// geometry ranges, manifest cross-checks) happens at registration
    /// in [`crate::kernels::register`].
    pub fn kernel_decls_from_doc(doc: &TomlDoc) -> Result<Vec<KernelDecl>> {
        if !doc.has_table("kernels") {
            return Ok(Vec::new());
        }
        let names = doc.tables_under("kernels");
        if names.is_empty() {
            return Err(crate::ElasticError::Config(
                "[kernels] declared but empty — declare kernels as \
                 [kernels.<name>] subtables or drop the section"
                    .into(),
            ));
        }
        let mut decls = Vec::with_capacity(names.len());
        for name in names {
            let prefix = format!("kernels.{name}");
            let keys = doc.keys_under(&prefix);
            if keys.is_empty() {
                return Err(crate::ElasticError::Config(format!(
                    "[kernels.{name}] is empty — a kernel needs at least \
                     an op or artifact field"
                )));
            }
            let mut decl = KernelDecl { name: name.to_string(), ..KernelDecl::default() };
            for key in keys {
                let field = &key[prefix.len() + 1..];
                let val = doc.get(key).expect("key came from the doc");
                let set = |v: &toml::TomlValue, what: &str| {
                    v.as_i64()
                        .filter(|&x| (0..=u32::MAX as i64).contains(&x))
                        .map(|x| x as u32)
                        .ok_or_else(|| {
                            crate::ElasticError::Config(format!(
                                "[kernels.{name}] {what} must be a u32"
                            ))
                        })
                };
                match field {
                    "op" => {
                        decl.op = Some(
                            val.as_str()
                                .ok_or_else(|| {
                                    crate::ElasticError::Config(format!(
                                        "[kernels.{name}] op must be a string"
                                    ))
                                })?
                                .to_string(),
                        );
                    }
                    "artifact" => {
                        decl.artifact = Some(
                            val.as_str()
                                .ok_or_else(|| {
                                    crate::ElasticError::Config(format!(
                                        "[kernels.{name}] artifact must be a string"
                                    ))
                                })?
                                .to_string(),
                        );
                    }
                    "operand" => decl.operand = set(val, "operand")?,
                    "mask" => decl.mask = set(val, "mask")?,
                    "latency_base" => {
                        decl.latency_base = set(val, "latency_base")?
                    }
                    "latency_per_word" => {
                        decl.latency_per_word = set(val, "latency_per_word")?
                    }
                    "input_words" => {
                        decl.input_words =
                            Some(val.as_usize().ok_or_else(|| {
                                crate::ElasticError::Config(format!(
                                    "[kernels.{name}] input_words must be \
                                     a non-negative integer"
                                ))
                            })?)
                    }
                    "batch_words" => {
                        decl.batch_words = val.as_usize().ok_or_else(|| {
                            crate::ElasticError::Config(format!(
                                "[kernels.{name}] batch_words must be a \
                                 non-negative integer"
                            ))
                        })?
                    }
                    "luts" => decl.luts = set(val, "luts")? as u64,
                    "ffs" => decl.ffs = set(val, "ffs")? as u64,
                    other => {
                        return Err(crate::ElasticError::Config(format!(
                            "[kernels.{name}] unknown field '{other}' \
                             (known: op, operand, mask, artifact, \
                             input_words, batch_words, latency_base, \
                             latency_per_word, luts, ffs)"
                        )));
                    }
                }
            }
            decls.push(decl);
        }
        Ok(decls)
    }

    /// Load only the kernel declarations from a TOML file (the
    /// `--kernels FILE` CLI path).  The file must actually declare
    /// kernels: a kernels file without a `[kernels]` section is a typo,
    /// not an empty registry.
    pub fn load_kernel_decls(path: &Path) -> Result<Vec<KernelDecl>> {
        let doc = TomlDoc::load(path)?;
        if !doc.has_table("kernels") {
            return Err(crate::ElasticError::Config(format!(
                "{path:?} has no [kernels] section"
            )));
        }
        Self::kernel_decls_from_doc(&doc)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = Self::paper_defaults();
        // Range-check the full-width value before narrowing to u32, so
        // an out-of-range 64-bit quantum fails instead of wrapping.
        let rotation_packages = doc.usize_or(
            "qos.rotation_packages",
            d.qos.rotation_packages as usize,
        );
        if !(1..=255).contains(&rotation_packages) {
            return Err(crate::ElasticError::Config(format!(
                "qos.rotation_packages {rotation_packages} must be 1..=255"
            )));
        }
        let qos = QosConfig {
            rotation_packages: rotation_packages as u32,
            shares: Self::qos_shares(doc)?,
        };
        // Reject overcommitted share tables at parse time, so every
        // consumer downstream can trust the configured plan.
        qos.plan()?;
        // The default budget is an 8-bit regfile field and a plan
        // compiler input: out-of-range values must fail here with a
        // typed error, not at manager construction.
        let default_packages = doc.usize_or(
            "crossbar.default_packages",
            d.crossbar.default_packages as usize,
        );
        if !(1..=255).contains(&default_packages) {
            return Err(crate::ElasticError::Config(format!(
                "crossbar.default_packages {default_packages} must be 1..=255"
            )));
        }
        // The batch window bounds per-stream look-ahead; cap it so a
        // typo cannot turn the coalescer into head-of-line blocking.
        let batch_window =
            doc.usize_or("server.batch_window", d.server.batch_window);
        if !(1..=64).contains(&batch_window) {
            return Err(crate::ElasticError::Config(format!(
                "server.batch_window {batch_window} must be 1..=64"
            )));
        }
        Ok(Self {
            fabric: FabricConfig {
                num_ports: doc.usize_or("fabric.num_ports", d.fabric.num_ports),
                clock_mhz: doc.f64_or("fabric.clock_mhz", d.fabric.clock_mhz),
                icap_clock_mhz: doc
                    .f64_or("fabric.icap_clock_mhz", d.fabric.icap_clock_mhz),
                num_pr_regions: doc
                    .usize_or("fabric.num_pr_regions", d.fabric.num_pr_regions),
            },
            crossbar: CrossbarConfig {
                grant_timeout: doc
                    .usize_or("crossbar.grant_timeout", d.crossbar.grant_timeout as usize)
                    as u64,
                ack_timeout: doc
                    .usize_or("crossbar.ack_timeout", d.crossbar.ack_timeout as usize)
                    as u64,
                default_packages: doc.usize_or(
                    "crossbar.default_packages",
                    d.crossbar.default_packages as usize,
                ) as u32,
                slave_buffer_words: doc.usize_or(
                    "crossbar.slave_buffer_words",
                    d.crossbar.slave_buffer_words,
                ),
            },
            timing: TimingConfig {
                pcie_gbps: doc.f64_or("timing.pcie_gbps", d.timing.pcie_gbps),
                xdma_round_ms: doc
                    .f64_or("timing.xdma_round_ms", d.timing.xdma_round_ms),
                cpu_stage_ms: doc
                    .f64_or("timing.cpu_stage_ms", d.timing.cpu_stage_ms),
                measure_cpu_stages: doc.bool_or(
                    "timing.measure_cpu_stages",
                    d.timing.measure_cpu_stages,
                ),
            },
            manager: ManagerConfig {
                bitstream_bytes: doc.usize_or(
                    "manager.bitstream_bytes",
                    d.manager.bitstream_bytes,
                ),
                poll_cycles: doc
                    .usize_or("manager.poll_cycles", d.manager.poll_cycles as usize)
                    as u64,
                verify_results: doc
                    .bool_or("manager.verify_results", d.manager.verify_results),
                config_cache_regions: doc.usize_or(
                    "manager.config_cache_regions",
                    d.manager.config_cache_regions,
                ),
            },
            server: ServerConfig {
                workers: doc.usize_or("server.workers", d.server.workers),
                queue_depth: doc
                    .usize_or("server.queue_depth", d.server.queue_depth),
                batch_window,
                batch_cycles: doc.usize_or(
                    "server.batch_cycles",
                    d.server.batch_cycles as usize,
                ) as u64,
            },
            qos,
            artifact_dir: doc.str_or("artifact_dir", &d.artifact_dir),
            kernels: Self::kernel_decls_from_doc(doc)?,
        })
    }

    /// Fabric clock period in nanoseconds.
    pub fn clock_period_ns(&self) -> f64 {
        1000.0 / self.fabric.clock_mhz
    }

    /// Convert fabric cycles to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 * self.clock_period_ns() / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = SystemConfig::paper_defaults();
        assert_eq!(c.fabric.num_ports, 4);
        assert_eq!(c.fabric.num_pr_regions, 3);
        assert_eq!(c.fabric.regfile_layout().num_regs(), 20, "Table III");
        assert_eq!(c.fabric.clock_mhz, 250.0);
        assert_eq!(c.fabric.icap_clock_mhz, 125.0);
        assert_eq!(c.crossbar.default_packages, 8);
        // Configuration cache ships off: legacy release semantics.
        assert_eq!(c.manager.config_cache_regions, 0);
        assert_eq!(c.clock_period_ns(), 4.0);
    }

    #[test]
    fn overlay_from_text() {
        let c = SystemConfig::parse(
            "[fabric]\nnum_ports = 8\n[timing]\ncpu_stage_ms = 5.5\n\
             [manager]\nconfig_cache_regions = 3\n",
        )
        .unwrap();
        assert_eq!(c.fabric.num_ports, 8);
        assert_eq!(c.timing.cpu_stage_ms, 5.5);
        assert_eq!(c.manager.config_cache_regions, 3);
        // untouched values keep defaults
        assert_eq!(c.fabric.clock_mhz, 250.0);
    }

    #[test]
    fn qos_table_parses_and_validates() {
        let c = SystemConfig::parse(
            "[qos]\nrotation_packages = 100\n\
             [qos.shares]\napp0 = 600\napp2 = 200\n",
        )
        .unwrap();
        assert_eq!(c.qos.rotation_packages, 100);
        assert_eq!(c.qos.shares, vec![(0, 600), (2, 200)]);
        let plan = c.qos.plan().unwrap();
        assert_eq!(plan.share_of(0), Some(600));
        assert_eq!(plan.best_effort_share(), 200);
        // Unconfigured: empty plan, default quantum.
        let d = SystemConfig::paper_defaults();
        assert_eq!(d.qos.rotation_packages, 64);
        assert!(d.qos.plan().unwrap().is_empty());
        // Overcommit, bad keys and bad quanta are parse-time errors.
        assert!(SystemConfig::parse(
            "[qos.shares]\napp0 = 700\napp1 = 400\n"
        )
        .is_err());
        assert!(SystemConfig::parse("[qos.shares]\ntenant0 = 10\n").is_err());
        assert!(SystemConfig::parse("[qos]\nrotation_packages = 0\n").is_err());
        assert!(
            SystemConfig::parse("[qos]\nrotation_packages = 256\n").is_err()
        );
        // The default budget is an 8-bit field and a compiler input:
        // out-of-range values fail at parse, not at manager start.
        assert!(
            SystemConfig::parse("[crossbar]\ndefault_packages = 300\n")
                .is_err()
        );
        assert!(
            SystemConfig::parse("[crossbar]\ndefault_packages = 0\n").is_err()
        );
        // 64-bit values must fail, not wrap into the valid range
        // (4294967360 = 2^32 + 64; 4294968296 = 2^32 + 1000).
        assert!(SystemConfig::parse(
            "[qos]\nrotation_packages = 4294967360\n"
        )
        .is_err());
        assert!(
            SystemConfig::parse("[qos.shares]\napp0 = 4294968296\n").is_err()
        );
    }

    #[test]
    fn batch_window_parses_and_validates() {
        let c = SystemConfig::parse(
            "[server]\nbatch_window = 8\nbatch_cycles = 4096\n",
        )
        .unwrap();
        assert_eq!(c.server.batch_window, 8);
        assert_eq!(c.server.batch_cycles, 4096);
        // Unconfigured: window 1 — coalescing off, legacy scheduling.
        let d = SystemConfig::paper_defaults();
        assert_eq!(d.server.batch_window, 1);
        assert_eq!(d.server.batch_cycles, 0);
        // A window of 0 would stall every stream; huge windows are
        // head-of-line blocking.  Both fail at parse time.
        assert!(SystemConfig::parse("[server]\nbatch_window = 0\n").is_err());
        assert!(SystemConfig::parse("[server]\nbatch_window = 65\n").is_err());
    }

    #[test]
    fn kernels_tables_parse_into_declarations() {
        let c = SystemConfig::parse(
            "[kernels.heavy-mul]\nop = \"mul\"\noperand = 0x9E37_79B1\n\
             latency_base = 64\nlatency_per_word = 8\nluts = 900\nffs = 500\n\
             [kernels.light-xor]\nop = \"xor\"\noperand = 255\nmask = 0xFFFF\n",
        )
        .unwrap();
        assert_eq!(c.kernels.len(), 2);
        // Sorted-name order (BTreeMap-backed doc) => deterministic
        // registration order.
        assert_eq!(c.kernels[0].name, "heavy-mul");
        assert_eq!(c.kernels[0].op.as_deref(), Some("mul"));
        assert_eq!(c.kernels[0].operand, 0x9E37_79B1);
        assert_eq!(c.kernels[0].latency_base, 64);
        assert_eq!(c.kernels[0].latency_per_word, 8);
        assert_eq!(c.kernels[0].luts, 900);
        assert_eq!(c.kernels[1].name, "light-xor");
        assert_eq!(c.kernels[1].mask, 0xFFFF);
        // No [kernels] section at all: empty declaration list.
        assert!(SystemConfig::parse("[fabric]\nnum_ports = 4\n")
            .unwrap()
            .kernels
            .is_empty());
    }

    #[test]
    fn hostile_kernels_tables_are_refused() {
        // Bare [kernels] with no subtables.
        assert!(SystemConfig::parse("[kernels]\n").is_err());
        // Empty [kernels.<name>] table.
        assert!(SystemConfig::parse("[kernels.ghost]\n").is_err());
        // Unknown field.
        assert!(SystemConfig::parse(
            "[kernels.k]\nop = \"mul\"\nspeed = 9\n"
        )
        .is_err());
        // Type confusion.
        assert!(SystemConfig::parse("[kernels.k]\nop = 3\n").is_err());
        assert!(SystemConfig::parse(
            "[kernels.k]\nop = \"mul\"\noperand = \"x\"\n"
        )
        .is_err());
        // u32 overflow must fail, not wrap (2^32 + 1).
        assert!(SystemConfig::parse(
            "[kernels.k]\nop = \"mul\"\noperand = 4294967297\n"
        )
        .is_err());
    }

    #[test]
    fn cycles_to_ms_at_250mhz() {
        let c = SystemConfig::paper_defaults();
        assert!((c.cycles_to_ms(250_000) - 1.0).abs() < 1e-12);
    }
}
