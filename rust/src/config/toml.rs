//! Minimal TOML-subset parser for system configuration files.
//!
//! Supported grammar (everything the shipped configs use):
//! `[section]` / `[section.sub]` headers, `key = value` pairs with
//! integer, float, boolean, string, and flat-array values, `#` comments.
//! Not supported (rejected, not silently ignored): inline tables, arrays
//! of tables, multi-line strings, datetimes.
//!
//! serde/toml crates are unavailable offline — see DESIGN.md §7.

use std::collections::BTreeMap;

use crate::{ElasticError, Result};

/// A TOML scalar or flat array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(v) if *v >= 0 => Some(*v as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path key -> value (e.g. `"timing.cpu_stage_ms"`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
    /// Every `[section]` header that appeared, including empty ones —
    /// so a bare `[kernels]` or empty `[kernels.foo]` table is
    /// *visible* to validation instead of silently vanishing.
    sections: std::collections::BTreeSet<String>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut sections = std::collections::BTreeSet::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    err(lineno, "unterminated section header")
                })?;
                if name.starts_with('[') {
                    return Err(err(lineno, "arrays of tables not supported"));
                }
                let name = name.trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                sections.insert(section.clone());
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let parsed = parse_value(val.trim(), lineno)?;
            if values.insert(full.clone(), parsed).is_some() {
                return Err(err(lineno, &format!("duplicate key '{full}'")));
            }
        }
        Ok(Self { values, sections })
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Look up a dotted-path key.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    /// All keys under a section prefix.
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let dotted = format!("{prefix}.");
        self.values
            .keys()
            .filter(|k| k.starts_with(&dotted))
            .map(|k| k.as_str())
            .collect()
    }

    /// Did the document declare `[name]` (or any `[name.sub]`) as a
    /// section header — even an empty one?
    pub fn has_table(&self, name: &str) -> bool {
        let dotted = format!("{name}.");
        self.sections
            .iter()
            .any(|s| s == name || s.starts_with(&dotted))
    }

    /// Immediate child-table names declared under `[prefix.<child>]`
    /// headers, sorted (BTreeSet order) and deduplicated — includes
    /// children whose tables carry no keys.
    pub fn tables_under(&self, prefix: &str) -> Vec<&str> {
        let dotted = format!("{prefix}.");
        self.sections
            .iter()
            .filter_map(|s| s.strip_prefix(&dotted))
            .map(|rest| rest.split('.').next().unwrap_or(rest))
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect()
    }

    /// Typed getters with defaulting.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(TomlValue::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(TomlValue::as_str)
            .unwrap_or(default)
            .to_string()
    }
}

fn err(lineno: usize, msg: &str) -> ElasticError {
    ElasticError::Config(format!("toml line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue> {
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Some(hex) = clean.strip_prefix("0x") {
        return i64::from_str_radix(hex, 16)
            .map(TomlValue::Int)
            .map_err(|_| err(lineno, "invalid hex integer"));
    }
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(v) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| err(lineno, &format!("cannot parse value '{text}'")))
}

/// Split an array body on commas (no nested arrays in our subset, but
/// respect quoted strings).
fn split_top_level(text: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < text.len() {
        parts.push(&text[start..]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # header comment
            top = 1
            [fabric]
            num_ports = 4          # inline comment
            clock_mhz = 250.0
            name = "kcu1500"
            enabled = true
            sizes = [1, 2, 3]
            [timing.pcie]
            bandwidth_gbps = 7.9
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(doc.usize_or("fabric.num_ports", 0), 4);
        assert_eq!(doc.f64_or("fabric.clock_mhz", 0.0), 250.0);
        assert_eq!(doc.str_or("fabric.name", ""), "kcu1500");
        assert!(doc.bool_or("fabric.enabled", false));
        assert_eq!(doc.f64_or("timing.pcie.bandwidth_gbps", 0.0), 7.9);
        assert_eq!(
            doc.get("fabric.sizes").unwrap(),
            &TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ])
        );
    }

    #[test]
    fn hex_and_underscores() {
        let doc = TomlDoc::parse("k = 0x9E37_79B1\nbig = 1_000_000").unwrap();
        assert_eq!(doc.get("k").unwrap().as_i64(), Some(0x9E37_79B1));
        assert_eq!(doc.get("big").unwrap().as_i64(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("= 3").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("[[tables]]\n").is_err());
    }

    #[test]
    fn tracks_section_headers_even_when_empty() {
        let doc = TomlDoc::parse(
            "[kernels]\n[kernels.heavy]\nop = \"mul\"\n[kernels.empty]\n",
        )
        .unwrap();
        assert!(doc.has_table("kernels"));
        assert!(!doc.has_table("qos"));
        assert_eq!(doc.tables_under("kernels"), vec!["empty", "heavy"]);
        let none = TomlDoc::parse("a = 1").unwrap();
        assert!(!none.has_table("kernels"));
        assert!(none.tables_under("kernels").is_empty());
    }

    #[test]
    fn defaulting_getters() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "d"), "d");
    }
}
