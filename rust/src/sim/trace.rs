//! Bounded cycle-stamped trace ring, used for debugging waveform-level
//! behaviour without unbounded memory growth (the hardware analogue is an
//! on-chip ILA capture buffer).
//!
//! This is the free-form, string-payload debug ring.  The structured,
//! schema-versioned observability plane — typed events, per-tenant
//! metrics, flight-recorder dumps — lives in [`crate::telemetry`]
//! (DESIGN.md §14); prefer it for anything programmatic.

use std::collections::VecDeque;

/// One trace record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Fabric cycle at which the event occurred.
    pub cycle: u64,
    /// Component identifier (e.g. `"xbar.m1"`).
    pub who: &'static str,
    /// Human-readable description.
    pub what: String,
}

/// Fixed-capacity ring of trace events (oldest evicted first).
#[derive(Debug)]
pub struct TraceRing {
    buf: VecDeque<TraceEvent>,
    cap: usize,
    enabled: bool,
}

impl TraceRing {
    /// Create a ring holding at most `cap` events.
    pub fn new(cap: usize) -> Self {
        Self { buf: VecDeque::with_capacity(cap.min(4096)), cap, enabled: false }
    }

    /// Enable/disable capture (disabled capture is free).
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Whether capture is on.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record an event (no-op when disabled).
    pub fn push(&mut self, cycle: u64, who: &'static str, what: impl Into<String>) {
        if !self.enabled {
            return;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(TraceEvent { cycle, who, what: what.into() });
    }

    /// Snapshot of the captured events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.buf.iter().cloned().collect()
    }

    /// Drop all captured events.
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_ring_records_nothing() {
        let mut r = TraceRing::new(4);
        r.push(1, "x", "e");
        assert!(r.events().is_empty());
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut r = TraceRing::new(3);
        r.set_enabled(true);
        for i in 1..=5 {
            r.push(i, "x", format!("e{i}"));
        }
        let ev = r.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].cycle, 3);
        assert_eq!(ev[2].cycle, 5);
    }
}
