//! Discrete, cycle-accurate simulation core.
//!
//! The fabric is a synchronous digital design at one clock (250 MHz);
//! every component implements [`Tick`] and advances exactly one clock
//! per call.  §V.E of the paper is specified in clock cycles, so the
//! simulator's unit of time *is* the fabric clock cycle; wall-clock
//! quantities are derived via `SystemConfig::cycles_to_ms`.

mod trace;

pub use trace::{TraceEvent, TraceRing};

/// A synchronous component clocked by the fabric clock.
pub trait Tick {
    /// Advance one clock cycle.  `cycle` is the 1-indexed cycle number
    /// being executed (the paper counts "cc 1, cc 2, ..." the same way).
    fn tick(&mut self, cycle: u64);
}

/// The fabric clock: a monotonically increasing cycle counter with
/// helpers for running components in lock-step.
#[derive(Debug, Default, Clone)]
pub struct Clock {
    cycle: u64,
}

impl Clock {
    /// A clock at cycle 0 (nothing executed yet).
    pub fn new() -> Self {
        Self { cycle: 0 }
    }

    /// The last executed cycle (0 = none yet).
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Advance to the next cycle and return its number.
    pub fn advance(&mut self) -> u64 {
        self.cycle += 1;
        self.cycle
    }

    /// Run `component` for `n` cycles.
    pub fn run<T: Tick + ?Sized>(&mut self, component: &mut T, n: u64) {
        for _ in 0..n {
            let c = self.advance();
            component.tick(c);
        }
    }

    /// Run until `done` returns true or `max` cycles elapse; returns the
    /// cycle at which `done` first held, or `None` on budget exhaustion.
    pub fn run_until<T: Tick + ?Sized>(
        &mut self,
        component: &mut T,
        max: u64,
        mut done: impl FnMut(&T) -> bool,
    ) -> Option<u64> {
        for _ in 0..max {
            let c = self.advance();
            component.tick(c);
            if done(component) {
                return Some(c);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        seen: Vec<u64>,
    }

    impl Tick for Counter {
        fn tick(&mut self, cycle: u64) {
            self.seen.push(cycle);
        }
    }

    #[test]
    fn cycles_are_one_indexed_and_consecutive() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        clk.run(&mut c, 5);
        assert_eq!(c.seen, vec![1, 2, 3, 4, 5]);
        assert_eq!(clk.now(), 5);
    }

    #[test]
    fn run_until_stops_at_predicate() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        let hit = clk.run_until(&mut c, 100, |c| c.seen.len() == 7);
        assert_eq!(hit, Some(7));
        assert_eq!(clk.now(), 7);
    }

    #[test]
    fn run_until_exhausts_budget() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        assert_eq!(clk.run_until(&mut c, 3, |_| false), None);
    }
}
