//! Discrete, cycle-accurate simulation core — with an event-driven
//! fast-path for fleet-scale runs.
//!
//! The fabric is a synchronous digital design at one clock (250 MHz);
//! every component implements [`Tick`] and advances exactly one clock
//! per call.  §V.E of the paper is specified in clock cycles, so the
//! simulator's unit of time *is* the fabric clock cycle; wall-clock
//! quantities are derived via `SystemConfig::cycles_to_ms`.
//!
//! # Fast-path vs oracle
//!
//! Serving workloads spend most virtual time *idle*: between request
//! arrivals nothing on the fabric changes, yet the cycle-by-cycle loop
//! still executes every cycle.  [`Clock::run_scheduled`] is the
//! event-driven alternative: when the component reports a stable fixed
//! point ([`EventDriven::stable`]) and the next scheduled stimulus is
//! k > 1 cycles away, the run jumps straight to the stimulus cycle
//! (accounting the skipped cycles via [`EventDriven::fast_forward`]).
//!
//! # Busy-period skipping (the horizon contract, DESIGN.md §12)
//!
//! Even *inside* a busy period many cycles are deterministic counter
//! arithmetic: a module compute countdown, the ICAP's word-streaming
//! cadence.  A component may advertise this through
//! [`EventDriven::next_interesting_cycle`]: the earliest future cycle
//! whose tick does anything beyond arithmetic that
//! [`EventDriven::fast_forward`] can replay exactly.  The fast-path
//! jumps to the cycle before that horizon (bounded by the next
//! stimulus) instead of single-stepping.  Either way the fast-path is
//! **cycle-exact**: the same schedule replayed in oracle mode (`fast =
//! false`, every cycle ticked) produces identical component state,
//! events, and statistics — pinned by `tests/fastpath_equivalence.rs`
//! over randomized crossbar *and* full-fabric workloads (long compute
//! chains, mid-trace ICAP churn, saturated crossbars).

mod trace;

pub use trace::{TraceEvent, TraceRing};

/// A synchronous component clocked by the fabric clock.
pub trait Tick {
    /// Advance one clock cycle.  `cycle` is the 1-indexed cycle number
    /// being executed (the paper counts "cc 1, cc 2, ..." the same way).
    fn tick(&mut self, cycle: u64);
}

/// Horizon sentinel: the component will do nothing observable without
/// new external stimulus ([`EventDriven::next_interesting_cycle`]).
pub const HORIZON_NONE: u64 = u64::MAX;

/// A component the event-driven scheduler can fast-forward.
pub trait EventDriven: Tick {
    /// True when the component sits at a fixed point: ticking it cannot
    /// change any observable state until new external stimulus arrives.
    /// Implementations must be conservative — returning `false` only
    /// costs cycles, returning `true` spuriously breaks cycle-exactness.
    fn stable(&self) -> bool;

    /// Account a jump to `to_cycle` (cycle counters, statistics, and any
    /// deterministic busy-period arithmetic — compute countdowns, word
    /// stream positions) without executing the skipped cycles.  Called
    /// either while [`stable`] holds, or with `to_cycle` strictly below
    /// [`next_interesting_cycle`]; in both cases the implementation must
    /// land on *exactly* the state the skipped ticks would have produced.
    ///
    /// [`stable`]: EventDriven::stable
    /// [`next_interesting_cycle`]: EventDriven::next_interesting_cycle
    fn fast_forward(&mut self, to_cycle: u64);

    /// Busy-period horizon (DESIGN.md §12): the earliest cycle strictly
    /// after `now` whose tick may do anything beyond the deterministic
    /// counter arithmetic [`fast_forward`] replays.  `now + 1` (the
    /// default) means every cycle is interesting — never skip;
    /// [`HORIZON_NONE`] means nothing will happen without external
    /// stimulus.  Implementations must be conservative: a horizon that
    /// is too near only costs cycles, one that is too far breaks
    /// cycle-exactness, and every implementation owes the oracle an
    /// equivalence test (`tests/fastpath_equivalence.rs`).
    ///
    /// [`fast_forward`]: EventDriven::fast_forward
    fn next_interesting_cycle(&self, now: u64) -> u64 {
        now + 1
    }
}

/// External stimulus applied at scheduled cycles during a
/// [`Clock::run_scheduled`] run: each entry runs immediately *before*
/// its cycle executes, so a job pushed at cycle `t` is latched in cycle
/// `t` — the same semantics as pushing it by hand and then ticking.
pub struct Schedule<T> {
    events: Vec<(u64, Box<dyn FnOnce(&mut T)>)>,
}

impl<T> Schedule<T> {
    /// An empty schedule.
    pub fn new() -> Self {
        Self { events: Vec::new() }
    }

    /// Schedule `f` to run immediately before cycle `cycle` executes.
    pub fn at(&mut self, cycle: u64, f: impl FnOnce(&mut T) + 'static) {
        self.events.push((cycle, Box::new(f)));
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Is the schedule empty?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl<T> Default for Schedule<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A periodic control-tick cadence on a virtual clock, with its own
/// busy-period horizon.  The serving loop's lane executors use one per
/// lane for the autoscale tick: between period boundaries the cadence is
/// pure counter arithmetic, so its [`EventDriven::next_interesting_cycle`]
/// is the next boundary — a pending control tick never drags a lane back
/// to cycle-stepping, it just bounds the lane's jump (DESIGN.md §13).
///
/// Driven either tick-by-tick ([`Tick::tick`]) or by a jumping virtual
/// clock through [`due`](ControlCadence::due); both fire exactly once
/// per crossed boundary.
#[derive(Debug, Clone)]
pub struct ControlCadence {
    period: u64,
    next: u64,
    fired: u64,
}

impl ControlCadence {
    /// A cadence firing every `period` cycles (`0` disables it).
    pub fn new(period: u64) -> Self {
        Self {
            period,
            next: if period == 0 { HORIZON_NONE } else { period },
            fired: 0,
        }
    }

    /// Has the clock reached the next boundary?  Consumes one boundary
    /// per call, so a clock that jumped several periods in one request
    /// fires once per crossed boundary: `while cadence.due(now) { .. }`.
    pub fn due(&mut self, now: u64) -> bool {
        if self.period == 0 || now < self.next {
            return false;
        }
        self.fired += 1;
        self.next += self.period;
        true
    }

    /// Control ticks fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }
}

impl Tick for ControlCadence {
    fn tick(&mut self, cycle: u64) {
        let _ = self.due(cycle);
    }
}

impl EventDriven for ControlCadence {
    fn stable(&self) -> bool {
        // An enabled cadence always has a boundary pending — it will
        // fire without any external stimulus.
        self.period == 0
    }

    fn fast_forward(&mut self, to_cycle: u64) {
        // Nothing to replay: between boundaries the cadence only waits.
        debug_assert!(
            self.period == 0 || to_cycle < self.next,
            "fast-forward crossed a control-tick boundary"
        );
    }

    fn next_interesting_cycle(&self, now: u64) -> u64 {
        if self.period == 0 {
            HORIZON_NONE
        } else {
            self.next.max(now + 1)
        }
    }
}

/// The fabric clock: a monotonically increasing cycle counter with
/// helpers for running components in lock-step.
#[derive(Debug, Default, Clone)]
pub struct Clock {
    cycle: u64,
}

impl Clock {
    /// A clock at cycle 0 (nothing executed yet).
    pub fn new() -> Self {
        Self { cycle: 0 }
    }

    /// The last executed cycle (0 = none yet).
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Advance to the next cycle and return its number.
    pub fn advance(&mut self) -> u64 {
        self.cycle += 1;
        self.cycle
    }

    /// Jump forward to `cycle` without executing the skipped cycles
    /// (event-driven fast-path; the component must be fast-forwarded in
    /// lock-step).
    pub fn jump_to(&mut self, cycle: u64) {
        debug_assert!(cycle >= self.cycle, "clock cannot run backwards");
        self.cycle = cycle;
    }

    /// Run `component` for `n` cycles.
    pub fn run<T: Tick + ?Sized>(&mut self, component: &mut T, n: u64) {
        for _ in 0..n {
            let c = self.advance();
            component.tick(c);
        }
    }

    /// Run until `done` returns true or `max` cycles elapse; returns the
    /// cycle at which `done` first held, or `None` on budget exhaustion.
    pub fn run_until<T: Tick + ?Sized>(
        &mut self,
        component: &mut T,
        max: u64,
        mut done: impl FnMut(&T) -> bool,
    ) -> Option<u64> {
        for _ in 0..max {
            let c = self.advance();
            component.tick(c);
            if done(component) {
                return Some(c);
            }
        }
        None
    }

    /// Run `component` under `schedule` until it is stable with no
    /// stimulus left, or until `max` cycles (executed plus skipped) have
    /// elapsed.  Returns the cycle at which the run settled, or `None`
    /// on budget exhaustion.
    ///
    /// `fast = false` is the cycle-by-cycle **oracle**: every cycle is
    /// ticked, including idle gaps between scheduled events.  `fast =
    /// true` is the event-driven **fast-path**: while the component is
    /// [`stable`](EventDriven::stable), idle gaps are skipped in one
    /// jump, and inside busy periods the component's
    /// [`next_interesting_cycle`](EventDriven::next_interesting_cycle)
    /// horizon is skipped to the same way.  Both modes are cycle-exact
    /// and produce identical runs.
    ///
    /// Same-cycle stimuli are delivered in **insertion order** (the sort
    /// below is stable) — load-bearing for multi-source schedules and
    /// pinned by `same_cycle_stimuli_apply_in_insertion_order`.
    pub fn run_scheduled<T: EventDriven>(
        &mut self,
        component: &mut T,
        schedule: Schedule<T>,
        max: u64,
        fast: bool,
    ) -> Option<u64> {
        let mut events = schedule.events;
        events.sort_by_key(|(cycle, _)| *cycle);
        let mut it = events.into_iter().peekable();
        let end = self.cycle + max;
        while self.cycle < end {
            if component.stable() {
                match it.peek().map(|(cycle, _)| *cycle) {
                    // Settled: stable and nothing left to deliver.
                    None => return Some(self.cycle),
                    Some(t) if fast && t > self.cycle + 1 => {
                        // Idle gap: jump to the cycle before the next
                        // stimulus so the stimulus cycle itself executes.
                        let target = (t - 1).min(end);
                        component.fast_forward(target);
                        self.jump_to(target);
                        if self.cycle >= end {
                            break;
                        }
                    }
                    _ => {}
                }
            } else if fast {
                // Busy-period skipping: jump to the cycle before the
                // component's next interesting cycle, bounded by the next
                // stimulus and the budget.  The skipped ticks are
                // deterministic counter arithmetic that `fast_forward`
                // replays exactly (DESIGN.md §12).
                let mut target = component
                    .next_interesting_cycle(self.cycle)
                    .saturating_sub(1)
                    .min(end);
                if let Some(t) = it.peek().map(|(cycle, _)| *cycle) {
                    target = target.min(t.saturating_sub(1));
                }
                if target > self.cycle {
                    component.fast_forward(target);
                    self.jump_to(target);
                    if self.cycle >= end {
                        break;
                    }
                }
            }
            let c = self.advance();
            while it.peek().map(|(cycle, _)| *cycle <= c).unwrap_or(false) {
                let (_, stimulus) = it.next().expect("peeked");
                stimulus(component);
            }
            component.tick(c);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        seen: Vec<u64>,
    }

    impl Tick for Counter {
        fn tick(&mut self, cycle: u64) {
            self.seen.push(cycle);
        }
    }

    #[test]
    fn cycles_are_one_indexed_and_consecutive() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        clk.run(&mut c, 5);
        assert_eq!(c.seen, vec![1, 2, 3, 4, 5]);
        assert_eq!(clk.now(), 5);
    }

    #[test]
    fn run_until_stops_at_predicate() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        let hit = clk.run_until(&mut c, 100, |c| c.seen.len() == 7);
        assert_eq!(hit, Some(7));
        assert_eq!(clk.now(), 7);
    }

    #[test]
    fn run_until_exhausts_budget() {
        let mut clk = Clock::new();
        let mut c = Counter { seen: vec![] };
        assert_eq!(clk.run_until(&mut c, 3, |_| false), None);
    }

    /// Toy event-driven component: a down-counter that is busy for
    /// `work` ticks after each kick and records which cycles executed
    /// versus were skipped.
    struct Worker {
        work: u64,
        ticked: Vec<u64>,
        skipped_to: Vec<u64>,
        cycle: u64,
        accounted: u64,
    }

    impl Worker {
        fn new() -> Self {
            Self {
                work: 0,
                ticked: vec![],
                skipped_to: vec![],
                cycle: 0,
                accounted: 0,
            }
        }

        fn kick(&mut self, work: u64) {
            self.work += work;
        }
    }

    impl Tick for Worker {
        fn tick(&mut self, cycle: u64) {
            self.cycle = cycle;
            self.accounted += 1;
            self.ticked.push(cycle);
            if self.work > 0 {
                self.work -= 1;
            }
        }
    }

    impl EventDriven for Worker {
        fn stable(&self) -> bool {
            self.work == 0
        }

        fn fast_forward(&mut self, to_cycle: u64) {
            self.accounted += to_cycle - self.cycle;
            self.cycle = to_cycle;
            self.skipped_to.push(to_cycle);
        }
    }

    #[test]
    fn scheduled_oracle_ticks_every_cycle() {
        let mut clk = Clock::new();
        let mut w = Worker::new();
        let mut sched = Schedule::new();
        sched.at(3, |w: &mut Worker| w.kick(2));
        sched.at(10, |w: &mut Worker| w.kick(1));
        let settled = clk.run_scheduled(&mut w, sched, 1000, false);
        assert_eq!(settled, Some(10));
        assert_eq!(w.ticked, (1..=10).collect::<Vec<u64>>());
        assert!(w.skipped_to.is_empty());
        assert_eq!(w.accounted, 10);
    }

    #[test]
    fn scheduled_fast_path_skips_idle_gaps_exactly() {
        let mut clk = Clock::new();
        let mut w = Worker::new();
        let mut sched = Schedule::new();
        sched.at(3, |w: &mut Worker| w.kick(2));
        sched.at(10, |w: &mut Worker| w.kick(1));
        let settled = clk.run_scheduled(&mut w, sched, 1000, true);
        // Identical settle cycle and accounted-cycle total as the oracle.
        assert_eq!(settled, Some(10));
        assert_eq!(w.accounted, 10);
        // Cycles 1..2 and 5..9 were idle: only 3, 4, 10 executed.
        assert_eq!(w.ticked, vec![3, 4, 10]);
        assert_eq!(w.skipped_to, vec![2, 9]);
    }

    #[test]
    fn scheduled_run_exhausts_budget_when_never_stable() {
        let mut clk = Clock::new();
        let mut w = Worker::new();
        let mut sched = Schedule::new();
        sched.at(1, |w: &mut Worker| w.kick(1_000_000));
        assert_eq!(clk.run_scheduled(&mut w, sched, 50, true), None);
        assert_eq!(clk.now(), 50);
    }

    #[test]
    fn immediate_settle_with_empty_schedule() {
        let mut clk = Clock::new();
        let mut w = Worker::new();
        assert_eq!(clk.run_scheduled(&mut w, Schedule::new(), 10, true), Some(0));
        assert_eq!(clk.run_scheduled(&mut w, Schedule::new(), 10, false), Some(0));
        assert_eq!(clk.now(), 0);
    }

    /// Like [`Worker`], but it advertises its countdown as a busy-period
    /// horizon and fast-forwards it arithmetically (DESIGN.md §12).
    struct HorizonWorker {
        inner: Worker,
    }

    impl Tick for HorizonWorker {
        fn tick(&mut self, cycle: u64) {
            self.inner.tick(cycle);
        }
    }

    impl EventDriven for HorizonWorker {
        fn stable(&self) -> bool {
            self.inner.stable()
        }

        fn fast_forward(&mut self, to_cycle: u64) {
            // Reached via idle-gap skips (work == 0) and busy-period
            // skips (work > 0) alike.
            let delta = to_cycle - self.inner.cycle;
            if self.inner.work > 0 {
                debug_assert!(delta < self.inner.work, "skip crossed the countdown");
                self.inner.work -= delta;
            }
            self.inner.fast_forward(to_cycle);
        }

        fn next_interesting_cycle(&self, now: u64) -> u64 {
            if self.inner.work == 0 {
                HORIZON_NONE
            } else {
                // The tick that drains the countdown to zero is the next
                // observable event; everything before it only decrements.
                now + self.inner.work
            }
        }
    }

    #[test]
    fn busy_period_horizon_skips_countdowns_exactly() {
        let mut sched_fast = Schedule::new();
        let mut sched_oracle = Schedule::new();
        for s in [&mut sched_fast, &mut sched_oracle] {
            s.at(3, |w: &mut HorizonWorker| w.inner.kick(1000));
            s.at(2000, |w: &mut HorizonWorker| w.inner.kick(4));
        }
        let mut clk_f = Clock::new();
        let mut f = HorizonWorker { inner: Worker::new() };
        let settled_f = clk_f.run_scheduled(&mut f, sched_fast, 10_000, true);
        let mut clk_o = Clock::new();
        let mut o = HorizonWorker { inner: Worker::new() };
        let settled_o = clk_o.run_scheduled(&mut o, sched_oracle, 10_000, false);
        // Identical settle cycle, clock, and accounted-cycle totals.
        assert_eq!(settled_f, settled_o);
        assert_eq!(settled_f, Some(2003));
        assert_eq!(clk_f.now(), clk_o.now());
        assert_eq!(f.inner.accounted, o.inner.accounted);
        // The oracle executed every cycle; the fast path executed only
        // the interesting ones: the kick at 3, the countdown expiry at
        // 1002, the kick at 2000, and the second expiry at 2003.
        assert_eq!(o.inner.ticked, (1..=2003).collect::<Vec<u64>>());
        assert_eq!(f.inner.ticked, vec![3, 1002, 2000, 2003]);
        assert_eq!(f.inner.skipped_to, vec![2, 1001, 1999, 2002]);
    }

    #[test]
    fn control_cadence_fires_once_per_crossed_boundary() {
        let mut c = ControlCadence::new(10);
        assert!(!c.due(9));
        assert!(c.due(10), "first boundary");
        assert!(!c.due(10), "consumed");
        // A jump across several periods fires once per boundary.
        assert!(c.due(45));
        assert!(c.due(45));
        assert!(c.due(45), "boundaries 20, 30, 40");
        assert!(!c.due(45));
        assert_eq!(c.fired(), 4);
        // Disabled cadence never fires and has no horizon.
        let mut off = ControlCadence::new(0);
        assert!(!off.due(1_000_000));
        assert!(off.stable());
        assert_eq!(off.next_interesting_cycle(7), HORIZON_NONE);
    }

    #[test]
    fn control_cadence_horizon_matches_oracle_drive() {
        // Tick-by-tick (oracle) and horizon-jump (fast) drives agree on
        // the fire count — the §12 horizon contract for the control tick.
        let mut oracle = ControlCadence::new(8);
        for cycle in 1..=50 {
            oracle.tick(cycle);
        }
        let mut fast = ControlCadence::new(8);
        let mut now = 0;
        while now < 50 {
            let target = fast.next_interesting_cycle(now).min(50);
            fast.fast_forward(target - 1);
            now = target;
            fast.tick(now);
        }
        assert_eq!(oracle.fired(), fast.fired());
        assert_eq!(oracle.fired(), 6, "boundaries 8..=48");
    }

    /// Same-cycle stimuli must apply in insertion order in both modes —
    /// `run_scheduled`'s stable `sort_by_key` is load-bearing.
    struct StimLog {
        applied: Vec<u32>,
    }

    impl Tick for StimLog {
        fn tick(&mut self, _cycle: u64) {}
    }

    impl EventDriven for StimLog {
        fn stable(&self) -> bool {
            true
        }

        fn fast_forward(&mut self, _to_cycle: u64) {}
    }

    #[test]
    fn same_cycle_stimuli_apply_in_insertion_order() {
        for fast in [false, true] {
            let mut clk = Clock::new();
            let mut s = StimLog { applied: vec![] };
            let mut sched: Schedule<StimLog> = Schedule::new();
            // Inserted out of cycle order on purpose; the three entries
            // at cycle 7 must still run in insertion order (1, 2, 3).
            sched.at(7, |s: &mut StimLog| s.applied.push(1));
            sched.at(3, |s: &mut StimLog| s.applied.push(0));
            sched.at(7, |s: &mut StimLog| s.applied.push(2));
            sched.at(7, |s: &mut StimLog| s.applied.push(3));
            let settled = clk.run_scheduled(&mut s, sched, 100, fast);
            assert_eq!(settled, Some(7), "fast={fast}");
            assert_eq!(s.applied, vec![0, 1, 2, 3], "fast={fast}");
        }
    }
}
