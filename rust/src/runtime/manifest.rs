//! Artifact manifest (`artifacts/manifest.json`, written by
//! `python -m compile.aot`): maps artifact names to HLO files and their
//! expected input geometry.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::json::Json;
use crate::{ElasticError, Result};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    /// File name relative to the artifact directory.
    pub file: String,
    /// Input buffer length in 32-bit words.
    pub input_words: usize,
    /// Element dtype (currently always `"u32"`).
    pub dtype: String,
    /// SHA-256 of the HLO text (build provenance).
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    entries: BTreeMap<String, ManifestEntry>,
}

impl ArtifactManifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            ElasticError::Artifact(format!(
                "cannot read {path:?}: {e} — run `make artifacts` first"
            ))
        })?;
        Self::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let obj = root.as_obj().ok_or_else(|| {
            ElasticError::Artifact("manifest root must be an object".into())
        })?;
        let mut entries = BTreeMap::new();
        for (name, v) in obj {
            let field = |k: &str| {
                v.get(k).ok_or_else(|| {
                    ElasticError::Artifact(format!(
                        "manifest entry '{name}' missing field '{k}'"
                    ))
                })
            };
            let entry = ManifestEntry {
                file: field("file")?
                    .as_str()
                    .ok_or_else(|| {
                        ElasticError::Artifact(format!(
                            "'{name}'.file must be a string"
                        ))
                    })?
                    .to_string(),
                input_words: field("input_words")?.as_usize().ok_or_else(
                    || {
                        ElasticError::Artifact(format!(
                            "'{name}'.input_words must be a non-negative int"
                        ))
                    },
                )?,
                dtype: field("dtype")?
                    .as_str()
                    .unwrap_or("u32")
                    .to_string(),
                sha256: field("sha256")?.as_str().unwrap_or("").to_string(),
            };
            if entry.dtype != "u32" {
                return Err(ElasticError::Artifact(format!(
                    "'{name}': unsupported dtype '{}'",
                    entry.dtype
                )));
            }
            entries.insert(name.clone(), entry);
        }
        Ok(Self { entries })
    }

    /// Look up one artifact.
    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// All artifact names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "multiplier": {"file": "multiplier.hlo.txt", "input_words": 4096,
                     "dtype": "u32", "sha256": "aa"},
      "pipeline_small": {"file": "pipeline_small.hlo.txt", "input_words": 256,
                         "dtype": "u32", "sha256": "bb"}
    }"#;

    #[test]
    fn parses_entries() {
        let m = ArtifactManifest::parse(DOC).unwrap();
        assert_eq!(m.names(), vec!["multiplier", "pipeline_small"]);
        assert_eq!(m.get("multiplier").unwrap().input_words, 4096);
        assert_eq!(m.get("pipeline_small").unwrap().input_words, 256);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ArtifactManifest::parse(r#"{"x": {"file": "x"}}"#).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let doc = r#"{"x": {"file": "x", "input_words": 1,
                      "dtype": "f32", "sha256": ""}}"#;
        assert!(ArtifactManifest::parse(doc).is_err());
    }
}
