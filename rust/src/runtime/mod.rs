//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! on the request path.
//!
//! Python is build-time only; this module is the *only* bridge between
//! the Rust coordinator and the JAX/Pallas compute graphs.  Pattern
//! follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`,
//! with HLO **text** as the interchange format (serialized protos from
//! jax ≥ 0.5 are rejected by xla_extension 0.5.1).

mod handle;
mod manifest;

pub use handle::{RuntimeHandle, RuntimeThread};
pub use manifest::{ArtifactManifest, ManifestEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::{ElasticError, Result};

/// A compiled, ready-to-run artifact.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    input_words: usize,
}

impl Executable {
    /// Artifact name (e.g. `"hamming_enc"`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Expected input buffer length in 32-bit words.
    pub fn input_words(&self) -> usize {
        self.input_words
    }

    /// Execute on a u32 buffer, returning the u32 result buffer.
    ///
    /// All exported graphs take one `u32[n]` parameter and return a
    /// 1-tuple of `u32[n]` (lowered with `return_tuple=True`).
    pub fn run_u32(&self, input: &[u32]) -> Result<Vec<u32>> {
        if input.len() != self.input_words {
            return Err(ElasticError::Artifact(format!(
                "{}: input length {} != expected {}",
                self.name,
                input.len(),
                self.input_words
            )));
        }
        let lit = xla::Literal::vec1(input);
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<u32>()?)
    }
}

/// Artifact registry + executable cache over one PJRT client.
///
/// Compilation happens once per artifact (at load or first use); the
/// request path only calls [`Executable::run_u32`].  `Runtime` is
/// `Send + Sync`-shareable via `Arc`; the executable cache is mutexed,
/// execution itself does not take the lock.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json` produced
    /// by `python -m compile.aot`) on a fresh PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "pjrt client up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.names().len()
        );
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.names()
    }

    /// Load (compile-once, cached) an artifact by name.
    // `Executable` wraps a thread-confined PJRT pointer; the Arc is only
    // ever shared within the runtime's own thread (RuntimeHandle is the
    // cross-thread interface), so the non-Send Arc is intentional.
    #[allow(clippy::arc_with_non_send_sync)]
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.get(name).ok_or_else(|| {
            ElasticError::Artifact(format!("unknown artifact '{name}'"))
        })?;
        let path = self.dir.join(&entry.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                ElasticError::Artifact(format!("non-utf8 path {path:?}"))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("compiled '{name}' in {:?}", t0.elapsed());
        let exe = Arc::new(Executable {
            name: name.to_string(),
            exe,
            input_words: entry.input_words,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact in the manifest (server warm-up, so
    /// compilation never lands on the request path).
    pub fn preload_all(&self) -> Result<()> {
        for name in self.artifact_names() {
            self.load(&name)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hamming;
    use crate::util::SplitMix64;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rand_buf(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = SplitMix64::new(seed);
        let mut buf = vec![0u32; n];
        rng.fill_u32(&mut buf);
        buf
    }

    #[test]
    fn manifest_lists_all_exports() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let mut names = rt.artifact_names();
        names.sort();
        assert_eq!(
            names,
            vec![
                "hamming_dec",
                "hamming_enc",
                "multiplier",
                "pipeline",
                "pipeline_small"
            ]
        );
    }

    #[test]
    fn multiplier_artifact_matches_golden() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let exe = rt.load("multiplier").unwrap();
        let x = rand_buf(exe.input_words(), 11);
        let got = exe.run_u32(&x).unwrap();
        assert_eq!(got, hamming::multiply_buf(&x, hamming::MULT_CONSTANT));
    }

    #[test]
    fn encoder_artifact_matches_golden() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let exe = rt.load("hamming_enc").unwrap();
        let x = rand_buf(exe.input_words(), 12);
        let got = exe.run_u32(&x).unwrap();
        assert_eq!(got, hamming::encode_buf(&x));
    }

    #[test]
    fn decoder_artifact_matches_golden() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let exe = rt.load("hamming_dec").unwrap();
        // Feed it corrupted codewords: decode must correct them.
        let payload = rand_buf(exe.input_words(), 13);
        let mut rng = SplitMix64::new(14);
        let corrupted: Vec<u32> = payload
            .iter()
            .map(|&w| hamming::encode_word(w) ^ (1 << rng.below(31)))
            .collect();
        let got = exe.run_u32(&corrupted).unwrap();
        let want: Vec<u32> =
            payload.iter().map(|&w| w & hamming::DATA_MASK).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn pipeline_artifact_matches_identity() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let exe = rt.load("pipeline_small").unwrap();
        let x = rand_buf(exe.input_words(), 15);
        let got = exe.run_u32(&x).unwrap();
        assert_eq!(got, hamming::pipeline_buf(&x, hamming::MULT_CONSTANT));
    }

    #[test]
    fn wrong_input_length_rejected() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let exe = rt.load("pipeline_small").unwrap();
        assert!(exe.run_u32(&[0u32; 3]).is_err());
    }

    #[test]
    fn unknown_artifact_rejected() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        assert!(rt.load("nonexistent").is_err());
    }

    #[test]
    fn executables_are_cached() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let a = rt.load("multiplier").unwrap();
        let b = rt.load("multiplier").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
